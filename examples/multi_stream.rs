//! Multi-stream DAG with first-class FlowUnits, in the **typed API**:
//! two edge sources of `i64` readings are `union`ed into a named
//! "detector" unit in the cloud, whose output is `split` into an alerts
//! sink and an archive sink. While the job runs, the detector FlowUnit
//! is hot-swapped *by name* — sources and sinks keep flowing throughout
//! (queue-decoupled unit boundaries).
//!
//! The typed layer carries through the whole DAG: `union` requires both
//! fleets to produce the same element type, the alerts sink returns a
//! `CollectHandle<i64>` redeemed with `report.take(..)`, and no closure
//! touches `Value`.
//!
//! Needs no artifacts; runs out of the box:
//!
//! ```sh
//! cargo run --release --example multi_stream
//! ```

use flowunits::config::eval_cluster;
use flowunits::coordinator::Coordinator;
use flowunits::prelude::*;
use std::sync::atomic::Ordering;
use std::time::Duration;

fn config() -> JobConfig {
    JobConfig {
        planner: PlannerKind::FlowUnits,
        decouple_units: true, // queue substrate between FlowUnits
        poll_timeout: Duration::from_millis(10),
        batch_size: 128,
        ..Default::default()
    }
}

/// Two sensor fleets -> union -> detector(tag) -> split -> two sinks.
/// `tag` marks which detector version scored each event. Returns the
/// lowered graph plus the alerts sink's typed collect handle — the
/// final report accepts handles from the launch graph and from every
/// `update_unit` replacement graph alike.
fn dag(tag: i64) -> Result<(LogicalGraph, CollectHandle<i64>)> {
    let mut ctx = StreamContext::new(eval_cluster(None, Duration::ZERO), config());
    let north = ctx
        .stream(Source::synthetic_rated(u64::MAX / 2, 4_000.0, |_, i| {
            i as i64
        }))
        .unit("fleet-north")
        .to_layer("edge")
        .filter(|v| v % 2 == 0); // pre-filter at the edge
    let south = ctx
        .stream(Source::synthetic_rated(u64::MAX / 2, 4_000.0, |_, i| {
            i as i64
        }))
        .unit("fleet-south")
        .to_layer("edge");
    let scored = north
        .union(south)
        .unit("detector")
        .to_layer("cloud")
        .map(move |v| v * 10 + tag);
    let (alerts, archive) = scored.split();
    let alerts = alerts
        .unit("alerts")
        .filter(|v| v % 100 < 10) // "anomalies" only
        .collect();
    archive.unit("archive").collect_count();
    Ok((ctx.into_graph()?, alerts))
}

fn main() -> Result<()> {
    let phase = Duration::from_millis(
        std::env::var("PHASE_MS")
            .ok()
            .and_then(|s| s.parse().ok())
            .unwrap_or(600),
    );

    let coord = Coordinator::new(eval_cluster(None, Duration::ZERO), config());
    let (graph_v1, alerts) = dag(1)?;
    let mut dep = coord.deploy(&graph_v1)?;
    let m = dep.metrics();
    println!("deployed units: {}", dep.unit_names().join(", "));

    std::thread::sleep(phase);
    let in_v1 = m.events_in.load(Ordering::Relaxed);
    println!("phase 1 : {in_v1} events in, detector v1 scoring");

    // hot-swap the detector by name; fleets and sinks never stop
    let (graph_v2, _alerts_v2) = dag(2)?;
    dep.update_unit("detector", graph_v2)?;
    println!("update  : detector FlowUnit swapped to v2 (4 other units untouched)");

    std::thread::sleep(phase);
    let in_v2 = m.events_in.load(Ordering::Relaxed);
    assert!(in_v2 > in_v1, "sources kept producing through the swap");

    dep.stop_sources();
    let mut report = dep.wait()?;

    let collected: Vec<i64> = report.take(alerts)?;
    let (mut v1, mut v2) = (0u64, 0u64);
    for v in &collected {
        match v % 10 {
            1 => v1 += 1,
            2 => v2 += 1,
            _ => unreachable!("unscored value leaked past the detector"),
        }
    }
    println!("\n{}", report.render());
    println!(
        "alerts collected: {} ({v1} scored by v1, {v2} by v2) | total archived+alerted: {}",
        collected.len(),
        report.events_out
    );
    println!("hot swap completed with zero producer downtime ✔");
    Ok(())
}
