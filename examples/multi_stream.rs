//! Multi-stream DAG with first-class FlowUnits: two edge sources are
//! `union`ed into a named "detector" unit in the cloud, whose output is
//! `split` into an alerts sink and an archive sink. While the job runs,
//! the detector FlowUnit is hot-swapped *by name* — sources and sinks
//! keep flowing throughout (queue-decoupled unit boundaries).
//!
//! Needs no artifacts; runs out of the box:
//!
//! ```sh
//! cargo run --release --example multi_stream
//! ```

use flowunits::api::{JobConfig, PlannerKind, Source, StreamContext};
use flowunits::config::eval_cluster;
use flowunits::coordinator::Coordinator;
use flowunits::value::Value;
use std::sync::atomic::Ordering;
use std::time::Duration;

fn config() -> JobConfig {
    JobConfig {
        planner: PlannerKind::FlowUnits,
        decouple_units: true, // queue substrate between FlowUnits
        poll_timeout: Duration::from_millis(10),
        batch_size: 128,
        ..Default::default()
    }
}

/// Two sensor fleets -> union -> detector(tag) -> split -> two sinks.
/// `tag` marks which detector version scored each event.
fn dag(tag: i64) -> flowunits::error::Result<flowunits::graph::LogicalGraph> {
    let mut ctx = StreamContext::new(eval_cluster(None, Duration::ZERO), config());
    let north = ctx
        .stream(Source::synthetic_rated(u64::MAX / 2, 4_000.0, |_, i| {
            Value::I64(i as i64)
        }))
        .unit("fleet-north")
        .to_layer("edge")
        .filter(|v| v.as_i64().unwrap() % 2 == 0); // pre-filter at the edge
    let south = ctx
        .stream(Source::synthetic_rated(u64::MAX / 2, 4_000.0, |_, i| {
            Value::I64(i as i64)
        }))
        .unit("fleet-south")
        .to_layer("edge");
    let scored = north
        .union(south)
        .unit("detector")
        .to_layer("cloud")
        .map(move |v| Value::I64(v.as_i64().unwrap() * 10 + tag));
    let (alerts, archive) = scored.split();
    alerts
        .unit("alerts")
        .filter(|v| v.as_i64().unwrap() % 100 < 10) // "anomalies" only
        .collect_vec();
    archive.unit("archive").collect_count();
    ctx.into_graph()
}

fn main() -> flowunits::error::Result<()> {
    let phase = Duration::from_millis(
        std::env::var("PHASE_MS")
            .ok()
            .and_then(|s| s.parse().ok())
            .unwrap_or(600),
    );

    let coord = Coordinator::new(eval_cluster(None, Duration::ZERO), config());
    let mut dep = coord.deploy(&dag(1)?)?;
    let m = dep.metrics();
    println!("deployed units: {}", dep.unit_names().join(", "));

    std::thread::sleep(phase);
    let in_v1 = m.events_in.load(Ordering::Relaxed);
    println!("phase 1 : {in_v1} events in, detector v1 scoring");

    // hot-swap the detector by name; fleets and sinks never stop
    dep.update_unit("detector", dag(2)?)?;
    println!("update  : detector FlowUnit swapped to v2 (4 other units untouched)");

    std::thread::sleep(phase);
    let in_v2 = m.events_in.load(Ordering::Relaxed);
    assert!(in_v2 > in_v1, "sources kept producing through the swap");

    dep.stop_sources();
    let report = dep.wait()?;

    let (mut v1, mut v2) = (0u64, 0u64);
    for v in &report.collected {
        match v.as_i64().unwrap() % 10 {
            1 => v1 += 1,
            2 => v2 += 1,
            _ => unreachable!("unscored value leaked past the detector"),
        }
    }
    println!("\n{}", report.render());
    println!(
        "alerts collected: {} ({v1} scored by v1, {v2} by v2) | total archived+alerted: {}",
        report.collected.len(),
        report.events_out
    );
    println!("hot swap completed with zero producer downtime ✔");
    Ok(())
}
