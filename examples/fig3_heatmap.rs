//! Reproduces **Fig. 3** of the paper: the heatmap of execution-time
//! ratios (Renoir baseline / FlowUnits deployment) across 4 bandwidth
//! limits × 3 added latencies on the §V evaluation cluster
//! (4×1-core edge zones, 2×4-core site DC, 1×16-core cloud VM).
//!
//! Pipeline: O1 filters 67% at the edge, O2 partitions + windows + means
//! at the site, O3 computes Collatz convergence steps in the cloud.
//!
//! The paper processes 10M events per cell on a 16-core Ryzen workstation;
//! this driver defaults to 200k per cell (36 runs total on one core) —
//! set `FIG3_EVENTS=10000000` to match the paper exactly.
//!
//! The pipeline is built with the bare `to_layer` sugar (each layer
//! switch opens an anonymous, layer-named FlowUnit) — see
//! `examples/multi_stream.rs` for the explicit named-unit DAG surface.
//!
//! ```sh
//! cargo run --release --example fig3_heatmap
//! ```

use flowunits::api::raw::{JobConfig, PlannerKind, Source, StreamContext, WindowAgg};
use flowunits::config::eval_cluster;
use flowunits::value::Value;
use std::time::Duration;

fn build_pipeline(ctx: &mut StreamContext, events: u64) {
    ctx.stream(Source::synthetic(events, |_, i| Value::I64(i as i64)))
        .to_layer("edge")
        .filter(|v| v.as_i64().unwrap() % 3 == 0) // O1: drop 67%
        .to_layer("site")
        .key_by(|v| Value::I64(v.as_i64().unwrap() % 16))
        .window(100, WindowAgg::Mean) // O2
        .to_layer("cloud")
        .map(|v| {
            // O3: Collatz convergence steps
            let (_k, mean) = v.as_pair().unwrap();
            let mut n = (mean.as_f64().unwrap().abs() as u64).max(1);
            let mut steps = 0i64;
            while n != 1 {
                n = if n % 2 == 0 { n / 2 } else { 3 * n + 1 };
                steps += 1;
            }
            Value::I64(steps)
        })
        .collect_count();
}

fn run_cell(planner: PlannerKind, bw: Option<u64>, lat: Duration, events: u64) -> f64 {
    let cluster = eval_cluster(bw, lat);
    let mut ctx = StreamContext::new(
        cluster,
        JobConfig {
            planner,
            ..Default::default()
        },
    );
    build_pipeline(&mut ctx, events);
    let report = ctx.execute().expect("cell run");
    report.wall_time.as_secs_f64()
}

fn main() {
    let events: u64 = std::env::var("FIG3_EVENTS")
        .ok()
        .and_then(|s| s.parse().ok())
        .unwrap_or(200_000);
    let bandwidths: [(Option<u64>, &str); 4] = [
        (None, "unlimited"),
        (Some(1_000_000_000), "1Gbit"),
        (Some(100_000_000), "100Mbit"),
        (Some(10_000_000), "10Mbit"),
    ];
    let latencies = [
        (Duration::ZERO, "0ms"),
        (Duration::from_millis(10), "10ms"),
        (Duration::from_millis(100), "100ms"),
    ];

    println!("Fig. 3 — execution-time ratio Renoir/FlowUnits ({events} events/cell)\n");
    println!(
        "{:<12} {:<8} {:>11} {:>13} {:>7}",
        "bandwidth", "latency", "renoir(s)", "flowunits(s)", "ratio"
    );
    let mut heat: Vec<(String, String, f64)> = Vec::new();
    for (bw, bwname) in bandwidths {
        for (lat, latname) in latencies {
            let r = run_cell(PlannerKind::Renoir, bw, lat, events);
            let f = run_cell(PlannerKind::FlowUnits, bw, lat, events);
            let ratio = r / f;
            println!("{bwname:<12} {latname:<8} {r:>11.3} {f:>13.3} {ratio:>7.2}");
            heat.push((bwname.to_string(), latname.to_string(), ratio));
        }
    }

    // heatmap render (rows = bandwidth, cols = latency), like the figure
    println!("\nheatmap (ratio > 1 ⇒ FlowUnits faster):\n");
    print!("{:<12}", "");
    for (_, l) in latencies.iter() {
        print!("{l:>9}");
    }
    println!();
    for (bw, _) in bandwidths.iter().rev() {
        let name = match bw {
            None => "unlimited",
            Some(1_000_000_000) => "1Gbit",
            Some(100_000_000) => "100Mbit",
            _ => "10Mbit",
        };
        print!("{name:<12}");
        for (_, l) in latencies.iter() {
            let v = heat
                .iter()
                .find(|(b, lt, _)| b == name && lt == *l)
                .map(|(_, _, r)| *r)
                .unwrap_or(f64::NAN);
            print!("{v:>9.2}");
        }
        println!();
    }
    println!(
        "\nexpected shape (paper): ≈1 at unlimited/0ms, monotonically \
         increasing toward 10Mbit/100ms."
    );
}
