//! Real multi-process distribution: one coordinator + two worker
//! *processes* over Unix domain sockets.
//!
//! ```sh
//! cargo run --example distributed
//! ```
//!
//! The example re-invokes its own executable in worker mode (so it needs
//! no installed binary), runs a keyed wordcount across both workers,
//! verifies the distributed output is identical to an in-process run of
//! the same pipeline, and then demonstrates failure detection by killing
//! one worker mid-job.

use flowunits::api::raw::{JobConfig, StreamContext};
use flowunits::config::eval_cluster;
use flowunits::metrics::MetricsRegistry;
use flowunits::pipelines;
use flowunits::transport::daemon::CoordinatorDaemon;
use flowunits::transport::socket::Addr;
use flowunits::transport::worker::{run_worker, WorkerOpts};
use std::process::{Child, Command, Stdio};
use std::sync::Arc;
use std::time::{Duration, Instant};

fn main() {
    let args: Vec<String> = std::env::args().skip(1).collect();
    if args.first().map(String::as_str) == Some("worker") {
        // child mode: ["worker", <addr>, <id>, <state-dir>]
        let mut opts = WorkerOpts::new(Addr::parse(&args[1]), &args[2]);
        opts.state_dir = args[3].clone().into();
        opts.install_signals = true;
        if let Err(e) = run_worker(opts) {
            eprintln!("worker {}: {e}", args[2]);
            std::process::exit(1);
        }
        return;
    }
    coordinate();
}

fn spawn_worker(addr: &Addr, id: &str, dir: &std::path::Path) -> Child {
    Command::new(std::env::current_exe().expect("current_exe"))
        .arg("worker")
        .arg(addr.to_string())
        .arg(id)
        .arg(dir)
        .stdout(Stdio::null())
        .spawn()
        .expect("spawn worker process")
}

fn wait_for(daemon: &CoordinatorDaemon, n: usize) {
    let deadline = Instant::now() + Duration::from_secs(10);
    while daemon.workers().iter().filter(|(_, _, a)| *a).count() < n {
        assert!(Instant::now() < deadline, "workers never registered");
        std::thread::sleep(Duration::from_millis(20));
    }
}

fn coordinate() {
    let dir = std::env::temp_dir().join(format!("fu-distributed-{}", std::process::id()));
    let _ = std::fs::remove_dir_all(&dir);
    std::fs::create_dir_all(&dir).unwrap();
    let addr = Addr::parse(&dir.join("coordinator.sock").to_string_lossy());

    let daemon = Arc::new(
        CoordinatorDaemon::start(
            addr.clone(),
            Duration::from_millis(300),
            MetricsRegistry::new(),
        )
        .expect("start coordinator"),
    );
    println!("coordinator listening on {}", daemon.addr());
    let mut children = vec![spawn_worker(&addr, "w1", &dir), spawn_worker(&addr, "w2", &dir)];
    wait_for(&daemon, 2);
    println!("2 worker processes registered\n");

    // --- distributed wordcount, checked against the in-process engine ---
    let events = 6_000;
    let report = daemon
        .run_job("wordcount", events, 2, Duration::from_secs(30))
        .expect("distributed wordcount");
    print!("{}", report.render());
    let dist = pipelines::render_collected(&report.collected);
    for line in &dist {
        println!("{line}");
    }
    let mut ctx = StreamContext::new(eval_cluster(None, Duration::ZERO), JobConfig::default());
    pipelines::build(&mut ctx, "wordcount", events).unwrap();
    let local = pipelines::render_collected(&ctx.execute().unwrap().collected);
    assert_eq!(dist, local, "distributed output differs from in-process");
    println!("\n✓ distributed output identical to the in-process run\n");

    // --- failure detection: kill one worker mid-job -------------------
    let runner = {
        let daemon = daemon.clone();
        std::thread::spawn(move || {
            daemon.run_job("wordcount_paced", 2_000_000, 2, Duration::from_secs(60))
        })
    };
    std::thread::sleep(Duration::from_millis(700));
    println!("killing worker w2 mid-job...");
    children[1].kill().expect("kill w2");
    let _ = children[1].wait();
    match runner.join().expect("runner") {
        Err(e) => println!("✓ coordinator reported: {e}"),
        Ok(_) => panic!("job should have failed after the worker died"),
    }

    daemon.shutdown_workers();
    std::thread::sleep(Duration::from_millis(300));
    drop(daemon);
    for mut c in children.drain(..) {
        let _ = c.wait();
    }
    let _ = std::fs::remove_dir_all(&dir);
    println!("done");
}
