//! Dynamic updates (paper §III): while the Acme pipeline runs,
//!
//! 1. location **L5** joins the computation — FlowUnit FP is deployed to
//!    edge server E5, which starts feeding the (already running) S2 site
//!    queue, with zero disruption elsewhere;
//! 2. the cloud **ML FlowUnit is swapped** from `anomaly_v1` to the
//!    retrained `anomaly_v2` artifact — only that unit restarts; edge and
//!    site units keep producing into the decoupling queues throughout, and
//!    the replacement consumers resume from committed offsets.
//!
//! Requires `make artifacts`.
//!
//! ```sh
//! make artifacts && cargo run --release --example dynamic_update
//! ```

use flowunits::api::{JobConfig, PlannerKind, Source, StreamContext, WindowAgg};
use flowunits::config::fig2_cluster;
use flowunits::coordinator::Coordinator;
use flowunits::value::Value;
use std::sync::atomic::Ordering;
use std::time::Duration;

const FEATURES: usize = 5;
const XLA_BATCH: usize = 64;

fn pipeline_graph(artifact: &'static str) -> flowunits::error::Result<flowunits::graph::LogicalGraph> {
    let mut ctx = StreamContext::new(fig2_cluster(), config());
    ctx.stream(Source::synthetic_rated(u64::MAX / 2, 30_000.0, |m, i| {
        let t = i as f64 * 0.01;
        Value::F64(50.0 + 8.0 * (t * 0.37).sin() + m as f64)
    }))
    .unit("FP")
    .to_layer("edge")
    .filter(|v| v.as_f64().unwrap().is_finite())
    .unit("AD")
    .to_layer("site")
    .key_by(|v| Value::I64((v.as_f64().unwrap() * 7.0) as i64 % 4))
    .window(32, WindowAgg::FeatureStats)
    .unit("ML")
    .to_layer("cloud")
    .add_constraint("xla = yes")
    .xla_map(artifact, XLA_BATCH, FEATURES)
    .collect_count();
    ctx.into_graph()
}

fn config() -> JobConfig {
    JobConfig {
        planner: PlannerKind::FlowUnits,
        locations: vec!["L1".into(), "L2".into(), "L4".into()],
        decouple_units: true, // queue substrate between FlowUnits
        poll_timeout: Duration::from_millis(10),
        batch_size: 256,
        ..Default::default()
    }
}

fn main() -> flowunits::error::Result<()> {
    if !std::path::Path::new("artifacts/anomaly_v2.hlo.txt").exists() {
        eprintln!("artifacts missing — run `make artifacts` first");
        std::process::exit(2);
    }
    let phase = Duration::from_millis(
        std::env::var("UPDATE_PHASE_MS")
            .ok()
            .and_then(|s| s.parse().ok())
            .unwrap_or(700),
    );

    let coord = Coordinator::new(fig2_cluster(), config());
    let mut dep = coord.deploy(&pipeline_graph("anomaly_v1")?)?;
    let m = dep.metrics();
    println!("deployed: locations L1, L2, L4; ML = anomaly_v1");

    std::thread::sleep(phase);
    let in_phase1 = m.events_in.load(Ordering::Relaxed);
    let xla_phase1 = m.xla_rows.load(Ordering::Relaxed);
    println!("phase 1  : {in_phase1} events in, {xla_phase1} windows scored by v1");

    // --- update 1: location L5 joins (edge server E5 starts producing) ---
    dep.add_location("L5")?;
    println!("update 1 : location L5 joined (FlowUnit FP now on E5 -> S2 queue)");
    std::thread::sleep(phase);
    let in_phase2 = m.events_in.load(Ordering::Relaxed);
    assert!(in_phase2 > in_phase1, "pipeline kept flowing through add_location");

    // --- update 2: swap the ML FlowUnit to the retrained model ----------
    let scored_before_swap = m.xla_rows.load(Ordering::Relaxed);
    dep.update_unit("ML", pipeline_graph("anomaly_v2")?)?;
    println!("update 2 : ML FlowUnit swapped to anomaly_v2 (units FP/AD untouched)");
    std::thread::sleep(phase);
    let in_phase3 = m.events_in.load(Ordering::Relaxed);
    let scored_after_swap = m.xla_rows.load(Ordering::Relaxed);
    assert!(in_phase3 > in_phase2, "producers survived the ML swap");
    assert!(scored_after_swap > scored_before_swap, "v2 is scoring");

    dep.stop_sources();
    let report = dep.wait()?;
    println!("\nfinal report:\n{}", report.render());
    println!(
        "events in {} | windows scored {} | scored-before-swap {} | scored-after {}",
        report.events_in,
        report.metrics.xla_rows.load(Ordering::Relaxed),
        scored_before_swap,
        scored_after_swap
    );
    println!("dynamic updates completed with zero producer downtime ✔");
    Ok(())
}
