//! Dynamic updates (paper §III): while the Acme pipeline runs,
//!
//! 1. location **L5** joins the computation — FlowUnit FP is deployed to
//!    edge server E5, which starts feeding the (already running) S2 site
//!    queue, with zero disruption elsewhere;
//! 2. the cloud **ML FlowUnit is swapped** from `anomaly_v1` to the
//!    retrained `anomaly_v2` artifact. ML is the shape that used to be
//!    rejected: it holds keyed *window state* and a **direct internal
//!    channel** (its `key_by` stage feeds its window/inference stage
//!    in-process). The epoch drain-and-handoff protocol quiesces it:
//!    entry instances commit their queue offsets and forward an epoch
//!    marker through the internal channel, the window stage snapshots its
//!    partial windows into the unit's state topic, and the replacement
//!    instances restore them and resume from the committed offsets — no
//!    batch is lost or duplicated, no partial window is dropped, and
//!    units FP/AD never stop producing.
//!
//! Requires `make artifacts`.
//!
//! ```sh
//! make artifacts && cargo run --release --example dynamic_update
//! ```

use flowunits::api::raw::{JobConfig, PlannerKind, Source, StreamContext, WindowAgg};
use flowunits::config::fig2_cluster;
use flowunits::coordinator::Coordinator;
use flowunits::value::Value;
use std::sync::atomic::Ordering;
use std::time::Duration;

const FEATURES: usize = 5;
const XLA_BATCH: usize = 64;

fn pipeline_graph(artifact: &'static str) -> flowunits::error::Result<flowunits::graph::LogicalGraph> {
    let mut ctx = StreamContext::new(fig2_cluster(), config());
    ctx.stream(Source::synthetic_rated(u64::MAX / 2, 30_000.0, |m, i| {
        let t = i as f64 * 0.01;
        Value::F64(50.0 + 8.0 * (t * 0.37).sin() + m as f64)
    }))
    .unit("FP")
    .to_layer("edge")
    .filter(|v| v.as_f64().unwrap().is_finite())
    .unit("AD")
    .to_layer("site")
    .map(|v| Value::F64(v.as_f64().unwrap().clamp(0.0, 100.0)))
    // ML: stateful (keyed windows) with a direct internal channel between
    // its key_by stage and its window/inference stage — hot-swapped below
    .unit("ML")
    .to_layer("cloud")
    .add_constraint("xla = yes")
    .key_by(|v| Value::I64((v.as_f64().unwrap() * 7.0) as i64 % 4))
    .window(32, WindowAgg::FeatureStats)
    .xla_map(artifact, XLA_BATCH, FEATURES)
    .collect_count();
    ctx.into_graph()
}

fn config() -> JobConfig {
    JobConfig {
        planner: PlannerKind::FlowUnits,
        locations: vec!["L1".into(), "L2".into(), "L4".into()],
        decouple_units: true, // queue substrate between FlowUnits
        poll_timeout: Duration::from_millis(10),
        batch_size: 256,
        ..Default::default()
    }
}

fn main() -> flowunits::error::Result<()> {
    if !std::path::Path::new("artifacts/anomaly_v2.hlo.txt").exists() {
        eprintln!("artifacts missing — run `make artifacts` first");
        std::process::exit(2);
    }
    let phase = Duration::from_millis(
        std::env::var("UPDATE_PHASE_MS")
            .ok()
            .and_then(|s| s.parse().ok())
            .unwrap_or(700),
    );

    let coord = Coordinator::new(fig2_cluster(), config());
    let mut dep = coord.deploy(&pipeline_graph("anomaly_v1")?)?;
    let m = dep.metrics();
    println!("deployed: locations L1, L2, L4; ML = anomaly_v1 (stateful, internal channels)");

    std::thread::sleep(phase);
    let in_phase1 = m.events_in.load(Ordering::Relaxed);
    let xla_phase1 = m.xla_rows.load(Ordering::Relaxed);
    println!("phase 1  : {in_phase1} events in, {xla_phase1} windows scored by v1");

    // --- update 1: location L5 joins (edge server E5 starts producing) ---
    dep.add_location("L5")?;
    println!("update 1 : location L5 joined (FlowUnit FP now on E5 -> S2 queue)");
    std::thread::sleep(phase);
    let in_phase2 = m.events_in.load(Ordering::Relaxed);
    assert!(in_phase2 > in_phase1, "pipeline kept flowing through add_location");

    // --- update 2: hot-swap the stateful ML FlowUnit to the retrained
    // model via the epoch drain-and-handoff protocol -----------------------
    let scored_before_swap = m.xla_rows.load(Ordering::Relaxed);
    dep.update_unit("ML", pipeline_graph("anomaly_v2")?)?;
    let pause = m.update_pause_ms.load(Ordering::Relaxed);
    let epochs = m.epochs_forwarded.load(Ordering::Relaxed);
    println!(
        "update 2 : ML swapped to anomaly_v2 — pause {pause} ms, {epochs} epoch markers; \
         partial windows handed off, FP/AD untouched"
    );
    std::thread::sleep(phase);
    let in_phase3 = m.events_in.load(Ordering::Relaxed);
    let scored_after_swap = m.xla_rows.load(Ordering::Relaxed);
    assert!(in_phase3 > in_phase2, "producers survived the ML swap");
    assert!(scored_after_swap > scored_before_swap, "v2 is scoring");

    dep.stop_sources();
    let report = dep.wait()?;
    println!("\nfinal report:\n{}", report.render());
    println!(
        "events in {} | windows scored {} | scored-before-swap {} | scored-after {}",
        report.events_in,
        report.metrics.xla_rows.load(Ordering::Relaxed),
        scored_before_swap,
        scored_after_swap
    );
    println!("dynamic updates completed with zero producer downtime ✔");
    Ok(())
}
