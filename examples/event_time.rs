//! Event-time sessionization across the continuum: edge sources assign
//! timestamps and watermarks to a jittery clickstream, and the cloud
//! groups each user's clicks into activity **sessions** — windows that
//! extend while clicks keep arriving and close after a silence gap —
//! firing each session exactly once when the watermark passes its end.
//!
//! The delivery schedule is deliberately disordered (every click is
//! delayed by a deterministic pseudo-random latency, then replayed in
//! arrival order — the shape of a flaky uplink), yet the session counts
//! come out identical to an ordered replay: disorder within the
//! watermark bound is invisible to event-time operators. One click is a
//! genuine straggler from the distant past; it arrives beyond the
//! allowed lateness and lands on the late side output — observable,
//! never silently dropped.
//!
//! ```sh
//! cargo run --release --example event_time
//! ```

use flowunits::config::eval_cluster;
use flowunits::prelude::*;
use std::time::Duration;

/// Deterministic per-click delivery jitter in `[0, 150)` ms.
fn jitter(seed: i64) -> i64 {
    let x = (seed as u64).wrapping_mul(0x9E37_79B9_7F4A_7C15);
    ((x >> 33) % 150) as i64
}

fn main() -> Result<()> {
    // The paper's evaluation cluster with shaped links (1 Gbit / 5 ms).
    let cluster = eval_cluster(Some(1_000_000_000), Duration::from_millis(5));
    let mut ctx = StreamContext::new(cluster, JobConfig::default());

    // Clickstream: 4 users x 3 browsing sessions of 20 clicks each,
    // clicks 50 ms apart, sessions separated by ~10 s of silence.
    let mut clicks: Vec<(i64, i64)> = Vec::new();
    for user in 0..4i64 {
        for session in 0..3i64 {
            let base = session * 10_000 + user * 37;
            clicks.extend((0..20).map(|i| (user, base + i * 50)));
        }
    }
    // Replay in arrival order under bounded jitter — the stream the
    // cloud actually sees is out of order, but never by more than the
    // watermark bound below.
    let mut arrival: Vec<(i64, (i64, i64))> = clicks
        .iter()
        .map(|&(u, ts)| (ts + jitter(u * 31 + ts), (u, ts)))
        .collect();
    arrival.sort_by_key(|&(at, (u, ts))| (at, u, ts));
    let mut clicks: Vec<(i64, i64)> = arrival.into_iter().map(|(_, c)| c).collect();
    // ...plus one straggler from the distant past, delivered last: by
    // then the watermark is tens of seconds ahead, far beyond the
    // allowed lateness — this click is *late*.
    clicks.push((0, 0));
    let total = clicks.len();

    let (sessions, late) = ctx
        .stream(Source::vector(clicks))
        .unit("ingest")
        .to_layer("edge")
        .replicate(Replication::Fixed(1)) // one uplink: arrival order is the schedule above
        .assign_timestamps(|c: &(i64, i64)| c.1, WatermarkGen::bounded(150))
        .unit("sessionize")
        .to_layer("cloud")
        .key_by(|c: &(i64, i64)| c.0)
        .event_window_with_late::<i64>(
            |c| c.1,
            WindowAssigner::session(1_000), // 1 s of silence closes a session
            WindowAgg::Count,
            200, // allowed lateness before a session's books close
        );
    let sessions = sessions.collect();

    let mut report = ctx.execute()?;
    println!("{}", report.render());

    let mut sessions: Vec<(i64, i64)> = report.take(sessions)?;
    sessions.sort_unstable();
    println!("sessions ({} clicks in):", total);
    for (user, count) in &sessions {
        println!("  user {user}: session of {count} clicks");
    }
    let lates: Vec<(i64, (i64, i64))> = report.take(late)?;
    for (user, (_, ts)) in &lates {
        println!("late: user {user} click at t={ts}ms arrived after its session closed");
    }
    let in_sessions: i64 = sessions.iter().map(|&(_, c)| c).sum();
    println!(
        "accounted: {} in sessions + {} late = {} of {} clicks",
        in_sessions,
        lates.len(),
        in_sessions + lates.len() as i64,
        total
    );
    Ok(())
}
