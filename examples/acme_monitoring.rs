//! The paper's running example (Fig. 1/2): Acme's production-machine
//! monitoring across the continuum, in the **typed API**.
//!
//! * **FP** — filtering/preprocessing at the **edge** server of each
//!   machine;
//! * **AD** — per-site anomaly aggregation (windowed feature extraction)
//!   in the **site** data centre;
//! * **ML** — model scoring in the **cloud**, constrained to hosts with
//!   the XLA accelerator capability; the model is the AOT-compiled
//!   JAX/Pallas artifact `anomaly_v1` executed through PJRT from the
//!   streaming hot path (no Python at runtime).
//!
//! The pipeline carries native types end to end: readings are
//! `(machine, reading)` tuples, `key_by(|r| r.0)` keys by machine,
//! `map_values` strips to the raw reading, the window emits a typed
//! [`Features`] row, and `xla_map` is only callable on feature-row
//! streams — feeding the model anything else would not compile. No
//! closure unwraps a `Value`.
//!
//! Requires `make artifacts`. This is the end-to-end driver recorded in
//! EXPERIMENTS.md: it runs the full three-layer stack on a synthetic
//! multi-site sensor workload and reports the anomaly rate + throughput.
//!
//! ```sh
//! make artifacts && cargo run --release --example acme_monitoring
//! ```

use flowunits::config::fig2_cluster;
use flowunits::prelude::*;

const WINDOW: usize = 32;
const FEATURES: usize = 5; // [mean, std, min, max, last]
const XLA_BATCH: usize = 64; // compiled batch of anomaly_v1

fn main() -> Result<()> {
    if !std::path::Path::new("artifacts/anomaly_v1.hlo.txt").exists() {
        eprintln!("artifacts missing — run `make artifacts` first");
        std::process::exit(2);
    }
    let events: u64 = std::env::var("ACME_EVENTS")
        .ok()
        .and_then(|s| s.parse().ok())
        .unwrap_or(400_000);

    // Fig. 2 infrastructure: 5 edge zones under 2 sites under one cloud,
    // with mixed GPU/non-GPU cloud hosts. Locations L1, L2, L4 enabled —
    // exactly the paper's §III example.
    let cluster = fig2_cluster();
    let config = JobConfig {
        locations: vec!["L1".into(), "L2".into(), "L4".into()],
        ..Default::default()
    };
    let mut ctx = StreamContext::new(cluster, config);

    // Temperature-like readings tagged with their machine id: a slow
    // sinusoid + machine offset + rare spikes (the anomalies ML must catch).
    let scores = ctx
        .stream(Source::synthetic(events, |machine, i| {
            let t = i as f64 * 0.01;
            let base = 50.0 + 2.0 * (t * 0.37).sin() + machine as f64;
            let spike = if i.wrapping_mul(2_654_435_761) % 997 == 0 {
                60.0
            } else {
                0.0
            };
            (machine as i64, base + spike)
        }))
        // FP: drop sensor glitches before anything crosses the uplink
        .unit("FP")
        .to_layer("edge")
        .filter(|r| r.1.is_finite() && (-20.0..200.0).contains(&r.1))
        // AD: per-machine windows -> [mean, std, min, max, last]
        .unit("AD")
        .to_layer("site")
        .key_by(|r| r.0)
        .map_values(|r| r.1) // (machine, reading) value -> raw reading
        .window::<Features>(WINDOW, WindowAgg::FeatureStats)
        // ML: AOT-compiled JAX/Pallas anomaly scorer, gated on capability —
        // the constraint scopes to the whole ML FlowUnit
        .unit("ML")
        .to_layer("cloud")
        .add_constraint("xla = yes && n_cpu >= 4")
        .xla_map("anomaly_v1", XLA_BATCH, FEATURES)
        .map_values(|Features(row)| row[0] as f64)
        .collect();

    let mut report = ctx.execute()?;
    println!("{}", report.render());

    // redeem the typed handle: Vec<(machine, score)>, no unwraps
    let collected: Vec<(i64, f64)> = report.take(scores)?;

    // self-calibrating detection: a window is anomalous when its score
    // deviates > 3σ from its *own machine group's* baseline
    let mut by_key: std::collections::BTreeMap<i64, Vec<f64>> = Default::default();
    for (k, s) in &collected {
        by_key.entry(*k).or_default().push(*s);
    }
    let windows = collected.len();
    let mut anomalies = 0usize;
    for (key, scores) in &by_key {
        let n = scores.len().max(1) as f64;
        let mean = scores.iter().sum::<f64>() / n;
        let std =
            (scores.iter().map(|s| (s - mean) * (s - mean)).sum::<f64>() / n).sqrt();
        let hits = scores.iter().filter(|s| (*s - mean).abs() > 3.0 * std).count();
        println!(
            "group {key}: {} windows, score {mean:.3}±{std:.3}, {hits} anomalous",
            scores.len()
        );
        anomalies += hits;
    }
    println!(
        "windows scored : {windows} ({WINDOW} events/window)\n\
         anomalies (3σ) : {anomalies} ({:.3}%)",
        100.0 * anomalies as f64 / windows.max(1) as f64
    );
    println!(
        "throughput     : {}",
        flowunits::util::fmt_rate(report.events_in, report.wall_time)
    );
    Ok(())
}
