//! The paper's running example (Fig. 1/2): Acme's production-machine
//! monitoring across the continuum.
//!
//! * **FP** — filtering/preprocessing at the **edge** server of each
//!   machine;
//! * **AD** — per-site anomaly aggregation (windowed feature extraction)
//!   in the **site** data centre;
//! * **ML** — model scoring in the **cloud**, constrained to hosts with
//!   the XLA accelerator capability; the model is the AOT-compiled
//!   JAX/Pallas artifact `anomaly_v1` executed through PJRT from the
//!   streaming hot path (no Python at runtime).
//!
//! Requires `make artifacts`. This is the end-to-end driver recorded in
//! EXPERIMENTS.md: it runs the full three-layer stack on a synthetic
//! multi-site sensor workload and reports the anomaly rate + throughput.
//!
//! ```sh
//! make artifacts && cargo run --release --example acme_monitoring
//! ```

use flowunits::api::{JobConfig, Source, StreamContext, WindowAgg};
use flowunits::config::fig2_cluster;
use flowunits::value::Value;

const WINDOW: usize = 32;
const FEATURES: usize = 5; // [mean, std, min, max, last]
const XLA_BATCH: usize = 64; // compiled batch of anomaly_v1

fn main() -> flowunits::error::Result<()> {
    if !std::path::Path::new("artifacts/anomaly_v1.hlo.txt").exists() {
        eprintln!("artifacts missing — run `make artifacts` first");
        std::process::exit(2);
    }
    let events: u64 = std::env::var("ACME_EVENTS")
        .ok()
        .and_then(|s| s.parse().ok())
        .unwrap_or(400_000);

    // Fig. 2 infrastructure: 5 edge zones under 2 sites under one cloud,
    // with mixed GPU/non-GPU cloud hosts. Locations L1, L2, L4 enabled —
    // exactly the paper's §III example.
    let cluster = fig2_cluster();
    let config = JobConfig {
        locations: vec!["L1".into(), "L2".into(), "L4".into()],
        ..Default::default()
    };
    let mut ctx = StreamContext::new(cluster, config);

    // Temperature-like readings tagged with their machine id: a slow
    // sinusoid + machine offset + rare spikes (the anomalies ML must catch).
    ctx.stream(Source::synthetic(events, |machine, i| {
        let t = i as f64 * 0.01;
        let base = 50.0 + 2.0 * (t * 0.37).sin() + machine as f64;
        let spike = if i.wrapping_mul(2_654_435_761) % 997 == 0 {
            60.0
        } else {
            0.0
        };
        Value::pair(Value::I64(machine as i64), Value::F64(base + spike))
    }))
    // FP: drop sensor glitches before anything crosses the uplink
    .unit("FP")
    .to_layer("edge")
    .filter(|v| {
        let (_m, x) = v.as_pair().unwrap();
        let x = x.as_f64().unwrap();
        x.is_finite() && (-20.0..200.0).contains(&x)
    })
    // AD: per-machine windows -> [mean, std, min, max, last]
    .unit("AD")
    .to_layer("site")
    .key_by(|v| v.as_pair().unwrap().0.clone())
    .map(|keyed| {
        // Pair(machine, Pair(machine, reading)) -> Pair(machine, reading)
        let (k, mr) = keyed.into_pair().unwrap();
        Value::pair(k, mr.into_pair().unwrap().1)
    })
    .window(WINDOW, WindowAgg::FeatureStats)
    // ML: AOT-compiled JAX/Pallas anomaly scorer, gated on capability —
    // the constraint scopes to the whole ML FlowUnit
    .unit("ML")
    .to_layer("cloud")
    .add_constraint("xla = yes && n_cpu >= 4")
    .xla_map("anomaly_v1", XLA_BATCH, FEATURES)
    .map(|scored| {
        // Pair(key, F32s[score]) -> Pair(key, F64(score))
        let (k, s) = scored.into_pair().unwrap();
        Value::pair(k, Value::F64(s.as_f32s().unwrap()[0] as f64))
    })
    .collect_vec();

    let report = ctx.execute()?;
    println!("{}", report.render());

    // self-calibrating detection: a window is anomalous when its score
    // deviates > 3σ from its *own machine group's* baseline
    let mut by_key: std::collections::BTreeMap<i64, Vec<f64>> = Default::default();
    for v in &report.collected {
        let (k, s) = v.as_pair().unwrap();
        by_key
            .entry(k.as_i64().unwrap())
            .or_default()
            .push(s.as_f64().unwrap());
    }
    let windows = report.collected.len();
    let mut anomalies = 0usize;
    for (key, scores) in &by_key {
        let n = scores.len().max(1) as f64;
        let mean = scores.iter().sum::<f64>() / n;
        let std =
            (scores.iter().map(|s| (s - mean) * (s - mean)).sum::<f64>() / n).sqrt();
        let hits = scores.iter().filter(|s| (*s - mean).abs() > 3.0 * std).count();
        println!(
            "group {key}: {} windows, score {mean:.3}±{std:.3}, {hits} anomalous",
            scores.len()
        );
        anomalies += hits;
    }
    println!(
        "windows scored : {windows} ({WINDOW} events/window)\n\
         anomalies (3σ) : {anomalies} ({:.3}%)",
        100.0 * anomalies as f64 / windows.max(1) as f64
    );
    println!(
        "throughput     : {}",
        flowunits::util::fmt_rate(report.events_in, report.wall_time)
    );
    Ok(())
}
