//! Quickstart: the classic word count, written against the **typed API**
//! and deployed across the continuum with one named FlowUnit per segment.
//! `unit(name)` opens a FlowUnit — the unit of placement, replication,
//! and dynamic update — and `to_layer` pins it to a continuum layer.
//!
//! Every closure below works in native Rust types (`String`, `i64`); the
//! engine's dynamic `Value` representation never appears, and the keyed
//! fold is only reachable after `group_by` — `fold` before keying would
//! not compile.
//!
//! ```sh
//! cargo run --release --example quickstart
//! ```

use flowunits::config::eval_cluster;
use flowunits::prelude::*;
use std::time::Duration;

fn main() -> Result<()> {
    // The paper's evaluation cluster: 4 edge zones, one site DC, one cloud
    // VM — links here are healthy (1 Gbit / 5 ms).
    let cluster = eval_cluster(Some(1_000_000_000), Duration::from_millis(5));
    let mut ctx = StreamContext::new(cluster, JobConfig::default());

    // Synthetic "log lines" produced at the edge; splitting/cleaning
    // happens next to the sources, counting in the cloud.
    let phrases = [
        "edge computing moves compute to the data",
        "dataflow moves data through compute",
        "flowunits moves dataflow to the continuum",
    ];
    let counts = ctx
        .stream(Source::synthetic(300_000, move |_, i| {
            phrases[(i % phrases.len() as u64) as usize].to_string()
        }))
        .unit("tokenize")
        .to_layer("edge")
        .flat_map(|line| {
            line.split(' ')
                .map(str::to_string)
                .collect::<Vec<String>>()
        })
        .filter(|w| w.len() > 3) // drop stop-words at the edge
        .unit("count")
        .to_layer("cloud")
        .group_by(|w| w.clone())
        .fold(0i64, |acc, _| *acc += 1)
        .collect();

    let mut report = ctx.execute()?;
    println!("{}", report.render());

    // redeem the typed collect handle: Vec<(word, count)>, no unwraps
    let mut counts: Vec<(String, i64)> = report.take(counts)?;
    counts.sort_by_key(|&(_, c)| -c);
    println!("top words:");
    for (w, c) in counts.iter().take(8) {
        println!("  {w:<12} {c}");
    }
    Ok(())
}
