//! Quickstart: the classic word count, written once and deployed across
//! the continuum with one named FlowUnit per segment. `unit(name)` opens
//! a FlowUnit — the unit of placement, replication, and dynamic update —
//! and `to_layer` pins it to a continuum layer.
//!
//! ```sh
//! cargo run --release --example quickstart
//! ```

use flowunits::api::{JobConfig, Source, StreamContext};
use flowunits::config::eval_cluster;
use flowunits::value::Value;
use std::time::Duration;

fn main() -> flowunits::error::Result<()> {
    // The paper's evaluation cluster: 4 edge zones, one site DC, one cloud
    // VM — links here are healthy (1 Gbit / 5 ms).
    let cluster = eval_cluster(Some(1_000_000_000), Duration::from_millis(5));
    let mut ctx = StreamContext::new(cluster, JobConfig::default());

    // Synthetic "log lines" produced at the edge; splitting/cleaning
    // happens next to the sources, counting in the cloud.
    let phrases = [
        "edge computing moves compute to the data",
        "dataflow moves data through compute",
        "flowunits moves dataflow to the continuum",
    ];
    ctx.stream(Source::synthetic(300_000, move |_, i| {
        Value::Str(phrases[(i % phrases.len() as u64) as usize].to_string())
    }))
    .unit("tokenize")
    .to_layer("edge")
    .flat_map(|line| {
        line.as_str()
            .unwrap()
            .split(' ')
            .map(|w| Value::Str(w.to_string()))
            .collect()
    })
    .filter(|w| w.as_str().unwrap().len() > 3) // drop stop-words at the edge
    .unit("count")
    .to_layer("cloud")
    .group_by(|w| w.clone())
    .fold(Value::I64(0), |acc, _| {
        *acc = Value::I64(acc.as_i64().unwrap() + 1)
    })
    .collect_vec();

    let report = ctx.execute()?;
    println!("{}", report.render());

    let mut counts: Vec<(String, i64)> = report
        .collected
        .iter()
        .map(|v| {
            let (w, c) = v.as_pair().unwrap();
            (w.as_str().unwrap().to_string(), c.as_i64().unwrap())
        })
        .collect();
    counts.sort_by_key(|(_, c)| -c);
    println!("top words:");
    for (w, c) in counts.iter().take(8) {
        println!("  {w:<12} {c}");
    }
    Ok(())
}
