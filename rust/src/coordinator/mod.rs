//! The leader/coordinator: turns an execution plan into a running
//! deployment — channels, emulated links, queue topics, and one worker
//! thread per stage instance — then drives it to completion and collects
//! the report. Also implements the paper's *dynamic update* operations:
//! replacing a FlowUnit's logic and adding a geographical location while
//! the rest of the deployment keeps running (§III "Dynamic updates").

use crate::channels::{checkpoint_epoch, epoch_seq, FanOut, Inbox, Msg, OutPort, Target};
use crate::config::ClusterSpec;
use crate::error::{Error, Result};
use crate::graph::{LogicalGraph, OpKind, SourceKind};
use crate::metrics::{Metrics, MetricsRegistry};
use crate::netsim::Link;
use crate::placement::{ancestor_at_layer, plan as make_plan, ExecPlan, PlannerKind};
use crate::queue::{watermark_record, Broker, OverloadPolicy, QueueBroker, Topic};
use crate::runtime::{
    exec::{
        AssignTsExec, Collector, EventWindowExec, FilterExec, FilterMapExec, FlatMapExec,
        FoldExec, IntervalJoinExec, KeyByExec, KeyByFusedExec, MapExec, ReduceExec, SideTagExec,
        SinkExec, WindowExec, XlaExec,
    },
    run_instance, state_record, Handoff, InputKind, InstanceRuntime, OpExec, SourceRuntime,
};
use crate::topology::LocationId;
use crate::transport::{Endpoint, NetsimTransport, Transport};
use crate::value::{StreamData, Value};
use std::collections::{BTreeMap, BTreeSet, HashMap};
use std::marker::PhantomData;
use std::sync::atomic::{AtomicBool, AtomicU64, AtomicUsize, Ordering};
use std::sync::mpsc::{sync_channel, Receiver, SyncSender};
use std::sync::Arc;
use std::time::{Duration, Instant};

/// Job-level configuration.
#[derive(Debug, Clone)]
pub struct JobConfig {
    /// Deployment strategy.
    pub planner: PlannerKind,
    /// Enabled locations (empty ⇒ all locations of the root zone).
    pub locations: Vec<LocationId>,
    /// Events per batch on the hot path.
    pub batch_size: usize,
    /// Bound (in batches) of instance inboxes.
    pub channel_capacity: usize,
    /// Route FlowUnit-boundary edges through the queue substrate
    /// (required for dynamic updates; FlowUnits planner only).
    pub decouple_units: bool,
    /// Directory for durable queue segments (None ⇒ in-memory queues).
    pub queue_dir: Option<std::path::PathBuf>,
    /// Queue consumer poll timeout (upper bound on one uninterrupted
    /// wait-set park; consumption itself is event-driven).
    pub poll_timeout: Duration,
    /// Maximum records a queue consumer drains from one partition per
    /// poll (bounds per-wakeup work and commit granularity).
    pub poll_max_records: usize,
    /// Lower typed (`api::typed`) chains onto the columnar data plane
    /// where their types allow it (monomorphized column operators, no
    /// per-record `Value` allocation). Off ⇒ every typed chain lowers to
    /// the classic `Value` pipeline; results are identical either way.
    pub columnar: bool,
    /// Interval between coordinator-driven checkpoint epochs (requires
    /// `decouple_units`). `Some(_)` switches the deployment into
    /// *checkpoint mode*: every unit roll becomes an atomically-committed
    /// checkpoint (state + covered input offsets in the unit's state
    /// topic, offsets advanced by the coordinator only after the whole
    /// unit-zone quiesced), and an instance-thread death triggers
    /// recovery from the last committed checkpoint instead of failing
    /// the job. `None` keeps the legacy behavior: planned hot-swaps
    /// only, per-drain offset commits, fail-fast on panics.
    pub checkpoint_interval: Option<Duration>,
    /// Lag-driven elastic rescaling policy (None ⇒ autoscaler off).
    pub autoscale: Option<AutoscaleConfig>,
    /// Resident-byte budget for the queue broker (None ⇒ unbounded).
    /// Durable brokers spill records beyond the hot tail to their segment
    /// files and re-read them on demand; in-memory brokers apply the
    /// overload policy below once the budget is hit.
    pub queue_budget: Option<u64>,
    /// What happens when a bounded broker cannot make room:
    /// [`OverloadPolicy::Backpressure`] blocks producers (propagating
    /// slowdown end-to-end through queue ingest),
    /// [`OverloadPolicy::Shed`] drops the oldest or samples records —
    /// always counted in `records_shed`, never silent. State topics pin
    /// `Backpressure` regardless: checkpoints must never be shed.
    pub overload_policy: OverloadPolicy,
    /// Event-time idleness bound per input: a producer whose watermark
    /// has not advanced for this long is excluded from the min-of-inputs
    /// merge, so one silent edge source cannot stall windows for a whole
    /// zone. `None` = strict semantics (wait forever).
    pub idle_timeout: Option<Duration>,
}

/// Policy of the lag-driven autoscaler: how the control loop inside
/// [`Deployment::wait`] turns sustained queue lag on a unit's entry
/// topics into replication changes. Scaling rides the planned-update
/// path (placement re-plan + zone-by-zone drain/splice), so records are
/// neither lost nor duplicated by a scale action.
#[derive(Debug, Clone)]
pub struct AutoscaleConfig {
    /// Sampling period of the per-unit lag probe.
    pub sample_interval: Duration,
    /// Total entry-topic lag (records appended but not committed by the
    /// unit's consumer groups) at or above which the unit counts as
    /// overloaded.
    pub scale_up_lag: u64,
    /// Lag at or below which the unit counts as drained.
    pub scale_down_lag: u64,
    /// Consecutive samples past a threshold before the autoscaler acts
    /// (hysteresis against transient spikes).
    pub samples: u32,
    /// Minimum wait between consecutive scale actions on the same unit.
    pub cooldown: Duration,
    /// Per-zone replication floor for scale-down.
    pub min_instances: usize,
    /// Per-zone replication ceiling for scale-up (additionally capped by
    /// the entry topics' partition counts, which are fixed at launch).
    pub max_instances: usize,
}

impl Default for AutoscaleConfig {
    fn default() -> Self {
        AutoscaleConfig {
            sample_interval: Duration::from_millis(100),
            scale_up_lag: 10_000,
            scale_down_lag: 1_000,
            samples: 3,
            cooldown: Duration::from_secs(2),
            min_instances: 1,
            max_instances: 8,
        }
    }
}

impl Default for JobConfig {
    fn default() -> Self {
        JobConfig {
            planner: PlannerKind::FlowUnits,
            locations: Vec::new(),
            batch_size: 512,
            channel_capacity: 64,
            decouple_units: false,
            queue_dir: None,
            poll_timeout: Duration::from_millis(50),
            poll_max_records: 64,
            columnar: true,
            checkpoint_interval: None,
            autoscale: None,
            queue_budget: None,
            overload_policy: OverloadPolicy::default(),
            idle_timeout: None,
        }
    }
}

/// Final report of a completed job.
#[derive(Debug)]
pub struct JobReport {
    /// Wall-clock execution time (sources started → all sinks flushed).
    pub wall_time: Duration,
    /// Events produced by sources.
    pub events_in: u64,
    /// Events delivered to sinks.
    pub events_out: u64,
    /// Values gathered by `Collect` sinks.
    pub collected: Vec<Value>,
    /// Bytes that traversed emulated links.
    pub net_bytes: u64,
    /// Events that crossed a zone boundary.
    pub zone_crossings: u64,
    /// Wire encodes actually performed (encode-once: at most one per
    /// batch, no matter how many edges it crossed).
    pub wire_encodes: u64,
    /// Corrupt queue records consumers skipped (0 in a healthy run — the
    /// job completes and reports the count instead of aborting).
    pub corrupt_records: u64,
    /// Plan summary (stages → per-zone instance counts).
    pub plan_description: String,
    /// Per-topic queue lag at completion — records appended to each
    /// decoupling topic minus records its consumer group committed,
    /// keyed by topic name. 0 everywhere in a fully drained run; the
    /// same probe feeds the autoscaler while the job runs.
    pub queue_lag: BTreeMap<String, u64>,
    /// Batches processed per instance id — the per-instance throughput
    /// signal the control plane samples, surfaced for observability.
    /// Instances that processed no batch are omitted.
    pub instance_batches: BTreeMap<usize, u64>,
    /// Full metrics registry snapshot.
    pub metrics: Metrics,
    /// Values gathered by typed (tagged) collect sinks, keyed by sink
    /// operator id; redeemed per handle through [`JobReport::take`].
    pub(crate) collected_tagged: BTreeMap<usize, Vec<Value>>,
    /// Builder-context identities this deployment executed
    /// (`LogicalGraph::origin` of the launch graph and of every
    /// `update_unit` replacement graph); [`JobReport::take`] rejects
    /// handles minted by any other context.
    pub(crate) origins: BTreeSet<u64>,
}

/// Receipt for one typed collect sink: returned by the typed layer's
/// `Stream::collect`/`KeyedStream::collect` and redeemed against the
/// finished job's [`JobReport`] with [`JobReport::take`], which decodes
/// the sink's events into native `T` values. Bound to the builder
/// context that minted it — redeeming it against another job's report is
/// an error, never a silent mix-up.
pub struct CollectHandle<T: StreamData> {
    /// Logical operator id of the tagged sink.
    pub(crate) op: usize,
    /// Builder-context identity the handle was minted by.
    pub(crate) origin: u64,
    pub(crate) _t: PhantomData<T>,
}

impl<T: StreamData> std::fmt::Debug for CollectHandle<T> {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        write!(f, "CollectHandle(sink op {})", self.op)
    }
}

impl JobReport {
    /// Renders the report.
    pub fn render(&self) -> String {
        format!(
            "{}\n{}",
            self.plan_description,
            self.metrics.render(self.wall_time)
        )
    }

    /// Redeems a typed collect handle: removes the sink's events from the
    /// report and decodes them into native values. A sink that received
    /// no events yields an empty vector; a value that does not match `T`
    /// surfaces as [`Error::Decode`](crate::error::Error::Decode), as
    /// does a handle minted by a different builder context than the job
    /// behind this report.
    pub fn take<T: StreamData>(&mut self, handle: CollectHandle<T>) -> Result<Vec<T>> {
        if !self.origins.contains(&handle.origin) {
            return Err(Error::Decode(format!(
                "{handle:?} was minted by a different builder context than the job \
                 behind this report — redeem it against its own job's report"
            )));
        }
        self.collected_tagged
            .remove(&handle.op)
            .unwrap_or_default()
            .into_iter()
            .map(T::try_from_value)
            .collect()
    }
}

/// Coordinator: plans and launches jobs on a cluster.
pub struct Coordinator {
    /// Cluster description.
    pub cluster: ClusterSpec,
    /// Job configuration.
    pub config: JobConfig,
}

impl Coordinator {
    /// Creates a coordinator.
    pub fn new(cluster: ClusterSpec, config: JobConfig) -> Self {
        Coordinator { cluster, config }
    }

    /// Plans, deploys, runs to completion, and reports.
    pub fn run(&self, graph: &LogicalGraph) -> Result<JobReport> {
        let dep = self.deploy(graph)?;
        dep.wait()
    }

    /// Plans and launches a deployment, returning a handle that supports
    /// dynamic updates before [`Deployment::wait`].
    pub fn deploy(&self, graph: &LogicalGraph) -> Result<Deployment> {
        // File-backed sources are validated up front so an unreadable
        // file is a job-level error here, not a panic (or silently empty
        // stream) on the instance thread that first opens it.
        for op in &graph.ops {
            if let OpKind::Source(SourceKind::FileLines(path)) = &op.kind {
                let cannot = |detail: String| {
                    Error::Runtime(format!(
                        "source '{}': cannot read file {}: {detail}",
                        op.name,
                        path.display()
                    ))
                };
                let meta = std::fs::metadata(path).map_err(|e| cannot(e.to_string()))?;
                if !meta.is_file() {
                    return Err(cannot("not a regular file".into()));
                }
                std::fs::File::open(path).map_err(|e| cannot(e.to_string()))?;
            }
        }
        let decouple = self.config.decouple_units && self.config.planner == PlannerKind::FlowUnits;
        let plan = make_plan(
            graph,
            &self.cluster,
            self.config.planner,
            &self.config.locations,
            decouple,
        )?;
        Deployment::launch(
            graph.clone(),
            self.cluster.clone(),
            self.config.clone(),
            plan,
        )
    }
}

/// Key of a decoupling topic: (downstream stage, downstream zone).
type TopicKey = (usize, String);

struct TopicRuntime {
    topic: Arc<Topic>,
    /// Ingest channel per partition (producers send frames here, through
    /// the emulated link; an ingest thread appends them to the log).
    ingest: Vec<SyncSender<Msg>>,
    /// Number of producers expected to EOS each partition; dynamic
    /// `add_location` increments this while the deployment runs.
    expected_producers: Arc<AtomicUsize>,
}

/// What one unit-zone's quiesce records hand the control plane: operator
/// state to restore into the replacement instances, and the input
/// offsets that state covers (to be committed if the roll is a
/// checkpoint).
#[derive(Default)]
struct ZoneState {
    /// Instance id → per-executor restore entries.
    restores: HashMap<usize, Vec<Value>>,
    /// Stage → (partition → next offset) covered by the records.
    offsets: BTreeMap<usize, BTreeMap<usize, usize>>,
}

/// A running deployment.
pub struct Deployment {
    graph: LogicalGraph,
    cluster: ClusterSpec,
    config: JobConfig,
    plan: ExecPlan,
    metrics: Metrics,
    collector: Arc<Collector>,
    /// Emulated-network transport: owns the shared uplink cache and hands
    /// out lanes for direct edges (in-process on the same simulated host,
    /// shaped through a [`Link`] otherwise).
    netsim: NetsimTransport,
    broker: Option<Broker>,
    topics: HashMap<TopicKey, TopicRuntime>,
    /// Worker threads grouped by (FlowUnit index, zone) — dynamic updates
    /// roll a unit's replicas zone by zone.
    unit_threads: BTreeMap<(usize, String), Vec<std::thread::JoinHandle<u64>>>,
    ingest_threads: Vec<std::thread::JoinHandle<()>>,
    source_stop: Arc<AtomicBool>,
    unit_stops: BTreeMap<(usize, String), Arc<AtomicBool>>,
    /// Builder-context identities executed by this deployment (launch
    /// graph + every update_unit replacement), for CollectHandle
    /// validation in the final report.
    origins: BTreeSet<u64>,
    /// Deployment-wide drain-and-handoff epoch, bumped once per roll
    /// (planned update, periodic checkpoint, or rescale) before any stop
    /// flag is raised; quiescing instances stamp their state snapshots
    /// (and markers) with it. In checkpoint mode the stamp carries the
    /// [`crate::channels::CHECKPOINT_BIT`] tag.
    update_epoch: Arc<AtomicU64>,
    /// Last *committed* checkpoint per (unit, zone): the stamped
    /// checkpoint epoch and the state-topic offset its records start at.
    /// Recovery restores from here; a roll that dies before its commit
    /// marker leaves the previous entry in force.
    checkpoints: HashMap<(usize, String), (u64, usize)>,
    /// Per-instance end-of-stream flags, set by each instance on its
    /// normal EOS path. Checkpoint-mode rolls and recoveries consult
    /// them so an instance that already finished is not respawned into a
    /// second end-of-stream toward downstream topics.
    inst_done: HashMap<usize, Arc<AtomicBool>>,
    /// Committed checkpoints found on disk by a relaunch after a
    /// coordinator death (`(unit, zone, epoch)`), drained by the next
    /// `spawn_set`: state is restored and covered offsets re-committed
    /// *before* any instance starts consuming.
    resume_pending: Vec<(usize, String, u64)>,
    started: Instant,
}

impl Deployment {
    fn launch(
        graph: LogicalGraph,
        cluster: ClusterSpec,
        config: JobConfig,
        plan: ExecPlan,
    ) -> Result<Deployment> {
        let metrics = MetricsRegistry::new();
        let broker = if plan.edges.iter().any(|e| e.decoupled) {
            let b = match (&config.queue_dir, config.queue_budget) {
                (Some(d), Some(n)) => QueueBroker::durable_bounded(d, n, Some(metrics.clone()))?,
                (Some(d), None) => QueueBroker::durable(d, Some(metrics.clone()))?,
                (None, Some(n)) => QueueBroker::in_memory_bounded(n, Some(metrics.clone())),
                (None, None) => QueueBroker::in_memory(Some(metrics.clone())),
            };
            b.set_default_policy(config.overload_policy);
            Some(b)
        } else {
            None
        };
        let origins = BTreeSet::from([graph.origin]);
        let netsim = NetsimTransport::new(cluster.clone(), metrics.clone());
        let mut dep = Deployment {
            graph,
            cluster,
            config,
            plan,
            metrics: metrics.clone(),
            collector: Arc::new(Collector::default()),
            netsim,
            broker,
            topics: HashMap::new(),
            unit_threads: BTreeMap::new(),
            ingest_threads: Vec::new(),
            source_stop: Arc::new(AtomicBool::new(false)),
            unit_stops: BTreeMap::new(),
            origins,
            update_epoch: Arc::new(AtomicU64::new(0)),
            checkpoints: HashMap::new(),
            inst_done: HashMap::new(),
            resume_pending: Vec::new(),
            started: Instant::now(),
        };
        // A durable broker that reopened existing segments may hold
        // committed checkpoints from a previous coordinator incarnation
        // (a crashed or killed process): adopt them so the relaunch
        // resumes instead of recomputing from offset zero.
        if dep.config.checkpoint_interval.is_some() && dep.config.queue_dir.is_some() {
            dep.detect_committed_checkpoints()?;
        }
        dep.wire_and_spawn()?;
        Ok(dep)
    }

    /// Scans every unit's durable state topic for checkpoint commit
    /// markers (`stage = -1`, checkpoint-tagged epoch) left behind by a
    /// previous coordinator process, adopting the newest one per
    /// unit-zone. Fast-forwards the update epoch past everything found so
    /// fresh epochs never alias resumed ones; the actual state restore
    /// and offset re-commit happen in `spawn_set` (the entry topics must
    /// exist first).
    fn detect_committed_checkpoints(&mut self) -> Result<()> {
        let Some(broker) = self.broker.as_ref() else {
            return Ok(());
        };
        let mut newest: HashMap<(usize, String), u64> = HashMap::new();
        let mut max_seq = 0u64;
        for unit in 0..self.graph.units.len() {
            let part = state_topic(broker, unit)?.partition(0);
            let n = part.len();
            if n == 0 {
                continue;
            }
            let Some((records, _)) = part.poll(0, n, Duration::ZERO) else {
                continue;
            };
            for rec in records {
                if rec.is_empty() {
                    continue; // compaction tombstone
                }
                let fields = match Value::decode_exact(&rec) {
                    Ok(Value::List(f)) if f.len() == 5 => f,
                    _ => continue,
                };
                let (Some(stage), Some(zone), Some(epoch)) =
                    (fields[0].as_i64(), fields[1].as_str(), fields[2].as_i64())
                else {
                    continue;
                };
                let epoch = epoch as u64;
                if stage != -1 || !crate::channels::is_checkpoint(epoch) {
                    continue;
                }
                max_seq = max_seq.max(epoch_seq(epoch));
                let e = newest.entry((unit, zone.to_string())).or_insert(epoch);
                if epoch_seq(epoch) > epoch_seq(*e) {
                    *e = epoch;
                }
            }
        }
        self.update_epoch.fetch_max(max_seq, Ordering::SeqCst);
        MetricsRegistry::add(&self.metrics.recoveries, newest.len() as u64);
        for ((unit, zone), epoch) in newest {
            // scan_from 0: the resumed collect filters by zone + epoch, so
            // scanning the whole (compacted) topic is correct, just not
            // incremental — the next fresh checkpoint tightens it again
            self.checkpoints
                .insert((unit, zone.clone()), (epoch, 0));
            self.resume_pending.push((unit, zone, epoch));
        }
        Ok(())
    }

    /// Returns (creating if needed) the shared uplink for the route
    /// `za → zb` plus the route latency to stamp on each frame. The cache
    /// itself lives in [`NetsimTransport::route`] since the transport
    /// subsystem re-homed the emulated network behind the `Transport`
    /// trait; this delegate remains for the queue-ingest wiring.
    fn link_for_route(&mut self, za: &str, zb: &str) -> Result<(Arc<Link<Msg>>, Duration)> {
        self.netsim.route(za, zb)
    }

    fn wire_and_spawn(&mut self) -> Result<()> {
        let all = self.plan.instances.clone();
        self.spawn_set(&all, true, &HashMap::new())
    }

    /// Wires and spawns a *set* of planned instances. At launch the set is
    /// the whole plan; dynamic updates pass subsets (a FlowUnit's instances
    /// for `update_unit`, a new zone's instances for `add_location`).
    ///
    /// Direct (non-queue) edges may only connect instances *inside* the
    /// set — under the FlowUnits planner intra-unit edges are same-zone, so
    /// any complete unit-zone subset satisfies this; violations are
    /// reported as errors rather than producing dangling channels.
    ///
    /// `register_producers`: count the set's producers toward the
    /// decoupling topics' expected-EOS totals. True for launch and
    /// `add_location` (genuinely new producers); false for `update_unit`
    /// (replacement instances inherit their predecessors' registration,
    /// which never signalled EOS — quiescing instances exit through the
    /// epoch protocol instead).
    ///
    /// `restores`: per-instance handed-off operator state (one entry per
    /// executor in the instance's fused chain), produced by
    /// [`Deployment::collect_restores`] during a dynamic update.
    fn spawn_set(
        &mut self,
        set: &[crate::placement::InstancePlan],
        register_producers: bool,
        restores: &HashMap<usize, Vec<Value>>,
    ) -> Result<()> {
        let plan = self.plan.clone();
        let topo = self.cluster.topology.clone();
        let in_set: std::collections::BTreeSet<usize> = set.iter().map(|i| i.id).collect();

        // --- pass 1: inboxes for direct-edge consumers in the set --------
        let mut inst_tx: HashMap<usize, SyncSender<Msg>> = HashMap::new();
        let mut inst_rx: HashMap<usize, Receiver<Msg>> = HashMap::new();
        for edge in &plan.edges {
            if edge.decoupled {
                continue;
            }
            for inst in plan.instances_of(edge.to_stage) {
                if !in_set.contains(&inst) || inst_tx.contains_key(&inst) {
                    continue;
                }
                let (tx, rx) = sync_channel(self.config.channel_capacity);
                // the transport hands producers lanes to this inbox
                self.netsim.register(inst, tx.clone());
                inst_tx.insert(inst, tx);
                inst_rx.insert(inst, rx);
            }
        }

        // --- pass 2: topics (+ ingest threads) for decoupled edges -------
        // created once; subset respawns reuse the existing topics
        for edge in &plan.edges {
            if !edge.decoupled {
                continue;
            }
            let broker = self.broker.as_ref().expect("broker exists when decoupled");
            let mut by_zone: BTreeMap<String, Vec<usize>> = BTreeMap::new();
            for inst in plan.instances_of(edge.to_stage) {
                by_zone
                    .entry(plan.instances[inst].zone.clone())
                    .or_default()
                    .push(inst);
            }
            for (zone, insts) in by_zone {
                if self.topics.contains_key(&(edge.to_stage, zone.clone())) {
                    continue;
                }
                let name = format!("fu-s{}-{zone}", edge.to_stage);
                // partition count = the zone's core capacity (at least the
                // planned instance count): partition ownership is
                // round-robin, so extra partitions cost only idle ingest
                // threads while leaving headroom for the autoscaler to
                // raise replication beyond the launch instance count
                let capacity: usize = topo
                    .hosts
                    .values()
                    .filter(|h| h.zone == zone)
                    .map(|h| h.cores)
                    .sum();
                let topic = broker.topic(&name, insts.len().max(capacity))?;
                let expected = Arc::new(AtomicUsize::new(0));
                let mut ingest = Vec::new();
                // one ingest thread per partition (not per instance):
                // producers hash-route over the ingest senders, so the
                // sender count must equal the partition count for the
                // checkpoint re-partition mapping to agree with routing —
                // and every partition needs its EOS-driven close
                for p in 0..topic.partitions() {
                    let (tx, rx) = sync_channel::<Msg>(self.config.channel_capacity);
                    ingest.push(tx);
                    let topic2 = topic.clone();
                    let expected2 = expected.clone();
                    let metrics2 = self.metrics.clone();
                    let h = std::thread::Builder::new()
                        .name(format!("ingest-{name}-{p}"))
                        .spawn(move || ingest_loop(topic2, p, rx, expected2, metrics2))
                        .expect("spawn ingest thread");
                    self.ingest_threads.push(h);
                }
                self.topics.insert(
                    (edge.to_stage, zone),
                    TopicRuntime {
                        topic,
                        ingest,
                        expected_producers: expected,
                    },
                );
            }
        }

        // --- pass 3: validation + producer accounting ---------------------
        let mut producer_count: HashMap<usize, usize> = HashMap::new();
        for edge in &plan.edges {
            if edge.decoupled {
                if register_producers {
                    for from in plan.instances_of(edge.from_stage) {
                        if !in_set.contains(&from) {
                            continue;
                        }
                        let fz = &plan.instances[from].zone;
                        let tz = ancestor_at_layer(&topo, fz, &plan.stages[edge.to_stage].layer)
                            .ok_or_else(|| {
                                Error::Placement(format!(
                                    "no ancestor zone for {fz} on decoupled edge"
                                ))
                            })?;
                        if let Some(tr) = self.topics.get(&(edge.to_stage, tz.clone())) {
                            tr.topic.register_producer();
                            tr.expected_producers.fetch_add(1, Ordering::SeqCst);
                        }
                    }
                }
                continue;
            }
            for from in plan.instances_of(edge.from_stage) {
                for t in plan.allowed_targets(&topo, from, edge) {
                    match (in_set.contains(&from), in_set.contains(&t)) {
                        (true, true) => *producer_count.entry(t).or_default() += 1,
                        (false, false) => {}
                        _ => {
                            return Err(Error::Runtime(format!(
                                "direct edge {}->{} crosses the respawn boundary \
                                 (instances {from} -> {t}); the affected FlowUnit \
                                 boundary must be decoupled",
                                edge.from_stage, edge.to_stage
                            )))
                        }
                    }
                }
            }
        }

        // --- pass 3.5: coordinator-restart resume -------------------------
        // Committed checkpoints adopted from disk: group offsets are not
        // persisted in the segments, so the checkpoint records are the
        // source of truth — re-commit the offsets they cover and seed the
        // instances with the restored state, all before anything consumes.
        let mut resumed: HashMap<usize, Vec<Value>> = HashMap::new();
        for (unit, zone, epoch) in std::mem::take(&mut self.resume_pending) {
            let zs = self.collect_zone_state(unit, &zone, epoch, 0)?;
            for (&stage, parts) in &zs.offsets {
                if let Some(tr) = self.topics.get(&(stage, zone.clone())) {
                    let group = format!("unit{unit}-{zone}");
                    for (&p, &off) in parts {
                        tr.topic.partition(p).commit(&group, off);
                    }
                }
            }
            resumed.extend(zs.restores);
        }

        // --- pass 4: spawn instance threads -------------------------------
        for inst in set.to_vec() {
            let stage = plan.stages[inst.stage].clone();
            // input — the planner guarantees a fan-in stage's incoming
            // edges are either all direct or all queue-decoupled
            let incoming_decoupled = plan
                .edges
                .iter()
                .any(|e| e.to_stage == inst.stage && e.decoupled);
            let input = if stage.is_source() {
                let OpKind::Source(kind) = &self.graph.ops[stage.ops[0]].kind else {
                    return Err(Error::Runtime("stage 0 op is not a source".into()));
                };
                InputKind::Source(SourceRuntime {
                    kind: kind.clone(),
                    share: inst.source_share.unwrap_or((0, 1)),
                    batch_size: self.config.batch_size,
                    stop: self.source_stop.clone(),
                })
            } else if incoming_decoupled {
                let key = (inst.stage, inst.zone.clone());
                let tr = self
                    .topics
                    .get(&key)
                    .ok_or_else(|| Error::Runtime(format!("no topic for {key:?}")))?;
                // round-robin partition ownership by position among the
                // zone's instances — placement-affecting updates may leave
                // more (or fewer) instances than partitions
                let peers: Vec<usize> = plan
                    .instances
                    .iter()
                    .filter(|i| i.stage == inst.stage && i.zone == inst.zone)
                    .map(|i| i.id)
                    .collect();
                let pos = peers.iter().position(|&p| p == inst.id).ok_or_else(|| {
                    Error::Placement(format!(
                        "instance {} (stage {}, zone {}) is missing from its own peer \
                         list — malformed placement plan",
                        inst.id, inst.stage, inst.zone
                    ))
                })?;
                let partitions: Vec<usize> = (0..tr.topic.partitions())
                    .filter(|p| p % peers.len() == pos)
                    .collect();
                let unit_stop = self
                    .unit_stops
                    .entry((stage.unit_index, inst.zone.clone()))
                    .or_insert_with(|| Arc::new(AtomicBool::new(false)))
                    .clone();
                InputKind::Queue {
                    topic: tr.topic.clone(),
                    partitions,
                    group: format!("unit{}-{}", stage.unit_index, inst.zone),
                    poll_timeout: self.config.poll_timeout,
                    poll_max: self.config.poll_max_records.max(1),
                    stop: unit_stop,
                    commit_each_drain: self.config.checkpoint_interval.is_none(),
                    producers: tr.expected_producers.clone(),
                    idle_timeout: self.config.idle_timeout,
                }
            } else {
                let rx = inst_rx.remove(&inst.id).ok_or_else(|| {
                    Error::Runtime(format!("instance {} missing inbox", inst.id))
                })?;
                InputKind::Inbox(
                    Inbox::new(rx, *producer_count.get(&inst.id).unwrap_or(&0))
                        .with_metrics(self.metrics.clone())
                        .with_idle_timeout(self.config.idle_timeout),
                )
            };

            // output: one port per outgoing stage edge (a `split` stream
            // has several; every edge receives every batch)
            let mut ports = Vec::new();
            for edge in plan.edges.iter().filter(|e| e.from_stage == inst.stage) {
                let port = if edge.decoupled {
                    let tz = ancestor_at_layer(
                        &topo,
                        &inst.zone,
                        &plan.stages[edge.to_stage].layer,
                    )
                    .ok_or_else(|| Error::Placement("no ancestor for decoupled edge".into()))?;
                    let (link, latency) = self.link_for_route(&inst.zone, &tz)?;
                    let tr = self.topics.get(&(edge.to_stage, tz.clone())).ok_or_else(|| {
                        Error::Placement(format!(
                            "no queue topic for stage {} in zone {tz} (no consumer \
                             instance was planned there)",
                            edge.to_stage
                        ))
                    })?;
                    let crossing = inst.zone != tz;
                    let targets = tr
                        .ingest
                        .iter()
                        .map(|tx| Target::linked(tx.clone(), link.clone(), latency, crossing))
                        .collect();
                    OutPort::new(
                        targets,
                        edge.routing,
                        self.config.batch_size,
                        Some(self.metrics.clone()),
                    )
                } else {
                    // direct edges go through the transport trait: same
                    // simulated host ⇒ in-process lane, otherwise a shaped
                    // lane over the route's shared uplink
                    let mut targets = Vec::new();
                    let from_ep = Endpoint::of(&inst);
                    for t in plan.allowed_targets(&topo, inst.id, edge) {
                        let tgt = &plan.instances[t];
                        let lane = self.netsim.open(&from_ep, &Endpoint::of(tgt))?;
                        targets.push(Target::over(lane, tgt.zone != inst.zone));
                    }
                    OutPort::new(
                        targets,
                        edge.routing,
                        self.config.batch_size,
                        Some(self.metrics.clone()),
                    )
                };
                // stamp the producer's identity so downstream inboxes can
                // min-merge watermarks per producer
                ports.push(port.with_sender(inst.id as u32));
            }
            let outputs = FanOut::new(ports);

            // drain-and-handoff context: where this instance snapshots its
            // state if a dynamic update quiesces it (source units are not
            // hot-swappable, and without a queue substrate neither is
            // anything else)
            let handoff = match (&self.broker, stage.is_source()) {
                (Some(broker), false) => {
                    let done = Arc::new(AtomicBool::new(false));
                    self.inst_done.insert(inst.id, done.clone());
                    Some(Handoff {
                        state_topic: state_topic(broker, stage.unit_index)?,
                        stage: inst.stage,
                        zone: inst.zone.clone(),
                        epoch: self.update_epoch.clone(),
                        checkpoint: self.config.checkpoint_interval.is_some(),
                        eos_done: done,
                    })
                }
                _ => None,
            };

            // fused operator chain (source op handled by InputKind)
            let ops = self.build_ops(&stage)?;
            let metrics = self.metrics.clone();
            let rt = InstanceRuntime {
                id: inst.id,
                ops,
                input,
                outputs,
                metrics,
                handoff,
                restore: restores
                    .get(&inst.id)
                    .or_else(|| resumed.get(&inst.id))
                    .cloned()
                    .unwrap_or_default(),
            };
            let h = std::thread::Builder::new()
                .name(format!("inst-{}-s{}-{}", inst.id, inst.stage, inst.host))
                .spawn(move || run_instance(rt))
                .expect("spawn instance thread");
            self.unit_threads
                .entry((stage.unit_index, inst.zone.clone()))
                .or_default()
                .push(h);
        }
        // Senders must live only inside targets from here on: a producer
        // panic must disconnect its consumers' channels so they fall back
        // to the EOS path instead of blocking forever. The transport's
        // registry holds clones purely for lane wiring, so clear it too.
        drop(inst_tx);
        self.netsim.clear_inboxes();
        Ok(())
    }

    /// Builds the fused executor chain for a stage from the job graph.
    fn build_ops(&self, stage: &crate::graph::Stage) -> Result<Vec<Box<dyn OpExec>>> {
        build_stage_ops(&self.graph, stage, &self.collector, &self.metrics)
    }

    /// Signals all sources to stop after their current batch (used with
    /// unbounded/rate-limited sources before [`wait`](Self::wait)).
    pub fn stop_sources(&self) {
        self.source_stop.store(true, Ordering::SeqCst);
    }

    /// The execution plan.
    pub fn plan(&self) -> &ExecPlan {
        &self.plan
    }

    /// The live metrics registry.
    pub fn metrics(&self) -> Metrics {
        self.metrics.clone()
    }

    /// The deployed FlowUnit names, in unit-id order.
    pub fn unit_names(&self) -> Vec<String> {
        self.graph.unit_names()
    }

    /// **Dynamic update**: replaces the logic of the FlowUnit named
    /// `unit` with the corresponding operators of `new_graph`, without
    /// stopping any other unit. See [`Deployment::update_unit_at`].
    pub fn update_unit(&mut self, unit: &str, new_graph: LogicalGraph) -> Result<()> {
        let idx = self.graph.unit_named(unit).ok_or_else(|| {
            Error::Runtime(format!(
                "unknown FlowUnit '{unit}' (deployed units: {})",
                self.unit_names().join(", ")
            ))
        })?;
        self.update_unit_at(idx, new_graph)
    }

    /// **Dynamic update** (index form): replaces FlowUnit `unit` with the
    /// corresponding definition of `new_graph`, without stopping any other
    /// unit, via the **epoch-based drain-and-handoff protocol**:
    ///
    /// 1. the update epoch is bumped and the unit's per-zone stop flags
    ///    are raised; queue-fed (entry) instances commit their offsets,
    ///    snapshot stateful-operator state into the unit's state topic,
    ///    forward an epoch marker down their direct internal channels, and
    ///    exit **without** emitting EOS;
    /// 2. instances fed by direct internal channels drain until every
    ///    producer has delivered the marker, then snapshot, forward, and
    ///    exit the same way — so multi-stage units with direct internal
    ///    channels hot-swap without leaking a premature end-of-stream;
    /// 3. replacement instances restore the snapshots (re-partitioned by
    ///    key hash to mirror the input routing) and resume from the
    ///    committed queue offsets.
    ///
    /// Downstream units observe a pause, never a lost or duplicated batch.
    /// Producers upstream keep appending to the decoupling queues
    /// throughout — zero disruption outside the unit.
    ///
    /// Requirements (checked): every FlowUnit-*boundary* edge touching the
    /// unit is decoupled through the queue substrate, and `new_graph`
    /// keeps the stage partitioning and unit names/layers. Changing the
    /// unit's **constraint or replication** is allowed: placement is
    /// re-run for the unit and its replicas are rolled zone by zone.
    pub fn update_unit_at(&mut self, unit: usize, new_graph: LogicalGraph) -> Result<()> {
        let old_stages = self.graph.stages();
        let new_stages = new_graph.stages();
        if old_stages.len() != new_stages.len() {
            return Err(Error::Runtime(format!(
                "update_unit: stage count changed ({} -> {})",
                old_stages.len(),
                new_stages.len()
            )));
        }
        for (a, b) in old_stages.iter().zip(&new_stages) {
            if a.layer != b.layer || a.unit_index != b.unit_index || a.ops != b.ops {
                return Err(Error::Runtime(format!(
                    "update_unit: stage {} shape changed; updates must preserve the graph structure",
                    a.index
                )));
            }
        }
        if self.graph.units.len() != new_graph.units.len() {
            return Err(Error::Runtime(
                "update_unit: FlowUnit table changed (unit count); structural changes \
                 need a redeploy"
                    .into(),
            ));
        }
        for (i, (a, b)) in self.graph.units.iter().zip(&new_graph.units).enumerate() {
            if a.name != b.name || a.layer != b.layer {
                return Err(Error::Runtime(
                    "update_unit: FlowUnit names/layers changed; renames and re-layering \
                     need a redeploy"
                        .into(),
                ));
            }
            if i != unit && (a.constraint != b.constraint || a.replication != b.replication) {
                return Err(Error::Runtime(format!(
                    "update_unit: constraint/replication of unit '{}' changed, but only \
                     unit {unit} is being updated — update one unit at a time",
                    a.name
                )));
            }
        }
        let unit_stages: BTreeSet<usize> = self
            .plan
            .stages
            .iter()
            .filter(|s| s.unit_index == unit)
            .map(|s| s.index)
            .collect();
        if unit_stages.is_empty() {
            return Err(Error::Runtime(format!("unknown unit {unit}")));
        }
        if self
            .plan
            .stages
            .iter()
            .any(|s| unit_stages.contains(&s.index) && s.is_source())
        {
            return Err(Error::Runtime("cannot update the source unit".into()));
        }
        let incoming: Vec<&crate::placement::EdgePlan> = self
            .plan
            .edges
            .iter()
            .filter(|e| unit_stages.contains(&e.to_stage))
            .collect();
        if !incoming.iter().any(|e| !unit_stages.contains(&e.from_stage)) {
            return Err(Error::Runtime("cannot update the source unit".into()));
        }
        // Boundary edges (in and out) must be queue-decoupled so the rest
        // of the deployment is insulated from the swap. *Internal* direct
        // channels are fine: the epoch marker protocol drains them.
        if self.plan.edges.iter().any(|e| {
            !e.decoupled
                && (unit_stages.contains(&e.to_stage) != unit_stages.contains(&e.from_stage))
        }) {
            return Err(Error::Runtime(
                "update_unit requires every FlowUnit-boundary edge touching the unit to \
                 be decoupled (JobConfig::decouple_units)"
                    .into(),
            ));
        }
        if self.broker.is_none() {
            return Err(Error::Runtime(
                "update_unit requires the queue substrate (no decoupled edges exist)".into(),
            ));
        }
        // Unreachable through Coordinator::deploy (the Renoir baseline
        // never decouples, so the boundary check above already fired), but
        // fail explicitly before any teardown: Renoir's all-to-all internal
        // edges span zones, which the per-zone roll cannot respawn.
        if self.plan.planner != PlannerKind::FlowUnits {
            return Err(Error::Runtime(
                "dynamic updates require the FlowUnits planner".into(),
            ));
        }

        // placement-affecting change (constraint/replication): re-run
        // placement for the unit's stages and splice the new instances in
        let placement_changed = {
            let a = &self.graph.units[unit];
            let b = &new_graph.units[unit];
            a.constraint != b.constraint || a.replication != b.replication
        };
        if placement_changed {
            self.replace_unit_placement(unit, &unit_stages, &new_graph)?;
        }

        // the epoch is bumped *before* any stop flag so quiescing
        // instances stamp their snapshots and markers consistently
        let epoch = self.bump_epoch();
        // this epoch's snapshots land at or after the current end of the
        // state topic — remember it so restore scans skip older epochs'
        // records instead of re-decoding the whole history every update
        let scan_from = match &self.broker {
            Some(broker) => state_topic(broker, unit)?.partition(0).len(),
            None => 0,
        };
        let t0 = Instant::now();

        // swap the graph (same shape; new closures/artifacts, possibly a
        // re-scoped target unit); both the original graph's CollectHandles
        // and the replacement's stay redeemable against the final report
        self.origins.insert(new_graph.origin);
        self.graph = new_graph;

        // roll the unit zone by zone: quiesce, collect handed-off state,
        // respawn with restores — replicas in other zones keep running
        // until their turn
        for zone in self.unit_zones(unit) {
            self.roll_zone(unit, &unit_stages, &zone, epoch, scan_from)?;
        }
        MetricsRegistry::add(
            &self.metrics.update_pause_ms,
            t0.elapsed().as_millis() as u64,
        );
        Ok(())
    }

    /// Advances the deployment epoch for one roll and returns the stamped
    /// value. In checkpoint mode every roll is a checkpoint, so the stamp
    /// carries the checkpoint tag bit. All rolls run on the coordinator
    /// thread, so a plain load-compute-store cannot race.
    fn bump_epoch(&self) -> u64 {
        let seq = epoch_seq(self.update_epoch.load(Ordering::SeqCst)) + 1;
        let stamped = if self.config.checkpoint_interval.is_some() {
            checkpoint_epoch(seq)
        } else {
            seq
        };
        self.update_epoch.store(stamped, Ordering::SeqCst);
        stamped
    }

    /// Every zone the unit has planned instances (or still-tracked
    /// threads) in.
    fn unit_zones(&self, unit: usize) -> BTreeSet<String> {
        let mut zones: BTreeSet<String> = self
            .plan
            .instances
            .iter()
            .filter(|i| self.plan.stages[i.stage].unit_index == unit)
            .map(|i| i.zone.clone())
            .collect();
        for key in self.unit_threads.keys() {
            if key.0 == unit {
                zones.insert(key.1.clone());
            }
        }
        zones
    }

    /// Quiesces, collects, (in checkpoint mode) commits, and respawns one
    /// unit-zone — the shared building block of planned updates, periodic
    /// checkpoints, rescaling, and recovery. If a thread of the zone
    /// turns out to have *panicked* rather than quiesced, the roll
    /// degrades into a recovery from the last committed checkpoint
    /// instead of trusting the partial quiesce records.
    fn roll_zone(
        &mut self,
        unit: usize,
        unit_stages: &BTreeSet<usize>,
        zone: &str,
        epoch: u64,
        scan_from: usize,
    ) -> Result<()> {
        self.stop_zone(unit, unit_stages, zone);
        if self.join_zone(unit, zone) > 0 {
            return self.restore_zone_from_checkpoint(unit, zone);
        }
        let state = self.collect_zone_state(unit, zone, epoch, scan_from)?;
        if self.config.checkpoint_interval.is_some() {
            self.commit_checkpoint(unit, zone, epoch, scan_from, &state)?;
        }
        self.respawn_zone(unit, zone, &state.restores)
    }

    /// Raises the zone's stop flag and wakes only the consumers it
    /// targets (topics feeding the unit's stages in this zone) so the
    /// flag is observed immediately instead of after a full poll timeout
    /// — shrinks the pause window without a job-wide wake storm.
    fn stop_zone(&self, unit: usize, unit_stages: &BTreeSet<usize>, zone: &str) {
        if let Some(stop) = self.unit_stops.get(&(unit, zone.to_string())) {
            stop.store(true, Ordering::SeqCst);
            for (key, tr) in &self.topics {
                if unit_stages.contains(&key.0) && key.1 == zone {
                    tr.topic.kick();
                }
            }
        }
    }

    /// Joins every tracked thread of the unit-zone; returns how many of
    /// them panicked instead of exiting cleanly.
    fn join_zone(&mut self, unit: usize, zone: &str) -> usize {
        let mut panicked = 0;
        for h in self
            .unit_threads
            .remove(&(unit, zone.to_string()))
            .unwrap_or_default()
        {
            if h.join().is_err() {
                panicked += 1;
            }
        }
        panicked
    }

    /// Arms a fresh stop flag and respawns the unit-zone's instances with
    /// `restores`. In checkpoint mode, instances that already delivered
    /// their end-of-stream are not resurrected: replaying a finished
    /// exit-stage instance would send downstream topics a second EOS and
    /// close them early. A zone whose instances *all* finished respawns
    /// nothing; a partially-finished zone with internal direct channels
    /// respawns everything (safe — the finished instances re-emit EOS on
    /// the *internal* channels only, and an exit stage cannot have
    /// finished while a sibling still runs).
    fn respawn_zone(
        &mut self,
        unit: usize,
        zone: &str,
        restores: &HashMap<usize, Vec<Value>>,
    ) -> Result<()> {
        self.unit_stops
            .insert((unit, zone.to_string()), Arc::new(AtomicBool::new(false)));
        let insts: Vec<_> = self
            .plan
            .instances
            .iter()
            .filter(|i| self.plan.stages[i.stage].unit_index == unit && i.zone == zone)
            .cloned()
            .collect();
        let set: Vec<_> = if self.config.checkpoint_interval.is_some() {
            let done = |i: &crate::placement::InstancePlan| {
                self.inst_done
                    .get(&i.id)
                    .is_some_and(|d| d.load(Ordering::SeqCst))
            };
            let stages: BTreeSet<usize> = insts.iter().map(|i| i.stage).collect();
            let internal_direct = self.plan.edges.iter().any(|e| {
                !e.decoupled && stages.contains(&e.from_stage) && stages.contains(&e.to_stage)
            });
            if insts.iter().all(done) {
                Vec::new()
            } else if internal_direct {
                insts
            } else {
                insts.into_iter().filter(|i| !done(i)).collect()
            }
        } else {
            insts
        };
        self.spawn_set(&set, false, restores)
    }

    /// Re-runs placement for one unit (constraint/replication changed) and
    /// splices the new instances into the running plan, renumbering ids.
    /// Decoupling-topic partition counts are fixed at creation, so entry
    /// instances own partitions round-robin; downstream topics' expected
    /// producer counts are adjusted by the instance-count delta.
    fn replace_unit_placement(
        &mut self,
        unit: usize,
        unit_stages: &BTreeSet<usize>,
        new_graph: &LogicalGraph,
    ) -> Result<()> {
        let decouple = self.plan.edges.iter().any(|e| e.decoupled);
        let new_plan = make_plan(
            new_graph,
            &self.cluster,
            self.plan.planner,
            &self.plan.locations,
            decouple,
        )?;
        // Fail fast, before anything is stopped or mutated: every queue-fed
        // stage of the unit must keep its zones within the topics created
        // at launch, and within their fixed partition counts. (Constraint/
        // replication changes cannot add zones — zones come from layer +
        // locations — but an instance count above the partition count would
        // leave partition-less instances that EOS immediately, and their
        // EOS would double-count against the downstream expected totals on
        // a later update.)
        for stage in self.plan.stages.iter().filter(|s| {
            unit_stages.contains(&s.index)
                && self
                    .plan
                    .edges
                    .iter()
                    .any(|e| e.to_stage == s.index && e.decoupled)
        }) {
            let mut per_zone: BTreeMap<&str, usize> = BTreeMap::new();
            for inst in new_plan.instances.iter().filter(|i| i.stage == stage.index) {
                *per_zone.entry(inst.zone.as_str()).or_default() += 1;
            }
            for (zone, count) in per_zone {
                let Some(tr) = self.topics.get(&(stage.index, zone.to_string())) else {
                    return Err(Error::Placement(format!(
                        "update_unit: new placement puts stage {} in zone {zone}, which \
                         has no decoupling topic from launch — redeploy instead",
                        stage.index
                    )));
                };
                if count > tr.topic.partitions() {
                    return Err(Error::Placement(format!(
                        "update_unit: new placement needs {count} instances of stage {} \
                         in zone {zone}, but its topic has only {} partitions (fixed at \
                         launch) — scale-out beyond the launch partition count needs a \
                         redeploy",
                        stage.index,
                        tr.topic.partitions()
                    )));
                }
            }
        }
        let topo = self.cluster.topology.clone();
        // producer-count deltas for topics the unit appends into
        for edge in self
            .plan
            .edges
            .iter()
            .filter(|e| e.decoupled && unit_stages.contains(&e.from_stage))
        {
            let mut delta: BTreeMap<String, i64> = BTreeMap::new();
            let to_layer = &self.plan.stages[edge.to_stage].layer;
            for inst in self.plan.instances.iter().filter(|i| i.stage == edge.from_stage) {
                if let Some(tz) = ancestor_at_layer(&topo, &inst.zone, to_layer) {
                    *delta.entry(tz).or_default() -= 1;
                }
            }
            for inst in new_plan.instances.iter().filter(|i| i.stage == edge.from_stage) {
                if let Some(tz) = ancestor_at_layer(&topo, &inst.zone, to_layer) {
                    *delta.entry(tz).or_default() += 1;
                }
            }
            for (tz, d) in delta {
                if d == 0 {
                    continue;
                }
                if let Some(tr) = self.topics.get(&(edge.to_stage, tz)) {
                    if d > 0 {
                        for _ in 0..d {
                            tr.topic.register_producer();
                        }
                        tr.expected_producers.fetch_add(d as usize, Ordering::SeqCst);
                    } else {
                        tr.expected_producers
                            .fetch_sub((-d) as usize, Ordering::SeqCst);
                    }
                }
            }
        }
        // splice: other units keep their instances (and relative order);
        // the updated unit adopts the new placement
        let mut instances = Vec::with_capacity(new_plan.instances.len());
        for s in 0..self.plan.stages.len() {
            if unit_stages.contains(&s) {
                instances.extend(new_plan.instances.iter().filter(|i| i.stage == s).cloned());
            } else {
                instances.extend(self.plan.instances.iter().filter(|i| i.stage == s).cloned());
            }
        }
        for (id, inst) in instances.iter_mut().enumerate() {
            inst.id = id;
        }
        self.plan.instances = instances;
        // adopt the re-scoped stage metadata (constraint/replication)
        self.plan.stages = new_plan.stages;
        Ok(())
    }

    /// Commits one unit-zone checkpoint: advances the zone's consumer
    /// groups to the offsets its quiesce records cover, then appends the
    /// commit marker (a `stage = -1` record) to the unit's state topic
    /// and publishes the checkpoint for recovery. Ordering is the
    /// atomicity argument: a roll that dies *before* the marker leaves
    /// the previous checkpoint in force, and since offsets only advance
    /// here — never inside the instances — replay after a mid-roll crash
    /// re-reads everything the dead roll had consumed.
    fn commit_checkpoint(
        &mut self,
        unit: usize,
        zone: &str,
        epoch: u64,
        scan_from: usize,
        state: &ZoneState,
    ) -> Result<()> {
        for (&stage, parts) in &state.offsets {
            if let Some(tr) = self.topics.get(&(stage, zone.to_string())) {
                let group = format!("unit{unit}-{zone}");
                for (&p, &off) in parts {
                    tr.topic.partition(p).commit(&group, off);
                }
            }
        }
        let broker = self
            .broker
            .as_ref()
            .ok_or_else(|| Error::Runtime("checkpoint without queue substrate".into()))?;
        let marker = state_record(-1, zone, epoch, Vec::new(), &[]);
        let topic = state_topic(broker, unit)?;
        if topic.partition(0).append(&marker.encode()).is_err() {
            MetricsRegistry::add(&self.metrics.state_append_failures, 1);
            return Err(Error::Runtime(
                "state topic rejected the checkpoint commit marker".into(),
            ));
        }
        self.checkpoints
            .insert((unit, zone.to_string()), (epoch, scan_from));
        MetricsRegistry::add(&self.metrics.checkpoints_taken, 1);
        // Compact the state topic: every committed checkpoint of this unit
        // re-reads its records from its own `scan_from` onward, so nothing
        // below the minimum scan_from across the unit's zones can ever be
        // read again. Tombstoning (not removal) keeps the surviving
        // records' absolute offsets intact, so the topic's length — and
        // the memory/disk behind it — stays bounded across arbitrarily
        // many checkpoint cycles.
        let keep_from = self
            .checkpoints
            .iter()
            .filter(|((u, _), _)| *u == unit)
            .map(|(_, &(_, sf))| sf)
            .min()
            .unwrap_or(0);
        topic.partition(0).compact_before(keep_from);
        Ok(())
    }

    /// Respawns a unit-zone from its last *committed* checkpoint: state
    /// snapshots are re-read from the checkpoint's records, and the queue
    /// consumers resume from the group offsets the checkpoint committed —
    /// anything consumed after it is replayed. Quiesce records any
    /// surviving siblings wrote while being stopped are deliberately
    /// ignored (they are stamped with a fresher epoch): state and offsets
    /// must rewind *together* or replay would double-count.
    ///
    /// Without checkpoint mode this degenerates into the legacy fail-fast
    /// error.
    fn restore_zone_from_checkpoint(&mut self, unit: usize, zone: &str) -> Result<()> {
        if self.config.checkpoint_interval.is_none() {
            return Err(Error::Runtime("instance thread panicked".into()));
        }
        let restores = match self.checkpoints.get(&(unit, zone.to_string())).copied() {
            Some((epoch, scan_from)) => {
                self.collect_zone_state(unit, zone, epoch, scan_from)?.restores
            }
            // no checkpoint committed yet: restart from scratch — the
            // group offsets were never advanced, so the entry topics
            // replay from the beginning
            None => HashMap::new(),
        };
        MetricsRegistry::add(&self.metrics.recoveries, 1);
        self.respawn_zone(unit, zone, &restores)
    }

    /// **Unplanned-failure recovery**: called when an instance thread of
    /// the unit-zone is found dead. Stops and joins the surviving
    /// siblings (their fresh quiesce records are ignored — the epoch is
    /// bumped first so they cannot alias the checkpoint being restored),
    /// then respawns the whole unit-zone from the last committed
    /// checkpoint. Source units are not recoverable (their progress lives
    /// outside the queue substrate), nor is anything without checkpoint
    /// mode — those fail the job exactly as before.
    fn recover_unit_zone(&mut self, unit: usize, zone: &str) -> Result<()> {
        let Some(unit_stages) = self.unit_rollable(unit) else {
            return Err(Error::Runtime("instance thread panicked".into()));
        };
        self.bump_epoch();
        self.stop_zone(unit, &unit_stages, zone);
        self.join_zone(unit, zone);
        self.restore_zone_from_checkpoint(unit, zone)
    }

    /// Returns the unit's stage set if the unit can be rolled: non-source,
    /// every boundary edge queue-decoupled, FlowUnits planner — the same
    /// preconditions `update_unit_at` enforces, in predicate form for the
    /// checkpoint and autoscale ticks.
    fn unit_rollable(&self, unit: usize) -> Option<BTreeSet<usize>> {
        let unit_stages: BTreeSet<usize> = self
            .plan
            .stages
            .iter()
            .filter(|s| s.unit_index == unit)
            .map(|s| s.index)
            .collect();
        if unit_stages.is_empty()
            || self
                .plan
                .stages
                .iter()
                .any(|s| unit_stages.contains(&s.index) && s.is_source())
            || self.plan.edges.iter().any(|e| {
                !e.decoupled
                    && (unit_stages.contains(&e.to_stage) != unit_stages.contains(&e.from_stage))
            })
            || self.broker.is_none()
            || self.plan.planner != PlannerKind::FlowUnits
        {
            return None;
        }
        Some(unit_stages)
    }

    /// Takes a coordinated checkpoint of every rollable unit that still
    /// has live instances: each unit-zone quiesces, its state and covered
    /// offsets land in the state topic, the coordinator commits and
    /// respawns it restored. Public so tests (and embedding applications)
    /// can force a checkpoint at a deterministic point; the supervisor
    /// calls it on every `checkpoint_interval` tick.
    pub fn checkpoint(&mut self) -> Result<()> {
        for unit in 0..self.graph.units.len() {
            let Some(unit_stages) = self.unit_rollable(unit) else {
                continue;
            };
            let zones: Vec<String> = self
                .unit_threads
                .keys()
                .filter(|k| k.0 == unit)
                .map(|k| k.1.clone())
                .collect();
            if zones.is_empty() {
                continue;
            }
            let epoch = self.bump_epoch();
            let scan_from = match &self.broker {
                Some(broker) => state_topic(broker, unit)?.partition(0).len(),
                None => 0,
            };
            for zone in zones {
                self.roll_zone(unit, &unit_stages, &zone, epoch, scan_from)?;
            }
        }
        Ok(())
    }

    /// Current per-zone instance count of a unit (max across its zones
    /// and stages).
    fn unit_replication(&self, unit: usize) -> usize {
        let mut per_zone: BTreeMap<(&str, usize), usize> = BTreeMap::new();
        for i in &self.plan.instances {
            if self.plan.stages[i.stage].unit_index == unit {
                *per_zone.entry((i.zone.as_str(), i.stage)).or_default() += 1;
            }
        }
        per_zone.values().copied().max().unwrap_or(0)
    }

    /// One autoscaler sample: probes every rollable unit's entry-topic
    /// lag, updates its hysteresis streaks, and — when a streak crosses
    /// the configured sample count outside the cooldown window — steps
    /// the unit's replication by one through the planned-update path.
    fn autoscale_tick(
        &mut self,
        a: &AutoscaleConfig,
        streaks: &mut HashMap<usize, (u32, u32)>,
        last_action: &mut HashMap<usize, Instant>,
    ) -> Result<()> {
        for unit in 0..self.graph.units.len() {
            let Some(unit_stages) = self.unit_rollable(unit) else {
                continue;
            };
            let mut lag = 0u64;
            let mut part_cap = usize::MAX;
            for ((stage, zone), tr) in &self.topics {
                if unit_stages.contains(stage) {
                    lag += tr.topic.lag(&format!("unit{unit}-{zone}"));
                    part_cap = part_cap.min(tr.topic.partitions());
                }
            }
            if part_cap == usize::MAX {
                continue; // no entry topics — nothing to scale on
            }
            let (mut ups, mut downs) = streaks.get(&unit).copied().unwrap_or((0, 0));
            ups = if lag >= a.scale_up_lag { ups + 1 } else { 0 };
            downs = if lag <= a.scale_down_lag { downs + 1 } else { 0 };
            streaks.insert(unit, (ups, downs));
            let cur = self.unit_replication(unit);
            let max = a.max_instances.min(part_cap);
            let target = if ups >= a.samples && cur < max {
                cur + 1
            } else if downs >= a.samples && cur > a.min_instances.max(1) {
                cur - 1
            } else {
                continue;
            };
            let cooled = last_action
                .get(&unit)
                .map_or(true, |t| t.elapsed() >= a.cooldown);
            if !cooled {
                continue;
            }
            streaks.insert(unit, (0, 0));
            last_action.insert(unit, Instant::now());
            let mut g = self.graph.clone();
            g.units[unit].replication = crate::graph::Replication::Fixed(target);
            self.update_unit_at(unit, g)?;
            if target > cur {
                MetricsRegistry::add(&self.metrics.autoscale_ups, 1);
            } else {
                MetricsRegistry::add(&self.metrics.autoscale_downs, 1);
            }
        }
        Ok(())
    }

    /// Reads the unit's state topic and partitions the snapshot entries of
    /// `zone` at `epoch` across the unit's (new) instances, mirroring the
    /// key routing each stage's input applies: keys of a queue-fed stage
    /// land on partition `hash % P` owned by instance `(hash % P) % n`;
    /// keys of an inbox-fed stage come from a hash-routed port at
    /// `hash % n`. Also gathers the input offsets the records declare
    /// covered, which a checkpoint commit advances the consumer groups
    /// to. Corrupt state records are skipped and counted; `stage = -1`
    /// commit markers are ignored.
    ///
    /// `scan_from`: state-topic offset recorded when the roll began —
    /// records before it belong to earlier epochs and are skipped without
    /// decoding.
    fn collect_zone_state(
        &self,
        unit: usize,
        zone: &str,
        epoch: u64,
        scan_from: usize,
    ) -> Result<ZoneState> {
        let broker = self
            .broker
            .as_ref()
            .ok_or_else(|| Error::Runtime("update without queue substrate".into()))?;
        let topic = state_topic(broker, unit)?;
        let part = topic.partition(0);
        let mut out: HashMap<usize, Vec<Value>> = HashMap::new();
        let mut offsets: BTreeMap<usize, BTreeMap<usize, usize>> = BTreeMap::new();
        let n_records = part.len();
        if n_records <= scan_from {
            return Ok(ZoneState::default());
        }
        let records = match part.poll(scan_from, n_records - scan_from, Duration::ZERO) {
            Some((recs, _)) => recs,
            None => return Ok(ZoneState::default()),
        };
        // stage → per-executor entry lists, merged across the zone's
        // quiesced instances
        let mut per_stage: BTreeMap<usize, Vec<Vec<Value>>> = BTreeMap::new();
        for rec in records {
            if rec.is_empty() {
                continue; // compaction tombstone — superseded epoch
            }
            let fields = match Value::decode_exact(&rec) {
                Ok(Value::List(f)) if f.len() == 5 => f,
                Ok(_) => continue,
                Err(_) => {
                    MetricsRegistry::add(&self.metrics.corrupt_records, 1);
                    continue;
                }
            };
            let mut fields = fields.into_iter();
            let (stage_v, zone_v, epoch_v, snaps_v, offs_v) = (
                fields.next().unwrap(),
                fields.next().unwrap(),
                fields.next().unwrap(),
                fields.next().unwrap(),
                fields.next().unwrap(),
            );
            let (Some(stage), Some(rec_zone), Some(rec_epoch)) =
                (stage_v.as_i64(), zone_v.as_str(), epoch_v.as_i64())
            else {
                continue;
            };
            // the epoch comparison goes through the same `as i64` cast the
            // writer applied, so checkpoint-tagged stamps compare exactly
            if rec_zone != zone || rec_epoch != epoch as i64 || stage < 0 {
                continue;
            }
            if let Value::List(offs) = offs_v {
                let covered = offsets.entry(stage as usize).or_default();
                for pr in offs {
                    if let Some((p_v, o_v)) = pr.into_pair() {
                        if let (Some(p), Some(o)) = (p_v.as_i64(), o_v.as_i64()) {
                            let slot = covered.entry(p as usize).or_default();
                            *slot = (*slot).max(o as usize);
                        }
                    }
                }
            }
            let Value::List(snaps) = snaps_v else { continue };
            let slot = per_stage
                .entry(stage as usize)
                .or_insert_with(|| vec![Vec::new(); snaps.len()]);
            if slot.len() < snaps.len() {
                slot.resize(snaps.len(), Vec::new());
            }
            for (oi, snap) in snaps.into_iter().enumerate() {
                if let Value::List(entries) = snap {
                    slot[oi].extend(entries);
                }
            }
        }
        for (stage, op_entries) in per_stage {
            let peers: Vec<usize> = self
                .plan
                .instances
                .iter()
                .filter(|i| i.stage == stage && i.zone == zone)
                .map(|i| i.id)
                .collect();
            if peers.is_empty() {
                // Defensive only: zones come from layer + locations, so a
                // placement-affecting update cannot drop one (a constraint
                // that empties a zone fails make_plan before any teardown).
                continue;
            }
            let n = peers.len() as u64;
            let qparts = self
                .topics
                .get(&(stage, zone.to_string()))
                .map(|tr| tr.topic.partitions() as u64);
            let n_ops = op_entries.len();
            for (oi, entries) in op_entries.into_iter().enumerate() {
                for e in entries {
                    let h = crate::channels::route_hash(&e);
                    let pos = match qparts {
                        Some(p) if p > 0 => ((h % p) % n) as usize,
                        _ => (h % n) as usize,
                    };
                    let slot = out
                        .entry(peers[pos])
                        .or_insert_with(|| vec![Value::Null; n_ops]);
                    match &mut slot[oi] {
                        Value::List(l) => l.push(e),
                        s => *s = Value::List(vec![e]),
                    }
                }
            }
        }
        Ok(ZoneState {
            restores: out,
            offsets,
        })
    }

    /// **Dynamic update**: enables a new location while the job runs.
    /// Supported case (the paper's E5 example): the new location adds
    /// instances only to the *source unit*, whose output boundary is
    /// decoupled, and the downstream zones it feeds are already active.
    pub fn add_location(&mut self, loc: &str) -> Result<()> {
        if self.plan.locations.iter().any(|l| l == loc) {
            return Err(Error::Runtime(format!("location '{loc}' already enabled")));
        }
        let mut locations = self.plan.locations.clone();
        locations.push(loc.to_string());
        let decouple = self.plan.edges.iter().any(|e| e.decoupled);
        let new_plan = make_plan(
            &self.graph,
            &self.cluster,
            self.plan.planner,
            &locations,
            decouple,
        )?;
        // diff: instances present in new plan but not in the old one
        let old_keys: std::collections::BTreeSet<(usize, String, usize)> = self
            .plan
            .instances
            .iter()
            .map(|i| (i.stage, i.host.clone(), i.core))
            .collect();
        let added: Vec<_> = new_plan
            .instances
            .iter()
            .filter(|i| !old_keys.contains(&(i.stage, i.host.clone(), i.core)))
            .cloned()
            .collect();
        if added.is_empty() {
            return Err(Error::Runtime(format!(
                "location '{loc}' adds no new instances"
            )));
        }
        // units that contain a source stage may grow at a new location;
        // everything downstream must already be active
        let source_units: std::collections::BTreeSet<usize> = new_plan
            .stages
            .iter()
            .filter(|s| s.is_source())
            .map(|s| s.unit_index)
            .collect();
        for a in &added {
            let unit = new_plan.stages[a.stage].unit_index;
            if !source_units.contains(&unit) {
                return Err(Error::Runtime(format!(
                    "add_location currently supports new instances in source units only \
                     (instance on stage {} is in unit {unit}); zone '{}' must already be active",
                    a.stage, a.zone
                )));
            }
            for e in new_plan.edges.iter().filter(|e| e.from_stage == a.stage) {
                if e.unit_boundary && !e.decoupled {
                    return Err(Error::Runtime(
                        "add_location requires decoupled unit boundaries".into(),
                    ));
                }
                if e.decoupled {
                    // the new producers must feed topics that already exist
                    // (i.e. their downstream zone is already active)
                    let tz = ancestor_at_layer(
                        &self.cluster.topology,
                        &a.zone,
                        &new_plan.stages[e.to_stage].layer,
                    )
                    .ok_or_else(|| Error::Runtime("new zone has no ancestor".into()))?;
                    if !self.topics.contains_key(&(e.to_stage, tz.clone())) {
                        return Err(Error::Runtime(format!(
                            "downstream zone '{tz}' is not active; adding whole new branches is unsupported"
                        )));
                    }
                }
            }
        }
        // adopt the new plan's locations and instance list (ids realign:
        // we keep the old plan and append the new instances with fresh ids)
        let mut adopted = Vec::new();
        for mut a in added {
            a.id = self.plan.instances.len();
            self.plan.instances.push(a.clone());
            adopted.push(a);
        }
        self.plan.locations = locations;
        self.spawn_set(&adopted, true, &HashMap::new())?;
        Ok(())
    }

    /// Waits for the job to finish, tears down links, and reports.
    ///
    /// Legacy (no checkpoint interval, no autoscaler) semantics are
    /// fail-fast: if any instance thread panicked (a user closure
    /// fault), the first failed join surfaces as
    /// `Error::Runtime("instance thread panicked")` immediately;
    /// downstream threads of the failed unit are abandoned to process
    /// teardown rather than joined (they may be blocked on an EOS that
    /// will never arrive).
    ///
    /// With `checkpoint_interval` or `autoscale` configured, waiting
    /// becomes supervision (see [`Deployment::supervise`]): dead
    /// unit-zones are recovered from their last committed checkpoint
    /// instead of failing the job, checkpoints are taken on the
    /// configured interval, and the autoscaler steps replication with
    /// queue lag.
    pub fn wait(mut self) -> Result<JobReport> {
        if self.config.checkpoint_interval.is_some() || self.config.autoscale.is_some() {
            self.supervise()?;
        }
        for (_, handles) in std::mem::take(&mut self.unit_threads) {
            for h in handles {
                h.join().map_err(|_| Error::Runtime("instance thread panicked".into()))?;
            }
        }
        for h in std::mem::take(&mut self.ingest_threads) {
            let _ = h.join();
        }
        self.netsim.shutdown_links();
        let wall_time = self.started.elapsed();
        let queue_lag = self.queue_lags();
        let m = &self.metrics;
        let instance_batches = m
            .labelled_snapshot()
            .into_iter()
            .filter_map(|(k, v)| {
                let id = k.strip_prefix("inst.")?.strip_suffix(".batches")?;
                Some((id.parse().ok()?, v))
            })
            .collect();
        Ok(JobReport {
            wall_time,
            events_in: m.events_in.load(Ordering::Relaxed),
            events_out: m.events_out.load(Ordering::Relaxed),
            collected: std::mem::take(&mut *self.collector.values.lock().unwrap()),
            net_bytes: m.net_bytes.load(Ordering::Relaxed),
            zone_crossings: m.zone_crossings.load(Ordering::Relaxed),
            wire_encodes: m.batch_encodes.load(Ordering::Relaxed),
            corrupt_records: m.corrupt_records.load(Ordering::Relaxed),
            plan_description: self.plan.describe(&self.graph),
            queue_lag,
            instance_batches,
            metrics: self.metrics.clone(),
            collected_tagged: std::mem::take(&mut *self.collector.tagged.lock().unwrap()),
            origins: std::mem::take(&mut self.origins),
        })
    }

    /// Live per-topic queue lag (records appended minus records the
    /// consuming unit's group committed), keyed by topic name — the
    /// autoscaler's input, exposed for observability.
    pub fn queue_lags(&self) -> BTreeMap<String, u64> {
        self.topics
            .iter()
            .map(|((stage, zone), tr)| {
                let unit = self.plan.stages[*stage].unit_index;
                (
                    format!("fu-s{stage}-{zone}"),
                    tr.topic.lag(&format!("unit{unit}-{zone}")),
                )
            })
            .collect()
    }

    /// The control loop of checkpoint mode. Repeatedly:
    ///
    /// - **reaps** finished instance threads — a clean exit is collected,
    ///   a panic triggers [`Deployment::recover_unit_zone`] for its
    ///   unit-zone (which fails the job only if the unit is not
    ///   recoverable, e.g. a source unit or no checkpoint substrate);
    /// - **checkpoints** every rollable unit each `checkpoint_interval`;
    /// - **autoscales** on the configured lag policy.
    ///
    /// Returns once every instance thread has exited cleanly.
    fn supervise(&mut self) -> Result<()> {
        let auto = self.config.autoscale.clone();
        let mut last_ckpt = Instant::now();
        let mut last_sample = Instant::now();
        let mut streaks: HashMap<usize, (u32, u32)> = HashMap::new();
        let mut last_action: HashMap<usize, Instant> = HashMap::new();
        loop {
            let keys: Vec<(usize, String)> = self.unit_threads.keys().cloned().collect();
            let mut dead: Vec<(usize, String)> = Vec::new();
            for key in keys {
                let mut handles = self.unit_threads.remove(&key).unwrap_or_default();
                let mut live = Vec::new();
                let mut panicked = false;
                for h in handles.drain(..) {
                    if h.is_finished() {
                        if h.join().is_err() {
                            panicked = true;
                        }
                    } else {
                        live.push(h);
                    }
                }
                if !live.is_empty() {
                    self.unit_threads.insert(key.clone(), live);
                }
                if panicked {
                    dead.push(key);
                }
            }
            for (unit, zone) in dead {
                self.recover_unit_zone(unit, &zone)?;
            }
            if self.unit_threads.is_empty() {
                return Ok(());
            }
            if let Some(iv) = self.config.checkpoint_interval {
                if last_ckpt.elapsed() >= iv {
                    self.checkpoint()?;
                    last_ckpt = Instant::now();
                }
            }
            if let Some(a) = &auto {
                if last_sample.elapsed() >= a.sample_interval {
                    last_sample = Instant::now();
                    self.autoscale_tick(a, &mut streaks, &mut last_action)?;
                }
            }
            std::thread::sleep(Duration::from_millis(2));
        }
    }
}

/// Appends frames arriving from producers to a queue partition; closes the
/// partition when every expected producer has signalled EOS. The expected
/// count is shared (and may grow while the job runs — `add_location`
/// registers new producers before they start).
///
/// Appends are batch-granular and zero-copy: a frame's refcounted bytes
/// (already the producer's cached encoding) become the log record
/// directly, and a same-host batch re-uses its cached wire encoding —
/// one encode per batch across the whole boundary.
fn ingest_loop(
    topic: Arc<Topic>,
    partition: usize,
    rx: Receiver<Msg>,
    expected: Arc<AtomicUsize>,
    metrics: Metrics,
) {
    let part = topic.partition(partition);
    let mut eos = 0usize;
    // A refused append (backpressure deadline expired, or a closed
    // partition during teardown) drops the batch at the boundary. That is
    // the load-shedding contract — but it must never be silent, so every
    // refusal is counted.
    let count_refused = |r: crate::error::Result<()>| {
        if r.is_err() {
            MetricsRegistry::add(&metrics.records_shed, 1);
        }
    };
    loop {
        match rx.recv() {
            Ok(Msg::Frame(bytes)) => {
                count_refused(part.append_shared(bytes));
            }
            Ok(Msg::Batch(batch)) => {
                count_refused(part.append_batch(&batch));
            }
            Ok(Msg::Columns(cb)) => {
                // decoupled edges deliver frames (OutPort encodes before a
                // framed target), so this is defensive — the columnar wire
                // bytes are the same row-format frame either way
                count_refused(part.append_shared(cb.wire()));
            }
            Ok(Msg::Watermark(wm)) => {
                // event-time sentinel: logged in-line with the data so
                // consumers replay watermarks in order (and recovery
                // re-reads them with the records they cover). Refusal
                // under backpressure is safe to swallow — watermarks are
                // promises, the next one supersedes this one.
                let _ = part.append_shared(watermark_record(&wm));
            }
            Ok(Msg::Epoch(_)) => {
                // a producer quiesced for a dynamic update; its replacement
                // inherits the registration — downstream units observe a
                // pause, not a marker and never a premature EOS
            }
            Ok(Msg::Eos) => {
                eos += 1;
                if eos >= expected.load(Ordering::SeqCst) {
                    part.close();
                    break;
                }
            }
            Err(_) => {
                // all senders gone (teardown without EOS): close so
                // consumers do not hang
                part.close();
                break;
            }
        }
    }
}

/// Name of the per-unit state topic that drain-and-handoff snapshots are
/// exchanged through.
fn unit_state_topic(unit: usize) -> String {
    format!("fu-state-u{unit}")
}

/// Opens (or creates) a unit's state topic. Pinned to the default
/// [`OverloadPolicy::Backpressure`] no matter what overload policy the
/// job runs its data topics under: checkpoint and handoff records must
/// never be shed, only slowed down.
fn state_topic(broker: &Broker, unit: usize) -> Result<Arc<Topic>> {
    broker.topic_with_policy(&unit_state_topic(unit), 1, OverloadPolicy::default())
}

/// Builds the fused executor chain for a stage from a job graph. Shared
/// with worker processes, which rebuild the graph locally and execute the
/// instances the deterministic plan assigns to them.
pub fn build_stage_ops(
    graph: &LogicalGraph,
    stage: &crate::graph::Stage,
    collector: &Arc<Collector>,
    metrics: &Metrics,
) -> Result<Vec<Box<dyn OpExec>>> {
    let mut ops: Vec<Box<dyn OpExec>> = Vec::new();
    for &oid in &stage.ops {
        match &graph.ops[oid].kind {
            OpKind::Source(_) => {} // driven by InputKind::Source
            OpKind::Map(f) => ops.push(Box::new(MapExec(f.clone()))),
            OpKind::Filter(f) => ops.push(Box::new(FilterExec(f.clone()))),
            OpKind::FilterMap(f) => ops.push(Box::new(FilterMapExec(f.clone()))),
            OpKind::FlatMap(f) => ops.push(Box::new(FlatMapExec(f.clone()))),
            OpKind::KeyBy(f) => ops.push(Box::new(KeyByExec(f.clone()))),
            // FilterMap semantics (the closure already emits the
            // finished Pair(key, value) or None), plus the key-hash
            // column the hash shuffle reads
            OpKind::KeyByFused(f) => ops.push(Box::new(KeyByFusedExec(f.clone()))),
            OpKind::Fold { init, step } => {
                ops.push(Box::new(FoldExec::new(init.clone(), step.clone())))
            }
            OpKind::Reduce { f } => ops.push(Box::new(ReduceExec::new(f.clone()))),
            // monomorphized columnar executor built by the typed layer's
            // captured factory (closes over the concrete types)
            OpKind::Columnar(c) => ops.push((c.factory)()),
            // merge happens in the channel wiring feeding this stage
            OpKind::Union => {}
            OpKind::Window { size, slide, agg } => {
                ops.push(Box::new(WindowExec::new(*size, *slide, agg.clone())))
            }
            OpKind::AssignTimestamps { ts, gen } => {
                ops.push(Box::new(AssignTsExec::new(ts.clone(), gen.clone())))
            }
            OpKind::EventWindow {
                ts,
                assigner,
                agg,
                lateness_ms,
                late_side,
            } => {
                let mut exec = EventWindowExec::new(ts.clone(), *assigner, agg.clone(), *lateness_ms)
                    .with_metrics(metrics.clone());
                if *late_side {
                    exec = exec.with_late_side(oid, collector.clone());
                }
                ops.push(Box::new(exec));
            }
            OpKind::SideTag(side) => ops.push(Box::new(SideTagExec(*side))),
            OpKind::IntervalJoin {
                ts_left,
                ts_right,
                lower_ms,
                upper_ms,
            } => ops.push(Box::new(
                IntervalJoinExec::new(ts_left.clone(), ts_right.clone(), *lower_ms, *upper_ms)
                    .with_metrics(metrics.clone()),
            )),
            OpKind::XlaMap {
                artifact,
                batch,
                in_dim,
            } => {
                let engine = crate::runtime::xla_exec::XlaEngine::global()?;
                let art = engine.load(artifact)?;
                ops.push(Box::new(XlaExec::new(art, *batch, *in_dim, metrics.clone())));
            }
            OpKind::Sink(kind) => ops.push(Box::new(SinkExec::new(
                *kind,
                oid,
                collector.clone(),
                metrics.clone(),
            ))),
        }
    }
    Ok(ops)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::config::{eval_cluster, fig2_cluster};
    use crate::graph::{SinkKind, SourceKind};
    use std::time::Duration;

    fn tiny_graph(layers: (&str, &str)) -> LogicalGraph {
        let mut g = LogicalGraph::default();
        g.push(
            OpKind::Source(SourceKind::Synthetic {
                total: 100,
                gen: Arc::new(|_, i| Value::I64(i as i64)),
                rate: None,
            }),
            layers.0.into(),
            None,
            "src",
        );
        g.push(
            OpKind::Sink(SinkKind::Count),
            layers.1.into(),
            None,
            "sink",
        );
        g
    }

    #[test]
    fn link_cache_reuses_uplinks_across_routes() {
        let cluster = fig2_cluster();
        let coord = Coordinator::new(cluster, JobConfig::default());
        let mut dep = coord.deploy(&tiny_graph(("edge", "cloud"))).unwrap();
        let (a, _) = dep.link_for_route("E1", "S1").unwrap();
        let (b, _) = dep.link_for_route("E1", "C1").unwrap();
        assert!(Arc::ptr_eq(&a, &b), "same egress hop -> same Link");
        let (c, _) = dep.link_for_route("E2", "C1").unwrap();
        assert!(!Arc::ptr_eq(&a, &c), "different egress hop -> different Link");
        let report = dep.wait().unwrap();
        assert_eq!(report.events_out, 100);
    }

    #[test]
    fn route_latencies_accumulate_per_hop() {
        let mut cluster = fig2_cluster();
        cluster.set_uniform_links(crate::netsim::LinkSpec {
            bandwidth_bps: None,
            latency: Duration::from_millis(7),
        });
        let coord = Coordinator::new(cluster, JobConfig::default());
        let mut dep = coord.deploy(&tiny_graph(("edge", "cloud"))).unwrap();
        let (_, lat1) = dep.link_for_route("E1", "S1").unwrap();
        let (_, lat2) = dep.link_for_route("E1", "C1").unwrap();
        assert_eq!(lat1, Duration::from_millis(7));
        assert_eq!(lat2, Duration::from_millis(14));
        dep.stop_sources();
        dep.wait().unwrap();
    }

    #[test]
    fn run_reports_plan_and_counts() {
        let coord = Coordinator::new(eval_cluster(None, Duration::ZERO), JobConfig::default());
        let report = coord.run(&tiny_graph(("edge", "cloud"))).unwrap();
        assert_eq!(report.events_in, 100);
        assert_eq!(report.events_out, 100);
        assert!(report.plan_description.contains("planner: FlowUnits"));
    }

    #[test]
    fn update_unit_unknown_unit_is_error() {
        let coord = Coordinator::new(
            eval_cluster(None, Duration::ZERO),
            JobConfig {
                decouple_units: true,
                ..Default::default()
            },
        );
        let g = tiny_graph(("edge", "cloud"));
        let mut dep = coord.deploy(&g).unwrap();
        assert!(dep.update_unit_at(99, g.clone()).is_err());
        let err = dep.update_unit("no-such-unit", g.clone()).unwrap_err();
        assert!(err.to_string().contains("unknown FlowUnit"));
        dep.wait().unwrap();
    }

    #[test]
    fn deployment_exposes_unit_names() {
        let coord = Coordinator::new(eval_cluster(None, Duration::ZERO), JobConfig::default());
        let dep = coord.deploy(&tiny_graph(("edge", "cloud"))).unwrap();
        assert_eq!(dep.unit_names(), vec!["edge", "cloud"]);
        dep.wait().unwrap();
    }
}
