//! The leader/coordinator: turns an execution plan into a running
//! deployment — channels, emulated links, queue topics, and one worker
//! thread per stage instance — then drives it to completion and collects
//! the report. Also implements the paper's *dynamic update* operations:
//! replacing a FlowUnit's logic and adding a geographical location while
//! the rest of the deployment keeps running (§III "Dynamic updates").

use crate::channels::{FanOut, Inbox, Msg, OutPort, Target};
use crate::config::ClusterSpec;
use crate::error::{Error, Result};
use crate::graph::{LogicalGraph, OpKind};
use crate::metrics::{Metrics, MetricsRegistry};
use crate::netsim::Link;
use crate::placement::{ancestor_at_layer, plan as make_plan, ExecPlan, PlannerKind};
use crate::queue::{Broker, QueueBroker, Topic};
use crate::runtime::{
    exec::{
        Collector, FilterExec, FlatMapExec, FoldExec, KeyByExec, MapExec, ReduceExec, SinkExec,
        WindowExec, XlaExec,
    },
    run_instance, InputKind, InstanceRuntime, OpExec, SourceRuntime,
};
use crate::topology::LocationId;
use crate::value::Value;
use std::collections::{BTreeMap, HashMap};
use std::sync::atomic::{AtomicBool, AtomicUsize, Ordering};
use std::sync::mpsc::{sync_channel, Receiver, SyncSender};
use std::sync::Arc;
use std::time::{Duration, Instant};

/// Job-level configuration.
#[derive(Debug, Clone)]
pub struct JobConfig {
    /// Deployment strategy.
    pub planner: PlannerKind,
    /// Enabled locations (empty ⇒ all locations of the root zone).
    pub locations: Vec<LocationId>,
    /// Events per batch on the hot path.
    pub batch_size: usize,
    /// Bound (in batches) of instance inboxes.
    pub channel_capacity: usize,
    /// Route FlowUnit-boundary edges through the queue substrate
    /// (required for dynamic updates; FlowUnits planner only).
    pub decouple_units: bool,
    /// Directory for durable queue segments (None ⇒ in-memory queues).
    pub queue_dir: Option<std::path::PathBuf>,
    /// Queue consumer poll timeout.
    pub poll_timeout: Duration,
}

impl Default for JobConfig {
    fn default() -> Self {
        JobConfig {
            planner: PlannerKind::FlowUnits,
            locations: Vec::new(),
            batch_size: 512,
            channel_capacity: 64,
            decouple_units: false,
            queue_dir: None,
            poll_timeout: Duration::from_millis(50),
        }
    }
}

/// Final report of a completed job.
#[derive(Debug)]
pub struct JobReport {
    /// Wall-clock execution time (sources started → all sinks flushed).
    pub wall_time: Duration,
    /// Events produced by sources.
    pub events_in: u64,
    /// Events delivered to sinks.
    pub events_out: u64,
    /// Values gathered by `Collect` sinks.
    pub collected: Vec<Value>,
    /// Bytes that traversed emulated links.
    pub net_bytes: u64,
    /// Events that crossed a zone boundary.
    pub zone_crossings: u64,
    /// Wire encodes actually performed (encode-once: at most one per
    /// batch, no matter how many edges it crossed).
    pub wire_encodes: u64,
    /// Plan summary (stages → per-zone instance counts).
    pub plan_description: String,
    /// Full metrics registry snapshot.
    pub metrics: Metrics,
}

impl JobReport {
    /// Renders the report.
    pub fn render(&self) -> String {
        format!(
            "{}\n{}",
            self.plan_description,
            self.metrics.render(self.wall_time)
        )
    }
}

/// Coordinator: plans and launches jobs on a cluster.
pub struct Coordinator {
    /// Cluster description.
    pub cluster: ClusterSpec,
    /// Job configuration.
    pub config: JobConfig,
}

impl Coordinator {
    /// Creates a coordinator.
    pub fn new(cluster: ClusterSpec, config: JobConfig) -> Self {
        Coordinator { cluster, config }
    }

    /// Plans, deploys, runs to completion, and reports.
    pub fn run(&self, graph: &LogicalGraph) -> Result<JobReport> {
        let dep = self.deploy(graph)?;
        dep.wait()
    }

    /// Plans and launches a deployment, returning a handle that supports
    /// dynamic updates before [`Deployment::wait`].
    pub fn deploy(&self, graph: &LogicalGraph) -> Result<Deployment> {
        let decouple = self.config.decouple_units && self.config.planner == PlannerKind::FlowUnits;
        let plan = make_plan(
            graph,
            &self.cluster,
            self.config.planner,
            &self.config.locations,
            decouple,
        )?;
        Deployment::launch(
            graph.clone(),
            self.cluster.clone(),
            self.config.clone(),
            plan,
        )
    }
}

/// Key of a decoupling topic: (downstream stage, downstream zone).
type TopicKey = (usize, String);

struct TopicRuntime {
    topic: Arc<Topic>,
    /// Ingest channel per partition (producers send frames here, through
    /// the emulated link; an ingest thread appends them to the log).
    ingest: Vec<SyncSender<Msg>>,
    /// Number of producers expected to EOS each partition; dynamic
    /// `add_location` increments this while the deployment runs.
    expected_producers: Arc<AtomicUsize>,
}

/// A running deployment.
pub struct Deployment {
    graph: LogicalGraph,
    cluster: ClusterSpec,
    config: JobConfig,
    plan: ExecPlan,
    metrics: Metrics,
    collector: Arc<Collector>,
    links: HashMap<String, Arc<Link<Msg>>>,
    broker: Option<Broker>,
    topics: HashMap<TopicKey, TopicRuntime>,
    /// Worker threads grouped by FlowUnit index.
    unit_threads: BTreeMap<usize, Vec<std::thread::JoinHandle<u64>>>,
    ingest_threads: Vec<std::thread::JoinHandle<()>>,
    source_stop: Arc<AtomicBool>,
    unit_stops: BTreeMap<usize, Arc<AtomicBool>>,
    started: Instant,
}

impl Deployment {
    fn launch(
        graph: LogicalGraph,
        cluster: ClusterSpec,
        config: JobConfig,
        plan: ExecPlan,
    ) -> Result<Deployment> {
        let metrics = MetricsRegistry::new();
        let broker = if plan.edges.iter().any(|e| e.decoupled) {
            Some(match &config.queue_dir {
                Some(d) => QueueBroker::durable(d, Some(metrics.clone()))?,
                None => QueueBroker::in_memory(Some(metrics.clone())),
            })
        } else {
            None
        };
        let mut dep = Deployment {
            graph,
            cluster,
            config,
            plan,
            metrics: metrics.clone(),
            collector: Arc::new(Collector::default()),
            links: HashMap::new(),
            broker,
            topics: HashMap::new(),
            unit_threads: BTreeMap::new(),
            ingest_threads: Vec::new(),
            source_stop: Arc::new(AtomicBool::new(false)),
            unit_stops: BTreeMap::new(),
            started: Instant::now(),
        };
        dep.wire_and_spawn()?;
        Ok(dep)
    }

    /// Returns (creating if needed) the shared uplink for the route
    /// `za → zb` plus the route latency to stamp on each frame.
    fn link_for_route(&mut self, za: &str, zb: &str) -> Result<(Arc<Link<Msg>>, Duration)> {
        if za == zb {
            let name = format!("intra-{za}");
            let link = self
                .links
                .entry(name.clone())
                .or_insert_with(|| Link::new(&name, None, false, Some(self.metrics.clone())))
                .clone();
            return Ok((link, Duration::ZERO));
        }
        let spec = crate::placement::route_spec(&self.cluster, za, zb)?;
        // links are keyed by the route's egress hop so that all routes
        // leaving a zone contend for the same uplink
        let first_hop = first_hop_of_route(&self.cluster, za, zb)?;
        let name = format!("up-{}->{}", first_hop.0, first_hop.1);
        let needs_delay = !spec.latency.is_zero();
        let metrics = self.metrics.clone();
        let link = self
            .links
            .entry(name.clone())
            .or_insert_with(|| Link::new(&name, spec.bandwidth_bps, needs_delay, Some(metrics)))
            .clone();
        Ok((link, spec.latency))
    }

    fn wire_and_spawn(&mut self) -> Result<()> {
        let all = self.plan.instances.clone();
        self.spawn_set(&all, true)
    }

    /// Wires and spawns a *set* of planned instances. At launch the set is
    /// the whole plan; dynamic updates pass subsets (a FlowUnit's instances
    /// for `update_unit`, a new zone's instances for `add_location`).
    ///
    /// Direct (non-queue) edges may only connect instances *inside* the
    /// set — under the FlowUnits planner intra-unit edges are same-zone, so
    /// any complete unit-zone subset satisfies this; violations are
    /// reported as errors rather than producing dangling channels.
    ///
    /// `register_producers`: count the set's producers toward the
    /// decoupling topics' expected-EOS totals. True for launch and
    /// `add_location` (genuinely new producers); false for `update_unit`
    /// (replacement instances inherit their predecessors' registration,
    /// which never signalled EOS).
    fn spawn_set(
        &mut self,
        set: &[crate::placement::InstancePlan],
        register_producers: bool,
    ) -> Result<()> {
        let plan = self.plan.clone();
        let topo = self.cluster.topology.clone();
        let in_set: std::collections::BTreeSet<usize> = set.iter().map(|i| i.id).collect();

        // --- pass 1: inboxes for direct-edge consumers in the set --------
        let mut inst_tx: HashMap<usize, SyncSender<Msg>> = HashMap::new();
        let mut inst_rx: HashMap<usize, Receiver<Msg>> = HashMap::new();
        for edge in &plan.edges {
            if edge.decoupled {
                continue;
            }
            for inst in plan.instances_of(edge.to_stage) {
                if !in_set.contains(&inst) || inst_tx.contains_key(&inst) {
                    continue;
                }
                let (tx, rx) = sync_channel(self.config.channel_capacity);
                inst_tx.insert(inst, tx);
                inst_rx.insert(inst, rx);
            }
        }

        // --- pass 2: topics (+ ingest threads) for decoupled edges -------
        // created once; subset respawns reuse the existing topics
        for edge in &plan.edges {
            if !edge.decoupled {
                continue;
            }
            let broker = self.broker.as_ref().expect("broker exists when decoupled");
            let mut by_zone: BTreeMap<String, Vec<usize>> = BTreeMap::new();
            for inst in plan.instances_of(edge.to_stage) {
                by_zone
                    .entry(plan.instances[inst].zone.clone())
                    .or_default()
                    .push(inst);
            }
            for (zone, insts) in by_zone {
                if self.topics.contains_key(&(edge.to_stage, zone.clone())) {
                    continue;
                }
                let name = format!("fu-s{}-{zone}", edge.to_stage);
                let topic = broker.topic(&name, insts.len())?;
                let expected = Arc::new(AtomicUsize::new(0));
                let mut ingest = Vec::new();
                for p in 0..insts.len() {
                    let (tx, rx) = sync_channel::<Msg>(self.config.channel_capacity);
                    ingest.push(tx);
                    let topic2 = topic.clone();
                    let expected2 = expected.clone();
                    let h = std::thread::Builder::new()
                        .name(format!("ingest-{name}-{p}"))
                        .spawn(move || ingest_loop(topic2, p, rx, expected2))
                        .expect("spawn ingest thread");
                    self.ingest_threads.push(h);
                }
                self.topics.insert(
                    (edge.to_stage, zone),
                    TopicRuntime {
                        topic,
                        ingest,
                        expected_producers: expected,
                    },
                );
            }
        }

        // --- pass 3: validation + producer accounting ---------------------
        let mut producer_count: HashMap<usize, usize> = HashMap::new();
        for edge in &plan.edges {
            if edge.decoupled {
                if register_producers {
                    for from in plan.instances_of(edge.from_stage) {
                        if !in_set.contains(&from) {
                            continue;
                        }
                        let fz = &plan.instances[from].zone;
                        let tz = ancestor_at_layer(&topo, fz, &plan.stages[edge.to_stage].layer)
                            .ok_or_else(|| {
                                Error::Placement(format!(
                                    "no ancestor zone for {fz} on decoupled edge"
                                ))
                            })?;
                        if let Some(tr) = self.topics.get(&(edge.to_stage, tz.clone())) {
                            tr.topic.register_producer();
                            tr.expected_producers.fetch_add(1, Ordering::SeqCst);
                        }
                    }
                }
                continue;
            }
            for from in plan.instances_of(edge.from_stage) {
                for t in plan.allowed_targets(&topo, from, edge) {
                    match (in_set.contains(&from), in_set.contains(&t)) {
                        (true, true) => *producer_count.entry(t).or_default() += 1,
                        (false, false) => {}
                        _ => {
                            return Err(Error::Runtime(format!(
                                "direct edge {}->{} crosses the respawn boundary \
                                 (instances {from} -> {t}); the affected FlowUnit \
                                 boundary must be decoupled",
                                edge.from_stage, edge.to_stage
                            )))
                        }
                    }
                }
            }
        }

        // --- pass 4: spawn instance threads -------------------------------
        for inst in set.to_vec() {
            let stage = plan.stages[inst.stage].clone();
            // input — the planner guarantees a fan-in stage's incoming
            // edges are either all direct or all queue-decoupled
            let incoming_decoupled = plan
                .edges
                .iter()
                .any(|e| e.to_stage == inst.stage && e.decoupled);
            let input = if stage.is_source() {
                let OpKind::Source(kind) = &self.graph.ops[stage.ops[0]].kind else {
                    return Err(Error::Runtime("stage 0 op is not a source".into()));
                };
                InputKind::Source(SourceRuntime {
                    kind: kind.clone(),
                    share: inst.source_share.unwrap_or((0, 1)),
                    batch_size: self.config.batch_size,
                    stop: self.source_stop.clone(),
                })
            } else if incoming_decoupled {
                let key = (inst.stage, inst.zone.clone());
                let tr = self
                    .topics
                    .get(&key)
                    .ok_or_else(|| Error::Runtime(format!("no topic for {key:?}")))?;
                // partition index = position among the zone's instances
                let peers: Vec<usize> = plan
                    .instances
                    .iter()
                    .filter(|i| i.stage == inst.stage && i.zone == inst.zone)
                    .map(|i| i.id)
                    .collect();
                let partition = peers.iter().position(|&p| p == inst.id).unwrap();
                let unit_stop = self
                    .unit_stops
                    .entry(stage.unit_index)
                    .or_insert_with(|| Arc::new(AtomicBool::new(false)))
                    .clone();
                InputKind::Queue {
                    topic: tr.topic.clone(),
                    partition,
                    group: format!("unit{}-{}", stage.unit_index, inst.zone),
                    poll_timeout: self.config.poll_timeout,
                    stop: unit_stop,
                }
            } else {
                let rx = inst_rx.remove(&inst.id).ok_or_else(|| {
                    Error::Runtime(format!("instance {} missing inbox", inst.id))
                })?;
                InputKind::Inbox(Inbox::new(rx, *producer_count.get(&inst.id).unwrap_or(&0)))
            };

            // output: one port per outgoing stage edge (a `split` stream
            // has several; every edge receives every batch)
            let mut ports = Vec::new();
            for edge in plan.edges.iter().filter(|e| e.from_stage == inst.stage) {
                let port = if edge.decoupled {
                    let tz = ancestor_at_layer(
                        &topo,
                        &inst.zone,
                        &plan.stages[edge.to_stage].layer,
                    )
                    .ok_or_else(|| Error::Placement("no ancestor for decoupled edge".into()))?;
                    let (link, latency) = self.link_for_route(&inst.zone, &tz)?;
                    let tr = self.topics.get(&(edge.to_stage, tz.clone())).ok_or_else(|| {
                        Error::Placement(format!(
                            "no queue topic for stage {} in zone {tz} (no consumer \
                             instance was planned there)",
                            edge.to_stage
                        ))
                    })?;
                    let crossing = inst.zone != tz;
                    let targets = tr
                        .ingest
                        .iter()
                        .map(|tx| Target {
                            tx: tx.clone(),
                            link: Some(link.clone()),
                            latency,
                            crossing,
                        })
                        .collect();
                    OutPort::new(
                        targets,
                        edge.routing,
                        self.config.batch_size,
                        Some(self.metrics.clone()),
                    )
                } else {
                    let mut targets = Vec::new();
                    for t in plan.allowed_targets(&topo, inst.id, edge) {
                        let tgt = &plan.instances[t];
                        let (link, latency) = if tgt.host == inst.host {
                            (None, Duration::ZERO)
                        } else {
                            let (l, lat) = self.link_for_route(&inst.zone, &tgt.zone)?;
                            (Some(l), lat)
                        };
                        targets.push(Target {
                            tx: inst_tx[&t].clone(),
                            link,
                            latency,
                            crossing: tgt.zone != inst.zone,
                        });
                    }
                    OutPort::new(
                        targets,
                        edge.routing,
                        self.config.batch_size,
                        Some(self.metrics.clone()),
                    )
                };
                ports.push(port);
            }
            let outputs = FanOut::new(ports);

            // fused operator chain (source op handled by InputKind)
            let ops = self.build_ops(&stage)?;
            let metrics = self.metrics.clone();
            let rt = InstanceRuntime {
                id: inst.id,
                ops,
                input,
                outputs,
                metrics,
            };
            let h = std::thread::Builder::new()
                .name(format!("inst-{}-s{}-{}", inst.id, inst.stage, inst.host))
                .spawn(move || run_instance(rt))
                .expect("spawn instance thread");
            self.unit_threads
                .entry(stage.unit_index)
                .or_default()
                .push(h);
        }
        drop(inst_tx); // senders live only inside targets now
        Ok(())
    }

    /// Builds the fused executor chain for a stage from the job graph.
    fn build_ops(&self, stage: &crate::graph::Stage) -> Result<Vec<Box<dyn OpExec>>> {
        let mut ops: Vec<Box<dyn OpExec>> = Vec::new();
        for &oid in &stage.ops {
            match &self.graph.ops[oid].kind {
                OpKind::Source(_) => {} // driven by InputKind::Source
                OpKind::Map(f) => ops.push(Box::new(MapExec(f.clone()))),
                OpKind::Filter(f) => ops.push(Box::new(FilterExec(f.clone()))),
                OpKind::FlatMap(f) => ops.push(Box::new(FlatMapExec(f.clone()))),
                OpKind::KeyBy(f) => ops.push(Box::new(KeyByExec(f.clone()))),
                OpKind::Fold { init, step } => {
                    ops.push(Box::new(FoldExec::new(init.clone(), step.clone())))
                }
                OpKind::Reduce { f } => ops.push(Box::new(ReduceExec::new(f.clone()))),
                // merge happens in the channel wiring feeding this stage
                OpKind::Union => {}
                OpKind::Window { size, slide, agg } => {
                    ops.push(Box::new(WindowExec::new(*size, *slide, agg.clone())))
                }
                OpKind::XlaMap {
                    artifact,
                    batch,
                    in_dim,
                } => {
                    let engine = crate::runtime::xla_exec::XlaEngine::global()?;
                    let art = engine.load(artifact)?;
                    ops.push(Box::new(XlaExec::new(
                        art,
                        *batch,
                        *in_dim,
                        self.metrics.clone(),
                    )));
                }
                OpKind::Sink(kind) => ops.push(Box::new(SinkExec::new(
                    *kind,
                    self.collector.clone(),
                    self.metrics.clone(),
                ))),
            }
        }
        Ok(ops)
    }

    /// Signals all sources to stop after their current batch (used with
    /// unbounded/rate-limited sources before [`wait`](Self::wait)).
    pub fn stop_sources(&self) {
        self.source_stop.store(true, Ordering::SeqCst);
    }

    /// The execution plan.
    pub fn plan(&self) -> &ExecPlan {
        &self.plan
    }

    /// The live metrics registry.
    pub fn metrics(&self) -> Metrics {
        self.metrics.clone()
    }

    /// The deployed FlowUnit names, in unit-id order.
    pub fn unit_names(&self) -> Vec<String> {
        self.graph.unit_names()
    }

    /// **Dynamic update**: replaces the logic of the FlowUnit named
    /// `unit` with the corresponding operators of `new_graph`, without
    /// stopping any other unit. See [`Deployment::update_unit_at`].
    pub fn update_unit(&mut self, unit: &str, new_graph: LogicalGraph) -> Result<()> {
        let idx = self.graph.unit_named(unit).ok_or_else(|| {
            Error::Runtime(format!(
                "unknown FlowUnit '{unit}' (deployed units: {})",
                self.unit_names().join(", ")
            ))
        })?;
        self.update_unit_at(idx, new_graph)
    }

    /// **Dynamic update** (index form): replaces the logic of FlowUnit
    /// `unit` with the corresponding operators of `new_graph`, without
    /// stopping any other unit. Requirements (checked): every edge into
    /// the unit is decoupled through the queue substrate, and `new_graph`
    /// produces the same unit table and stage partitioning (so plans stay
    /// aligned).
    ///
    /// Consumers of the unit commit their queue offsets, drain held state
    /// downstream, and exit; replacement instances resume from the
    /// committed offsets with the new logic. Producers upstream keep
    /// appending throughout — zero disruption outside the unit.
    pub fn update_unit_at(&mut self, unit: usize, new_graph: LogicalGraph) -> Result<()> {
        let old_stages = self.graph.stages();
        let new_stages = new_graph.stages();
        if old_stages.len() != new_stages.len() {
            return Err(Error::Runtime(format!(
                "update_unit: stage count changed ({} -> {})",
                old_stages.len(),
                new_stages.len()
            )));
        }
        for (a, b) in old_stages.iter().zip(&new_stages) {
            if a.layer != b.layer || a.unit_index != b.unit_index || a.ops != b.ops {
                return Err(Error::Runtime(format!(
                    "update_unit: stage {} shape changed; updates must preserve the graph structure",
                    a.index
                )));
            }
        }
        if self.graph.units.len() != new_graph.units.len()
            || self.graph.units.iter().zip(&new_graph.units).any(|(a, b)| {
                a.name != b.name
                    || a.layer != b.layer
                    || a.constraint != b.constraint
                    || a.replication != b.replication
            })
        {
            return Err(Error::Runtime(
                "update_unit: FlowUnit table changed (name/layer/constraint/replication); \
                 updates replace logic only — placement-affecting changes need a redeploy"
                    .into(),
            ));
        }
        let unit_stages: std::collections::BTreeSet<usize> = self
            .plan
            .stages
            .iter()
            .filter(|s| s.unit_index == unit)
            .map(|s| s.index)
            .collect();
        if unit_stages.is_empty() {
            return Err(Error::Runtime(format!("unknown unit {unit}")));
        }
        if self
            .plan
            .stages
            .iter()
            .any(|s| unit_stages.contains(&s.index) && s.is_source())
        {
            return Err(Error::Runtime("cannot update the source unit".into()));
        }
        let incoming: Vec<&crate::placement::EdgePlan> = self
            .plan
            .edges
            .iter()
            .filter(|e| unit_stages.contains(&e.to_stage))
            .collect();
        if !incoming.iter().any(|e| !unit_stages.contains(&e.from_stage)) {
            return Err(Error::Runtime("cannot update the source unit".into()));
        }
        // Every edge into the unit — boundary AND internal — must be
        // queue-decoupled: an inbox-fed stage inside the unit would exit
        // through the normal sender-drop path during the swap and leak a
        // premature EOS into downstream topics.
        if incoming.iter().any(|e| !e.decoupled) {
            return Err(Error::Runtime(
                "update_unit requires every edge into the unit (including intra-unit stage \
                 edges) to be decoupled (JobConfig::decouple_units); multi-stage units with \
                 direct internal channels cannot be hot-swapped"
                    .into(),
            ));
        }

        // 1. stop the unit's consumers; they commit, drain, and exit
        let stop = self
            .unit_stops
            .get(&unit)
            .ok_or_else(|| Error::Runtime("unit has no queue consumers".into()))?
            .clone();
        stop.store(true, Ordering::SeqCst);
        let handles = self.unit_threads.remove(&unit).unwrap_or_default();
        for h in handles {
            let _ = h.join();
        }

        // 2. swap the graph (same shape, new closures/artifacts)
        self.graph = new_graph;

        // 3. relaunch the unit's instances with fresh stop flag
        let fresh = Arc::new(AtomicBool::new(false));
        self.unit_stops.insert(unit, fresh);
        let insts: Vec<_> = self
            .plan
            .instances
            .iter()
            .filter(|i| self.plan.stages[i.stage].unit_index == unit)
            .cloned()
            .collect();
        self.spawn_set(&insts, false)?;
        Ok(())
    }

    /// **Dynamic update**: enables a new location while the job runs.
    /// Supported case (the paper's E5 example): the new location adds
    /// instances only to the *source unit*, whose output boundary is
    /// decoupled, and the downstream zones it feeds are already active.
    pub fn add_location(&mut self, loc: &str) -> Result<()> {
        if self.plan.locations.iter().any(|l| l == loc) {
            return Err(Error::Runtime(format!("location '{loc}' already enabled")));
        }
        let mut locations = self.plan.locations.clone();
        locations.push(loc.to_string());
        let decouple = self.plan.edges.iter().any(|e| e.decoupled);
        let new_plan = make_plan(
            &self.graph,
            &self.cluster,
            self.plan.planner,
            &locations,
            decouple,
        )?;
        // diff: instances present in new plan but not in the old one
        let old_keys: std::collections::BTreeSet<(usize, String, usize)> = self
            .plan
            .instances
            .iter()
            .map(|i| (i.stage, i.host.clone(), i.core))
            .collect();
        let added: Vec<_> = new_plan
            .instances
            .iter()
            .filter(|i| !old_keys.contains(&(i.stage, i.host.clone(), i.core)))
            .cloned()
            .collect();
        if added.is_empty() {
            return Err(Error::Runtime(format!(
                "location '{loc}' adds no new instances"
            )));
        }
        // units that contain a source stage may grow at a new location;
        // everything downstream must already be active
        let source_units: std::collections::BTreeSet<usize> = new_plan
            .stages
            .iter()
            .filter(|s| s.is_source())
            .map(|s| s.unit_index)
            .collect();
        for a in &added {
            let unit = new_plan.stages[a.stage].unit_index;
            if !source_units.contains(&unit) {
                return Err(Error::Runtime(format!(
                    "add_location currently supports new instances in source units only \
                     (instance on stage {} is in unit {unit}); zone '{}' must already be active",
                    a.stage, a.zone
                )));
            }
            for e in new_plan.edges.iter().filter(|e| e.from_stage == a.stage) {
                if e.unit_boundary && !e.decoupled {
                    return Err(Error::Runtime(
                        "add_location requires decoupled unit boundaries".into(),
                    ));
                }
                if e.decoupled {
                    // the new producers must feed topics that already exist
                    // (i.e. their downstream zone is already active)
                    let tz = ancestor_at_layer(
                        &self.cluster.topology,
                        &a.zone,
                        &new_plan.stages[e.to_stage].layer,
                    )
                    .ok_or_else(|| Error::Runtime("new zone has no ancestor".into()))?;
                    if !self.topics.contains_key(&(e.to_stage, tz.clone())) {
                        return Err(Error::Runtime(format!(
                            "downstream zone '{tz}' is not active; adding whole new branches is unsupported"
                        )));
                    }
                }
            }
        }
        // adopt the new plan's locations and instance list (ids realign:
        // we keep the old plan and append the new instances with fresh ids)
        let mut adopted = Vec::new();
        for mut a in added {
            a.id = self.plan.instances.len();
            self.plan.instances.push(a.clone());
            adopted.push(a);
        }
        self.plan.locations = locations;
        self.spawn_set(&adopted, true)?;
        Ok(())
    }

    /// Waits for the job to finish, tears down links, and reports.
    ///
    /// Fail-fast semantics: if any instance thread panicked (a user
    /// closure fault), the first failed join surfaces as
    /// `Error::Runtime("instance thread panicked")` immediately;
    /// downstream threads of the failed unit are abandoned to process
    /// teardown rather than joined (they may be blocked on an EOS that
    /// will never arrive).
    pub fn wait(mut self) -> Result<JobReport> {
        for (_, handles) in std::mem::take(&mut self.unit_threads) {
            for h in handles {
                h.join().map_err(|_| Error::Runtime("instance thread panicked".into()))?;
            }
        }
        for h in std::mem::take(&mut self.ingest_threads) {
            let _ = h.join();
        }
        for link in self.links.values() {
            link.shutdown();
        }
        let wall_time = self.started.elapsed();
        let m = &self.metrics;
        Ok(JobReport {
            wall_time,
            events_in: m.events_in.load(Ordering::Relaxed),
            events_out: m.events_out.load(Ordering::Relaxed),
            collected: std::mem::take(&mut *self.collector.values.lock().unwrap()),
            net_bytes: m.net_bytes.load(Ordering::Relaxed),
            zone_crossings: m.zone_crossings.load(Ordering::Relaxed),
            wire_encodes: m.batch_encodes.load(Ordering::Relaxed),
            plan_description: self.plan.describe(&self.graph),
            metrics: self.metrics.clone(),
        })
    }
}

/// Appends frames arriving from producers to a queue partition; closes the
/// partition when every expected producer has signalled EOS. The expected
/// count is shared (and may grow while the job runs — `add_location`
/// registers new producers before they start).
///
/// Appends are batch-granular and zero-copy: a frame's refcounted bytes
/// (already the producer's cached encoding) become the log record
/// directly, and a same-host batch re-uses its cached wire encoding —
/// one encode per batch across the whole boundary.
fn ingest_loop(topic: Arc<Topic>, partition: usize, rx: Receiver<Msg>, expected: Arc<AtomicUsize>) {
    let part = topic.partition(partition);
    let mut eos = 0usize;
    loop {
        match rx.recv() {
            Ok(Msg::Frame(bytes)) => {
                let _ = part.append_shared(bytes);
            }
            Ok(Msg::Batch(batch)) => {
                let _ = part.append_batch(&batch);
            }
            Ok(Msg::Eos) => {
                eos += 1;
                if eos >= expected.load(Ordering::SeqCst) {
                    part.close();
                    break;
                }
            }
            Err(_) => {
                // all senders gone (teardown without EOS): close so
                // consumers do not hang
                part.close();
                break;
            }
        }
    }
}

/// First hop of the tree route from `za` toward `zb` (used to key shared
/// uplinks).
fn first_hop_of_route(cluster: &ClusterSpec, za: &str, zb: &str) -> Result<(String, String)> {
    let topo = &cluster.topology;
    // ascend from za; if zb is not on that path, the first hop is still
    // za -> parent(za) (all inter-zone routes leave through the uplink),
    // except when za is an ancestor of zb — then descend toward zb.
    if ancestor_at_layer(topo, zb, &topo.zones[za].layer).as_deref() == Some(za) {
        // za is an ancestor of zb: first hop descends toward zb
        let mut cur = zb.to_string();
        loop {
            let parent = topo.zones[&cur].parent.clone().ok_or_else(|| {
                Error::Topology(format!("no path from {za} down to {zb}"))
            })?;
            if parent == za {
                return Ok((za.to_string(), cur));
            }
            cur = parent;
        }
    }
    let parent = topo.zones[za]
        .parent
        .clone()
        .ok_or_else(|| Error::Topology(format!("root zone {za} has no uplink")))?;
    Ok((za.to_string(), parent))
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::config::{eval_cluster, fig2_cluster};
    use crate::graph::{SinkKind, SourceKind};
    use std::time::Duration;

    fn tiny_graph(layers: (&str, &str)) -> LogicalGraph {
        let mut g = LogicalGraph::default();
        g.push(
            OpKind::Source(SourceKind::Synthetic {
                total: 100,
                gen: Arc::new(|_, i| Value::I64(i as i64)),
                rate: None,
            }),
            layers.0.into(),
            None,
            "src",
        );
        g.push(
            OpKind::Sink(SinkKind::Count),
            layers.1.into(),
            None,
            "sink",
        );
        g
    }

    #[test]
    fn first_hop_keys_shared_uplinks() {
        let cluster = fig2_cluster();
        // upward routes leave through the child's uplink
        assert_eq!(
            first_hop_of_route(&cluster, "E1", "S1").unwrap(),
            ("E1".into(), "S1".into())
        );
        assert_eq!(
            first_hop_of_route(&cluster, "E1", "C1").unwrap(),
            ("E1".into(), "S1".into()),
            "E1->C1 and E1->S1 share the E1 uplink"
        );
        // sibling routes also leave through the uplink
        assert_eq!(
            first_hop_of_route(&cluster, "E1", "E4").unwrap(),
            ("E1".into(), "S1".into())
        );
        // downward route from an ancestor descends toward the target
        assert_eq!(
            first_hop_of_route(&cluster, "C1", "E1").unwrap(),
            ("C1".into(), "S1".into())
        );
    }

    #[test]
    fn link_cache_reuses_uplinks_across_routes() {
        let cluster = fig2_cluster();
        let coord = Coordinator::new(cluster, JobConfig::default());
        let mut dep = coord.deploy(&tiny_graph(("edge", "cloud"))).unwrap();
        let (a, _) = dep.link_for_route("E1", "S1").unwrap();
        let (b, _) = dep.link_for_route("E1", "C1").unwrap();
        assert!(Arc::ptr_eq(&a, &b), "same egress hop -> same Link");
        let (c, _) = dep.link_for_route("E2", "C1").unwrap();
        assert!(!Arc::ptr_eq(&a, &c), "different egress hop -> different Link");
        let report = dep.wait().unwrap();
        assert_eq!(report.events_out, 100);
    }

    #[test]
    fn route_latencies_accumulate_per_hop() {
        let mut cluster = fig2_cluster();
        cluster.set_uniform_links(crate::netsim::LinkSpec {
            bandwidth_bps: None,
            latency: Duration::from_millis(7),
        });
        let coord = Coordinator::new(cluster, JobConfig::default());
        let mut dep = coord.deploy(&tiny_graph(("edge", "cloud"))).unwrap();
        let (_, lat1) = dep.link_for_route("E1", "S1").unwrap();
        let (_, lat2) = dep.link_for_route("E1", "C1").unwrap();
        assert_eq!(lat1, Duration::from_millis(7));
        assert_eq!(lat2, Duration::from_millis(14));
        dep.stop_sources();
        dep.wait().unwrap();
    }

    #[test]
    fn run_reports_plan_and_counts() {
        let coord = Coordinator::new(eval_cluster(None, Duration::ZERO), JobConfig::default());
        let report = coord.run(&tiny_graph(("edge", "cloud"))).unwrap();
        assert_eq!(report.events_in, 100);
        assert_eq!(report.events_out, 100);
        assert!(report.plan_description.contains("planner: FlowUnits"));
    }

    #[test]
    fn update_unit_unknown_unit_is_error() {
        let coord = Coordinator::new(
            eval_cluster(None, Duration::ZERO),
            JobConfig {
                decouple_units: true,
                ..Default::default()
            },
        );
        let g = tiny_graph(("edge", "cloud"));
        let mut dep = coord.deploy(&g).unwrap();
        assert!(dep.update_unit_at(99, g.clone()).is_err());
        let err = dep.update_unit("no-such-unit", g.clone()).unwrap_err();
        assert!(err.to_string().contains("unknown FlowUnit"));
        dep.wait().unwrap();
    }

    #[test]
    fn deployment_exposes_unit_names() {
        let coord = Coordinator::new(eval_cluster(None, Duration::ZERO), JobConfig::default());
        let dep = coord.deploy(&tiny_graph(("edge", "cloud"))).unwrap();
        assert_eq!(dep.unit_names(), vec!["edge", "cloud"]);
        dep.wait().unwrap();
    }
}
