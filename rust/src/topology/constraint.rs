//! Host capabilities and operator requirement constraints (paper §III).
//!
//! Capabilities are attribute-value pairs (`n_cpu = 8`, `gpu = yes`,
//! `memory = 16GB`). Requirements are conjunctions of Boolean predicates
//! over those attributes (`n_cpu >= 4 && gpu = yes`). A host satisfies a
//! requirement iff *all* predicates evaluate to true on its capabilities.

use crate::error::{Error, Result};
use std::collections::BTreeMap;
use std::fmt;

/// A capability value.
#[derive(Debug, Clone, PartialEq)]
pub enum CapValue {
    /// Integer attribute (`n_cpu = 8`).
    Int(i64),
    /// Float attribute.
    Float(f64),
    /// Boolean attribute (`gpu = yes`).
    Bool(bool),
    /// String attribute (`arch = arm64`). `16GB`-style quantities are
    /// normalised to bytes at parse time when the suffix is recognised.
    Str(String),
}

impl CapValue {
    /// Parses a capability value literal: `yes/no/true/false`, integers,
    /// floats, size suffixes (`16GB` → bytes), otherwise a string.
    pub fn parse(s: &str) -> CapValue {
        let t = s.trim();
        match t.to_ascii_lowercase().as_str() {
            "yes" | "true" => return CapValue::Bool(true),
            "no" | "false" => return CapValue::Bool(false),
            _ => {}
        }
        // size suffixes
        for (suffix, mult) in [
            ("tb", 1u64 << 40),
            ("gb", 1 << 30),
            ("mb", 1 << 20),
            ("kb", 1 << 10),
        ] {
            let lower = t.to_ascii_lowercase();
            if let Some(num) = lower.strip_suffix(suffix) {
                if let Ok(n) = num.trim().parse::<f64>() {
                    return CapValue::Int((n * mult as f64) as i64);
                }
            }
        }
        if let Ok(i) = t.parse::<i64>() {
            return CapValue::Int(i);
        }
        if let Ok(f) = t.parse::<f64>() {
            return CapValue::Float(f);
        }
        CapValue::Str(t.to_string())
    }

    fn as_f64(&self) -> Option<f64> {
        match self {
            CapValue::Int(i) => Some(*i as f64),
            CapValue::Float(f) => Some(*f),
            _ => None,
        }
    }
}

impl fmt::Display for CapValue {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        match self {
            CapValue::Int(i) => write!(f, "{i}"),
            CapValue::Float(x) => write!(f, "{x}"),
            CapValue::Bool(b) => write!(f, "{}", if *b { "yes" } else { "no" }),
            CapValue::Str(s) => write!(f, "{s}"),
        }
    }
}

/// A host's capability profile.
#[derive(Debug, Clone, Default, PartialEq)]
pub struct Capabilities {
    attrs: BTreeMap<String, CapValue>,
}

impl Capabilities {
    /// Builds a profile from `(name, value)` pairs.
    pub fn of(pairs: &[(&str, CapValue)]) -> Self {
        Capabilities {
            attrs: pairs
                .iter()
                .map(|(k, v)| (k.to_string(), v.clone()))
                .collect(),
        }
    }

    /// Inserts/overwrites an attribute.
    pub fn set(&mut self, name: &str, value: CapValue) {
        self.attrs.insert(name.to_string(), value);
    }

    /// Looks up an attribute.
    pub fn get(&self, name: &str) -> Option<&CapValue> {
        self.attrs.get(name)
    }

    /// Iterates over attributes.
    pub fn iter(&self) -> impl Iterator<Item = (&String, &CapValue)> {
        self.attrs.iter()
    }
}

/// Comparison operator in a predicate.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum RelOp {
    /// `=`
    Eq,
    /// `!=`
    Ne,
    /// `>=`
    Ge,
    /// `<=`
    Le,
    /// `>`
    Gt,
    /// `<`
    Lt,
}

impl fmt::Display for RelOp {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        let s = match self {
            RelOp::Eq => "=",
            RelOp::Ne => "!=",
            RelOp::Ge => ">=",
            RelOp::Le => "<=",
            RelOp::Gt => ">",
            RelOp::Lt => "<",
        };
        write!(f, "{s}")
    }
}

/// One predicate: `attr op value`.
#[derive(Debug, Clone, PartialEq)]
pub struct Predicate {
    /// Capability attribute name.
    pub attr: String,
    /// Comparison operator.
    pub op: RelOp,
    /// Right-hand literal.
    pub value: CapValue,
}

impl Predicate {
    /// Evaluates the predicate against a capability profile. A missing
    /// attribute fails every predicate except `!=` (absence is "not that
    /// value") — conservative, mirroring the paper's "must satisfy all".
    pub fn eval(&self, caps: &Capabilities) -> bool {
        let Some(have) = caps.get(&self.attr) else {
            return self.op == RelOp::Ne;
        };
        match self.op {
            RelOp::Eq => cap_eq(have, &self.value),
            RelOp::Ne => !cap_eq(have, &self.value),
            RelOp::Ge | RelOp::Le | RelOp::Gt | RelOp::Lt => {
                let (Some(a), Some(b)) = (have.as_f64(), self.value.as_f64()) else {
                    return false;
                };
                match self.op {
                    RelOp::Ge => a >= b,
                    RelOp::Le => a <= b,
                    RelOp::Gt => a > b,
                    RelOp::Lt => a < b,
                    _ => unreachable!(),
                }
            }
        }
    }
}

fn cap_eq(a: &CapValue, b: &CapValue) -> bool {
    match (a.as_f64(), b.as_f64()) {
        (Some(x), Some(y)) => x == y,
        _ => a == b,
    }
}

impl fmt::Display for Predicate {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        write!(f, "{} {} {}", self.attr, self.op, self.value)
    }
}

/// A conjunction of predicates — the paper's operator requirement language.
#[derive(Debug, Clone, PartialEq, Default)]
pub struct ConstraintExpr {
    /// All predicates; a host must satisfy every one.
    pub predicates: Vec<Predicate>,
}

impl ConstraintExpr {
    /// Parses a requirement like `n_cpu >= 4 && gpu = yes`.
    ///
    /// Grammar: `expr := pred (('&&' | 'AND' | '∧') pred)*`,
    /// `pred := ident op literal`, `op ∈ {=, !=, >=, <=, >, <}`.
    pub fn parse(s: &str) -> Result<ConstraintExpr> {
        let mut predicates = Vec::new();
        let normalized = s.replace('∧', "&&").replace(" AND ", " && ").replace(" and ", " && ");
        for part in normalized.split("&&") {
            let part = part.trim();
            if part.is_empty() {
                return Err(Error::Constraint(format!("empty predicate in '{s}'")));
            }
            predicates.push(Self::parse_pred(part)?);
        }
        if predicates.is_empty() {
            return Err(Error::Constraint("empty constraint".into()));
        }
        Ok(ConstraintExpr { predicates })
    }

    fn parse_pred(p: &str) -> Result<Predicate> {
        // order matters: two-char ops first
        for (tok, op) in [
            (">=", RelOp::Ge),
            ("<=", RelOp::Le),
            ("!=", RelOp::Ne),
            (">", RelOp::Gt),
            ("<", RelOp::Lt),
            ("=", RelOp::Eq),
        ] {
            if let Some(idx) = p.find(tok) {
                let attr = p[..idx].trim();
                let val = p[idx + tok.len()..].trim();
                if attr.is_empty() || val.is_empty() {
                    return Err(Error::Constraint(format!("malformed predicate '{p}'")));
                }
                if !attr
                    .chars()
                    .all(|c| c.is_ascii_alphanumeric() || c == '_' || c == '.')
                {
                    return Err(Error::Constraint(format!("bad attribute name '{attr}'")));
                }
                return Ok(Predicate {
                    attr: attr.to_string(),
                    op,
                    value: CapValue::parse(val),
                });
            }
        }
        Err(Error::Constraint(format!("no operator in predicate '{p}'")))
    }

    /// True iff all predicates hold on `caps`.
    pub fn eval(&self, caps: &Capabilities) -> bool {
        self.predicates.iter().all(|p| p.eval(caps))
    }

    /// Conjunction of two constraints.
    pub fn and(mut self, other: ConstraintExpr) -> ConstraintExpr {
        self.predicates.extend(other.predicates);
        self
    }
}

impl fmt::Display for ConstraintExpr {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        let parts: Vec<String> = self.predicates.iter().map(|p| p.to_string()).collect();
        write!(f, "{}", parts.join(" && "))
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn gpu_host() -> Capabilities {
        Capabilities::of(&[
            ("n_cpu", CapValue::Int(16)),
            ("gpu", CapValue::Bool(true)),
            ("memory", CapValue::parse("16GB")),
            ("arch", CapValue::Str("x86_64".into())),
        ])
    }

    fn edge_host() -> Capabilities {
        Capabilities::of(&[("n_cpu", CapValue::Int(1)), ("gpu", CapValue::Bool(false))])
    }

    #[test]
    fn paper_example_constraint() {
        // the paper's ML operator: n_cpu >= 4 ∧ gpu = yes
        let e = ConstraintExpr::parse("n_cpu >= 4 && gpu = yes").unwrap();
        assert!(e.eval(&gpu_host()));
        assert!(!e.eval(&edge_host()));
        // unicode conjunction also accepted
        let e2 = ConstraintExpr::parse("n_cpu >= 4 ∧ gpu = yes").unwrap();
        assert_eq!(e, e2);
    }

    #[test]
    fn numeric_comparisons() {
        let caps = gpu_host();
        for (expr, expect) in [
            ("n_cpu > 15", true),
            ("n_cpu > 16", false),
            ("n_cpu >= 16", true),
            ("n_cpu < 17", true),
            ("n_cpu <= 15", false),
            ("n_cpu != 4", true),
            ("n_cpu = 16", true),
        ] {
            assert_eq!(
                ConstraintExpr::parse(expr).unwrap().eval(&caps),
                expect,
                "{expr}"
            );
        }
    }

    #[test]
    fn memory_size_suffix_normalises_to_bytes() {
        let e = ConstraintExpr::parse("memory >= 8GB").unwrap();
        assert!(e.eval(&gpu_host()));
        let e = ConstraintExpr::parse("memory >= 32GB").unwrap();
        assert!(!e.eval(&gpu_host()));
    }

    #[test]
    fn string_equality() {
        let e = ConstraintExpr::parse("arch = x86_64").unwrap();
        assert!(e.eval(&gpu_host()));
        let e = ConstraintExpr::parse("arch = arm64").unwrap();
        assert!(!e.eval(&gpu_host()));
    }

    #[test]
    fn missing_attribute_fails_except_ne() {
        let caps = edge_host();
        assert!(!ConstraintExpr::parse("tpu = yes").unwrap().eval(&caps));
        assert!(!ConstraintExpr::parse("tpu >= 1").unwrap().eval(&caps));
        assert!(ConstraintExpr::parse("tpu != yes").unwrap().eval(&caps));
    }

    #[test]
    fn bool_aliases() {
        let caps = gpu_host();
        assert!(ConstraintExpr::parse("gpu = true").unwrap().eval(&caps));
        assert!(ConstraintExpr::parse("gpu != no").unwrap().eval(&caps));
    }

    #[test]
    fn int_float_cross_comparison() {
        let caps = Capabilities::of(&[("clock", CapValue::Float(3.5))]);
        assert!(ConstraintExpr::parse("clock >= 3").unwrap().eval(&caps));
        assert!(ConstraintExpr::parse("clock = 3.5").unwrap().eval(&caps));
    }

    #[test]
    fn ordering_on_string_fails_closed() {
        let caps = gpu_host();
        assert!(!ConstraintExpr::parse("arch >= 4").unwrap().eval(&caps));
    }

    #[test]
    fn parse_errors() {
        assert!(ConstraintExpr::parse("").is_err());
        assert!(ConstraintExpr::parse("gpu").is_err());
        assert!(ConstraintExpr::parse("gpu = yes && ").is_err());
        assert!(ConstraintExpr::parse("bad attr! = 3").is_err());
        assert!(ConstraintExpr::parse(" = 3").is_err());
    }

    #[test]
    fn and_composes() {
        let a = ConstraintExpr::parse("gpu = yes").unwrap();
        let b = ConstraintExpr::parse("n_cpu >= 4").unwrap();
        let c = a.and(b);
        assert!(c.eval(&gpu_host()));
        assert!(!c.eval(&edge_host()));
        assert_eq!(c.to_string(), "gpu = yes && n_cpu >= 4");
    }

    #[test]
    fn display_roundtrip() {
        let e = ConstraintExpr::parse("n_cpu >= 4 && gpu = yes && arch = x86_64").unwrap();
        let e2 = ConstraintExpr::parse(&e.to_string()).unwrap();
        assert_eq!(e, e2);
    }
}
