//! The continuum topology model: layers, locations, zones, hosts,
//! capabilities, and operator requirement constraints (paper §III).
//!
//! Zones live in a two-dimensional space — a *layer* (edge → site → cloud,
//! increasing computational capability toward the centre) and a set of
//! geographical *locations* the zone covers — and are organised in a tree
//! whose edges are the only paths data may follow across zones.

mod constraint;

pub use constraint::{CapValue, Capabilities, ConstraintExpr, Predicate, RelOp};

use crate::error::{Error, Result};
use std::collections::{BTreeMap, BTreeSet};

/// Layer name, e.g. `edge`, `site`, `cloud` (ordered: index 0 is the
/// outermost periphery).
pub type LayerId = String;
/// Geographical location label, e.g. `L1`.
pub type LocationId = String;
/// Zone name, e.g. `E1`, `S1`, `C1`.
pub type ZoneId = String;
/// Host name.
pub type HostId = String;

/// A geographical zone: a set of well-connected hosts at one layer,
/// covering one or more locations.
#[derive(Debug, Clone)]
pub struct Zone {
    /// Zone name.
    pub id: ZoneId,
    /// The layer this zone belongs to.
    pub layer: LayerId,
    /// Locations covered by this zone.
    pub locations: Vec<LocationId>,
    /// Parent zone in the tree (`None` for the root, i.e. the cloud).
    pub parent: Option<ZoneId>,
}

/// A compute host inside a zone.
#[derive(Debug, Clone)]
pub struct Host {
    /// Host name.
    pub id: HostId,
    /// Zone this host belongs to.
    pub zone: ZoneId,
    /// Number of CPU cores (bounds operator replication, Renoir-style).
    pub cores: usize,
    /// Advertised capabilities (always includes `n_cpu`).
    pub caps: Capabilities,
}

/// The full continuum topology.
#[derive(Debug, Clone, Default)]
pub struct Topology {
    /// Layer names ordered from periphery (index 0) to centre.
    pub layers: Vec<LayerId>,
    /// Zones by id.
    pub zones: BTreeMap<ZoneId, Zone>,
    /// Hosts by id.
    pub hosts: BTreeMap<HostId, Host>,
}

impl Topology {
    /// Index of a layer in the periphery→centre order.
    pub fn layer_index(&self, layer: &str) -> Result<usize> {
        self.layers
            .iter()
            .position(|l| l == layer)
            .ok_or_else(|| Error::Topology(format!("unknown layer '{layer}'")))
    }

    /// All zones at a given layer.
    pub fn zones_at_layer(&self, layer: &str) -> Vec<&Zone> {
        self.zones.values().filter(|z| z.layer == layer).collect()
    }

    /// All hosts in a given zone.
    pub fn hosts_in_zone(&self, zone: &str) -> Vec<&Host> {
        self.hosts.values().filter(|h| h.zone == zone).collect()
    }

    /// The zone at `layer` that covers `location`, if any.
    ///
    /// Per the paper, a location is covered by exactly one zone per layer
    /// (e.g. L1 is covered by E1 at the edge, S1 at the site layer, C1 in
    /// the cloud); [`validate`](Self::validate) enforces uniqueness.
    pub fn covering_zone(&self, layer: &str, location: &str) -> Option<&Zone> {
        self.zones
            .values()
            .find(|z| z.layer == layer && z.locations.iter().any(|l| l == location))
    }

    /// Whether `child` is directly connected to `parent` in the zone tree.
    pub fn is_tree_edge(&self, child: &str, parent: &str) -> bool {
        self.zones
            .get(child)
            .and_then(|z| z.parent.as_deref())
            .map(|p| p == parent)
            .unwrap_or(false)
    }

    /// Walks the unique tree path from `from` upward and returns it
    /// (inclusive of both ends) if `to` is an ancestor of `from`.
    pub fn path_up(&self, from: &str, to: &str) -> Option<Vec<ZoneId>> {
        let mut path = vec![from.to_string()];
        let mut cur = from.to_string();
        let mut hops = 0;
        while cur != to {
            let z = self.zones.get(&cur)?;
            let p = z.parent.clone()?;
            path.push(p.clone());
            cur = p;
            hops += 1;
            if hops > self.zones.len() {
                return None; // cycle guard (validate() rejects cycles anyway)
            }
        }
        Some(path)
    }

    /// All hosts at a zone whose capabilities satisfy `expr` (or all hosts
    /// when `expr` is `None`).
    pub fn matching_hosts<'a>(
        &'a self,
        zone: &str,
        expr: Option<&ConstraintExpr>,
    ) -> Vec<&'a Host> {
        self.hosts_in_zone(zone)
            .into_iter()
            .filter(|h| expr.map(|e| e.eval(&h.caps)).unwrap_or(true))
            .collect()
    }

    /// Validates the topology:
    /// * every zone's layer exists and parents are at the next layer inward;
    /// * the zone graph is a tree (single root, no cycles);
    /// * every location is covered by at most one zone per layer;
    /// * hosts reference existing zones and have ≥ 1 core.
    pub fn validate(&self) -> Result<()> {
        if self.layers.is_empty() {
            return Err(Error::Topology("no layers defined".into()));
        }
        let mut roots = 0;
        for z in self.zones.values() {
            let li = self.layer_index(&z.layer)?;
            match &z.parent {
                None => {
                    roots += 1;
                    if li != self.layers.len() - 1 {
                        return Err(Error::Topology(format!(
                            "zone '{}' has no parent but is not at the innermost layer",
                            z.id
                        )));
                    }
                }
                Some(p) => {
                    let pz = self
                        .zones
                        .get(p)
                        .ok_or_else(|| Error::Topology(format!("zone '{}' has unknown parent '{p}'", z.id)))?;
                    let pi = self.layer_index(&pz.layer)?;
                    if pi != li + 1 {
                        return Err(Error::Topology(format!(
                            "zone '{}' (layer {}) has parent '{}' at layer {} — parents must be exactly one layer inward",
                            z.id, z.layer, pz.id, pz.layer
                        )));
                    }
                }
            }
        }
        if self.zones.is_empty() {
            return Err(Error::Topology("no zones defined".into()));
        }
        if roots != 1 {
            return Err(Error::Topology(format!(
                "zone tree must have exactly one root, found {roots}"
            )));
        }
        // acyclicity + reachability: walk up from every zone.
        for z in self.zones.values() {
            let mut cur = z.id.clone();
            let mut hops = 0;
            while let Some(p) = self.zones.get(&cur).and_then(|zz| zz.parent.clone()) {
                cur = p;
                hops += 1;
                if hops > self.zones.len() {
                    return Err(Error::Topology(format!("cycle through zone '{}'", z.id)));
                }
            }
        }
        // location uniqueness per layer
        for layer in &self.layers {
            let mut seen: BTreeSet<&str> = BTreeSet::new();
            for z in self.zones.values().filter(|z| &z.layer == layer) {
                for loc in &z.locations {
                    if !seen.insert(loc) {
                        return Err(Error::Topology(format!(
                            "location '{loc}' covered by multiple zones at layer '{layer}'"
                        )));
                    }
                }
            }
        }
        // parent zones must cover their children's locations so that a
        // location's per-layer covering zones form a tree path.
        for z in self.zones.values() {
            if let Some(p) = &z.parent {
                let pz = &self.zones[p];
                for loc in &z.locations {
                    if !pz.locations.iter().any(|l| l == loc) {
                        return Err(Error::Topology(format!(
                            "zone '{}' covers location '{loc}' but its parent '{}' does not",
                            z.id, pz.id
                        )));
                    }
                }
            }
        }
        for h in self.hosts.values() {
            if !self.zones.contains_key(&h.zone) {
                return Err(Error::Topology(format!(
                    "host '{}' references unknown zone '{}'",
                    h.id, h.zone
                )));
            }
            if h.cores == 0 {
                return Err(Error::Topology(format!("host '{}' has 0 cores", h.id)));
            }
        }
        Ok(())
    }

    /// Total core count across all hosts (Renoir's default replication
    /// factor for each operator).
    pub fn total_cores(&self) -> usize {
        self.hosts.values().map(|h| h.cores).sum()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    /// The paper's Fig. 2 topology: 5 edge zones, 2 sites, 1 cloud.
    pub fn fig2() -> Topology {
        let mut t = Topology {
            layers: vec!["edge".into(), "site".into(), "cloud".into()],
            ..Default::default()
        };
        let zone = |id: &str, layer: &str, locs: &[&str], parent: Option<&str>| Zone {
            id: id.into(),
            layer: layer.into(),
            locations: locs.iter().map(|s| s.to_string()).collect(),
            parent: parent.map(|s| s.to_string()),
        };
        for (id, locs, parent) in [
            ("E1", vec!["L1"], Some("S1")),
            ("E2", vec!["L2"], Some("S1")),
            ("E3", vec!["L3"], Some("S1")),
            ("E4", vec!["L4"], Some("S2")),
            ("E5", vec!["L5"], Some("S2")),
        ] {
            let locs: Vec<&str> = locs;
            t.zones.insert(id.into(), zone(id, "edge", &locs, parent));
        }
        t.zones.insert(
            "S1".into(),
            zone("S1", "site", &["L1", "L2", "L3"], Some("C1")),
        );
        t.zones
            .insert("S2".into(), zone("S2", "site", &["L4", "L5"], Some("C1")));
        t.zones.insert(
            "C1".into(),
            zone("C1", "cloud", &["L1", "L2", "L3", "L4", "L5"], None),
        );
        for (i, z) in ["E1", "E2", "E3", "E4", "E5"].iter().enumerate() {
            t.hosts.insert(
                format!("e{}", i + 1),
                Host {
                    id: format!("e{}", i + 1),
                    zone: z.to_string(),
                    cores: 1,
                    caps: Capabilities::of(&[("n_cpu", CapValue::Int(1))]),
                },
            );
        }
        t.hosts.insert(
            "s1a".into(),
            Host {
                id: "s1a".into(),
                zone: "S1".into(),
                cores: 4,
                caps: Capabilities::of(&[("n_cpu", CapValue::Int(4))]),
            },
        );
        t.hosts.insert(
            "c1a".into(),
            Host {
                id: "c1a".into(),
                zone: "C1".into(),
                cores: 16,
                caps: Capabilities::of(&[
                    ("n_cpu", CapValue::Int(16)),
                    ("gpu", CapValue::Bool(true)),
                ]),
            },
        );
        t
    }

    #[test]
    fn fig2_validates() {
        fig2().validate().unwrap();
    }

    #[test]
    fn covering_zone_resolution() {
        let t = fig2();
        assert_eq!(t.covering_zone("edge", "L1").unwrap().id, "E1");
        assert_eq!(t.covering_zone("site", "L1").unwrap().id, "S1");
        assert_eq!(t.covering_zone("site", "L4").unwrap().id, "S2");
        assert_eq!(t.covering_zone("cloud", "L5").unwrap().id, "C1");
        assert!(t.covering_zone("edge", "L99").is_none());
    }

    #[test]
    fn tree_paths() {
        let t = fig2();
        assert_eq!(
            t.path_up("E1", "C1").unwrap(),
            vec!["E1".to_string(), "S1".into(), "C1".into()]
        );
        assert!(t.is_tree_edge("E1", "S1"));
        assert!(!t.is_tree_edge("E1", "S2"));
        assert!(!t.is_tree_edge("E1", "C1")); // not direct
        assert!(t.path_up("E4", "S1").is_none()); // wrong branch
    }

    #[test]
    fn rejects_two_roots() {
        let mut t = fig2();
        t.zones.get_mut("S2").unwrap().parent = None;
        assert!(t.validate().is_err());
    }

    #[test]
    fn rejects_layer_skip() {
        let mut t = fig2();
        t.zones.get_mut("E1").unwrap().parent = Some("C1".into());
        assert!(t.validate().is_err());
    }

    #[test]
    fn rejects_duplicate_location_coverage() {
        let mut t = fig2();
        t.zones.get_mut("E2").unwrap().locations = vec!["L1".into()];
        assert!(t.validate().is_err());
    }

    #[test]
    fn rejects_parent_not_covering_child_location() {
        let mut t = fig2();
        t.zones.get_mut("E1").unwrap().locations = vec!["L1".into(), "L4".into()];
        // also breaks uniqueness with E4 -> use a fresh location instead
        t.zones.get_mut("E1").unwrap().locations = vec!["L1".into(), "L9".into()];
        assert!(t.validate().is_err());
    }

    #[test]
    fn rejects_zero_core_host() {
        let mut t = fig2();
        t.hosts.get_mut("e1").unwrap().cores = 0;
        assert!(t.validate().is_err());
    }

    #[test]
    fn matching_hosts_filters_by_constraint() {
        let t = fig2();
        let expr = ConstraintExpr::parse("gpu = yes").unwrap();
        let hosts = t.matching_hosts("C1", Some(&expr));
        assert_eq!(hosts.len(), 1);
        assert_eq!(hosts[0].id, "c1a");
        let none = t.matching_hosts("S1", Some(&expr));
        assert!(none.is_empty());
        assert_eq!(t.matching_hosts("S1", None).len(), 1);
    }

    #[test]
    fn total_cores_sums() {
        assert_eq!(fig2().total_cores(), 5 + 4 + 16);
    }
}
