//! Event time: timestamp extraction, watermark generation, and window
//! assignment.
//!
//! The engine's original windows are count-based — they close after a
//! fixed number of records, so results depend on arrival order. In the
//! edge-to-cloud continuum arrival order is exactly what the network does
//! *not* preserve (uplinks with different latencies reorder records across
//! paths), so aggregations over sensor time need a second clock: the
//! *event timestamp* carried by each record, plus *watermarks* — control
//! frames promising "no further record below time T" — that tell
//! operators when a window keyed by event time is complete.
//!
//! This module holds the pure event-time vocabulary shared by every
//! layer: timestamp extractors, the two watermark generator disciplines
//! (bounded out-of-orderness and punctuated), and window assigners
//! (tumbling / sliding / session). The plumbing lives elsewhere:
//! [`Msg::Watermark`](crate::channels::Msg::Watermark) frames travel the
//! channel layer and are merged min-of-inputs by each
//! [`Inbox`](crate::channels::Inbox); the event-time operators in
//! [`runtime`](crate::runtime) buffer panes and fire them as the merged
//! watermark passes each window's end plus its allowed lateness.

use crate::value::Value;
use std::fmt;
use std::sync::Arc;
use std::time::{SystemTime, UNIX_EPOCH};

/// Extracts the event timestamp (milliseconds) from a record.
pub type TsFn = Arc<dyn Fn(&Value) -> i64 + Send + Sync>;

/// Punctuated-watermark marker predicate: `true` on records that carry an
/// explicit watermark punctuation (e.g. a sensor's end-of-scan frame).
pub type PunctFn = Arc<dyn Fn(&Value) -> bool + Send + Sync>;

/// Wall-clock milliseconds since the Unix epoch (watermark lag metric).
pub fn now_ms() -> u64 {
    SystemTime::now()
        .duration_since(UNIX_EPOCH)
        .map(|d| d.as_millis() as u64)
        .unwrap_or(0)
}

/// Watermark generation discipline of a timestamp assigner.
#[derive(Clone)]
pub enum WatermarkGen {
    /// Heuristic generator: watermark trails the maximum observed
    /// timestamp by a fixed bound, tolerating up to `bound_ms` of
    /// disorder. Emitted once per processed batch.
    BoundedOutOfOrderness {
        /// Maximum tolerated out-of-orderness in milliseconds.
        bound_ms: i64,
    },
    /// Explicit generator: records matching the predicate punctuate the
    /// stream — the watermark advances to their timestamp immediately.
    Punctuated(PunctFn),
}

impl WatermarkGen {
    /// Bounded-out-of-orderness generator tolerating `bound_ms` of
    /// disorder.
    pub fn bounded(bound_ms: i64) -> Self {
        WatermarkGen::BoundedOutOfOrderness { bound_ms }
    }

    /// Punctuated generator: records matching `p` advance the watermark
    /// to their timestamp immediately.
    pub fn punctuated(p: impl Fn(&Value) -> bool + Send + Sync + 'static) -> Self {
        WatermarkGen::Punctuated(Arc::new(p))
    }
}

impl fmt::Debug for WatermarkGen {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        match self {
            WatermarkGen::BoundedOutOfOrderness { bound_ms } => {
                write!(f, "BoundedOutOfOrderness({bound_ms}ms)")
            }
            WatermarkGen::Punctuated(_) => write!(f, "Punctuated"),
        }
    }
}

/// Running state of a watermark generator: feeds on records via
/// [`observe`](WatermarkState::observe), yields monotone watermarks via
/// [`take`](WatermarkState::take). Snapshot/restore keep the promise
/// monotone across checkpoints and hot swaps (a restarted assigner must
/// never re-emit a lower watermark than its predecessor).
pub struct WatermarkState {
    gen: WatermarkGen,
    /// Maximum event timestamp observed so far.
    max_ts: i64,
    /// Last watermark handed out (monotonicity floor).
    emitted: i64,
    /// A punctuation fired since the last `take`.
    punct_pending: bool,
}

impl WatermarkState {
    /// Fresh generator state.
    pub fn new(gen: WatermarkGen) -> Self {
        WatermarkState {
            gen,
            max_ts: i64::MIN,
            emitted: i64::MIN,
            punct_pending: false,
        }
    }

    /// Feeds one record (with its extracted timestamp) to the generator.
    pub fn observe(&mut self, v: &Value, ts: i64) {
        self.max_ts = self.max_ts.max(ts);
        if let WatermarkGen::Punctuated(p) = &self.gen {
            if p(v) {
                self.punct_pending = true;
            }
        }
    }

    /// Feeds a bare timestamp (columnar path: no row to test for
    /// punctuation, so punctuated generators degrade to bounded-by-zero
    /// per-batch emission).
    pub fn observe_ts(&mut self, ts: i64) {
        self.max_ts = self.max_ts.max(ts);
        if matches!(self.gen, WatermarkGen::Punctuated(_)) {
            self.punct_pending = true;
        }
    }

    /// The next watermark to emit, if the promise advanced. Bounded
    /// generators emit `max_ts - bound` (typically polled once per
    /// batch); punctuated generators emit `max_ts` only after a
    /// punctuation record passed.
    pub fn take(&mut self) -> Option<i64> {
        let candidate = match &self.gen {
            WatermarkGen::BoundedOutOfOrderness { bound_ms } => {
                if self.max_ts == i64::MIN {
                    return None;
                }
                self.max_ts.saturating_sub(*bound_ms)
            }
            WatermarkGen::Punctuated(_) => {
                if !self.punct_pending {
                    return None;
                }
                self.punct_pending = false;
                self.max_ts
            }
        };
        if candidate > self.emitted {
            self.emitted = candidate;
            Some(candidate)
        } else {
            None
        }
    }

    /// Serialises the generator state (checkpoint / handoff).
    pub fn snapshot(&self) -> Value {
        Value::List(vec![Value::I64(self.max_ts), Value::I64(self.emitted)])
    }

    /// Restores a snapshot, keeping the higher of the saved and current
    /// promises (restore may merge multiple predecessor states).
    pub fn restore(&mut self, v: &Value) {
        if let Some(items) = v.as_list() {
            if let (Some(max_ts), Some(emitted)) = (
                items.first().and_then(Value::as_i64),
                items.get(1).and_then(Value::as_i64),
            ) {
                self.max_ts = self.max_ts.max(max_ts);
                self.emitted = self.emitted.max(emitted);
            }
        }
    }
}

/// Assigns each record (by event timestamp) to one or more windows.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum WindowAssigner {
    /// Fixed, non-overlapping windows of `size_ms`.
    Tumbling {
        /// Window length in milliseconds.
        size_ms: i64,
    },
    /// Overlapping windows of `size_ms` advancing every `slide_ms`.
    Sliding {
        /// Window length in milliseconds.
        size_ms: i64,
        /// Hop between window starts in milliseconds.
        slide_ms: i64,
    },
    /// Activity sessions: a window extends while successive records are
    /// within `gap_ms` of each other and closes after a silence of
    /// `gap_ms`.
    Session {
        /// Inactivity gap that closes a session, in milliseconds.
        gap_ms: i64,
    },
}

impl WindowAssigner {
    /// Fixed, non-overlapping windows of `size_ms`.
    pub fn tumbling(size_ms: i64) -> Self {
        WindowAssigner::Tumbling { size_ms }
    }

    /// Overlapping windows of `size_ms` advancing every `slide_ms`.
    pub fn sliding(size_ms: i64, slide_ms: i64) -> Self {
        WindowAssigner::Sliding { size_ms, slide_ms }
    }

    /// Activity sessions closed by a silence of `gap_ms`.
    pub fn session(gap_ms: i64) -> Self {
        WindowAssigner::Session { gap_ms }
    }

    /// Validates the assigner's parameters (builder-time check).
    pub fn validate(&self) -> std::result::Result<(), String> {
        match *self {
            WindowAssigner::Tumbling { size_ms } if size_ms <= 0 => {
                Err(format!("tumbling window size {size_ms}ms must be positive"))
            }
            WindowAssigner::Sliding { size_ms, slide_ms }
                if size_ms <= 0 || slide_ms <= 0 || slide_ms > size_ms =>
            {
                Err(format!(
                    "sliding window needs 0 < slide ({slide_ms}ms) <= size ({size_ms}ms)"
                ))
            }
            WindowAssigner::Session { gap_ms } if gap_ms <= 0 => {
                Err(format!("session gap {gap_ms}ms must be positive"))
            }
            _ => Ok(()),
        }
    }

    /// The `[start, end)` windows containing `ts`. Session windows are
    /// data-driven (the executor merges per-key spans instead) and yield
    /// nothing here.
    pub fn assign(&self, ts: i64) -> Vec<(i64, i64)> {
        match *self {
            WindowAssigner::Tumbling { size_ms } => {
                let start = ts - ts.rem_euclid(size_ms);
                vec![(start, start + size_ms)]
            }
            WindowAssigner::Sliding { size_ms, slide_ms } => {
                // last window starting at or before ts, then walk back
                // while the window still covers ts
                let mut start = ts - ts.rem_euclid(slide_ms);
                let mut out = Vec::with_capacity((size_ms / slide_ms) as usize);
                while start + size_ms > ts {
                    out.push((start, start + size_ms));
                    start -= slide_ms;
                }
                out.reverse();
                out
            }
            WindowAssigner::Session { .. } => Vec::new(),
        }
    }

    /// The session gap, for session assigners.
    pub fn session_gap(&self) -> Option<i64> {
        match *self {
            WindowAssigner::Session { gap_ms } => Some(gap_ms),
            _ => None,
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn tumbling_assignment_covers_negatives() {
        let w = WindowAssigner::Tumbling { size_ms: 10 };
        assert_eq!(w.assign(0), vec![(0, 10)]);
        assert_eq!(w.assign(9), vec![(0, 10)]);
        assert_eq!(w.assign(10), vec![(10, 20)]);
        assert_eq!(w.assign(-1), vec![(-10, 0)]);
    }

    #[test]
    fn sliding_assignment_yields_every_covering_window() {
        let w = WindowAssigner::Sliding {
            size_ms: 10,
            slide_ms: 5,
        };
        assert_eq!(w.assign(12), vec![(5, 15), (10, 20)]);
        assert_eq!(w.assign(10), vec![(5, 15), (10, 20)]);
        assert_eq!(w.assign(4), vec![(-5, 5), (0, 10)]);
    }

    #[test]
    fn assigner_validation_rejects_degenerate_shapes() {
        assert!(WindowAssigner::Tumbling { size_ms: 0 }.validate().is_err());
        assert!(WindowAssigner::Sliding {
            size_ms: 5,
            slide_ms: 10
        }
        .validate()
        .is_err());
        assert!(WindowAssigner::Session { gap_ms: -1 }.validate().is_err());
        assert!(WindowAssigner::Tumbling { size_ms: 1000 }.validate().is_ok());
    }

    #[test]
    fn bounded_generator_trails_max_by_bound() {
        let mut g = WatermarkState::new(WatermarkGen::BoundedOutOfOrderness { bound_ms: 5 });
        assert_eq!(g.take(), None, "no records, no promise");
        g.observe(&Value::I64(0), 100);
        assert_eq!(g.take(), Some(95));
        g.observe(&Value::I64(0), 90); // disorder within bound: no regress
        assert_eq!(g.take(), None);
        g.observe(&Value::I64(0), 200);
        assert_eq!(g.take(), Some(195));
    }

    #[test]
    fn punctuated_generator_fires_on_markers_only() {
        let mut g = WatermarkState::new(WatermarkGen::Punctuated(Arc::new(|v: &Value| {
            v.as_i64() == Some(-1)
        })));
        g.observe(&Value::I64(7), 50);
        assert_eq!(g.take(), None, "plain records never punctuate");
        g.observe(&Value::I64(-1), 60);
        assert_eq!(g.take(), Some(60));
        assert_eq!(g.take(), None, "punctuation is consumed");
    }

    #[test]
    fn watermark_state_snapshot_roundtrip_is_monotone() {
        let mut g = WatermarkState::new(WatermarkGen::BoundedOutOfOrderness { bound_ms: 0 });
        g.observe(&Value::I64(0), 500);
        assert_eq!(g.take(), Some(500));
        let snap = g.snapshot();
        let mut g2 = WatermarkState::new(WatermarkGen::BoundedOutOfOrderness { bound_ms: 0 });
        g2.restore(&snap);
        g2.observe(&Value::I64(0), 400); // older data after restore
        assert_eq!(g2.take(), None, "restored promise never regresses");
        g2.observe(&Value::I64(0), 600);
        assert_eq!(g2.take(), Some(600));
    }
}
