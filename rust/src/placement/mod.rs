//! Deployment planners: the FlowUnits locality/resource-aware planner and
//! the Renoir baseline planner (paper §II/§V comparison).
//!
//! * **FlowUnits planner** — each stage is instantiated once per zone (at
//!   the stage's layer) that covers an enabled location; within a zone,
//!   one instance per core of every capability-satisfying host. Instances
//!   may only talk to instances in the *same zone* (intra-unit exchange)
//!   or in the *ancestor zone* at the downstream layer (cross-unit
//!   collection along the zone tree).
//! * **Renoir planner** — the classic strategy: one instance of every
//!   operator per core of every host, all-to-all connectivity, layers
//!   ignored. This maximises utilisation in a co-located cluster but sends
//!   data across slow inter-zone links indiscriminately.

use crate::channels::Routing;
use crate::config::ClusterSpec;
use crate::error::{Error, Result};
use crate::graph::{LogicalGraph, Stage};
use crate::netsim::LinkSpec;
use crate::topology::{HostId, LocationId, Topology, ZoneId};
use std::collections::BTreeSet;

/// Which deployment strategy to use.
#[derive(Debug, Clone, Copy, PartialEq, Eq, Default)]
pub enum PlannerKind {
    /// Locality/resource-aware FlowUnits deployment (the paper's model).
    #[default]
    FlowUnits,
    /// Classic Renoir/Flink deployment: every operator everywhere.
    Renoir,
}

/// One planned operator-stage instance.
#[derive(Debug, Clone)]
pub struct InstancePlan {
    /// Globally unique instance id.
    pub id: usize,
    /// Stage this instance executes.
    pub stage: usize,
    /// Host it is pinned to.
    pub host: HostId,
    /// Zone of that host.
    pub zone: ZoneId,
    /// Core slot within the host.
    pub core: usize,
    /// For source stages: `(instance_index, instance_count)` used to split
    /// the input among source instances.
    pub source_share: Option<(u64, u64)>,
}

/// One planned stage-to-stage edge.
#[derive(Debug, Clone)]
pub struct EdgePlan {
    /// Upstream stage index.
    pub from_stage: usize,
    /// Downstream stage index.
    pub to_stage: usize,
    /// Record routing policy.
    pub routing: Routing,
    /// Whether this edge crosses a FlowUnit boundary.
    pub unit_boundary: bool,
    /// Whether the edge is decoupled through the queue substrate.
    pub decoupled: bool,
}

/// A full execution plan.
#[derive(Debug, Clone)]
pub struct ExecPlan {
    /// Strategy that produced the plan.
    pub planner: PlannerKind,
    /// Stages (fused operator runs) in chain order.
    pub stages: Vec<Stage>,
    /// All stage instances.
    pub instances: Vec<InstancePlan>,
    /// Edges between consecutive stages.
    pub edges: Vec<EdgePlan>,
    /// Locations enabled for this job.
    pub locations: Vec<LocationId>,
}

impl ExecPlan {
    /// Instance ids belonging to `stage`.
    pub fn instances_of(&self, stage: usize) -> Vec<usize> {
        self.instances
            .iter()
            .filter(|i| i.stage == stage)
            .map(|i| i.id)
            .collect()
    }

    /// Downstream instances that `from` may send to along `edge`.
    ///
    /// FlowUnits: same-zone for intra-unit edges; the covering ancestor
    /// zone at the downstream layer for cross-unit edges. Renoir: all
    /// downstream instances.
    pub fn allowed_targets(&self, topo: &Topology, from: usize, edge: &EdgePlan) -> Vec<usize> {
        let from_inst = &self.instances[from];
        debug_assert_eq!(from_inst.stage, edge.from_stage);
        let candidates: Vec<&InstancePlan> = self
            .instances
            .iter()
            .filter(|i| i.stage == edge.to_stage)
            .collect();
        match self.planner {
            PlannerKind::Renoir => candidates.iter().map(|i| i.id).collect(),
            PlannerKind::FlowUnits => {
                let from_stage = &self.stages[edge.from_stage];
                let to_stage = &self.stages[edge.to_stage];
                if from_stage.unit_index == to_stage.unit_index {
                    // intra-unit: same zone only
                    candidates
                        .iter()
                        .filter(|i| i.zone == from_inst.zone)
                        .map(|i| i.id)
                        .collect()
                } else {
                    // cross-unit: the unique ancestor zone at the target layer
                    let target_zone = ancestor_at_layer(topo, &from_inst.zone, &to_stage.layer);
                    match target_zone {
                        None => Vec::new(),
                        Some(z) => candidates
                            .iter()
                            .filter(|i| i.zone == z)
                            .map(|i| i.id)
                            .collect(),
                    }
                }
            }
        }
    }

    /// Human-readable plan summary (stage → instances per zone).
    pub fn describe(&self, graph: &LogicalGraph) -> String {
        let mut s = format!("planner: {:?}\n", self.planner);
        for st in &self.stages {
            let ops: Vec<&str> = st.ops.iter().map(|&o| graph.ops[o].name.as_str()).collect();
            let mut per_zone: std::collections::BTreeMap<&str, usize> = Default::default();
            for i in self.instances.iter().filter(|i| i.stage == st.index) {
                *per_zone.entry(i.zone.as_str()).or_default() += 1;
            }
            let zones: Vec<String> = per_zone
                .iter()
                .map(|(z, n)| format!("{z}×{n}"))
                .collect();
            s.push_str(&format!(
                "  stage {} (unit {}, layer {}) [{}]: {}\n",
                st.index,
                st.unit_index,
                st.layer,
                ops.join(", "),
                zones.join(" ")
            ));
        }
        s
    }
}

/// Finds the ancestor (or self) of `zone` at `layer`, walking the tree
/// upward.
pub fn ancestor_at_layer(topo: &Topology, zone: &str, layer: &str) -> Option<ZoneId> {
    let mut cur = zone.to_string();
    let mut hops = 0;
    loop {
        let z = topo.zones.get(&cur)?;
        if z.layer == layer {
            return Some(cur);
        }
        cur = z.parent.clone()?;
        hops += 1;
        if hops > topo.zones.len() {
            return None;
        }
    }
}

/// Composite link conditions along the tree path between two zones
/// (up to the lowest common ancestor, then down): latency adds per hop,
/// bandwidth is the minimum hop bandwidth. Same-zone routes are
/// transparent.
pub fn route_spec(cluster: &ClusterSpec, za: &str, zb: &str) -> Result<LinkSpec> {
    if za == zb {
        return Ok(LinkSpec::default());
    }
    let topo = &cluster.topology;
    let up_a = ancestry(topo, za)?;
    let up_b = ancestry(topo, zb)?;
    let set_a: BTreeSet<&str> = up_a.iter().map(|s| s.as_str()).collect();
    let lca = up_b
        .iter()
        .find(|z| set_a.contains(z.as_str()))
        .ok_or_else(|| Error::Topology(format!("no common ancestor of '{za}' and '{zb}'")))?
        .clone();
    let mut spec = LinkSpec::default();
    let mut extend = |path: &[String]| {
        for w in path.windows(2) {
            let hop = cluster.link_between(&w[0], &w[1]);
            spec.latency += hop.latency;
            spec.bandwidth_bps = match (spec.bandwidth_bps, hop.bandwidth_bps) {
                (None, b) => b,
                (a, None) => a,
                (Some(a), Some(b)) => Some(a.min(b)),
            };
        }
    };
    let a_path: Vec<String> = up_a.iter().take_while(|z| **z != lca).cloned().chain([lca.clone()]).collect();
    let b_path: Vec<String> = up_b.iter().take_while(|z| **z != lca).cloned().chain([lca.clone()]).collect();
    extend(&a_path);
    extend(&b_path);
    Ok(spec)
}

fn ancestry(topo: &Topology, zone: &str) -> Result<Vec<ZoneId>> {
    let mut out = vec![zone.to_string()];
    let mut cur = zone.to_string();
    loop {
        let z = topo
            .zones
            .get(&cur)
            .ok_or_else(|| Error::Topology(format!("unknown zone '{cur}'")))?;
        match &z.parent {
            None => return Ok(out),
            Some(p) => {
                out.push(p.clone());
                cur = p.clone();
                if out.len() > topo.zones.len() + 1 {
                    return Err(Error::Topology(format!("cycle above zone '{zone}'")));
                }
            }
        }
    }
}

/// Produces an execution plan for `graph` on `cluster`.
///
/// `locations`: enabled locations (empty ⇒ every location covered by the
/// root zone). `decouple_units`: route FlowUnit-boundary edges through the
/// queue substrate.
pub fn plan(
    graph: &LogicalGraph,
    cluster: &ClusterSpec,
    planner: PlannerKind,
    locations: &[LocationId],
    decouple_units: bool,
) -> Result<ExecPlan> {
    graph.validate(&cluster.topology.layers)?;
    let topo = &cluster.topology;
    let locations: Vec<LocationId> = if locations.is_empty() {
        let root = topo
            .zones
            .values()
            .find(|z| z.parent.is_none())
            .ok_or_else(|| Error::Placement("no root zone".into()))?;
        root.locations.clone()
    } else {
        for l in locations {
            let covered = topo.zones.values().any(|z| z.locations.iter().any(|x| x == l));
            if !covered {
                return Err(Error::Placement(format!("location '{l}' not covered by any zone")));
            }
        }
        locations.to_vec()
    };

    let stages = graph.stages();
    let mut instances: Vec<InstancePlan> = Vec::new();
    for stage in &stages {
        let placed = place_stage(topo, stage, planner, &locations)?;
        if placed.is_empty() {
            return Err(Error::Placement(format!(
                "stage {} (layer '{}', constraint {:?}) has no feasible host — unfeasible deployment",
                stage.index,
                stage.layer,
                stage.constraint.as_ref().map(|c| c.to_string())
            )));
        }
        let n = placed.len() as u64;
        for (host, zone, core) in placed {
            let id = instances.len();
            let idx = instances.iter().filter(|i| i.stage == stage.index).count() as u64;
            instances.push(InstancePlan {
                id,
                stage: stage.index,
                host,
                zone,
                core,
                source_share: if stage.is_source() { Some((idx, n)) } else { None },
            });
        }
    }

    let mut edges = Vec::new();
    for (from, to) in graph.stage_edges(&stages) {
        let unit_boundary = stages[from].unit_index != stages[to].unit_index;
        edges.push(EdgePlan {
            from_stage: from,
            to_stage: to,
            routing: graph.edge_routing(&stages[from]),
            unit_boundary,
            decoupled: decouple_units && unit_boundary,
        });
    }
    // A fan-in stage (union) must consume all its inputs the same way: if
    // any incoming edge is queue-decoupled, decouple them all so the stage
    // reads from one queue topic instead of mixing inbox and queue inputs.
    let decoupled_heads: BTreeSet<usize> = edges
        .iter()
        .filter(|e| e.decoupled)
        .map(|e| e.to_stage)
        .collect();
    for e in &mut edges {
        if decoupled_heads.contains(&e.to_stage) {
            e.decoupled = true;
        }
    }

    let plan = ExecPlan {
        planner,
        stages,
        instances,
        edges,
        locations,
    };

    // Feasibility: every upstream instance must reach at least one target.
    for edge in &plan.edges {
        for from in plan.instances_of(edge.from_stage) {
            if plan.allowed_targets(topo, from, edge).is_empty() {
                let inst = &plan.instances[from];
                return Err(Error::Placement(format!(
                    "instance {} (stage {}, zone {}) has no reachable downstream instance on edge {}->{}",
                    from, edge.from_stage, inst.zone, edge.from_stage, edge.to_stage
                )));
            }
        }
    }
    Ok(plan)
}

fn place_stage(
    topo: &Topology,
    stage: &Stage,
    planner: PlannerKind,
    locations: &[LocationId],
) -> Result<Vec<(HostId, ZoneId, usize)>> {
    let mut out = Vec::new();
    // Data origin is physical: source stages are always pinned to the
    // zones of their annotated layer (per enabled location), under both
    // planners. The Renoir baseline replicates *operators* everywhere, not
    // the sensors producing the data (paper §V).
    let planner = if stage.is_source() {
        PlannerKind::FlowUnits
    } else {
        planner
    };
    match planner {
        PlannerKind::Renoir => {
            // all capability-satisfying hosts anywhere, one instance per core
            for host in topo.hosts.values() {
                let ok = stage
                    .constraint
                    .as_ref()
                    .map(|c| c.eval(&host.caps))
                    .unwrap_or(true);
                if ok {
                    for core in 0..host.cores {
                        out.push((host.id.clone(), host.zone.clone(), core));
                    }
                }
            }
        }
        PlannerKind::FlowUnits => {
            let mut zones: BTreeSet<ZoneId> = BTreeSet::new();
            for loc in locations {
                if let Some(z) = topo.covering_zone(&stage.layer, loc) {
                    zones.insert(z.id.clone());
                }
            }
            if zones.is_empty() {
                return Err(Error::Placement(format!(
                    "no zone at layer '{}' covers any enabled location {:?}",
                    stage.layer, locations
                )));
            }
            for zone in zones {
                let hosts = topo.matching_hosts(&zone, stage.constraint.as_ref());
                if hosts.is_empty() {
                    return Err(Error::Placement(format!(
                        "zone '{zone}' has no host satisfying constraint {:?} for stage {}",
                        stage.constraint.as_ref().map(|c| c.to_string()),
                        stage.index
                    )));
                }
                let mut hosts: Vec<_> = hosts;
                hosts.sort_by(|a, b| a.id.cmp(&b.id));
                if let crate::graph::Replication::Fixed(n) = stage.replication {
                    // n slots round-robin across hosts, core-major wave by
                    // wave, capped at the zone's total core capacity
                    let want = n.max(1);
                    let max_cores = hosts.iter().map(|h| h.cores).max().unwrap_or(1);
                    let mut placed = 0usize;
                    'fill: for core in 0..max_cores {
                        for host in &hosts {
                            if core < host.cores {
                                out.push((host.id.clone(), host.zone.clone(), core));
                                placed += 1;
                                if placed == want {
                                    break 'fill;
                                }
                            }
                        }
                    }
                    continue;
                }
                for host in hosts {
                    match stage.replication {
                        crate::graph::Replication::PerCore => {
                            for core in 0..host.cores {
                                out.push((host.id.clone(), host.zone.clone(), core));
                            }
                        }
                        crate::graph::Replication::PerHost => {
                            out.push((host.id.clone(), host.zone.clone(), 0));
                        }
                        crate::graph::Replication::PerZone => {
                            out.push((host.id.clone(), host.zone.clone(), 0));
                            break;
                        }
                        crate::graph::Replication::Fixed(_) => unreachable!("handled above"),
                    }
                }
            }
        }
    }
    Ok(out)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::config::{eval_cluster, fig2_cluster};
    use crate::graph::{OpKind, SinkKind, SourceKind, WindowAgg};
    use crate::topology::ConstraintExpr;
    use crate::value::Value;
    use std::sync::Arc;
    use std::time::Duration;

    fn eval_graph() -> LogicalGraph {
        let mut g = LogicalGraph::default();
        g.push(
            OpKind::Source(SourceKind::Synthetic {
                total: 1000,
                gen: Arc::new(|_, i| Value::I64(i as i64)),
                rate: None,
            }),
            "edge".into(),
            None,
            "source",
        );
        g.push(
            OpKind::Filter(Arc::new(|v| v.as_i64().unwrap() % 3 == 0)),
            "edge".into(),
            None,
            "O1",
        );
        g.push(
            OpKind::KeyBy(Arc::new(|v| Value::I64(v.as_i64().unwrap() % 4))),
            "site".into(),
            None,
            "key",
        );
        g.push(
            OpKind::Window {
                size: 10,
                slide: 10,
                agg: WindowAgg::Mean,
            },
            "site".into(),
            None,
            "O2",
        );
        g.push(OpKind::Map(Arc::new(|v| v)), "cloud".into(), None, "O3");
        g.push(OpKind::Sink(SinkKind::Count), "cloud".into(), None, "sink");
        g
    }

    #[test]
    fn flowunits_plan_matches_paper_counts() {
        let cluster = eval_cluster(None, Duration::ZERO);
        let plan = plan(&eval_graph(), &cluster, PlannerKind::FlowUnits, &[], false).unwrap();
        // source stage + O1 stage: 4 edge zones × 1 host × 1 core = 4 each
        assert_eq!(plan.instances_of(0).len(), 4);
        assert_eq!(plan.instances_of(1).len(), 4);
        // key stage + window stage at site: 2 hosts × 4 cores = 8 each
        assert_eq!(plan.instances_of(2).len(), 8);
        assert_eq!(plan.instances_of(3).len(), 8);
        // cloud stage: 16
        assert_eq!(plan.instances_of(4).len(), 16);
    }

    #[test]
    fn renoir_plan_replicates_operators_but_not_sources() {
        let cluster = eval_cluster(None, Duration::ZERO);
        let plan = plan(&eval_graph(), &cluster, PlannerKind::Renoir, &[], false).unwrap();
        let total = cluster.topology.total_cores(); // 28
        // sources stay at the data origin (paper §V: data is born at the edge)
        assert_eq!(plan.instances_of(0).len(), 4);
        // every operator gets one instance per core of every host
        for s in 1..5 {
            assert_eq!(plan.instances_of(s).len(), total, "stage {s}");
        }
        // Renoir all-to-all: an edge source may send to any O1 instance
        let e = &plan.edges[0];
        let targets = plan.allowed_targets(&cluster.topology, 0, e);
        assert_eq!(targets.len(), total);
    }

    #[test]
    fn source_shares_split_total() {
        let cluster = eval_cluster(None, Duration::ZERO);
        let plan = plan(&eval_graph(), &cluster, PlannerKind::FlowUnits, &[], false).unwrap();
        let shares: Vec<(u64, u64)> = plan
            .instances
            .iter()
            .filter(|i| i.stage == 0)
            .map(|i| i.source_share.unwrap())
            .collect();
        assert_eq!(shares.len(), 4);
        for (k, n) in &shares {
            assert_eq!(*n, 4);
            assert!(*k < 4);
        }
        let idxs: BTreeSet<u64> = shares.iter().map(|(k, _)| *k).collect();
        assert_eq!(idxs.len(), 4);
    }

    #[test]
    fn flowunits_targets_follow_tree() {
        let cluster = fig2_cluster();
        // enable L1, L2, L4 like the paper's example
        let p = plan(
            &eval_graph(),
            &cluster,
            PlannerKind::FlowUnits,
            &["L1".into(), "L2".into(), "L4".into()],
            false,
        )
        .unwrap();
        let topo = &cluster.topology;
        // edge stage instances exist only in E1, E2, E4
        let zones: BTreeSet<&str> = p
            .instances
            .iter()
            .filter(|i| i.stage == 0)
            .map(|i| i.zone.as_str())
            .collect();
        assert_eq!(zones, ["E1", "E2", "E4"].into_iter().collect());
        // intra-unit edge 0->1 (source -> O1): same zone only
        for from in p.instances_of(0) {
            let fz = p.instances[from].zone.clone();
            for t in p.allowed_targets(topo, from, &p.edges[0]) {
                assert_eq!(p.instances[t].zone, fz);
            }
        }
        // cross-unit edge 1->2: E1/E2 go to S1 instances, E4 to S2
        let edge12 = &p.edges[1];
        for from in p.instances_of(1) {
            let from_zone = p.instances[from].zone.clone();
            let targets = p.allowed_targets(topo, from, edge12);
            assert!(!targets.is_empty());
            let expected = if from_zone == "E4" { "S2" } else { "S1" };
            for t in targets {
                assert_eq!(p.instances[t].zone, expected);
            }
        }
    }

    #[test]
    fn intra_unit_edges_stay_in_zone() {
        let cluster = fig2_cluster();
        let p = plan(
            &eval_graph(),
            &cluster,
            PlannerKind::FlowUnits,
            &["L1".into(), "L4".into()],
            false,
        )
        .unwrap();
        // edge 2->3 (key->window) is intra-unit at the site layer
        let e = &p.edges[2];
        assert!(!e.unit_boundary);
        for from in p.instances_of(2) {
            let fz = p.instances[from].zone.clone();
            for t in p.allowed_targets(&cluster.topology, from, e) {
                assert_eq!(p.instances[t].zone, fz);
            }
        }
    }

    #[test]
    fn constrained_stage_lands_on_gpu_hosts_only() {
        let cluster = fig2_cluster();
        let mut g = LogicalGraph::default();
        g.push(
            OpKind::Source(SourceKind::Synthetic {
                total: 10,
                gen: Arc::new(|_, i| Value::I64(i as i64)),
                rate: None,
            }),
            "cloud".into(),
            None,
            "src",
        );
        g.push(
            OpKind::Map(Arc::new(|v| v)),
            "cloud".into(),
            Some(ConstraintExpr::parse("n_cpu >= 4 && gpu = yes").unwrap()),
            "ml",
        );
        g.push(OpKind::Sink(SinkKind::Count), "cloud".into(), None, "sink");
        let p = plan(&g, &cluster, PlannerKind::FlowUnits, &[], false).unwrap();
        // ml stage = stage 1 (after the source stage): only c1gpu (8 cores)
        let ml = p
            .instances
            .iter()
            .filter(|i| i.stage == 1)
            .collect::<Vec<_>>();
        assert_eq!(ml.len(), 8);
        assert!(ml.iter().all(|i| i.host == "c1gpu"));
        // unconstrained stages use both cloud hosts (16 instances)
        assert_eq!(p.instances_of(0).len(), 16);
        assert_eq!(p.instances_of(2).len(), 16);
    }

    #[test]
    fn infeasible_constraint_is_an_error() {
        let cluster = eval_cluster(None, Duration::ZERO);
        let mut g = LogicalGraph::default();
        g.push(
            OpKind::Source(SourceKind::Synthetic {
                total: 10,
                gen: Arc::new(|_, i| Value::I64(i as i64)),
                rate: None,
            }),
            "edge".into(),
            None,
            "src",
        );
        g.push(
            OpKind::Map(Arc::new(|v| v)),
            "edge".into(),
            Some(ConstraintExpr::parse("gpu = yes").unwrap()),
            "needs-gpu-at-edge",
        );
        g.push(OpKind::Sink(SinkKind::Count), "edge".into(), None, "sink");
        let err = plan(&g, &cluster, PlannerKind::FlowUnits, &[], false).unwrap_err();
        assert!(err.to_string().contains("no host satisfying"));
    }

    #[test]
    fn unknown_location_is_an_error() {
        let cluster = eval_cluster(None, Duration::ZERO);
        let err = plan(
            &eval_graph(),
            &cluster,
            PlannerKind::FlowUnits,
            &["L99".into()],
            false,
        )
        .unwrap_err();
        assert!(err.to_string().contains("L99"));
    }

    #[test]
    fn decoupling_marks_unit_boundaries_only() {
        let cluster = eval_cluster(None, Duration::ZERO);
        let p = plan(&eval_graph(), &cluster, PlannerKind::FlowUnits, &[], true).unwrap();
        // edges: 0->1 (source->O1, intra edge unit), 1->2 (edge->site,
        // boundary), 2->3 (intra site), 3->4 (site->cloud, boundary)
        assert!(!p.edges[0].decoupled);
        assert!(p.edges[1].decoupled);
        assert!(!p.edges[2].decoupled);
        assert!(p.edges[3].decoupled);
    }

    #[test]
    fn replication_policies_scale_instances() {
        use crate::graph::Replication;
        let cluster = eval_cluster(None, Duration::ZERO);
        for (repl, expected_site_instances) in [
            (Replication::PerCore, 8), // 2 hosts × 4 cores
            (Replication::PerHost, 2),
            (Replication::PerZone, 1),
            (Replication::Fixed(3), 3),
            (Replication::Fixed(0), 1), // clamped to at least one
            (Replication::Fixed(99), 8), // capped at zone core capacity
        ] {
            let mut g = LogicalGraph::default();
            let u_edge = g.add_unit(Some("ingest"), "edge".into(), None, Replication::PerCore);
            let u_site = g.add_unit(Some("agg"), "site".into(), None, repl);
            let s = g.add_op(
                OpKind::Source(SourceKind::Synthetic {
                    total: 10,
                    gen: Arc::new(|_, i| Value::I64(i as i64)),
                    rate: None,
                }),
                u_edge,
                vec![],
                "src",
            );
            let m = g.add_op(OpKind::Map(Arc::new(|v| v)), u_site, vec![s], "m");
            g.add_op(OpKind::Sink(SinkKind::Count), u_site, vec![m], "sink");
            let p = plan(&g, &cluster, PlannerKind::FlowUnits, &[], false).unwrap();
            // stage 1 = [m, sink] at the site layer
            assert_eq!(p.instances_of(1).len(), expected_site_instances, "{repl:?}");
        }
    }

    #[test]
    fn union_fanin_edges_decouple_together() {
        use crate::graph::Replication;
        let cluster = eval_cluster(None, Duration::ZERO);
        let mut g = LogicalGraph::default();
        let u_edge = g.add_unit(Some("north"), "edge".into(), None, Replication::PerCore);
        let u_cloud = g.add_unit(Some("merge"), "cloud".into(), None, Replication::PerCore);
        let sa = g.add_op(
            OpKind::Source(SourceKind::Synthetic {
                total: 10,
                gen: Arc::new(|_, i| Value::I64(i as i64)),
                rate: None,
            }),
            u_edge,
            vec![],
            "srcA",
        );
        // srcB lives in the *same* unit as the union, so its edge into the
        // union is intra-unit; srcA's edge crosses a unit boundary
        let sb = g.add_op(
            OpKind::Source(SourceKind::Synthetic {
                total: 10,
                gen: Arc::new(|_, i| Value::I64(i as i64)),
                rate: None,
            }),
            u_cloud,
            vec![],
            "srcB",
        );
        let un = g.add_op(OpKind::Union, u_cloud, vec![sa, sb], "union");
        g.add_op(OpKind::Sink(SinkKind::Count), u_cloud, vec![un], "sink");
        let p = plan(&g, &cluster, PlannerKind::FlowUnits, &[], true).unwrap();
        // stages: [srcA] [srcB] [union, sink] — the union stage has two
        // incoming edges; because the unit-boundary edge from srcA is
        // decoupled, srcB's intra-unit edge must be decoupled too
        let incoming: Vec<_> = p.edges.iter().filter(|e| e.to_stage == 2).collect();
        assert_eq!(incoming.len(), 2);
        assert!(incoming.iter().all(|e| e.decoupled));
        assert_eq!(p.edges.len(), 2);
    }

    #[test]
    fn route_spec_composes_hops() {
        let mut cluster = fig2_cluster();
        cluster.set_uniform_links(LinkSpec {
            bandwidth_bps: Some(100_000_000),
            latency: Duration::from_millis(10),
        });
        // E1 -> C1: two hops up
        let r = route_spec(&cluster, "E1", "C1").unwrap();
        assert_eq!(r.latency, Duration::from_millis(20));
        assert_eq!(r.bandwidth_bps, Some(100_000_000));
        // E1 -> E2: up to S1, down to E2 = 2 hops
        let r = route_spec(&cluster, "E1", "E2").unwrap();
        assert_eq!(r.latency, Duration::from_millis(20));
        // E1 -> E4: E1-S1-C1-S2-E4 = 4 hops
        let r = route_spec(&cluster, "E1", "E4").unwrap();
        assert_eq!(r.latency, Duration::from_millis(40));
        // same zone transparent
        let r = route_spec(&cluster, "S1", "S1").unwrap();
        assert!(r.is_transparent());
    }

    #[test]
    fn ancestor_lookup() {
        let cluster = fig2_cluster();
        let t = &cluster.topology;
        assert_eq!(ancestor_at_layer(t, "E1", "site").unwrap(), "S1");
        assert_eq!(ancestor_at_layer(t, "E4", "cloud").unwrap(), "C1");
        assert_eq!(ancestor_at_layer(t, "C1", "cloud").unwrap(), "C1");
        assert!(ancestor_at_layer(t, "C1", "edge").is_none());
    }

    #[test]
    fn property_flowunits_placement_invariants() {
        use crate::proptest::forall;
        forall("flowunits placement invariants", 60, |g| {
            // random tree: E zones under S zones under one C
            let n_sites = g.usize_in(1, 4);
            let mut text = String::from("layers = edge, site, cloud\n");
            let mut locs: Vec<String> = Vec::new();
            let mut all_locs_by_site: Vec<Vec<String>> = Vec::new();
            let mut li = 0;
            for s in 0..n_sites {
                let n_edges = g.usize_in(1, 4);
                let mut site_locs = Vec::new();
                for _ in 0..n_edges {
                    li += 1;
                    let l = format!("L{li}");
                    text.push_str(&format!(
                        "[zone E{li}]\nlayer = edge\nlocations = {l}\nparent = S{s}\n[host e{li}]\nzone = E{li}\ncores = {}\n",
                        g.usize_in(1, 3)
                    ));
                    site_locs.push(l.clone());
                    locs.push(l);
                }
                text.push_str(&format!(
                    "[zone S{s}]\nlayer = site\nlocations = {}\nparent = C0\n[host s{s}]\nzone = S{s}\ncores = {}\n",
                    site_locs.join(", "),
                    g.usize_in(1, 5)
                ));
                all_locs_by_site.push(site_locs);
            }
            text.push_str(&format!(
                "[zone C0]\nlayer = cloud\nlocations = {}\n[host c0]\nzone = C0\ncores = {}\ncap.gpu = yes\n",
                locs.join(", "),
                g.usize_in(1, 9)
            ));
            let cluster = ClusterSpec::parse(&text).expect("generated cluster parses");
            // random subset of locations (non-empty)
            let mut enabled: Vec<String> =
                locs.iter().filter(|_| g.bool(0.6)).cloned().collect();
            if enabled.is_empty() {
                enabled.push(locs[0].clone());
            }
            let p = match plan(&eval_graph(), &cluster, PlannerKind::FlowUnits, &enabled, false) {
                Ok(p) => p,
                Err(_) => return, // infeasible random combos are fine
            };
            let topo = &cluster.topology;
            for inst in &p.instances {
                let st = &p.stages[inst.stage];
                // host is in the claimed zone and satisfies the constraint
                let host = &topo.hosts[&inst.host];
                assert_eq!(host.zone, inst.zone);
                if let Some(c) = &st.constraint {
                    assert!(c.eval(&host.caps));
                }
                // zone is at the stage layer and covers an enabled location
                let z = &topo.zones[&inst.zone];
                assert_eq!(z.layer, st.layer);
                assert!(z.locations.iter().any(|l| enabled.contains(l)));
            }
            // connectivity: targets are same-zone or the tree ancestor
            for e in &p.edges {
                for from in p.instances_of(e.from_stage) {
                    let fz = p.instances[from].zone.clone();
                    let ts = p.allowed_targets(topo, from, e);
                    assert!(!ts.is_empty());
                    for t in ts {
                        let tz = &p.instances[t].zone;
                        if p.stages[e.from_stage].unit_index == p.stages[e.to_stage].unit_index {
                            assert_eq!(tz, &fz);
                        } else {
                            assert_eq!(
                                Some(tz.clone()),
                                ancestor_at_layer(topo, &fz, &p.stages[e.to_stage].layer)
                            );
                        }
                    }
                }
            }
        });
    }
}
