//! Dynamic event values flowing through the dataflow graph.
//!
//! The engine is dynamically typed: every event is a [`Value`]. This keeps
//! operator plumbing, cross-host serialization, and the queue substrate
//! simple while still covering every workload in the paper (sensor readings,
//! words, windowed feature vectors, anomaly scores).
//!
//! Values that cross a host boundary are encoded with the compact binary
//! codec in this module (tag byte + payload, varint lengths); values that
//! stay on the same host move by pointer.
//!
//! The [`StreamData`] trait maps native Rust types onto this dynamic
//! representation; it is the contract behind the typed front-end
//! (`api::typed`), which lets user closures work with `i64`/`String`/tuple
//! values while the engine underneath keeps exchanging [`Value`] batches.

use crate::columnar::{Column, ColumnBatch, Layout};
use crate::error::{Error, Result};
use std::sync::atomic::{AtomicU64, Ordering};
use std::sync::{Arc, OnceLock};

/// A dynamically-typed event.
#[derive(Debug, Clone, PartialEq)]
pub enum Value {
    /// Absence of a value.
    Null,
    /// Boolean.
    Bool(bool),
    /// 64-bit signed integer.
    I64(i64),
    /// 64-bit float.
    F64(f64),
    /// UTF-8 string.
    Str(String),
    /// Pair (used for keyed records: `(key, payload)`). Single-boxed:
    /// one allocation per keyed event instead of two (hot-path relevant,
    /// see EXPERIMENTS.md §Perf).
    Pair(Box<(Value, Value)>),
    /// Heterogeneous list.
    List(Vec<Value>),
    /// Dense f32 vector (feature vectors fed to the XLA operator).
    F32s(Vec<f32>),
}

impl Value {
    /// Convenience constructor for a keyed record.
    pub fn pair(k: Value, v: Value) -> Value {
        Value::Pair(Box::new((k, v)))
    }

    /// Returns the integer payload, if this is an `I64`.
    pub fn as_i64(&self) -> Option<i64> {
        match self {
            Value::I64(v) => Some(*v),
            _ => None,
        }
    }

    /// Returns the float payload for `F64` (or converting `I64`).
    pub fn as_f64(&self) -> Option<f64> {
        match self {
            Value::F64(v) => Some(*v),
            Value::I64(v) => Some(*v as f64),
            _ => None,
        }
    }

    /// Returns the string payload, if this is a `Str`.
    pub fn as_str(&self) -> Option<&str> {
        match self {
            Value::Str(s) => Some(s),
            _ => None,
        }
    }

    /// Returns `(key, value)` references, if this is a `Pair`.
    pub fn as_pair(&self) -> Option<(&Value, &Value)> {
        match self {
            Value::Pair(kv) => Some((&kv.0, &kv.1)),
            _ => None,
        }
    }

    /// Consumes a `Pair`, returning its parts.
    pub fn into_pair(self) -> Option<(Value, Value)> {
        match self {
            Value::Pair(kv) => Some((kv.0, kv.1)),
            _ => None,
        }
    }

    /// Returns the f32 vector, if this is `F32s`.
    pub fn as_f32s(&self) -> Option<&[f32]> {
        match self {
            Value::F32s(v) => Some(v),
            _ => None,
        }
    }

    /// Returns the list elements, if this is a `List`.
    pub fn as_list(&self) -> Option<&[Value]> {
        match self {
            Value::List(v) => Some(v),
            _ => None,
        }
    }

    /// Name of this value's variant (diagnostics; decode-error messages).
    pub fn kind_name(&self) -> &'static str {
        match self {
            Value::Null => "Null",
            Value::Bool(_) => "Bool",
            Value::I64(_) => "I64",
            Value::F64(_) => "F64",
            Value::Str(_) => "Str",
            Value::Pair(_) => "Pair",
            Value::List(_) => "List",
            Value::F32s(_) => "F32s",
        }
    }

    /// Stable 64-bit hash of the value, used for key partitioning.
    ///
    /// Every sender must agree on `hash(key) % n_instances`, so this must be
    /// deterministic across hosts — we use FNV-1a over the canonical
    /// encoding of the value.
    pub fn stable_hash(&self) -> u64 {
        let mut h = Fnv1a::new();
        self.hash_into(&mut h);
        h.finish()
    }

    fn hash_into(&self, h: &mut Fnv1a) {
        match self {
            Value::Null => h.write_u8(0),
            Value::Bool(b) => {
                h.write_u8(1);
                h.write_u8(*b as u8);
            }
            Value::I64(v) => {
                h.write_u8(2);
                h.write(&v.to_le_bytes());
            }
            Value::F64(v) => {
                h.write_u8(3);
                h.write(&v.to_bits().to_le_bytes());
            }
            Value::Str(s) => {
                h.write_u8(4);
                h.write(s.as_bytes());
            }
            Value::Pair(kv) => {
                h.write_u8(5);
                kv.0.hash_into(h);
                kv.1.hash_into(h);
            }
            Value::List(vs) => {
                h.write_u8(6);
                for v in vs {
                    v.hash_into(h);
                }
            }
            Value::F32s(vs) => {
                h.write_u8(7);
                for v in vs {
                    h.write(&v.to_bits().to_le_bytes());
                }
            }
        }
    }

    /// Approximate in-memory footprint when serialized, in bytes. Used by
    /// the network emulation layer for bandwidth accounting without paying
    /// for a full encode when channels stay in-process.
    pub fn encoded_size(&self) -> usize {
        match self {
            Value::Null => 1,
            Value::Bool(_) => 2,
            Value::I64(_) => 9,
            Value::F64(_) => 9,
            Value::Str(s) => 1 + varint_len(s.len() as u64) + s.len(),
            Value::Pair(kv) => 1 + kv.0.encoded_size() + kv.1.encoded_size(),
            Value::List(vs) => {
                1 + varint_len(vs.len() as u64) + vs.iter().map(|v| v.encoded_size()).sum::<usize>()
            }
            Value::F32s(vs) => 1 + varint_len(vs.len() as u64) + 4 * vs.len(),
        }
    }

    /// Appends the canonical binary encoding of `self` to `out`.
    pub fn encode_into(&self, out: &mut Vec<u8>) {
        match self {
            Value::Null => out.push(TAG_NULL),
            Value::Bool(b) => {
                out.push(TAG_BOOL);
                out.push(*b as u8);
            }
            Value::I64(v) => {
                out.push(TAG_I64);
                out.extend_from_slice(&v.to_le_bytes());
            }
            Value::F64(v) => {
                out.push(TAG_F64);
                out.extend_from_slice(&v.to_bits().to_le_bytes());
            }
            Value::Str(s) => {
                out.push(TAG_STR);
                write_varint(out, s.len() as u64);
                out.extend_from_slice(s.as_bytes());
            }
            Value::Pair(kv) => {
                out.push(TAG_PAIR);
                kv.0.encode_into(out);
                kv.1.encode_into(out);
            }
            Value::List(vs) => {
                out.push(TAG_LIST);
                write_varint(out, vs.len() as u64);
                for v in vs {
                    v.encode_into(out);
                }
            }
            Value::F32s(vs) => {
                out.push(TAG_F32S);
                write_varint(out, vs.len() as u64);
                for v in vs {
                    out.extend_from_slice(&v.to_bits().to_le_bytes());
                }
            }
        }
    }

    /// Encodes `self` into a fresh buffer.
    pub fn encode(&self) -> Vec<u8> {
        let mut out = Vec::with_capacity(self.encoded_size());
        self.encode_into(&mut out);
        out
    }

    /// Decodes one value from the front of `cur`.
    pub fn decode(cur: &mut Cursor<'_>) -> Result<Value> {
        let tag = cur.u8()?;
        Ok(match tag {
            TAG_NULL => Value::Null,
            TAG_BOOL => Value::Bool(cur.u8()? != 0),
            TAG_I64 => Value::I64(i64::from_le_bytes(cur.array()?)),
            TAG_F64 => Value::F64(f64::from_bits(u64::from_le_bytes(cur.array()?))),
            TAG_STR => {
                let n = cur.varint()? as usize;
                let bytes = cur.take(n)?;
                Value::Str(
                    String::from_utf8(bytes.to_vec())
                        .map_err(|_| Error::Codec("invalid utf-8 in Str".into()))?,
                )
            }
            TAG_PAIR => {
                let k = Value::decode(cur)?;
                let v = Value::decode(cur)?;
                Value::pair(k, v)
            }
            TAG_LIST => {
                let n = cur.varint()? as usize;
                if n > cur.remaining() {
                    return Err(Error::Codec(format!("list length {n} exceeds frame")));
                }
                let mut vs = Vec::with_capacity(n);
                for _ in 0..n {
                    vs.push(Value::decode(cur)?);
                }
                Value::List(vs)
            }
            TAG_F32S => {
                let n = cur.varint()? as usize;
                if n * 4 > cur.remaining() {
                    return Err(Error::Codec(format!("f32s length {n} exceeds frame")));
                }
                let mut vs = Vec::with_capacity(n);
                for _ in 0..n {
                    vs.push(f32::from_bits(u32::from_le_bytes(cur.array()?)));
                }
                Value::F32s(vs)
            }
            t => return Err(Error::Codec(format!("unknown value tag {t}"))),
        })
    }

    /// Decodes a value from a standalone buffer, requiring full consumption.
    pub fn decode_exact(buf: &[u8]) -> Result<Value> {
        let mut cur = Cursor::new(buf);
        let v = Value::decode(&mut cur)?;
        if cur.remaining() != 0 {
            return Err(Error::Codec(format!(
                "{} trailing bytes after value",
                cur.remaining()
            )));
        }
        Ok(v)
    }
}

const TAG_NULL: u8 = 0;
const TAG_BOOL: u8 = 1;
const TAG_I64: u8 = 2;
const TAG_F64: u8 = 3;
const TAG_STR: u8 = 4;
const TAG_PAIR: u8 = 5;
const TAG_LIST: u8 = 6;
const TAG_F32S: u8 = 7;

/// Native Rust types that can travel through the dataflow engine.
///
/// The engine's data plane is dynamically typed — every event is a
/// [`Value`] — but the typed front-end (`api::typed`) lets user closures
/// work with native types. `StreamData` is the bridge: [`into_value`]
/// encodes a native value at the graph boundary, [`try_from_value`]
/// decodes it back on the way into a typed closure or out of a typed
/// collect sink. A shape mismatch is a recoverable
/// [`Error::Decode`](crate::error::Error::Decode), never a panic.
///
/// Provided implementations:
///
/// | Rust type | `Value` representation |
/// | --- | --- |
/// | `i64` | `I64` |
/// | `f64` | `F64` (decodes `I64` too, mirroring [`Value::as_f64`]) |
/// | `bool` | `Bool` |
/// | `String` | `Str` |
/// | `(A, B)` | `Pair` — the engine's keyed-record shape |
/// | `(A, B, C)` | `List` of three elements |
/// | `Vec<T>` | `List` |
/// | `Value` | itself (the escape hatch; never fails to decode) |
///
/// `api::data::Features` additionally maps a dense `f32` feature row onto
/// `F32s` for windowed feature extraction and the XLA operator.
///
/// [`into_value`]: StreamData::into_value
/// [`try_from_value`]: StreamData::try_from_value
pub trait StreamData: Sized + Send + Sync + 'static {
    /// Encodes `self` as the engine's dynamic [`Value`].
    fn into_value(self) -> Value;
    /// Decodes an engine [`Value`] back into the native type; a shape
    /// mismatch is an [`Error::Decode`](crate::error::Error::Decode).
    fn try_from_value(v: Value) -> Result<Self>;

    /// The static columnar [`Layout`] of this type, when it has one.
    ///
    /// `Some` means batches of this type can travel as a
    /// [`ColumnBatch`] (struct-of-arrays native columns) instead of
    /// boxed [`Value`] rows, and the typed front-end lowers operators on
    /// it to the monomorphized columnar executors. The default is `None`
    /// — the type flows as `Value` rows (`Value` itself, `Vec<T>`,
    /// `Features`, and any user type without a static shape).
    fn layout() -> Option<Layout> {
        None
    }

    /// Number of flattened leaf columns of [`layout`](StreamData::layout)
    /// (tuples split their fields without allocating a `Layout` tree,
    /// which keeps per-record column access allocation-free).
    fn column_count() -> usize {
        1
    }

    /// Appends `self` as one row across `cols` — exactly
    /// [`column_count`](StreamData::column_count) columns matching
    /// [`layout`](StreamData::layout). Only called for types whose
    /// `layout()` is `Some`.
    fn append_columns(self, _cols: &mut [Column]) {
        unreachable!("append_columns on a non-columnar StreamData type")
    }

    /// Reads row `row` of `cols` (same shape contract as
    /// [`append_columns`](StreamData::append_columns)) back as a native
    /// value. Only called for types whose `layout()` is `Some`.
    fn read_columns(_cols: &[Column], _row: usize) -> Self {
        unreachable!("read_columns on a non-columnar StreamData type")
    }
}

/// The [`Error::Decode`](crate::error::Error::Decode) a [`StreamData`]
/// implementation should return on a shape mismatch: names the expected
/// Rust type and the [`Value`] variant actually found.
pub fn decode_mismatch<T>(got: &Value) -> Error {
    Error::Decode(format!(
        "expected {}, got Value::{}",
        std::any::type_name::<T>(),
        got.kind_name()
    ))
}

impl StreamData for Value {
    fn into_value(self) -> Value {
        self
    }
    fn try_from_value(v: Value) -> Result<Value> {
        Ok(v)
    }
}

impl StreamData for i64 {
    fn into_value(self) -> Value {
        Value::I64(self)
    }
    fn try_from_value(v: Value) -> Result<i64> {
        match v {
            Value::I64(x) => Ok(x),
            other => Err(decode_mismatch::<i64>(&other)),
        }
    }
    fn layout() -> Option<Layout> {
        Some(Layout::I64)
    }
    fn append_columns(self, cols: &mut [Column]) {
        match &mut cols[0] {
            Column::I64(c) => c.push(self),
            _ => unreachable!("i64 column expected"),
        }
    }
    fn read_columns(cols: &[Column], row: usize) -> i64 {
        match &cols[0] {
            Column::I64(c) => c[row],
            _ => unreachable!("i64 column expected"),
        }
    }
}

impl StreamData for f64 {
    /// Decoding accepts `I64` too (mirroring [`Value::as_f64`]); like
    /// that conversion, integers with magnitude above 2^53 lose
    /// precision. Mixed raw/typed pipelines that must preserve full
    /// 64-bit integers should type the stream as `i64` or `Value`.
    fn into_value(self) -> Value {
        Value::F64(self)
    }
    fn try_from_value(v: Value) -> Result<f64> {
        match v {
            Value::F64(x) => Ok(x),
            Value::I64(x) => Ok(x as f64),
            other => Err(decode_mismatch::<f64>(&other)),
        }
    }
    fn layout() -> Option<Layout> {
        Some(Layout::F64)
    }
    fn append_columns(self, cols: &mut [Column]) {
        match &mut cols[0] {
            Column::F64(c) => c.push(self),
            _ => unreachable!("f64 column expected"),
        }
    }
    fn read_columns(cols: &[Column], row: usize) -> f64 {
        match &cols[0] {
            Column::F64(c) => c[row],
            _ => unreachable!("f64 column expected"),
        }
    }
}

impl StreamData for bool {
    fn into_value(self) -> Value {
        Value::Bool(self)
    }
    fn try_from_value(v: Value) -> Result<bool> {
        match v {
            Value::Bool(x) => Ok(x),
            other => Err(decode_mismatch::<bool>(&other)),
        }
    }
    fn layout() -> Option<Layout> {
        Some(Layout::Bool)
    }
    fn append_columns(self, cols: &mut [Column]) {
        match &mut cols[0] {
            Column::Bool(c) => c.push(self),
            _ => unreachable!("bool column expected"),
        }
    }
    fn read_columns(cols: &[Column], row: usize) -> bool {
        match &cols[0] {
            Column::Bool(c) => c[row],
            _ => unreachable!("bool column expected"),
        }
    }
}

impl StreamData for String {
    fn into_value(self) -> Value {
        Value::Str(self)
    }
    fn try_from_value(v: Value) -> Result<String> {
        match v {
            Value::Str(x) => Ok(x),
            other => Err(decode_mismatch::<String>(&other)),
        }
    }
    fn layout() -> Option<Layout> {
        Some(Layout::Str)
    }
    fn append_columns(self, cols: &mut [Column]) {
        match &mut cols[0] {
            Column::Str(c) => c.push(self),
            _ => unreachable!("String column expected"),
        }
    }
    fn read_columns(cols: &[Column], row: usize) -> String {
        match &cols[0] {
            Column::Str(c) => c[row].clone(),
            _ => unreachable!("String column expected"),
        }
    }
}

impl<A: StreamData, B: StreamData> StreamData for (A, B) {
    fn into_value(self) -> Value {
        Value::pair(self.0.into_value(), self.1.into_value())
    }
    fn try_from_value(v: Value) -> Result<(A, B)> {
        match v {
            Value::Pair(kv) => {
                let (a, b) = *kv;
                Ok((A::try_from_value(a)?, B::try_from_value(b)?))
            }
            other => Err(decode_mismatch::<(A, B)>(&other)),
        }
    }
    fn layout() -> Option<Layout> {
        Some(Layout::Pair(
            Box::new(A::layout()?),
            Box::new(B::layout()?),
        ))
    }
    fn column_count() -> usize {
        A::column_count() + B::column_count()
    }
    fn append_columns(self, cols: &mut [Column]) {
        let (a, b) = cols.split_at_mut(A::column_count());
        self.0.append_columns(a);
        self.1.append_columns(b);
    }
    fn read_columns(cols: &[Column], row: usize) -> (A, B) {
        let (a, b) = cols.split_at(A::column_count());
        (A::read_columns(a, row), B::read_columns(b, row))
    }
}

impl<A: StreamData, B: StreamData, C: StreamData> StreamData for (A, B, C) {
    fn into_value(self) -> Value {
        Value::List(vec![
            self.0.into_value(),
            self.1.into_value(),
            self.2.into_value(),
        ])
    }
    fn try_from_value(v: Value) -> Result<(A, B, C)> {
        match v {
            Value::List(l) if l.len() == 3 => {
                let mut it = l.into_iter();
                Ok((
                    A::try_from_value(it.next().unwrap())?,
                    B::try_from_value(it.next().unwrap())?,
                    C::try_from_value(it.next().unwrap())?,
                ))
            }
            other => Err(decode_mismatch::<(A, B, C)>(&other)),
        }
    }
    fn layout() -> Option<Layout> {
        Some(Layout::Triple(
            Box::new(A::layout()?),
            Box::new(B::layout()?),
            Box::new(C::layout()?),
        ))
    }
    fn column_count() -> usize {
        A::column_count() + B::column_count() + C::column_count()
    }
    fn append_columns(self, cols: &mut [Column]) {
        let (a, rest) = cols.split_at_mut(A::column_count());
        let (b, c) = rest.split_at_mut(B::column_count());
        self.0.append_columns(a);
        self.1.append_columns(b);
        self.2.append_columns(c);
    }
    fn read_columns(cols: &[Column], row: usize) -> (A, B, C) {
        let (a, rest) = cols.split_at(A::column_count());
        let (b, c) = rest.split_at(B::column_count());
        (
            A::read_columns(a, row),
            B::read_columns(b, row),
            C::read_columns(c, row),
        )
    }
}

impl<T: StreamData> StreamData for Vec<T> {
    fn into_value(self) -> Value {
        Value::List(self.into_iter().map(StreamData::into_value).collect())
    }
    fn try_from_value(v: Value) -> Result<Vec<T>> {
        match v {
            Value::List(l) => l.into_iter().map(T::try_from_value).collect(),
            other => Err(decode_mismatch::<Vec<T>>(&other)),
        }
    }
}

/// Encodes a batch of values as one frame body (count-prefixed).
pub fn encode_batch(batch: &[Value]) -> Vec<u8> {
    let mut out = Vec::with_capacity(8 + batch.iter().map(|v| v.encoded_size()).sum::<usize>());
    write_varint(&mut out, batch.len() as u64);
    for v in batch {
        v.encode_into(&mut out);
    }
    out
}

/// Decodes a frame body produced by [`encode_batch`].
pub fn decode_batch(buf: &[u8]) -> Result<Vec<Value>> {
    let mut cur = Cursor::new(buf);
    let n = cur.varint()? as usize;
    if n > buf.len() {
        return Err(Error::Codec(format!("batch count {n} exceeds frame")));
    }
    let mut out = Vec::with_capacity(n);
    for _ in 0..n {
        out.push(Value::decode(&mut cur)?);
    }
    if cur.remaining() != 0 {
        return Err(Error::Codec("trailing bytes after batch".into()));
    }
    Ok(out)
}

/// A reference-counted batch of values — the unit of exchange on the data
/// plane.
///
/// Cloning a `Batch` bumps a refcount; the `Vec<Value>` payload is never
/// deep-copied by the transport layers (`split` fan-out and `Broadcast`
/// routing share one allocation across all edges). The wire encoding is
/// computed lazily on the first cross-host delivery and cached, so a batch
/// that traverses several zone-crossing edges is encoded exactly once —
/// every clone sees the same cache. A batch decoded from a frame keeps the
/// frame bytes as its cache (the codec is canonical), so re-forwarding a
/// received batch across another boundary re-uses the original bytes.
///
/// Mutation is copy-on-write via [`Batch::into_values`]: the sole owner of
/// a batch takes the payload allocation back intact (pointer identity —
/// single-owner operator chains mutate in place), while a shared batch
/// yields a private clone, so downstream mutation is never observable on a
/// sibling edge. The cache cannot go stale: values behind the `Arc` are
/// immutable, and `into_values` detaches from the shared cell entirely.
#[derive(Clone, Debug)]
pub struct Batch {
    inner: Arc<BatchInner>,
}

#[derive(Debug)]
struct BatchInner {
    values: Vec<Value>,
    /// Lazily computed, cached wire encoding ([`encode_batch`] framing).
    wire: OnceLock<Arc<[u8]>>,
    /// Optional per-record key-hash column, aligned with `values`:
    /// `key_hashes[i]` is the routing hash of `values[i]` (the pair key's
    /// [`Value::stable_hash`] for keyed records). Populated by the keying
    /// operators at pair-construction time so hash shuffles read one `u64`
    /// per record instead of re-walking the `Value` tree. Local-only: the
    /// column is never serialized — a batch decoded from a frame carries
    /// no column and shuffles fall back to hashing on the fly.
    key_hashes: Option<Vec<u64>>,
}

impl Batch {
    /// Wraps `values` as a batch (no encoding is performed).
    pub fn new(values: Vec<Value>) -> Batch {
        Batch {
            inner: Arc::new(BatchInner {
                values,
                wire: OnceLock::new(),
                key_hashes: None,
            }),
        }
    }

    /// Wraps `values` as a batch carrying a per-record key-hash column
    /// (`hashes[i]` must be the routing hash of `values[i]`). A length
    /// mismatch is a routing bug upstream: it trips a debug assertion,
    /// and in release builds it is counted via
    /// [`hash_column_mismatches`] before the column is discarded, so the
    /// silent degradation to hash-on-the-fly stays observable.
    pub fn with_hashes(values: Vec<Value>, hashes: Vec<u64>) -> Batch {
        let key_hashes = if hashes.len() == values.len() {
            Some(hashes)
        } else {
            note_hash_column_mismatch();
            debug_assert_eq!(
                hashes.len(),
                values.len(),
                "key-hash column misaligned with batch"
            );
            None
        };
        Batch {
            inner: Arc::new(BatchInner {
                values,
                wire: OnceLock::new(),
                key_hashes,
            }),
        }
    }

    /// A shared, process-wide empty batch: returning it is a refcount
    /// bump, so empty chain outputs allocate nothing on the hot path.
    pub fn empty() -> Batch {
        static EMPTY: OnceLock<Batch> = OnceLock::new();
        EMPTY.get_or_init(|| Batch::new(Vec::new())).clone()
    }

    /// Decodes a batch from its wire encoding, retaining `wire` as the
    /// cached encoding (valid because the codec is canonical: encoding the
    /// decoded values reproduces `wire` byte-for-byte).
    pub fn from_wire(wire: Arc<[u8]>) -> Result<Batch> {
        let values = decode_batch(&wire)?;
        let cell = OnceLock::new();
        let _ = cell.set(wire);
        Ok(Batch {
            inner: Arc::new(BatchInner {
                values,
                wire: cell,
                key_hashes: None,
            }),
        })
    }

    /// The batch payload.
    pub fn values(&self) -> &[Value] {
        &self.inner.values
    }

    /// Number of records in the batch.
    pub fn len(&self) -> usize {
        self.inner.values.len()
    }

    /// True when the batch holds no records.
    pub fn is_empty(&self) -> bool {
        self.inner.values.is_empty()
    }

    /// The wire encoding, computed on first use and cached for every clone
    /// of this batch (at most one encode per batch, ever — `OnceLock`
    /// serialises racing encoders down to a single run).
    pub fn wire(&self) -> Arc<[u8]> {
        self.wire_with(|| {})
    }

    /// [`Batch::wire`] with an `on_encode` hook that runs *inside* the
    /// one-time initialiser — exact encode accounting even when several
    /// threads race on a shared batch (the hook fires exactly once per
    /// batch, on the thread that actually pays the encode).
    pub fn wire_with(&self, on_encode: impl FnOnce()) -> Arc<[u8]> {
        self.inner
            .wire
            .get_or_init(|| {
                on_encode();
                Arc::from(encode_batch(&self.inner.values))
            })
            .clone()
    }

    /// The cached wire encoding, if one has been computed — encode-count
    /// instrumentation for tests and the delivery layer.
    pub fn wire_cached(&self) -> Option<Arc<[u8]>> {
        self.inner.wire.get().cloned()
    }

    /// True when this handle is the sole owner of the payload.
    pub fn is_unique(&self) -> bool {
        Arc::strong_count(&self.inner) == 1
    }

    /// True when `a` and `b` share one payload allocation (zero-copy
    /// fan-out instrumentation).
    pub fn ptr_eq(a: &Batch, b: &Batch) -> bool {
        Arc::ptr_eq(&a.inner, &b.inner)
    }

    /// The per-record key-hash column, if the batch carries one (see
    /// [`Batch::with_hashes`]).
    pub fn key_hashes(&self) -> Option<&[u64]> {
        self.inner.key_hashes.as_deref()
    }

    /// Takes the payload, copy-on-write: the sole owner recovers the
    /// original allocation (in-place mutation downstream); a shared batch
    /// gets a private clone, leaving every sibling untouched.
    pub fn into_values(self) -> Vec<Value> {
        match Arc::try_unwrap(self.inner) {
            Ok(inner) => inner.values,
            Err(shared) => shared.values.clone(),
        }
    }

    /// [`Batch::into_values`] plus the key-hash column (if any), for
    /// consumers that partition by hash while taking the payload.
    pub fn into_parts(self) -> (Vec<Value>, Option<Vec<u64>>) {
        match Arc::try_unwrap(self.inner) {
            Ok(inner) => (inner.values, inner.key_hashes),
            Err(shared) => (shared.values.clone(), shared.key_hashes.clone()),
        }
    }
}

impl From<Vec<Value>> for Batch {
    fn from(values: Vec<Value>) -> Batch {
        Batch::new(values)
    }
}

impl IntoIterator for Batch {
    type Item = Value;
    type IntoIter = std::vec::IntoIter<Value>;
    fn into_iter(self) -> Self::IntoIter {
        self.into_values().into_iter()
    }
}

impl PartialEq for Batch {
    fn eq(&self, other: &Batch) -> bool {
        self.values() == other.values()
    }
}

impl PartialEq<Vec<Value>> for Batch {
    fn eq(&self, other: &Vec<Value>) -> bool {
        self.values() == other.as_slice()
    }
}

impl PartialEq<&[Value]> for Batch {
    fn eq(&self, other: &&[Value]) -> bool {
        self.values() == *other
    }
}

static HASH_COLUMN_MISMATCHES: AtomicU64 = AtomicU64::new(0);

/// Process-wide count of batches constructed with a hash column whose
/// length did not match the payload (see [`Batch::with_hashes`] and
/// `ColumnBatch::with_hashes`). Each mismatch silently costs a re-hash
/// per record at the next shuffle, so a nonzero value flags a routing
/// bug that would otherwise only show up as throughput loss.
pub fn hash_column_mismatches() -> u64 {
    HASH_COLUMN_MISMATCHES.load(Ordering::Relaxed)
}

pub(crate) fn note_hash_column_mismatch() {
    HASH_COLUMN_MISMATCHES.fetch_add(1, Ordering::Relaxed);
}

/// A batch in either of the data plane's representations: dynamic
/// [`Value`] rows or typed struct-of-arrays columns.
///
/// The row form is the universal one — every operator accepts it, and it
/// is the only form that crosses the wire. The columnar form exists on
/// the hot path between typed columnar sources/operators; anything that
/// cannot consume columns materializes rows via
/// [`BatchData::into_rows`] (exact `Value` parity by construction).
#[derive(Clone, Debug)]
pub enum BatchData {
    /// Dynamic row representation.
    Rows(Batch),
    /// Typed columnar representation.
    Columns(ColumnBatch),
}

impl BatchData {
    /// Number of records in the batch, in either representation.
    pub fn len(&self) -> usize {
        match self {
            BatchData::Rows(b) => b.len(),
            BatchData::Columns(c) => c.len(),
        }
    }

    /// True when the batch holds no records.
    pub fn is_empty(&self) -> bool {
        self.len() == 0
    }

    /// Materializes the row representation (a refcount bump when the
    /// batch already is rows).
    pub fn into_rows(self) -> Batch {
        match self {
            BatchData::Rows(b) => b,
            BatchData::Columns(c) => c.to_batch(),
        }
    }
}

impl From<Batch> for BatchData {
    fn from(b: Batch) -> BatchData {
        BatchData::Rows(b)
    }
}

impl From<ColumnBatch> for BatchData {
    fn from(c: ColumnBatch) -> BatchData {
        BatchData::Columns(c)
    }
}

impl From<Vec<Value>> for BatchData {
    fn from(values: Vec<Value>) -> BatchData {
        BatchData::Rows(Batch::new(values))
    }
}

/// Byte cursor for decoding.
pub struct Cursor<'a> {
    buf: &'a [u8],
    pos: usize,
}

impl<'a> Cursor<'a> {
    /// Creates a cursor over `buf`.
    pub fn new(buf: &'a [u8]) -> Self {
        Cursor { buf, pos: 0 }
    }

    /// Bytes left to read.
    pub fn remaining(&self) -> usize {
        self.buf.len() - self.pos
    }

    fn u8(&mut self) -> Result<u8> {
        if self.pos >= self.buf.len() {
            return Err(Error::Codec("unexpected end of frame".into()));
        }
        let b = self.buf[self.pos];
        self.pos += 1;
        Ok(b)
    }

    fn take(&mut self, n: usize) -> Result<&'a [u8]> {
        if self.remaining() < n {
            return Err(Error::Codec("unexpected end of frame".into()));
        }
        let s = &self.buf[self.pos..self.pos + n];
        self.pos += n;
        Ok(s)
    }

    fn array<const N: usize>(&mut self) -> Result<[u8; N]> {
        let s = self.take(N)?;
        let mut a = [0u8; N];
        a.copy_from_slice(s);
        Ok(a)
    }

    fn varint(&mut self) -> Result<u64> {
        let mut v = 0u64;
        let mut shift = 0;
        loop {
            let b = self.u8()?;
            v |= ((b & 0x7f) as u64) << shift;
            if b & 0x80 == 0 {
                return Ok(v);
            }
            shift += 7;
            if shift >= 64 {
                return Err(Error::Codec("varint overflow".into()));
            }
        }
    }
}

/// LEB128 varint encoding.
pub fn write_varint(out: &mut Vec<u8>, mut v: u64) {
    loop {
        let b = (v & 0x7f) as u8;
        v >>= 7;
        if v == 0 {
            out.push(b);
            return;
        }
        out.push(b | 0x80);
    }
}

fn varint_len(v: u64) -> usize {
    let bits = 64 - v.leading_zeros().max(0) as usize;
    std::cmp::max(1, bits.div_ceil(7))
}

/// FNV-1a 64-bit hasher (deterministic across hosts/platforms). Also
/// implements [`std::hash::Hasher`], so it doubles as the hasher of the
/// runtime's keyed-state maps — one FNV implementation serves both
/// routing (`stable_hash`) and state lookup. Initialization is explicit
/// (the offset basis is written at construction), so an intermediate
/// state that legitimately lands on 0 keeps hashing from 0.
pub struct Fnv1a(u64);

impl Fnv1a {
    /// Creates a hasher with the standard offset basis.
    pub fn new() -> Self {
        Fnv1a(0xcbf2_9ce4_8422_2325)
    }

    /// A hasher whose state is already `state` (test seam: stands in for
    /// a byte sequence whose intermediate FNV state lands there).
    #[cfg_attr(not(test), allow(dead_code))]
    pub(crate) fn from_state(state: u64) -> Self {
        Fnv1a(state)
    }

    /// Absorbs one byte.
    pub fn write_u8(&mut self, b: u8) {
        self.0 ^= b as u64;
        self.0 = self.0.wrapping_mul(0x1_0000_01b3);
    }

    /// Absorbs a byte slice.
    pub fn write(&mut self, bytes: &[u8]) {
        for &b in bytes {
            self.write_u8(b);
        }
    }

    /// Finalizes the hash.
    pub fn finish(&self) -> u64 {
        self.0
    }
}

impl Default for Fnv1a {
    fn default() -> Self {
        Self::new()
    }
}

impl std::hash::Hasher for Fnv1a {
    fn write(&mut self, bytes: &[u8]) {
        Fnv1a::write(self, bytes);
    }
    fn finish(&self) -> u64 {
        Fnv1a::finish(self)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn roundtrip(v: Value) {
        let enc = v.encode();
        assert_eq!(enc.len(), v.encoded_size(), "encoded_size mismatch for {v:?}");
        let dec = Value::decode_exact(&enc).unwrap();
        assert_eq!(v, dec);
    }

    #[test]
    fn roundtrip_scalars() {
        roundtrip(Value::Null);
        roundtrip(Value::Bool(true));
        roundtrip(Value::Bool(false));
        roundtrip(Value::I64(0));
        roundtrip(Value::I64(-1));
        roundtrip(Value::I64(i64::MAX));
        roundtrip(Value::I64(i64::MIN));
        roundtrip(Value::F64(3.25));
        // NaN compares unequal to itself; bit preservation is covered by
        // `nan_roundtrip_preserves_bits` below.
    }

    #[test]
    fn roundtrip_composites() {
        roundtrip(Value::Str(String::new()));
        roundtrip(Value::Str("héllo wörld".into()));
        roundtrip(Value::pair(Value::Str("k".into()), Value::I64(7)));
        roundtrip(Value::List(vec![
            Value::I64(1),
            Value::Str("x".into()),
            Value::pair(Value::Null, Value::F64(2.0)),
        ]));
        roundtrip(Value::F32s(vec![]));
        roundtrip(Value::F32s(vec![1.0, -2.5, f32::INFINITY]));
    }

    #[test]
    fn nan_roundtrip_preserves_bits() {
        let v = Value::F64(f64::from_bits(0x7ff8_dead_beef_0001));
        let dec = Value::decode_exact(&v.encode()).unwrap();
        match dec {
            Value::F64(f) => assert_eq!(f.to_bits(), 0x7ff8_dead_beef_0001),
            _ => panic!(),
        }
    }

    #[test]
    fn batch_roundtrip() {
        let batch: Vec<Value> = (0..100)
            .map(|i| Value::pair(Value::I64(i), Value::Str(format!("v{i}"))))
            .collect();
        let enc = encode_batch(&batch);
        let dec = decode_batch(&enc).unwrap();
        assert_eq!(batch, dec);
    }

    #[test]
    fn empty_batch_roundtrip() {
        let enc = encode_batch(&[]);
        assert_eq!(decode_batch(&enc).unwrap(), Vec::<Value>::new());
    }

    #[test]
    fn decode_rejects_truncation() {
        let enc = Value::Str("hello".into()).encode();
        for cut in 0..enc.len() {
            assert!(Value::decode_exact(&enc[..cut]).is_err());
        }
    }

    #[test]
    fn decode_rejects_trailing_garbage() {
        let mut enc = Value::I64(1).encode();
        enc.push(0);
        assert!(Value::decode_exact(&enc).is_err());
    }

    #[test]
    fn decode_rejects_bad_tag() {
        assert!(Value::decode_exact(&[200]).is_err());
    }

    #[test]
    fn decode_rejects_huge_list_len() {
        // tag LIST + varint claiming 2^40 entries
        let mut buf = vec![TAG_LIST];
        write_varint(&mut buf, 1 << 40);
        assert!(Value::decode_exact(&buf).is_err());
    }

    #[test]
    fn stable_hash_is_deterministic_and_discriminates() {
        let a = Value::Str("alpha".into()).stable_hash();
        let b = Value::Str("alpha".into()).stable_hash();
        let c = Value::Str("beta".into()).stable_hash();
        assert_eq!(a, b);
        assert_ne!(a, c);
        // I64(1) and Bool(true) must not collide via tag bytes
        assert_ne!(Value::I64(1).stable_hash(), Value::Bool(true).stable_hash());
    }

    #[test]
    fn batch_clone_shares_payload_without_copy() {
        let b = Batch::new(vec![Value::I64(1), Value::Str("x".into())]);
        let c = b.clone();
        assert!(Batch::ptr_eq(&b, &c));
        assert!(!b.is_unique());
        assert_eq!(b, c);
    }

    #[test]
    fn batch_wire_encodes_once_and_is_shared_across_clones() {
        let b = Batch::new(vec![Value::I64(7); 32]);
        assert!(b.wire_cached().is_none());
        let c = b.clone();
        let w1 = b.wire();
        let w2 = c.wire(); // cache hit through the sibling handle
        assert!(Arc::ptr_eq(&w1, &w2), "one encode serves every clone");
        assert_eq!(w1.as_ref(), encode_batch(b.values()).as_slice());
    }

    #[test]
    fn batch_from_wire_keeps_frame_bytes_as_cache() {
        let original = Batch::new(vec![Value::pair(Value::I64(1), Value::F64(0.5))]);
        let wire = original.wire();
        let decoded = Batch::from_wire(wire.clone()).unwrap();
        assert_eq!(decoded, original);
        let cached = decoded.wire_cached().expect("frame bytes retained");
        assert!(Arc::ptr_eq(&cached, &wire), "no re-encode after decode");
    }

    #[test]
    fn batch_from_wire_rejects_corrupt_frames() {
        assert!(Batch::from_wire(Arc::from(vec![200u8, 1, 2])).is_err());
    }

    #[test]
    fn unique_batch_recovers_payload_allocation() {
        let values = vec![Value::I64(1), Value::I64(2)];
        let ptr = values.as_ptr();
        let out = Batch::new(values).into_values();
        assert_eq!(out.as_ptr(), ptr, "sole owner takes the Vec back in place");
    }

    #[test]
    fn batch_hash_column_travels_locally_but_never_over_the_wire() {
        let values = vec![Value::pair(Value::I64(3), Value::Str("x".into()))];
        let hashes = vec![Value::I64(3).stable_hash()];
        let b = Batch::with_hashes(values.clone(), hashes.clone());
        assert_eq!(b.key_hashes(), Some(hashes.as_slice()));
        // the column survives refcount clones and shared take
        let twin = b.clone();
        let (vals, hs) = b.into_parts();
        assert_eq!(vals, values);
        assert_eq!(hs, Some(hashes.clone()));
        assert_eq!(twin.key_hashes(), Some(hashes.as_slice()));
        // the wire encoding is identical to a column-less batch, and a
        // decoded batch carries no column
        let plain = Batch::new(values);
        assert_eq!(twin.wire().as_ref(), plain.wire().as_ref());
        let decoded = Batch::from_wire(twin.wire()).unwrap();
        assert!(decoded.key_hashes().is_none());
    }

    #[test]
    fn mismatched_hash_column_is_counted_not_silent() {
        let before = hash_column_mismatches();
        let build = || Batch::with_hashes(vec![Value::I64(1), Value::I64(2)], vec![7]);
        if cfg!(debug_assertions) {
            assert!(
                std::panic::catch_unwind(build).is_err(),
                "debug builds assert on a misaligned hash column"
            );
        } else {
            assert!(
                build().key_hashes().is_none(),
                "release builds drop the misaligned column"
            );
        }
        assert!(hash_column_mismatches() > before, "mismatch was counted");
    }

    #[test]
    fn empty_batch_is_shared_and_allocation_free() {
        let a = Batch::empty();
        let b = Batch::empty();
        assert!(a.is_empty());
        assert!(Batch::ptr_eq(&a, &b), "one static allocation serves all");
    }

    #[test]
    fn shared_batch_into_values_copies_and_preserves_siblings() {
        let b = Batch::new(vec![Value::I64(1)]);
        let sibling = b.clone();
        let mut mine = b.into_values();
        mine[0] = Value::I64(999);
        assert_eq!(sibling.values(), &[Value::I64(1)]);
    }

    fn roundtrip_data<T: StreamData + Clone + PartialEq + std::fmt::Debug>(x: T) {
        let v = x.clone().into_value();
        assert_eq!(T::try_from_value(v).unwrap(), x);
    }

    #[test]
    fn stream_data_roundtrips_scalars_and_composites() {
        roundtrip_data(42i64);
        roundtrip_data(-3.25f64);
        roundtrip_data(true);
        roundtrip_data("héllo".to_string());
        roundtrip_data((7i64, "k".to_string()));
        roundtrip_data((1i64, 2.0f64, false));
        roundtrip_data(vec![1i64, 2, 3]);
        roundtrip_data(vec![("a".to_string(), 1i64), ("b".to_string(), 2i64)]);
        roundtrip_data(((1i64, 2i64), (true, "x".to_string())));
        roundtrip_data(Value::Null);
        roundtrip_data(Value::pair(Value::I64(1), Value::Str("v".into())));
    }

    #[test]
    fn stream_data_decode_mismatch_is_decode_error() {
        let err = i64::try_from_value(Value::Bool(true)).unwrap_err();
        assert!(matches!(err, Error::Decode(_)), "got {err}");
        assert!(err.to_string().contains("i64"), "got {err}");
        assert!(err.to_string().contains("Bool"), "got {err}");
        assert!(String::try_from_value(Value::Null).is_err());
        assert!(<(i64, i64)>::try_from_value(Value::I64(1)).is_err());
        assert!(<Vec<i64>>::try_from_value(Value::List(vec![Value::Bool(true)])).is_err());
    }

    #[test]
    fn stream_data_f64_accepts_i64_like_as_f64() {
        assert_eq!(f64::try_from_value(Value::I64(3)).unwrap(), 3.0);
    }

    #[test]
    fn varint_boundaries() {
        for v in [0u64, 1, 127, 128, 16383, 16384, u64::MAX] {
            let mut buf = Vec::new();
            write_varint(&mut buf, v);
            let mut cur = Cursor::new(&buf);
            assert_eq!(cur.varint().unwrap(), v);
            assert_eq!(cur.remaining(), 0);
        }
    }
}
