//! Minimal in-repo property-testing harness.
//!
//! The build environment only vendors the `xla` crate's dependency closure,
//! so `proptest`/`quickcheck` are unavailable; this module provides the
//! subset we need: seeded generators, a `forall` runner with failure
//! reporting (seed + iteration), and greedy input shrinking for
//! vector-shaped inputs.
//!
//! ```no_run
//! use flowunits::proptest::{forall, Gen};
//! forall("addition commutes", 256, |g| {
//!     let a = g.i64_in(-1000, 1000);
//!     let b = g.i64_in(-1000, 1000);
//!     assert_eq!(a + b, b + a);
//! });
//! ```

use crate::util::XorShift64;

/// Generator handle passed to property bodies.
pub struct Gen {
    rng: XorShift64,
}

impl Gen {
    /// Creates a generator for a given case seed.
    pub fn new(seed: u64) -> Self {
        Gen {
            rng: XorShift64::new(seed),
        }
    }

    /// Uniform u64 in `[0, n)`.
    pub fn usize_in(&mut self, lo: usize, hi: usize) -> usize {
        assert!(lo < hi);
        lo + self.rng.gen_range((hi - lo) as u64) as usize
    }

    /// Uniform i64 in `[lo, hi)`.
    pub fn i64_in(&mut self, lo: i64, hi: i64) -> i64 {
        assert!(lo < hi);
        lo + self.rng.gen_range((hi - lo) as u64) as i64
    }

    /// Uniform f64 in `[lo, hi)`.
    pub fn f64_in(&mut self, lo: f64, hi: f64) -> f64 {
        lo + self.rng.gen_f64() * (hi - lo)
    }

    /// Bernoulli draw.
    pub fn bool(&mut self, p: f64) -> bool {
        self.rng.gen_bool(p)
    }

    /// Random ASCII identifier of length `[1, max_len]`.
    pub fn ident(&mut self, max_len: usize) -> String {
        let n = self.usize_in(1, max_len + 1);
        (0..n)
            .map(|_| (b'a' + self.rng.gen_range(26) as u8) as char)
            .collect()
    }

    /// Vector of `n` items drawn from `f`.
    pub fn vec_of<T>(&mut self, n: usize, mut f: impl FnMut(&mut Gen) -> T) -> Vec<T> {
        (0..n).map(|_| f(self)).collect()
    }

    /// Picks one of the provided options.
    pub fn choose<'a, T>(&mut self, xs: &'a [T]) -> &'a T {
        self.rng.pick(xs)
    }

    /// Raw RNG access.
    pub fn rng(&mut self) -> &mut XorShift64 {
        &mut self.rng
    }
}

/// Runs `body` for `cases` seeded cases. Panics (preserving the inner panic
/// message) with the failing case seed so a failure is reproducible with
/// [`check_one`].
pub fn forall(name: &str, cases: u64, body: impl Fn(&mut Gen) + std::panic::RefUnwindSafe) {
    // FLOWUNITS_PROPTEST_SEED pins the base seed; FLOWUNITS_PROPTEST_CASES
    // scales the number of cases (e.g. overnight runs).
    let base = std::env::var("FLOWUNITS_PROPTEST_SEED")
        .ok()
        .and_then(|s| s.parse().ok())
        .unwrap_or(0xf10c_a11d_u64);
    let cases = std::env::var("FLOWUNITS_PROPTEST_CASES")
        .ok()
        .and_then(|s| s.parse().ok())
        .unwrap_or(cases);
    for i in 0..cases {
        let seed = base ^ (i.wrapping_mul(0x9e37_79b9_7f4a_7c15));
        let result = std::panic::catch_unwind(|| {
            let mut g = Gen::new(seed);
            body(&mut g);
        });
        if let Err(e) = result {
            let msg = e
                .downcast_ref::<String>()
                .cloned()
                .or_else(|| e.downcast_ref::<&str>().map(|s| s.to_string()))
                .unwrap_or_else(|| "<non-string panic>".into());
            panic!(
                "property '{name}' failed at case {i} (seed {seed:#x}): {msg}\n\
                 reproduce with FLOWUNITS_PROPTEST_SEED and check_one(seed, body)"
            );
        }
    }
}

/// Re-runs a single failing case by seed.
pub fn check_one(seed: u64, body: impl Fn(&mut Gen)) {
    let mut g = Gen::new(seed);
    body(&mut g);
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn forall_passes_trivial_property() {
        forall("x * 2 is even", 64, |g| {
            let x = g.i64_in(-1_000_000, 1_000_000);
            assert_eq!((x * 2) % 2, 0);
        });
    }

    #[test]
    #[should_panic(expected = "property 'always fails'")]
    fn forall_reports_failures() {
        forall("always fails", 8, |g| {
            let x = g.i64_in(0, 10);
            assert!(x > 100, "x was {x}");
        });
    }

    #[test]
    fn gen_is_deterministic_per_seed() {
        let mut a = Gen::new(5);
        let mut b = Gen::new(5);
        for _ in 0..32 {
            assert_eq!(a.i64_in(0, 1000), b.i64_in(0, 1000));
        }
    }
}
