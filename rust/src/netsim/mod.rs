//! Network emulation — the stand-in for the paper's Docker + `tc`
//! (Traffic Control) testbed (§V).
//!
//! Every cross-zone route is shaped by a [`Link`] modelling the route's
//! *egress hop* (the sender zone's uplink): frames transmit one at a time,
//! occupying the wire for `8·bytes / bandwidth` seconds — exactly what a
//! shaped veth pair does — then sit in a delay line for the route's
//! *end-to-end* propagation latency (passed per frame, since routes that
//! share an uplink may have different path lengths). All channels whose
//! routes leave a zone through the same hop share that hop's [`Link`], so
//! cross-zone traffic contends for uplink bandwidth like it would on a
//! real network. Intra-zone traffic is unshaped (the paper assumes
//! unlimited bandwidth / no added latency within a zone).
//!
//! Backpressure: the link queue is bounded; senders block when the wire is
//! saturated, which propagates back to the sources — the behaviour a TCP
//! connection under `tc` shaping exhibits.
//!
//! Frame sizing comes from the sender's **cached** batch encoding
//! ([`Batch::wire`](crate::value::Batch::wire) length + per-frame
//! overhead): the bytes accounted on the wire are the real serialised
//! bytes, but a batch fanned out over several routes is sized — and
//! encoded — exactly once, with every in-flight frame holding a refcount
//! on the same buffer rather than a private copy.

use crate::metrics::Metrics;
use std::collections::BinaryHeap;
use std::sync::atomic::{AtomicU64, Ordering};
use std::sync::mpsc::SyncSender;
use std::sync::{Arc, Condvar, Mutex};
use std::time::{Duration, Instant};

/// Link conditions for one inter-zone tree edge (configuration unit).
#[derive(Debug, Clone, PartialEq, Eq)]
pub struct LinkSpec {
    /// Bandwidth cap in bits/second; `None` = unlimited.
    pub bandwidth_bps: Option<u64>,
    /// Added one-way propagation delay.
    pub latency: Duration,
}

impl Default for LinkSpec {
    fn default() -> Self {
        LinkSpec {
            bandwidth_bps: None,
            latency: Duration::ZERO,
        }
    }
}

impl LinkSpec {
    /// Human-readable description, e.g. `100Mbit/10ms`.
    pub fn describe(&self) -> String {
        let bw = match self.bandwidth_bps {
            None => "unlimited".to_string(),
            Some(b) if b >= 1_000_000_000 => format!("{}Gbit", b / 1_000_000_000),
            Some(b) if b >= 1_000_000 => format!("{}Mbit", b / 1_000_000),
            Some(b) => format!("{b}bit"),
        };
        format!("{bw}/{:?}", self.latency)
    }

    /// True when the link adds no shaping at all.
    pub fn is_transparent(&self) -> bool {
        self.bandwidth_bps.is_none() && self.latency.is_zero()
    }
}

struct InFlight<T: Send> {
    size_bytes: usize,
    latency: Duration,
    msg: T,
    dest: SyncSender<T>,
}

struct Delayed<T: Send> {
    deliver_at: Instant,
    seq: u64,
    msg: T,
    dest: SyncSender<T>,
}

impl<T: Send> PartialEq for Delayed<T> {
    fn eq(&self, other: &Self) -> bool {
        self.deliver_at == other.deliver_at && self.seq == other.seq
    }
}
impl<T: Send> Eq for Delayed<T> {}
impl<T: Send> PartialOrd for Delayed<T> {
    fn partial_cmp(&self, other: &Self) -> Option<std::cmp::Ordering> {
        Some(self.cmp(other))
    }
}
impl<T: Send> Ord for Delayed<T> {
    fn cmp(&self, other: &Self) -> std::cmp::Ordering {
        // reversed: BinaryHeap is a max-heap, we want earliest first
        other
            .deliver_at
            .cmp(&self.deliver_at)
            .then(other.seq.cmp(&self.seq))
    }
}

struct WireState<T: Send> {
    queue: std::collections::VecDeque<InFlight<T>>,
    closed: bool,
}

struct DelayState<T: Send> {
    heap: BinaryHeap<Delayed<T>>,
    closed: bool,
    seq: u64,
}

/// An emulated uplink shared by all routes leaving a zone through the same
/// tree hop. Zero-shaping links deliver synchronously with no threads.
pub struct Link<T: Send + 'static> {
    name: String,
    bandwidth_bps: Option<u64>,
    has_delay_stage: bool,
    bytes: AtomicU64,
    frames: AtomicU64,
    metrics: Option<Metrics>,
    wire: Arc<(Mutex<WireState<T>>, Condvar)>,
    delay: Arc<(Mutex<DelayState<T>>, Condvar)>,
    threads: Mutex<Vec<std::thread::JoinHandle<()>>>,
    capacity: usize,
}

impl<T: Send + 'static> Link<T> {
    /// Creates a link. `bandwidth_bps = None` disables the wire stage;
    /// `needs_delay = false` promises every frame will carry zero latency,
    /// disabling the delay stage (no service threads at all when both are
    /// off).
    pub fn new(
        name: &str,
        bandwidth_bps: Option<u64>,
        needs_delay: bool,
        metrics: Option<Metrics>,
    ) -> Arc<Self> {
        let link = Arc::new(Link {
            name: name.to_string(),
            bandwidth_bps,
            has_delay_stage: needs_delay || bandwidth_bps.is_some(),
            bytes: AtomicU64::new(0),
            frames: AtomicU64::new(0),
            metrics,
            wire: Arc::new((
                Mutex::new(WireState {
                    queue: std::collections::VecDeque::new(),
                    closed: false,
                }),
                Condvar::new(),
            )),
            delay: Arc::new((
                Mutex::new(DelayState {
                    heap: BinaryHeap::new(),
                    closed: false,
                    seq: 0,
                }),
                Condvar::new(),
            )),
            threads: Mutex::new(Vec::new()),
            capacity: 256,
        });
        let mut handles = Vec::new();
        if link.bandwidth_bps.is_some() {
            let l = Arc::clone(&link);
            handles.push(
                std::thread::Builder::new()
                    .name(format!("link-wire-{name}"))
                    .spawn(move || l.wire_loop())
                    .expect("spawn link wire thread"),
            );
        }
        if link.has_delay_stage {
            let l = Arc::clone(&link);
            handles.push(
                std::thread::Builder::new()
                    .name(format!("link-delay-{name}"))
                    .spawn(move || l.delay_loop())
                    .expect("spawn link delay thread"),
            );
        }
        link.threads.lock().unwrap().extend(handles);
        link
    }

    /// Convenience constructor from a [`LinkSpec`] (tests / single-route
    /// links): the spec's latency decides whether a delay stage exists.
    pub fn from_spec(name: &str, spec: &LinkSpec, metrics: Option<Metrics>) -> Arc<Self> {
        Self::new(name, spec.bandwidth_bps, !spec.latency.is_zero(), metrics)
    }

    /// Link name (`E1->S1`).
    pub fn name(&self) -> &str {
        &self.name
    }

    /// Total payload bytes accepted.
    pub fn bytes_sent(&self) -> u64 {
        self.bytes.load(Ordering::Relaxed)
    }

    /// Total frames accepted.
    pub fn frames_sent(&self) -> u64 {
        self.frames.load(Ordering::Relaxed)
    }

    /// Sends a frame of `size_bytes` carrying `msg` toward `dest`, with
    /// `latency` end-to-end propagation delay. Blocks while the uplink
    /// queue is full (backpressure). Returns `false` if the link is closed
    /// or the destination disconnected.
    pub fn send(&self, size_bytes: usize, latency: Duration, msg: T, dest: &SyncSender<T>) -> bool {
        self.bytes.fetch_add(size_bytes as u64, Ordering::Relaxed);
        self.frames.fetch_add(1, Ordering::Relaxed);
        if let Some(m) = &self.metrics {
            crate::metrics::MetricsRegistry::add(&m.net_bytes, size_bytes as u64);
            crate::metrics::MetricsRegistry::add(&m.net_frames, 1);
        }
        if self.bandwidth_bps.is_none() {
            if latency.is_zero() || !self.has_delay_stage {
                return dest.send(msg).is_ok();
            }
            return self.enqueue_delay(latency, msg, dest.clone());
        }
        let (lock, cv) = &*self.wire;
        // A poisoned lock means a peer thread panicked mid-send; treat the
        // link as closed (callers count a transport error) — never panic
        // the delivering instance too.
        let Ok(mut st) = lock.lock() else { return false };
        while st.queue.len() >= self.capacity && !st.closed {
            st = match cv.wait(st) {
                Ok(g) => g,
                Err(_) => return false,
            };
        }
        if st.closed {
            return false;
        }
        st.queue.push_back(InFlight {
            size_bytes,
            latency,
            msg,
            dest: dest.clone(),
        });
        cv.notify_all();
        true
    }

    fn enqueue_delay(&self, latency: Duration, msg: T, dest: SyncSender<T>) -> bool {
        let (dlock, dcv) = &*self.delay;
        // poisoned ⇒ closed, not a cascading panic
        let Ok(mut dst) = dlock.lock() else {
            return false;
        };
        if dst.closed {
            return false;
        }
        let seq = dst.seq;
        dst.seq += 1;
        dst.heap.push(Delayed {
            deliver_at: Instant::now() + latency,
            seq,
            msg,
            dest,
        });
        dcv.notify_all();
        true
    }

    fn wire_loop(&self) {
        let (lock, cv) = &*self.wire;
        loop {
            let item = {
                let Ok(mut st) = lock.lock() else { break };
                loop {
                    if let Some(it) = st.queue.pop_front() {
                        cv.notify_all(); // wake blocked senders
                        break Some(it);
                    }
                    if st.closed {
                        break None;
                    }
                    st = match cv.wait(st) {
                        Ok(g) => g,
                        Err(_) => break None, // poisoned ⇒ shut the stage down
                    };
                }
            };
            let Some(item) = item else { break };
            if let Some(bps) = self.bandwidth_bps {
                let secs = (item.size_bytes as f64 * 8.0) / bps as f64;
                if secs > 0.0 {
                    std::thread::sleep(Duration::from_secs_f64(secs));
                }
            }
            self.enqueue_delay(item.latency, item.msg, item.dest);
        }
        // wire closed and drained: close the delay line
        let (dlock, dcv) = &*self.delay;
        if let Ok(mut d) = dlock.lock() {
            d.closed = true;
        }
        dcv.notify_all();
    }

    fn delay_loop(&self) {
        let (lock, cv) = &*self.delay;
        loop {
            let item = {
                let Ok(mut st) = lock.lock() else { break };
                loop {
                    let now = Instant::now();
                    match st.heap.peek() {
                        Some(d) if d.deliver_at <= now => break Some(st.heap.pop().unwrap()),
                        Some(d) => {
                            let wait = d.deliver_at - now;
                            match cv.wait_timeout(st, wait) {
                                Ok((g, _)) => st = g,
                                Err(_) => break None,
                            }
                        }
                        None if st.closed => break None,
                        None => {
                            st = match cv.wait(st) {
                                Ok(g) => g,
                                Err(_) => break None,
                            }
                        }
                    }
                }
            };
            let Some(item) = item else { break };
            // Blocking send keeps end-to-end backpressure.
            let _ = item.dest.send(item.msg);
        }
    }

    /// Closes the link after in-flight frames are delivered; joins threads.
    pub fn shutdown(&self) {
        {
            let (lock, cv) = &*self.wire;
            if let Ok(mut g) = lock.lock() {
                g.closed = true;
            }
            cv.notify_all();
        }
        if self.bandwidth_bps.is_none() {
            // no wire stage to propagate the close — close the delay line
            // directly (it still drains its heap first by construction).
            let (dlock, dcv) = &*self.delay;
            // wait for the heap to drain before flagging closed would race;
            // the delay loop drains everything already queued regardless.
            if let Ok(mut g) = dlock.lock() {
                g.closed = true;
            }
            dcv.notify_all();
        }
        // join even through a poisoned registry so shutdown stays a barrier
        let handles: Vec<_> = match self.threads.lock() {
            Ok(mut g) => g.drain(..).collect(),
            Err(p) => p.into_inner().drain(..).collect(),
        };
        for h in handles {
            let _ = h.join();
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use std::sync::mpsc::sync_channel;

    #[test]
    fn transparent_link_is_synchronous() {
        let link: Arc<Link<u32>> = Link::new("t", None, false, None);
        let (tx, rx) = sync_channel(4);
        assert!(link.send(100, Duration::ZERO, 7, &tx));
        assert_eq!(rx.try_recv().unwrap(), 7);
        assert_eq!(link.bytes_sent(), 100);
        link.shutdown();
    }

    #[test]
    fn latency_delays_delivery() {
        let link: Arc<Link<u32>> = Link::new("lat", None, true, None);
        let (tx, rx) = sync_channel(4);
        let t0 = Instant::now();
        link.send(10, Duration::from_millis(50), 1, &tx);
        let v = rx.recv().unwrap();
        let dt = t0.elapsed();
        assert_eq!(v, 1);
        assert!(dt >= Duration::from_millis(45), "delivered after {dt:?}");
        assert!(dt < Duration::from_millis(500), "delivered after {dt:?}");
        link.shutdown();
    }

    #[test]
    fn bandwidth_serialises_frames() {
        // 8 Mbit/s -> a 100_000-byte frame occupies the wire for 100 ms.
        let link: Arc<Link<u32>> = Link::new("bw", Some(8_000_000), false, None);
        let (tx, rx) = sync_channel(16);
        let t0 = Instant::now();
        for i in 0..3 {
            link.send(100_000, Duration::ZERO, i, &tx);
        }
        for _ in 0..3 {
            rx.recv().unwrap();
        }
        let dt = t0.elapsed();
        assert!(dt >= Duration::from_millis(280), "3 frames took {dt:?}");
        link.shutdown();
    }

    #[test]
    fn latency_pipelines_rather_than_serialises() {
        // 10 frames with 100 ms latency and no bandwidth cap should take
        // ~100 ms total (pipelined), not ~1 s (serialised).
        let link: Arc<Link<u32>> = Link::new("pipe", None, true, None);
        let (tx, rx) = sync_channel(64);
        let t0 = Instant::now();
        for i in 0..10 {
            link.send(10, Duration::from_millis(100), i, &tx);
        }
        let mut got = Vec::new();
        for _ in 0..10 {
            got.push(rx.recv().unwrap());
        }
        let dt = t0.elapsed();
        assert_eq!(got, (0..10).collect::<Vec<_>>(), "FIFO order preserved");
        assert!(dt < Duration::from_millis(600), "took {dt:?}, not pipelined");
        link.shutdown();
    }

    #[test]
    fn mixed_latency_routes_share_one_uplink() {
        // Two routes over the same uplink with different path latencies.
        let link: Arc<Link<u32>> = Link::new("shared", Some(80_000_000), true, None);
        let (tx_near, rx_near) = sync_channel(16);
        let (tx_far, rx_far) = sync_channel(16);
        let t0 = Instant::now();
        link.send(1000, Duration::from_millis(5), 1, &tx_near);
        link.send(1000, Duration::from_millis(60), 2, &tx_far);
        rx_near.recv().unwrap();
        let near_dt = t0.elapsed();
        rx_far.recv().unwrap();
        let far_dt = t0.elapsed();
        assert!(near_dt < far_dt);
        assert!(far_dt >= Duration::from_millis(55));
        link.shutdown();
    }

    #[test]
    fn frames_delivered_in_fifo_order_under_load() {
        let link: Arc<Link<u64>> = Link::new("fifo", Some(80_000_000), true, None);
        let (tx, rx) = sync_channel(512);
        for i in 0..200u64 {
            link.send(1000, Duration::from_millis(5), i, &tx);
        }
        let mut prev = None;
        for _ in 0..200 {
            let v = rx.recv().unwrap();
            if let Some(p) = prev {
                assert!(v > p, "out of order: {v} after {p}");
            }
            prev = Some(v);
        }
        link.shutdown();
    }

    #[test]
    fn shutdown_drains_inflight() {
        let link: Arc<Link<u32>> = Link::new("drain", None, true, None);
        let (tx, rx) = sync_channel(16);
        for i in 0..5 {
            link.send(10, Duration::from_millis(20), i, &tx);
        }
        link.shutdown(); // must wait for all 5 deliveries
        let mut n = 0;
        while rx.try_recv().is_ok() {
            n += 1;
        }
        assert_eq!(n, 5);
    }

    #[test]
    fn metrics_account_bytes() {
        let m = crate::metrics::MetricsRegistry::new();
        let link: Arc<Link<u32>> = Link::new("m", None, false, Some(m.clone()));
        let (tx, _rx) = sync_channel(4);
        link.send(123, Duration::ZERO, 0, &tx);
        link.send(77, Duration::ZERO, 1, &tx);
        assert_eq!(m.net_bytes.load(Ordering::Relaxed), 200);
        assert_eq!(m.net_frames.load(Ordering::Relaxed), 2);
        link.shutdown();
    }

    #[test]
    fn from_spec_matches_spec() {
        let spec = LinkSpec {
            bandwidth_bps: Some(1_000_000),
            latency: Duration::from_millis(1),
        };
        assert_eq!(spec.describe(), "1Mbit/1ms");
        let link: Arc<Link<u8>> = Link::from_spec("s", &spec, None);
        let (tx, rx) = sync_channel(4);
        link.send(100, spec.latency, 9, &tx);
        assert_eq!(rx.recv().unwrap(), 9);
        link.shutdown();
    }
}
