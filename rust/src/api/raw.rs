//! The untyped stream builder — an owned, DAG-capable builder with
//! first-class **FlowUnits** (paper §III/§IV), operating directly on
//! dynamic [`Value`] events.
//!
//! This module is the **stable substrate** of the API: the typed
//! front-end ([`api::typed`](crate::api::typed)) compiles down to it, and
//! dynamic-update graph construction
//! ([`Deployment::update_unit`](crate::coordinator::Deployment::update_unit))
//! is specified against the [`LogicalGraph`]s it produces. Prefer the
//! typed layer for new pipelines; reach for `api::raw` when a closure
//! genuinely needs the dynamic [`Value`] representation, when
//! constructing replacement graphs for `update_unit`, or when porting
//! code written against earlier versions of this API (both layers build
//! the same graphs, so they interoperate freely within one
//! [`StreamContext`]).
//!
//! A [`StreamContext`] owns the cluster description, the job
//! configuration, and the logical graph under construction. Each
//! [`StreamContext::stream`] call opens a new source; streams are *owned*
//! handles (no borrow ties the builder down), so several streams can be
//! built side by side, merged with [`Stream::union`], and forked with
//! [`Stream::split`] into multiple sinks — one job, one DAG.
//!
//! Every operator belongs to a **FlowUnit**, the unit of placement,
//! replication, and dynamic update. [`Stream::unit`] opens (or names) a
//! unit; [`Stream::to_layer`], [`Stream::add_constraint`], and
//! [`Stream::replicate`] configure the *current unit's* scope — layer,
//! capability requirements, and in-zone replication — rather than
//! annotating individual operators. Bare `to_layer` remains as sugar: it
//! opens an anonymous, layer-named unit exactly like earlier versions of
//! this API.
//!
//! Construction is **fallible but never panics**: malformed constraint
//! expressions, duplicate unit names, cross-context unions, and invalid
//! graph shapes are recorded in the builder and surfaced as
//! [`Error::Graph`](crate::error::Error::Graph) from
//! [`StreamContext::execute`] / [`StreamContext::deploy`].
//!
//! The data plane underneath is zero-copy: batches travel as
//! refcounted [`Batch`](crate::value::Batch) handles, `split` fan-out
//! and broadcast duplication share one payload allocation per batch,
//! and a batch crossing several host/zone boundaries is wire-encoded at
//! most once ([`JobReport::wire_encodes`] reports how many encodes a job
//! actually paid; see README *Architecture: the data plane*).
//!
//! A deployed job is dynamically updatable by unit name:
//! [`Deployment::update_unit`](crate::coordinator::Deployment::update_unit)
//! hot-swaps one FlowUnit — stateful, multi-stage, or re-scoped
//! (constraint/replication) — through an epoch-based drain-and-handoff
//! protocol that loses and duplicates zero events (see README *Dynamic
//! updates*).
//!
//! ```no_run
//! use flowunits::api::raw::{Source, StreamContext};
//! use flowunits::prelude::{JobConfig, Value, WindowAgg};
//!
//! let cluster = flowunits::config::fig2_cluster();
//! let mut ctx = StreamContext::new(cluster, JobConfig::default());
//!
//! // two independent edge sources, each its own named FlowUnit
//! let north = ctx
//!     .stream(Source::synthetic(500_000, |_, i| Value::F64((i % 100) as f64)))
//!     .unit("ingest-north")
//!     .to_layer("edge")
//!     .filter(|v| v.as_f64().unwrap() > 33.0);
//! let south = ctx
//!     .stream(Source::synthetic(500_000, |_, i| Value::F64((i % 90) as f64)))
//!     .unit("ingest-south")
//!     .to_layer("edge");
//!
//! // merge, process in a constrained cloud unit, then fork to two sinks
//! let scored = north
//!     .union(south)
//!     .unit("detector")
//!     .to_layer("cloud")
//!     .add_constraint("n_cpu >= 4")
//!     .key_by(|v| Value::I64(v.as_f64().unwrap() as i64 % 8))
//!     .window(100, WindowAgg::Mean);
//! let (alerts, archive) = scored.split();
//! alerts
//!     .unit("alerts")
//!     .filter(|v| v.as_pair().unwrap().1.as_f64().unwrap() > 60.0)
//!     .collect_vec();
//! archive.unit("archive").collect_count();
//!
//! let report = ctx.execute().unwrap();
//! println!("{} events out", report.events_out);
//! ```

pub use crate::coordinator::{JobConfig, JobReport};
pub use crate::graph::{Replication, WindowAgg};
pub use crate::placement::PlannerKind;
pub use crate::time::{WatermarkGen, WindowAssigner};

use super::data::DecodeErrors;
use super::OpenStream;
use crate::config::ClusterSpec;
use crate::coordinator::{Coordinator, Deployment};
use crate::error::{Error, Result};
use crate::graph::{LogicalGraph, OpId, OpKind, SinkKind, SourceKind, UnitId};
use crate::time::TsFn;
use crate::topology::ConstraintExpr;
use crate::value::Value;
use std::cell::RefCell;
use std::rc::Rc;
use std::sync::Arc;

/// Source builder.
pub struct Source(SourceKind);

impl Source {
    /// Synthetic generator: `total` events split across source instances,
    /// each produced by `gen(instance_index, event_index)`.
    pub fn synthetic(
        total: u64,
        gen: impl Fn(u64, u64) -> Value + Send + Sync + 'static,
    ) -> Source {
        Source(SourceKind::Synthetic {
            total,
            gen: Arc::new(gen),
            rate: None,
        })
    }

    /// Rate-limited synthetic generator (events/second per instance);
    /// pair with [`Deployment::stop_sources`] for unbounded streams.
    pub fn synthetic_rated(
        total: u64,
        rate: f64,
        gen: impl Fn(u64, u64) -> Value + Send + Sync + 'static,
    ) -> Source {
        Source(SourceKind::Synthetic {
            total,
            gen: Arc::new(gen),
            rate: Some(rate),
        })
    }

    /// A pre-materialised vector.
    pub fn vector(values: Vec<Value>) -> Source {
        Source(SourceKind::Vector(Arc::new(values)))
    }

    /// Lines of a text file as `Value::Str`. An unreadable file is a
    /// job-level error from `execute()`/`deploy()`, not a panic.
    pub fn file_lines(path: impl Into<std::path::PathBuf>) -> Source {
        Source(SourceKind::FileLines(path.into()))
    }
}

impl OpenStream for Source {
    type Handle = Stream;
    fn open(self, ctx: &mut StreamContext) -> Stream {
        ctx.open_source(self.0)
    }
}

/// Shared builder state behind every [`Stream`] handle of one context.
struct BuilderState {
    graph: LogicalGraph,
    /// Deferred construction errors, surfaced from `execute`/`deploy`.
    errors: Vec<String>,
    /// Cluster layer order (periphery → centre), for layer defaults.
    layers: Vec<String>,
    /// Runtime decode-failure accumulator shared with every typed-layer
    /// closure built on this context; checked after `execute()`.
    decode: Arc<DecodeErrors>,
    /// Mirror of [`JobConfig::columnar`]: when set, the typed layer
    /// lowers eligible chains onto monomorphized column operators
    /// instead of `Value` closures.
    columnar: bool,
}

impl BuilderState {
    fn innermost_layer(&self) -> String {
        self.layers.last().cloned().unwrap_or_else(|| "cloud".into())
    }

    fn layer_pos(&self, layer: &str) -> usize {
        self.layers.iter().position(|l| l == layer).unwrap_or(0)
    }

    /// Surfaces deferred construction errors.
    fn check(&self) -> Result<()> {
        if !self.errors.is_empty() {
            return Err(Error::Graph(self.errors.join("; ")));
        }
        if self.graph.ops.is_empty() {
            return Err(Error::Graph("no stream defined".into()));
        }
        Ok(())
    }
}

/// Builder context owning the cluster description, job configuration, and
/// the logical DAG under construction.
pub struct StreamContext {
    cluster: ClusterSpec,
    config: JobConfig,
    state: Rc<RefCell<BuilderState>>,
}

impl StreamContext {
    /// Creates a context. Until re-scoped with [`Stream::to_layer`] or
    /// [`Stream::unit`], new streams start in an anonymous unit on the
    /// innermost layer (the cloud).
    pub fn new(cluster: ClusterSpec, config: JobConfig) -> Self {
        // Unique per-context identity, stamped onto the graph (and from
        // there onto typed CollectHandles, which JobReport::take checks).
        static CONTEXT_IDS: std::sync::atomic::AtomicU64 = std::sync::atomic::AtomicU64::new(1);
        let layers = cluster.topology.layers.clone();
        let graph = LogicalGraph {
            origin: CONTEXT_IDS.fetch_add(1, std::sync::atomic::Ordering::Relaxed),
            ..LogicalGraph::default()
        };
        let columnar = config.columnar;
        StreamContext {
            cluster,
            config,
            state: Rc::new(RefCell::new(BuilderState {
                graph,
                errors: Vec::new(),
                layers,
                decode: Arc::new(DecodeErrors::default()),
                columnar,
            })),
        }
    }

    /// Opens a stream from `source` in a fresh FlowUnit. May be called
    /// multiple times: all streams belong to the same job DAG. Accepts
    /// both an untyped [`Source`] (returning the raw [`Stream`]) and a
    /// typed [`api::typed::Source<T>`](crate::api::typed::Source)
    /// (returning [`api::typed::Stream<T>`](crate::api::typed::Stream)).
    pub fn stream<S: OpenStream>(&mut self, source: S) -> S::Handle {
        source.open(self)
    }

    /// Opens the raw stream for an already-lowered source definition
    /// (shared by the raw and typed `OpenStream` impls).
    pub(crate) fn open_source(&mut self, kind: SourceKind) -> Stream {
        let (head, unit) = {
            let mut st = self.state.borrow_mut();
            let layer = st.innermost_layer();
            let unit = st
                .graph
                .add_unit(None, layer, None, Replication::PerCore);
            let head = st
                .graph
                .add_op(OpKind::Source(kind), unit, Vec::new(), "source");
            (head, unit)
        };
        Stream {
            state: self.state.clone(),
            head,
            unit,
            forked: false,
        }
    }

    /// The context's shared typed-decode failure accumulator.
    pub(crate) fn decode_errors(&self) -> Arc<DecodeErrors> {
        self.state.borrow().decode.clone()
    }

    /// Whether typed chains built on this context should lower onto the
    /// columnar data plane (mirrors [`JobConfig::columnar`]).
    pub(crate) fn columnar_enabled(&self) -> bool {
        self.state.borrow().columnar
    }

    /// Number of events that failed a typed-layer decode so far. Useful
    /// after [`StreamContext::deploy`], where the job outlives `execute`'s
    /// built-in check. Scope: each context counts only failures recorded
    /// by the typed closures *it* built — after a dynamic update, poll
    /// the context that built the replacement graph for the replacement
    /// unit's failures.
    pub fn decode_failures(&self) -> u64 {
        self.state.borrow().decode.count()
    }

    /// Returns the built graph, surfacing any deferred builder errors.
    fn build_graph(&self) -> Result<LogicalGraph> {
        let st = self.state.borrow();
        st.check()?;
        Ok(st.graph.clone())
    }

    /// Executes the built job to completion. Typed-layer decode failures
    /// recorded during the run surface as
    /// [`Error::Decode`](crate::error::Error::Decode) after it completes.
    pub fn execute(&mut self) -> Result<JobReport> {
        let graph = self.build_graph()?;
        let report = Coordinator::new(self.cluster.clone(), self.config.clone()).run(&graph)?;
        self.state.borrow().decode.check()?;
        Ok(report)
    }

    /// Deploys the built job and returns the live handle (for dynamic
    /// updates / unbounded sources).
    pub fn deploy(&mut self) -> Result<Deployment> {
        let graph = self.build_graph()?;
        Coordinator::new(self.cluster.clone(), self.config.clone()).deploy(&graph)
    }

    /// Consumes the context, returning the logical graph (for planning
    /// inspection or [`Coordinator`] reuse). When the context is the sole
    /// owner of the builder (no outstanding [`Stream`] handles), the graph
    /// is moved out without a deep clone; otherwise it falls back to
    /// cloning so live handles stay usable.
    pub fn into_graph(self) -> Result<LogicalGraph> {
        match Rc::try_unwrap(self.state) {
            Ok(cell) => {
                let st = cell.into_inner();
                st.check()?;
                Ok(st.graph)
            }
            Err(state) => {
                let st = state.borrow();
                st.check()?;
                Ok(st.graph.clone())
            }
        }
    }
}

/// An owned handle onto one path through the DAG under construction.
/// Operator methods append to the handle's current FlowUnit;
/// [`Stream::unit`]/[`Stream::to_layer`] re-scope it. Handles from the
/// same context can be merged ([`Stream::union`]) and forked
/// ([`Stream::split`]).
pub struct Stream {
    state: Rc<RefCell<BuilderState>>,
    head: crate::graph::OpId,
    unit: UnitId,
    /// True for handles produced by [`Stream::split`]: their current unit
    /// is shared with the sibling branch, so `unit`/`to_layer` must open a
    /// new unit instead of renaming/re-layering the shared one in place.
    forked: bool,
}

impl Stream {
    fn push(self, kind: OpKind, name: &str) -> Self {
        let head = {
            let mut st = self.state.borrow_mut();
            let (unit, input) = (self.unit, self.head);
            st.graph.add_op(kind, unit, vec![input], name)
        };
        Stream { head, ..self }
    }

    /// Appends a terminal sink, returning its operator id (the typed
    /// layer tags collect sinks by this id).
    pub(crate) fn terminal(self, kind: SinkKind, name: &str) -> OpId {
        let mut st = self.state.borrow_mut();
        let (unit, input) = (self.unit, self.head);
        st.graph.add_op(OpKind::Sink(kind), unit, vec![input], name)
    }

    /// The context's shared typed-decode failure accumulator.
    pub(crate) fn decode_errors(&self) -> Arc<DecodeErrors> {
        self.state.borrow().decode.clone()
    }

    /// Whether typed chains on this stream should lower onto the
    /// columnar data plane (mirrors [`JobConfig::columnar`]).
    pub(crate) fn columnar_enabled(&self) -> bool {
        self.state.borrow().columnar
    }

    /// Appends a monomorphized columnar operator built by the typed
    /// layer ([`OpKind::Columnar`]); the factory closes over the
    /// concrete element types.
    pub(crate) fn push_columnar(self, op: crate::graph::ColumnarOp) -> Self {
        let name = op.label;
        self.push(OpKind::Columnar(op), name)
    }

    /// The builder-context identity stamped on the graph (typed
    /// CollectHandles carry it so `JobReport::take` can reject handles
    /// redeemed against the wrong job's report).
    pub(crate) fn graph_origin(&self) -> u64 {
        self.state.borrow().graph.origin
    }

    /// Opens (or names) a FlowUnit. If the current unit holds no
    /// processing operator yet (it is "fresh": just a source or a union),
    /// it is renamed in place — so `stream(..).unit("ingest")` names the
    /// source's unit. Otherwise a new unit is opened at the current layer
    /// and subsequent operators belong to it. Duplicate names are
    /// recorded as builder errors.
    pub fn unit(self, name: &str) -> Self {
        let unit = {
            let mut st = self.state.borrow_mut();
            let fresh = !self.forked && st.graph.unit_is_fresh(self.unit);
            let clash = st
                .graph
                .units
                .iter()
                .any(|u| u.name == name && (!fresh || u.index != self.unit));
            if clash {
                st.errors.push(format!("duplicate FlowUnit name '{name}'"));
            }
            if fresh {
                let u = &mut st.graph.units[self.unit];
                u.name = name.to_string();
                u.auto = false;
                self.unit
            } else {
                let layer = st.graph.units[self.unit].layer.clone();
                st.graph
                    .add_unit(Some(name), layer, None, Replication::PerCore)
            }
        };
        Stream {
            unit,
            forked: false,
            ..self
        }
    }

    /// Moves the remainder of this stream to `layer` — the FlowUnits
    /// locality annotation. A fresh unit (one holding only its source or
    /// union so far) is re-layered in place, which is how the source
    /// itself is placed on its layer; otherwise this is sugar for opening
    /// a new anonymous unit on `layer`. A layer name that is not in the
    /// cluster's `ClusterSpec.topology.layers` (e.g. a typo) is recorded
    /// as a builder error and surfaced from `execute()`/`deploy()`.
    pub fn to_layer(self, layer: &str) -> Self {
        {
            let mut st = self.state.borrow_mut();
            if !st.layers.iter().any(|l| l == layer) {
                let msg = format!(
                    "to_layer({layer:?}): unknown layer (cluster layers: {})",
                    st.layers.join(", ")
                );
                st.errors.push(msg);
                drop(st);
                return self;
            }
        }
        let (unit, forked) = {
            let mut st = self.state.borrow_mut();
            if st.graph.units[self.unit].layer == layer {
                (self.unit, self.forked)
            } else if !self.forked && st.graph.unit_is_fresh(self.unit) {
                let fresh_name = if st.graph.units[self.unit].auto {
                    Some(st.graph.auto_unit_name(layer, Some(self.unit)))
                } else {
                    None
                };
                let u = &mut st.graph.units[self.unit];
                u.layer = layer.to_string();
                if let Some(n) = fresh_name {
                    u.name = n;
                }
                (self.unit, false)
            } else {
                (
                    st.graph
                        .add_unit(None, layer.into(), None, Replication::PerCore),
                    false,
                )
            }
        };
        Stream {
            unit,
            forked,
            ..self
        }
    }

    /// Declares a capability constraint for the *current FlowUnit* — the
    /// FlowUnits resource annotation (e.g. `"n_cpu >= 4 && gpu = yes"`).
    /// Repeated calls AND-compose. A malformed expression is recorded as
    /// a builder error and surfaced from `execute()`/`deploy()`.
    pub fn add_constraint(self, expr: &str) -> Self {
        {
            let mut st = self.state.borrow_mut();
            if self.forked {
                st.errors.push(format!(
                    "add_constraint({expr:?}) on a split() branch would constrain the unit \
                     shared with the sibling branch; open a unit first (`.unit(name)`)"
                ));
            } else {
                match ConstraintExpr::parse(expr) {
                    Ok(parsed) => {
                        let u = &mut st.graph.units[self.unit];
                        u.constraint = Some(match u.constraint.take() {
                            None => parsed,
                            Some(prev) => prev.and(parsed),
                        });
                    }
                    Err(e) => st.errors.push(format!("add_constraint({expr:?}): {e}")),
                }
            }
        }
        self
    }

    /// Sets the current FlowUnit's in-zone replication policy.
    pub fn replicate(self, policy: Replication) -> Self {
        {
            let mut st = self.state.borrow_mut();
            if self.forked {
                st.errors.push(
                    "replicate() on a split() branch would re-scope the unit shared with \
                     the sibling branch; open a unit first (`.unit(name)`)"
                        .into(),
                );
            } else {
                st.graph.units[self.unit].replication = policy;
            }
        }
        self
    }

    /// Merges this stream with `other` (from the same context) into one.
    /// The merge point lands in a fresh unit on the innermost of the two
    /// input layers; name it with [`Stream::unit`]. Unioning streams from
    /// different contexts is recorded as a builder error.
    pub fn union(self, other: Stream) -> Stream {
        if !Rc::ptr_eq(&self.state, &other.state) {
            self.state
                .borrow_mut()
                .errors
                .push("union: streams were built by different StreamContexts".into());
            return self;
        }
        if self.head == other.head {
            self.state.borrow_mut().errors.push(
                "union: both streams are the same branch (unioning a stream with itself \
                 delivers each event once, not twice — transform a branch first)"
                    .into(),
            );
            return self;
        }
        let (head, unit) = {
            let mut st = self.state.borrow_mut();
            let la = st.graph.units[self.unit].layer.clone();
            let lb = st.graph.units[other.unit].layer.clone();
            let layer = if st.layer_pos(&lb) > st.layer_pos(&la) {
                lb
            } else {
                la
            };
            let unit = st
                .graph
                .add_unit(None, layer, None, Replication::PerCore);
            let head = st
                .graph
                .add_op(OpKind::Union, unit, vec![self.head, other.head], "union");
            (head, unit)
        };
        Stream {
            head,
            unit,
            forked: false,
            ..self
        }
    }

    /// Forks the stream: both returned handles continue from the same
    /// point, and every downstream branch receives every event. Because
    /// the branches share the current unit, `unit`/`to_layer` on either
    /// handle always opens a *new* unit (never renames the shared one).
    pub fn split(self) -> (Stream, Stream) {
        let twin = Stream {
            state: self.state.clone(),
            head: self.head,
            unit: self.unit,
            forked: true,
        };
        (
            Stream {
                forked: true,
                ..self
            },
            twin,
        )
    }

    /// Element-wise transform.
    pub fn map(self, f: impl Fn(Value) -> Value + Send + Sync + 'static) -> Self {
        self.push(OpKind::Map(Arc::new(f)), "map")
    }

    /// Predicate filter.
    pub fn filter(self, f: impl Fn(&Value) -> bool + Send + Sync + 'static) -> Self {
        self.push(OpKind::Filter(Arc::new(f)), "filter")
    }

    /// Combined `map` + `filter` in one pass: keeps `Some` results and
    /// drops `None`. (Also the typed layer's lowering target: its shims
    /// suppress events that fail to decode instead of emitting poison.)
    pub fn filter_map(
        self,
        f: impl Fn(Value) -> Option<Value> + Send + Sync + 'static,
    ) -> Self {
        self.push(OpKind::FilterMap(Arc::new(f)), "filter_map")
    }

    /// One-to-many transform.
    pub fn flat_map(self, f: impl Fn(Value) -> Vec<Value> + Send + Sync + 'static) -> Self {
        self.push(OpKind::FlatMap(Arc::new(f)), "flat_map")
    }

    /// Keys the stream; downstream stateful operators group by this key
    /// and the repartitioning edge is hash-routed.
    pub fn key_by(self, f: impl Fn(&Value) -> Value + Send + Sync + 'static) -> Self {
        self.push(OpKind::KeyBy(Arc::new(f)), "key_by")
    }

    /// `group_by` is Renoir's name for [`Stream::key_by`].
    pub fn group_by(self, f: impl Fn(&Value) -> Value + Send + Sync + 'static) -> Self {
        self.key_by(f)
    }

    /// Fused owned key extraction (the typed layer's `key_by` lowering):
    /// the closure consumes the record and returns the complete
    /// `Pair(key, value)`, or `None` to drop it. Hash-routes downstream
    /// exactly like [`Stream::key_by`].
    pub(crate) fn key_by_fused(
        self,
        f: impl Fn(Value) -> Option<Value> + Send + Sync + 'static,
    ) -> Self {
        self.push(OpKind::KeyByFused(Arc::new(f)), "key_by")
    }

    /// Keyed fold with initial accumulator `init`; emits `Pair(key, acc)`
    /// per key at end-of-stream.
    pub fn fold(
        self,
        init: Value,
        step: impl Fn(&mut Value, Value) + Send + Sync + 'static,
    ) -> Self {
        self.push(
            OpKind::Fold {
                init,
                step: Arc::new(step),
            },
            "fold",
        )
    }

    /// Keyed reduction: combines pairs of payloads with `f`; emits
    /// `Pair(key, reduced)` per key at end-of-stream. Uses an explicit
    /// empty-accumulator representation, so streams that legitimately
    /// contain `Value::Null` reduce correctly.
    pub fn reduce(self, f: impl Fn(&Value, &Value) -> Value + Send + Sync + 'static) -> Self {
        self.push(OpKind::Reduce { f: Arc::new(f) }, "reduce")
    }

    /// Observes every element without changing it (debugging/metrics tap).
    pub fn inspect(self, f: impl Fn(&Value) + Send + Sync + 'static) -> Self {
        self.push(
            OpKind::Map(Arc::new(move |v| {
                f(&v);
                v
            })),
            "inspect",
        )
    }

    /// Assigns each record's *event timestamp* (milliseconds, extracted
    /// by `ts`) and mints watermarks with the generator discipline `gen`.
    /// Watermarks flow downstream as control frames — broadcast across
    /// fan-out, merged min-of-inputs at fan-in — and drive the event-time
    /// operators ([`Stream::event_window`], [`Stream::interval_join`]).
    /// An assigner *replaces* any upstream time domain: watermarks from
    /// further up are swallowed here.
    pub fn assign_timestamps(
        self,
        ts: impl Fn(&Value) -> i64 + Send + Sync + 'static,
        gen: WatermarkGen,
    ) -> Self {
        self.push(
            OpKind::AssignTimestamps {
                ts: Arc::new(ts),
                gen,
            },
            "assign_timestamps",
        )
    }

    /// Event-time window over a keyed stream: buffers `Pair(key, value)`
    /// records into windows by the event timestamp `ts` extracts from the
    /// *value*, and fires each window exactly once when the watermark
    /// passes its end plus `lateness_ms`. Records arriving after every
    /// window they belong to has fired are counted in the `late_records`
    /// metric. Needs watermarks: put an [`Stream::assign_timestamps`]
    /// upstream.
    pub fn event_window(
        self,
        ts: impl Fn(&Value) -> i64 + Send + Sync + 'static,
        assigner: WindowAssigner,
        agg: WindowAgg,
        lateness_ms: i64,
    ) -> Self {
        self.event_window_cfg(Arc::new(ts), assigner, agg, lateness_ms, false)
    }

    /// [`Stream::event_window`] with an explicit late-record side-output
    /// flag (the typed layer turns the flag into a [`CollectHandle`]
    /// redeemed under the window operator's id).
    ///
    /// [`CollectHandle`]: crate::coordinator::CollectHandle
    pub(crate) fn event_window_cfg(
        self,
        ts: TsFn,
        assigner: WindowAssigner,
        agg: WindowAgg,
        lateness_ms: i64,
        late_side: bool,
    ) -> Self {
        self.push(
            OpKind::EventWindow {
                ts,
                assigner,
                agg,
                lateness_ms,
                late_side,
            },
            "event_window",
        )
    }

    /// Keyed stream-stream interval join: matches records of this (left)
    /// stream with records of `other` (right) that share the same key and
    /// whose event timestamps satisfy
    /// `ts_right ∈ [ts_left + lower_ms, ts_left + upper_ms]`, emitting
    /// `Pair(key, Pair(left, right))` per match. Both inputs must be
    /// keyed; the merged watermark (min across both inputs) evicts
    /// buffered records. The join point lands in a fresh unit on the
    /// innermost of the two input layers; name it with [`Stream::unit`].
    pub fn interval_join(
        self,
        other: Stream,
        ts_left: impl Fn(&Value) -> i64 + Send + Sync + 'static,
        ts_right: impl Fn(&Value) -> i64 + Send + Sync + 'static,
        lower_ms: i64,
        upper_ms: i64,
    ) -> Stream {
        self.interval_join_cfg(other, Arc::new(ts_left), Arc::new(ts_right), lower_ms, upper_ms)
    }

    /// [`Stream::interval_join`] taking already-erased timestamp
    /// extractors (the typed layer's lowering target).
    pub(crate) fn interval_join_cfg(
        self,
        other: Stream,
        ts_left: TsFn,
        ts_right: TsFn,
        lower_ms: i64,
        upper_ms: i64,
    ) -> Stream {
        if !Rc::ptr_eq(&self.state, &other.state) {
            self.state
                .borrow_mut()
                .errors
                .push("interval_join: streams were built by different StreamContexts".into());
            return self;
        }
        // tag each input in its own unit so the two sides of the shared
        // inbox stay distinguishable after the fan-in merges them
        let left = self.push(OpKind::SideTag(0), "side_tag");
        let right = other.push(OpKind::SideTag(1), "side_tag");
        let (head, unit) = {
            let mut st = left.state.borrow_mut();
            let la = st.graph.units[left.unit].layer.clone();
            let lb = st.graph.units[right.unit].layer.clone();
            let layer = if st.layer_pos(&lb) > st.layer_pos(&la) {
                lb
            } else {
                la
            };
            let unit = st
                .graph
                .add_unit(None, layer, None, Replication::PerCore);
            let head = st.graph.add_op(
                OpKind::IntervalJoin {
                    ts_left,
                    ts_right,
                    lower_ms,
                    upper_ms,
                },
                unit,
                vec![left.head, right.head],
                "interval_join",
            );
            (head, unit)
        };
        Stream {
            head,
            unit,
            forked: false,
            ..left
        }
    }

    /// The operator id at the head of this stream (the typed layer tags
    /// late-record side outputs by the window operator's id).
    pub(crate) fn head_op(&self) -> OpId {
        self.head
    }

    /// Tumbling count window of `size` events with aggregate `agg`.
    pub fn window(self, size: usize, agg: WindowAgg) -> Self {
        self.push(
            OpKind::Window {
                size,
                slide: size,
                agg,
            },
            "window",
        )
    }

    /// Sliding count window.
    pub fn sliding_window(self, size: usize, slide: usize, agg: WindowAgg) -> Self {
        self.push(OpKind::Window { size, slide, agg }, "window")
    }

    /// Batched inference through the AOT-compiled XLA artifact `name`
    /// (`artifacts/<name>.hlo.txt`); `batch` rows per PJRT call, `in_dim`
    /// features per row.
    pub fn xla_map(self, name: &str, batch: usize, in_dim: usize) -> Self {
        self.push(
            OpKind::XlaMap {
                artifact: name.to_string(),
                batch,
                in_dim,
            },
            "xla_map",
        )
    }

    /// Terminal: collect events into [`JobReport::collected`].
    pub fn collect_vec(self) {
        self.terminal(SinkKind::Collect, "collect");
    }

    /// Terminal: count events only.
    pub fn collect_count(self) {
        self.terminal(SinkKind::Count, "count");
    }

    /// Terminal: discard events (benchmark sink).
    pub fn discard(self) {
        self.terminal(SinkKind::Discard, "discard");
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::config::eval_cluster;
    use std::time::Duration;

    fn transparent_cluster() -> ClusterSpec {
        eval_cluster(None, Duration::ZERO)
    }

    fn fast_config(planner: PlannerKind) -> JobConfig {
        JobConfig {
            planner,
            batch_size: 128,
            ..Default::default()
        }
    }

    #[test]
    fn end_to_end_filter_count_flowunits() {
        let mut ctx = StreamContext::new(transparent_cluster(), fast_config(PlannerKind::FlowUnits));
        ctx.stream(Source::synthetic(3000, |_, i| Value::I64(i as i64)))
            .to_layer("edge")
            .filter(|v| v.as_i64().unwrap() % 3 == 0)
            .to_layer("cloud")
            .map(|v| v)
            .collect_count();
        let report = ctx.execute().unwrap();
        assert_eq!(report.events_in, 3000);
        assert_eq!(report.events_out, 1000);
    }

    #[test]
    fn end_to_end_same_result_under_renoir_planner() {
        for planner in [PlannerKind::FlowUnits, PlannerKind::Renoir] {
            let mut ctx = StreamContext::new(transparent_cluster(), fast_config(planner));
            ctx.stream(Source::synthetic(3000, |_, i| Value::I64(i as i64)))
                .to_layer("edge")
                .filter(|v| v.as_i64().unwrap() % 3 == 0)
                .to_layer("cloud")
                .collect_count();
            let report = ctx.execute().unwrap();
            assert_eq!(report.events_out, 1000, "{planner:?}");
        }
    }

    #[test]
    fn end_to_end_wordcount() {
        let text = ["the cat", "the dog", "the cat sat"];
        let values: Vec<Value> = text.iter().map(|l| Value::Str(l.to_string())).collect();
        let mut ctx = StreamContext::new(transparent_cluster(), fast_config(PlannerKind::FlowUnits));
        ctx.stream(Source::vector(values))
            .to_layer("cloud")
            .flat_map(|v| {
                v.as_str()
                    .unwrap()
                    .split(' ')
                    .map(|w| Value::Str(w.to_string()))
                    .collect()
            })
            .group_by(|w| w.clone())
            .fold(Value::I64(0), |acc, _| {
                *acc = Value::I64(acc.as_i64().unwrap() + 1)
            })
            .collect_vec();
        let report = ctx.execute().unwrap();
        let mut counts: Vec<(String, i64)> = report
            .collected
            .iter()
            .map(|v| {
                let (k, c) = v.as_pair().unwrap();
                (k.as_str().unwrap().to_string(), c.as_i64().unwrap())
            })
            .collect();
        counts.sort();
        assert_eq!(
            counts,
            vec![
                ("cat".into(), 2),
                ("dog".into(), 1),
                ("sat".into(), 1),
                ("the".into(), 3)
            ]
        );
    }

    #[test]
    fn keyed_window_pipeline_produces_expected_window_count() {
        let mut ctx = StreamContext::new(transparent_cluster(), fast_config(PlannerKind::FlowUnits));
        // 4 edge sources × 2000 events each = 8000; keys 0..8; windows of 100
        ctx.stream(Source::synthetic(8000, |_, i| Value::I64(i as i64)))
            .to_layer("edge")
            .map(|v| v)
            .to_layer("site")
            .key_by(|v| Value::I64(v.as_i64().unwrap() % 8))
            .window(100, WindowAgg::Count)
            .to_layer("cloud")
            .collect_vec();
        let report = ctx.execute().unwrap();
        // 8000 events / 8 keys = 1000 per key = 10 full windows per key.
        // Keys are split across the site zone's instances; totals must add
        // up to exactly 80 full windows (count=100 each), no partials.
        let total: i64 = report
            .collected
            .iter()
            .map(|v| v.as_pair().unwrap().1.as_i64().unwrap())
            .sum();
        assert_eq!(total, 8000);
        assert_eq!(report.collected.len(), 80);
    }

    #[test]
    fn decoupled_boundaries_preserve_results() {
        let config = JobConfig {
            planner: PlannerKind::FlowUnits,
            decouple_units: true,
            batch_size: 64,
            poll_timeout: Duration::from_millis(10),
            ..Default::default()
        };
        let mut ctx = StreamContext::new(transparent_cluster(), config);
        ctx.stream(Source::synthetic(2000, |_, i| Value::I64(i as i64)))
            .to_layer("edge")
            .filter(|v| v.as_i64().unwrap() % 2 == 0)
            .to_layer("cloud")
            .collect_count();
        let report = ctx.execute().unwrap();
        assert_eq!(report.events_out, 1000);
        assert!(
            report.metrics.queue_appends.load(std::sync::atomic::Ordering::Relaxed) > 0,
            "queue substrate was used"
        );
    }

    #[test]
    fn constraints_scope_to_the_unit_and_compose() {
        let mut ctx = StreamContext::new(transparent_cluster(), fast_config(PlannerKind::FlowUnits));
        ctx.stream(Source::synthetic(10, |_, i| Value::I64(i as i64)))
            .to_layer("cloud")
            .map(|v| v)
            .add_constraint("gpu = yes")
            .add_constraint("n_cpu >= 4")
            .collect_count();
        let graph = ctx.into_graph().unwrap();
        let unit = graph.unit_named("cloud").expect("layer-named unit");
        let c = graph.units[unit].constraint.as_ref().unwrap();
        assert_eq!(c.to_string(), "gpu = yes && n_cpu >= 4");
    }

    #[test]
    fn bad_constraint_surfaces_at_execute_not_panic() {
        let mut ctx = StreamContext::new(transparent_cluster(), fast_config(PlannerKind::FlowUnits));
        ctx.stream(Source::synthetic(10, |_, i| Value::I64(i as i64)))
            .to_layer("cloud")
            .add_constraint("n_cpu >=") // malformed on purpose
            .collect_count();
        let err = ctx.execute().unwrap_err();
        assert!(matches!(err, Error::Graph(_)), "got {err}");
        assert!(err.to_string().contains("add_constraint"));
    }

    #[test]
    fn to_layer_relayers_the_source_unit_in_place() {
        // the old API special-cased `ops.len() == 1` to retroactively move
        // the source; unit scoping makes this structural
        let mut ctx = StreamContext::new(transparent_cluster(), fast_config(PlannerKind::FlowUnits));
        ctx.stream(Source::synthetic(10, |_, i| Value::I64(i as i64)))
            .to_layer("edge")
            .map(|v| v)
            .to_layer("cloud")
            .collect_count();
        let graph = ctx.into_graph().unwrap();
        // source sits in the (re-layered, auto-named) edge unit
        assert_eq!(graph.unit_of(0).layer, "edge");
        assert_eq!(graph.unit_of(0).name, "edge");
        assert_eq!(graph.unit_names(), vec!["edge", "cloud"]);
    }

    #[test]
    fn named_units_carry_layer_constraint_and_replication() {
        let mut ctx = StreamContext::new(transparent_cluster(), fast_config(PlannerKind::FlowUnits));
        ctx.stream(Source::synthetic(10, |_, i| Value::I64(i as i64)))
            .unit("ingest")
            .to_layer("edge")
            .map(|v| v)
            .unit("scorer")
            .to_layer("cloud")
            .add_constraint("gpu = yes")
            .replicate(Replication::PerHost)
            .map(|v| v)
            .collect_count();
        let graph = ctx.into_graph().unwrap();
        assert_eq!(graph.unit_names(), vec!["ingest", "scorer"]);
        let scorer = &graph.units[graph.unit_named("scorer").unwrap()];
        assert_eq!(scorer.layer, "cloud");
        assert_eq!(scorer.constraint.as_ref().unwrap().to_string(), "gpu = yes");
        assert_eq!(scorer.replication, Replication::PerHost);
        assert!(!scorer.auto);
    }

    #[test]
    fn duplicate_unit_names_surface_at_execute() {
        let mut ctx = StreamContext::new(transparent_cluster(), fast_config(PlannerKind::FlowUnits));
        ctx.stream(Source::synthetic(10, |_, i| Value::I64(i as i64)))
            .unit("dup")
            .to_layer("edge")
            .map(|v| v)
            .unit("dup")
            .collect_count();
        let err = ctx.execute().unwrap_err();
        assert!(err.to_string().contains("duplicate FlowUnit name"));
    }

    #[test]
    fn unknown_layer_is_a_builder_error() {
        let mut ctx = StreamContext::new(transparent_cluster(), fast_config(PlannerKind::FlowUnits));
        ctx.stream(Source::synthetic(10, |_, i| Value::I64(i as i64)))
            .to_layer("clouds") // typo: not in ClusterSpec.topology.layers
            .collect_count();
        let err = ctx.execute().unwrap_err();
        assert!(matches!(err, Error::Graph(_)), "got {err}");
        assert!(err.to_string().contains("unknown layer"), "got {err}");
        assert!(err.to_string().contains("clouds"), "got {err}");
        assert!(
            err.to_string().contains("edge, site, cloud"),
            "lists the cluster layers: {err}"
        );
    }

    #[test]
    fn into_graph_without_live_handles_and_with_them_agree() {
        let build = |hold: bool| {
            let mut ctx =
                StreamContext::new(transparent_cluster(), fast_config(PlannerKind::FlowUnits));
            ctx.stream(Source::synthetic(10, |_, i| Value::I64(i as i64)))
                .to_layer("edge")
                .map(|v| v)
                .to_layer("cloud")
                .collect_count();
            // a live handle forces the clone fallback; without one the
            // graph is moved out of the uniquely-owned builder
            let held = if hold {
                Some(ctx.stream(Source::synthetic(5, |_, _| Value::Null)))
            } else {
                None
            };
            let graph = ctx.into_graph().unwrap();
            drop(held);
            graph
        };
        let moved = build(false);
        let cloned = build(true);
        assert_eq!(moved.unit_names(), vec!["edge", "cloud"]);
        assert_eq!(cloned.ops.len(), moved.ops.len() + 1, "held source present");
    }

    #[test]
    fn union_of_two_sources_merges_all_events() {
        let mut ctx = StreamContext::new(transparent_cluster(), fast_config(PlannerKind::FlowUnits));
        let a = ctx
            .stream(Source::synthetic(600, |_, i| Value::I64(i as i64)))
            .unit("north")
            .to_layer("edge");
        let b = ctx
            .stream(Source::synthetic(400, |_, i| Value::I64(i as i64)))
            .unit("south")
            .to_layer("edge");
        a.union(b)
            .unit("merge")
            .to_layer("cloud")
            .map(|v| v)
            .collect_count();
        let report = ctx.execute().unwrap();
        assert_eq!(report.events_in, 1000);
        assert_eq!(report.events_out, 1000);
    }

    #[test]
    fn split_duplicates_stream_into_two_sinks() {
        let mut ctx = StreamContext::new(transparent_cluster(), fast_config(PlannerKind::FlowUnits));
        let s = ctx
            .stream(Source::synthetic(500, |_, i| Value::I64(i as i64)))
            .to_layer("edge")
            .map(|v| v)
            .to_layer("cloud");
        let (left, right) = s.split();
        left.unit("keep").filter(|v| v.as_i64().unwrap() % 2 == 0).collect_vec();
        right.unit("count-all").collect_count();
        let report = ctx.execute().unwrap();
        assert_eq!(report.events_in, 500);
        // both branches saw every event: 250 collected + 500 counted
        assert_eq!(report.collected.len(), 250);
        assert_eq!(report.events_out, 750);
    }

    #[test]
    fn split_fanout_encodes_each_batch_at_most_once() {
        let mut ctx = StreamContext::new(transparent_cluster(), fast_config(PlannerKind::FlowUnits));
        let s = ctx
            .stream(Source::synthetic(1000, |_, i| Value::I64(i as i64)))
            .to_layer("edge");
        let (site, cloud) = s.split();
        site.unit("site-count").to_layer("site").collect_count();
        cloud.unit("cloud-count").to_layer("cloud").collect_count();
        let report = ctx.execute().unwrap();
        assert_eq!(report.events_out, 2000, "both branches saw every event");
        // 4 edge source instances × ceil(250/128) = 8 batches, each
        // delivered over TWO crossing edges (site + cloud) — but encoded
        // exactly once thanks to the shared wire cache
        assert_eq!(report.wire_encodes, 8);
        assert!(
            report.metrics.net_frames.load(std::sync::atomic::Ordering::Relaxed) >= 16,
            "each batch still produced one frame per edge"
        );
    }

    #[test]
    fn union_across_contexts_is_a_builder_error() {
        let mut ctx1 = StreamContext::new(transparent_cluster(), fast_config(PlannerKind::FlowUnits));
        let mut ctx2 = StreamContext::new(transparent_cluster(), fast_config(PlannerKind::FlowUnits));
        let a = ctx1.stream(Source::synthetic(10, |_, i| Value::I64(i as i64)));
        let b = ctx2.stream(Source::synthetic(10, |_, i| Value::I64(i as i64)));
        a.union(b).collect_count();
        let err = ctx1.execute().unwrap_err();
        assert!(err.to_string().contains("different StreamContexts"));
    }

    #[test]
    fn execute_without_stream_errors() {
        let mut ctx = StreamContext::new(transparent_cluster(), JobConfig::default());
        assert!(ctx.execute().is_err());
    }

    #[test]
    fn dangling_stream_surfaces_at_execute() {
        let mut ctx = StreamContext::new(transparent_cluster(), JobConfig::default());
        // no sink attached
        let _ = ctx
            .stream(Source::synthetic(10, |_, i| Value::I64(i as i64)))
            .to_layer("edge")
            .map(|v| v);
        let err = ctx.execute().unwrap_err();
        assert!(err.to_string().contains("dangling"), "got {err}");
    }

    #[test]
    fn reduce_computes_keyed_max() {
        let mut ctx = StreamContext::new(transparent_cluster(), fast_config(PlannerKind::FlowUnits));
        ctx.stream(Source::synthetic(1000, |_, i| Value::I64(i as i64)))
            .to_layer("cloud")
            .key_by(|v| Value::I64(v.as_i64().unwrap() % 3))
            .reduce(|a, b| Value::I64(a.as_i64().unwrap().max(b.as_i64().unwrap())))
            .collect_vec();
        let report = ctx.execute().unwrap();
        let mut maxes: Vec<(i64, i64)> = report
            .collected
            .iter()
            .map(|v| {
                let (k, m) = v.as_pair().unwrap();
                (k.as_i64().unwrap(), m.as_i64().unwrap())
            })
            .collect();
        maxes.sort();
        assert_eq!(maxes, vec![(0, 999), (1, 997), (2, 998)]);
    }

    #[test]
    fn reduce_preserves_legitimate_null_values() {
        // a stream of Value::Null must be reduced like any other value —
        // the old fold-based sugar treated Null as "empty accumulator"
        let count = |v: &Value| match v {
            Value::Null => 1,
            other => other.as_i64().unwrap_or(0),
        };
        let mut ctx = StreamContext::new(transparent_cluster(), fast_config(PlannerKind::FlowUnits));
        ctx.stream(Source::vector(vec![Value::Null; 5]))
            .to_layer("cloud")
            .key_by(|_| Value::I64(0))
            .reduce(move |a, b| Value::I64(count(a) + count(b)))
            .collect_vec();
        let report = ctx.execute().unwrap();
        assert_eq!(report.collected.len(), 1);
        assert_eq!(
            report.collected[0].as_pair().unwrap().1.as_i64(),
            Some(5),
            "all five Null events were reduced"
        );
    }

    #[test]
    fn inspect_observes_all_events() {
        use std::sync::atomic::{AtomicU64, Ordering};
        let seen = Arc::new(AtomicU64::new(0));
        let seen2 = seen.clone();
        let mut ctx = StreamContext::new(transparent_cluster(), fast_config(PlannerKind::FlowUnits));
        ctx.stream(Source::synthetic(500, |_, i| Value::I64(i as i64)))
            .to_layer("edge")
            .inspect(move |_| {
                seen2.fetch_add(1, Ordering::Relaxed);
            })
            .to_layer("cloud")
            .collect_count();
        let report = ctx.execute().unwrap();
        assert_eq!(report.events_out, 500);
        assert_eq!(seen.load(Ordering::Relaxed), 500);
    }

    #[test]
    fn sliding_window_emits_overlapping_aggregates() {
        let mut ctx = StreamContext::new(transparent_cluster(), fast_config(PlannerKind::FlowUnits));
        ctx.stream(Source::synthetic(1000, |_, i| Value::F64(i as f64)))
            .to_layer("cloud")
            .key_by(|_| Value::I64(0))
            .sliding_window(100, 50, WindowAgg::Count)
            .collect_vec();
        let report = ctx.execute().unwrap();
        // 1000 events, size 100 slide 50: full windows at 100, 150, ... 1000
        // = 19 full windows, plus a 50-event partial at EOS
        assert_eq!(report.collected.len(), 20);
    }
}
