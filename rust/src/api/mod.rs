//! The user-facing stream API, in two layers sharing one builder:
//!
//! * [`typed`] — the **typed front-end**: [`Stream<T>`] and
//!   [`KeyedStream<K, V>`] carry native Rust element types
//!   ([`StreamData`]), operator closures never see the engine's dynamic
//!   [`Value`](crate::value::Value), and keyed-only operators
//!   (`fold`/`reduce`/`window`) are *unreachable* on unkeyed streams —
//!   illegal operator orderings are compile errors, not runtime
//!   surprises. Typed sinks return a [`CollectHandle<T>`] redeemed
//!   against the [`JobReport`].
//! * [`raw`] — the **stable untyped substrate** the typed layer compiles
//!   down to: closures over `Value`, `collect_vec` into
//!   `JobReport::collected`, and the graph-construction surface used by
//!   [`Deployment::update_unit`](crate::coordinator::Deployment::update_unit).
//!
//! Both layers drive the same [`StreamContext`]: it owns the cluster
//! description, the job configuration, and the logical DAG under
//! construction, and [`StreamContext::stream`](raw::StreamContext::stream)
//! opens a raw or typed stream depending on the [`Source`] handed to it
//! (the [`OpenStream`] dispatch trait). Everything downstream —
//! channels, planners, the zero-copy batch data plane, dynamic updates —
//! is shared and untouched by the choice of layer.
//!
//! ```no_run
//! use flowunits::prelude::*;
//!
//! let cluster = flowunits::config::fig2_cluster();
//! let mut ctx = StreamContext::new(cluster, JobConfig::default());
//!
//! // typed pipeline: closures take i64, not Value
//! let windows = ctx
//!     .stream(Source::synthetic(500_000, |_, i| i as i64))
//!     .unit("ingest")
//!     .to_layer("edge")
//!     .filter(|v| v % 3 == 0)
//!     .unit("detect")
//!     .to_layer("cloud")
//!     .key_by(|v| v % 8)
//!     .window::<i64>(100, WindowAgg::Count)
//!     .collect();
//!
//! let mut report = ctx.execute().unwrap();
//! let counts: Vec<(i64, i64)> = report.take(windows).unwrap();
//! println!("{} windows", counts.len());
//! ```

pub mod data;
pub mod raw;
pub mod typed;

pub use crate::coordinator::{AutoscaleConfig, CollectHandle, JobConfig, JobReport};
pub use crate::graph::{Replication, WindowAgg};
pub use crate::placement::PlannerKind;
pub use crate::time::{WatermarkGen, WindowAssigner};
pub use data::{DecodeErrors, Features};
pub use raw::StreamContext;
pub use typed::{KeyedStream, Source, Stream};

/// Re-export of the native-type bridge behind the typed layer.
pub use crate::value::StreamData;

/// Dispatch trait behind [`StreamContext::stream`]: implemented by the
/// untyped [`raw::Source`] (opening a raw [`raw::Stream`]) and the typed
/// [`typed::Source<T>`] (opening a [`typed::Stream<T>`]).
pub trait OpenStream {
    /// The stream handle this source opens.
    type Handle;
    /// Adds the source to the context's DAG and returns its handle.
    fn open(self, ctx: &mut raw::StreamContext) -> Self::Handle;
}
