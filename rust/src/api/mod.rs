//! The user-facing stream API — Renoir-style fluent builder extended with
//! the paper's two annotations: [`Stream::to_layer`] and
//! [`Stream::add_constraint`] (§IV).
//!
//! ```no_run
//! use flowunits::prelude::*;
//! use std::sync::Arc;
//!
//! let cluster = flowunits::config::fig2_cluster();
//! let mut ctx = StreamContext::new(cluster, JobConfig::default());
//! ctx.stream(Source::synthetic(1_000_000, |_, i| Value::F64((i % 100) as f64)))
//!     .to_layer("edge")
//!     .filter(|v| v.as_f64().unwrap() > 33.0)
//!     .to_layer("site")
//!     .key_by(|v| Value::I64(v.as_f64().unwrap() as i64 % 8))
//!     .window(100, WindowAgg::Mean)
//!     .to_layer("cloud")
//!     .map(|v| v)
//!     .collect_count();
//! let report = ctx.execute().unwrap();
//! ```

pub use crate::coordinator::{JobConfig, JobReport};
pub use crate::graph::WindowAgg;
pub use crate::placement::PlannerKind;

use crate::config::ClusterSpec;
use crate::coordinator::{Coordinator, Deployment};
use crate::error::{Error, Result};
use crate::graph::{LogicalGraph, OpKind, SinkKind, SourceKind};
use crate::topology::ConstraintExpr;
use crate::value::Value;
use std::sync::Arc;

/// Source builder.
pub struct Source(SourceKind);

impl Source {
    /// Synthetic generator: `total` events split across source instances,
    /// each produced by `gen(instance_index, event_index)`.
    pub fn synthetic(
        total: u64,
        gen: impl Fn(u64, u64) -> Value + Send + Sync + 'static,
    ) -> Source {
        Source(SourceKind::Synthetic {
            total,
            gen: Arc::new(gen),
            rate: None,
        })
    }

    /// Rate-limited synthetic generator (events/second per instance);
    /// pair with [`Deployment::stop_sources`] for unbounded streams.
    pub fn synthetic_rated(
        total: u64,
        rate: f64,
        gen: impl Fn(u64, u64) -> Value + Send + Sync + 'static,
    ) -> Source {
        Source(SourceKind::Synthetic {
            total,
            gen: Arc::new(gen),
            rate: Some(rate),
        })
    }

    /// A pre-materialised vector.
    pub fn vector(values: Vec<Value>) -> Source {
        Source(SourceKind::Vector(Arc::new(values)))
    }

    /// Lines of a text file as `Value::Str`.
    pub fn file_lines(path: impl Into<std::path::PathBuf>) -> Source {
        Source(SourceKind::FileLines(path.into()))
    }
}

/// Builder context owning the cluster description, job configuration, and
/// the logical graph under construction.
pub struct StreamContext {
    cluster: ClusterSpec,
    config: JobConfig,
    graph: Option<LogicalGraph>,
    current_layer: String,
}

impl StreamContext {
    /// Creates a context. Until the first [`Stream::to_layer`], operators
    /// are annotated with the innermost layer (the cloud).
    pub fn new(cluster: ClusterSpec, config: JobConfig) -> Self {
        let current_layer = cluster
            .topology
            .layers
            .last()
            .cloned()
            .unwrap_or_else(|| "cloud".into());
        StreamContext {
            cluster,
            config,
            graph: None,
            current_layer,
        }
    }

    /// Starts a stream from `source`.
    pub fn stream(&mut self, source: Source) -> Stream<'_> {
        let mut g = LogicalGraph::default();
        g.push(OpKind::Source(source.0), self.current_layer.clone(), None, "source");
        self.graph = Some(g);
        Stream { ctx: self }
    }

    /// Executes the built job to completion.
    pub fn execute(&mut self) -> Result<JobReport> {
        let graph = self
            .graph
            .take()
            .ok_or_else(|| Error::Graph("no stream defined".into()))?;
        Coordinator::new(self.cluster.clone(), self.config.clone()).run(&graph)
    }

    /// Deploys the built job and returns the live handle (for dynamic
    /// updates / unbounded sources).
    pub fn deploy(&mut self) -> Result<Deployment> {
        let graph = self
            .graph
            .take()
            .ok_or_else(|| Error::Graph("no stream defined".into()))?;
        Coordinator::new(self.cluster.clone(), self.config.clone()).deploy(&graph)
    }

    /// Consumes the context, returning the logical graph (for planning
    /// inspection or [`Coordinator`] reuse).
    pub fn into_graph(mut self) -> Result<LogicalGraph> {
        self.graph
            .take()
            .ok_or_else(|| Error::Graph("no stream defined".into()))
    }

    fn push(&mut self, kind: OpKind, name: &str) {
        let layer = self.current_layer.clone();
        self.graph
            .as_mut()
            .expect("stream() must be called first")
            .push(kind, layer, None, name);
    }
}

/// Fluent stream under construction. All methods annotate operators with
/// the context's current layer; [`Stream::to_layer`] switches it.
pub struct Stream<'a> {
    ctx: &'a mut StreamContext,
}

impl<'a> Stream<'a> {
    /// Moves the remainder of the pipeline to `layer` — the FlowUnits
    /// locality annotation. Subsequent operators form (part of) a new
    /// FlowUnit deployed on the zones of that layer.
    pub fn to_layer(self, layer: &str) -> Self {
        self.ctx.current_layer = layer.to_string();
        // retroactively annotate the source if no operator followed it yet
        let g = self.ctx.graph.as_mut().unwrap();
        if g.ops.len() == 1 {
            g.ops[0].layer = layer.to_string();
        }
        self
    }

    /// Declares a capability constraint for the *most recent* operator —
    /// the FlowUnits resource annotation (e.g. `"n_cpu >= 4 && gpu = yes"`).
    pub fn add_constraint(self, expr: &str) -> Self {
        let parsed = ConstraintExpr::parse(expr).expect("invalid constraint expression");
        let g = self.ctx.graph.as_mut().unwrap();
        let last = g.ops.last_mut().expect("no operator to constrain");
        last.constraint = Some(match last.constraint.take() {
            None => parsed,
            Some(prev) => prev.and(parsed),
        });
        self
    }

    /// Element-wise transform.
    pub fn map(self, f: impl Fn(Value) -> Value + Send + Sync + 'static) -> Self {
        self.ctx.push(OpKind::Map(Arc::new(f)), "map");
        self
    }

    /// Predicate filter.
    pub fn filter(self, f: impl Fn(&Value) -> bool + Send + Sync + 'static) -> Self {
        self.ctx.push(OpKind::Filter(Arc::new(f)), "filter");
        self
    }

    /// One-to-many transform.
    pub fn flat_map(self, f: impl Fn(Value) -> Vec<Value> + Send + Sync + 'static) -> Self {
        self.ctx.push(OpKind::FlatMap(Arc::new(f)), "flat_map");
        self
    }

    /// Keys the stream; downstream stateful operators group by this key
    /// and the repartitioning edge is hash-routed.
    pub fn key_by(self, f: impl Fn(&Value) -> Value + Send + Sync + 'static) -> Self {
        self.ctx.push(OpKind::KeyBy(Arc::new(f)), "key_by");
        self
    }

    /// `group_by` is Renoir's name for [`Stream::key_by`].
    pub fn group_by(self, f: impl Fn(&Value) -> Value + Send + Sync + 'static) -> Self {
        self.key_by(f)
    }

    /// Keyed fold with initial accumulator `init`; emits `Pair(key, acc)`
    /// per key at end-of-stream.
    pub fn fold(
        self,
        init: Value,
        step: impl Fn(&mut Value, Value) + Send + Sync + 'static,
    ) -> Self {
        self.ctx.push(
            OpKind::Fold {
                init,
                step: Arc::new(step),
            },
            "fold",
        );
        self
    }

    /// Keyed reduction: combines pairs of payloads with `f`; emits
    /// `Pair(key, reduced)` per key at end-of-stream. Sugar over
    /// [`Stream::fold`] with a first-element initializer.
    pub fn reduce(self, f: impl Fn(&Value, &Value) -> Value + Send + Sync + 'static) -> Self {
        self.fold(Value::Null, move |acc, v| {
            *acc = if matches!(acc, Value::Null) {
                v
            } else {
                f(acc, &v)
            };
        })
    }

    /// Observes every element without changing it (debugging/metrics tap).
    pub fn inspect(self, f: impl Fn(&Value) + Send + Sync + 'static) -> Self {
        self.ctx.push(
            OpKind::Map(Arc::new(move |v| {
                f(&v);
                v
            })),
            "inspect",
        );
        self
    }

    /// Tumbling count window of `size` events with aggregate `agg`.
    pub fn window(self, size: usize, agg: WindowAgg) -> Self {
        self.ctx.push(
            OpKind::Window {
                size,
                slide: size,
                agg,
            },
            "window",
        );
        self
    }

    /// Sliding count window.
    pub fn sliding_window(self, size: usize, slide: usize, agg: WindowAgg) -> Self {
        self.ctx.push(OpKind::Window { size, slide, agg }, "window");
        self
    }

    /// Batched inference through the AOT-compiled XLA artifact `name`
    /// (`artifacts/<name>.hlo.txt`); `batch` rows per PJRT call, `in_dim`
    /// features per row.
    pub fn xla_map(self, name: &str, batch: usize, in_dim: usize) -> Self {
        self.ctx.push(
            OpKind::XlaMap {
                artifact: name.to_string(),
                batch,
                in_dim,
            },
            "xla_map",
        );
        self
    }

    /// Terminal: collect events into [`JobReport::collected`].
    pub fn collect_vec(self) {
        self.ctx.push(OpKind::Sink(SinkKind::Collect), "collect");
    }

    /// Terminal: count events only.
    pub fn collect_count(self) {
        self.ctx.push(OpKind::Sink(SinkKind::Count), "count");
    }

    /// Terminal: discard events (benchmark sink).
    pub fn discard(self) {
        self.ctx.push(OpKind::Sink(SinkKind::Discard), "discard");
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::config::eval_cluster;
    use std::time::Duration;

    fn transparent_cluster() -> ClusterSpec {
        eval_cluster(None, Duration::ZERO)
    }

    fn fast_config(planner: PlannerKind) -> JobConfig {
        JobConfig {
            planner,
            batch_size: 128,
            ..Default::default()
        }
    }

    #[test]
    fn end_to_end_filter_count_flowunits() {
        let mut ctx = StreamContext::new(transparent_cluster(), fast_config(PlannerKind::FlowUnits));
        ctx.stream(Source::synthetic(3000, |_, i| Value::I64(i as i64)))
            .to_layer("edge")
            .filter(|v| v.as_i64().unwrap() % 3 == 0)
            .to_layer("cloud")
            .map(|v| v)
            .collect_count();
        let report = ctx.execute().unwrap();
        assert_eq!(report.events_in, 3000);
        assert_eq!(report.events_out, 1000);
    }

    #[test]
    fn end_to_end_same_result_under_renoir_planner() {
        for planner in [PlannerKind::FlowUnits, PlannerKind::Renoir] {
            let mut ctx = StreamContext::new(transparent_cluster(), fast_config(planner));
            ctx.stream(Source::synthetic(3000, |_, i| Value::I64(i as i64)))
                .to_layer("edge")
                .filter(|v| v.as_i64().unwrap() % 3 == 0)
                .to_layer("cloud")
                .collect_count();
            let report = ctx.execute().unwrap();
            assert_eq!(report.events_out, 1000, "{planner:?}");
        }
    }

    #[test]
    fn end_to_end_wordcount() {
        let text = ["the cat", "the dog", "the cat sat"];
        let values: Vec<Value> = text.iter().map(|l| Value::Str(l.to_string())).collect();
        let mut ctx = StreamContext::new(transparent_cluster(), fast_config(PlannerKind::FlowUnits));
        ctx.stream(Source::vector(values))
            .to_layer("cloud")
            .flat_map(|v| {
                v.as_str()
                    .unwrap()
                    .split(' ')
                    .map(|w| Value::Str(w.to_string()))
                    .collect()
            })
            .group_by(|w| w.clone())
            .fold(Value::I64(0), |acc, _| {
                *acc = Value::I64(acc.as_i64().unwrap() + 1)
            })
            .collect_vec();
        let report = ctx.execute().unwrap();
        let mut counts: Vec<(String, i64)> = report
            .collected
            .iter()
            .map(|v| {
                let (k, c) = v.as_pair().unwrap();
                (k.as_str().unwrap().to_string(), c.as_i64().unwrap())
            })
            .collect();
        counts.sort();
        assert_eq!(
            counts,
            vec![
                ("cat".into(), 2),
                ("dog".into(), 1),
                ("sat".into(), 1),
                ("the".into(), 3)
            ]
        );
    }

    #[test]
    fn keyed_window_pipeline_produces_expected_window_count() {
        let mut ctx = StreamContext::new(transparent_cluster(), fast_config(PlannerKind::FlowUnits));
        // 4 edge sources × 2000 events each = 8000; keys 0..8; windows of 100
        ctx.stream(Source::synthetic(8000, |_, i| Value::I64(i as i64)))
            .to_layer("edge")
            .map(|v| v)
            .to_layer("site")
            .key_by(|v| Value::I64(v.as_i64().unwrap() % 8))
            .window(100, WindowAgg::Count)
            .to_layer("cloud")
            .collect_vec();
        let report = ctx.execute().unwrap();
        // 8000 events / 8 keys = 1000 per key = 10 full windows per key.
        // Keys are split across the site zone's instances; totals must add
        // up to exactly 80 full windows (count=100 each), no partials.
        let total: i64 = report
            .collected
            .iter()
            .map(|v| v.as_pair().unwrap().1.as_i64().unwrap())
            .sum();
        assert_eq!(total, 8000);
        assert_eq!(report.collected.len(), 80);
    }

    #[test]
    fn decoupled_boundaries_preserve_results() {
        let config = JobConfig {
            planner: PlannerKind::FlowUnits,
            decouple_units: true,
            batch_size: 64,
            poll_timeout: Duration::from_millis(10),
            ..Default::default()
        };
        let mut ctx = StreamContext::new(transparent_cluster(), config);
        ctx.stream(Source::synthetic(2000, |_, i| Value::I64(i as i64)))
            .to_layer("edge")
            .filter(|v| v.as_i64().unwrap() % 2 == 0)
            .to_layer("cloud")
            .collect_count();
        let report = ctx.execute().unwrap();
        assert_eq!(report.events_out, 1000);
        assert!(
            report.metrics.queue_appends.load(std::sync::atomic::Ordering::Relaxed) > 0,
            "queue substrate was used"
        );
    }

    #[test]
    fn constraint_annotation_composes() {
        let mut ctx = StreamContext::new(transparent_cluster(), fast_config(PlannerKind::FlowUnits));
        ctx.stream(Source::synthetic(10, |_, i| Value::I64(i as i64)))
            .to_layer("cloud")
            .map(|v| v)
            .add_constraint("gpu = yes")
            .add_constraint("n_cpu >= 4")
            .collect_count();
        let graph = ctx.into_graph().unwrap();
        let c = graph.ops[1].constraint.as_ref().unwrap();
        assert_eq!(c.to_string(), "gpu = yes && n_cpu >= 4");
    }

    #[test]
    fn execute_without_stream_errors() {
        let mut ctx = StreamContext::new(transparent_cluster(), JobConfig::default());
        assert!(ctx.execute().is_err());
    }

    #[test]
    fn reduce_computes_keyed_max() {
        let mut ctx = StreamContext::new(transparent_cluster(), fast_config(PlannerKind::FlowUnits));
        ctx.stream(Source::synthetic(1000, |_, i| Value::I64(i as i64)))
            .to_layer("cloud")
            .key_by(|v| Value::I64(v.as_i64().unwrap() % 3))
            .reduce(|a, b| Value::I64(a.as_i64().unwrap().max(b.as_i64().unwrap())))
            .collect_vec();
        let report = ctx.execute().unwrap();
        let mut maxes: Vec<(i64, i64)> = report
            .collected
            .iter()
            .map(|v| {
                let (k, m) = v.as_pair().unwrap();
                (k.as_i64().unwrap(), m.as_i64().unwrap())
            })
            .collect();
        maxes.sort();
        assert_eq!(maxes, vec![(0, 999), (1, 997), (2, 998)]);
    }

    #[test]
    fn inspect_observes_all_events() {
        use std::sync::atomic::{AtomicU64, Ordering};
        let seen = Arc::new(AtomicU64::new(0));
        let seen2 = seen.clone();
        let mut ctx = StreamContext::new(transparent_cluster(), fast_config(PlannerKind::FlowUnits));
        ctx.stream(Source::synthetic(500, |_, i| Value::I64(i as i64)))
            .to_layer("edge")
            .inspect(move |_| {
                seen2.fetch_add(1, Ordering::Relaxed);
            })
            .to_layer("cloud")
            .collect_count();
        let report = ctx.execute().unwrap();
        assert_eq!(report.events_out, 500);
        assert_eq!(seen.load(Ordering::Relaxed), 500);
    }

    #[test]
    fn sliding_window_emits_overlapping_aggregates() {
        let mut ctx = StreamContext::new(transparent_cluster(), fast_config(PlannerKind::FlowUnits));
        ctx.stream(Source::synthetic(1000, |_, i| Value::F64(i as f64)))
            .to_layer("cloud")
            .key_by(|_| Value::I64(0))
            .sliding_window(100, 50, WindowAgg::Count)
            .collect_vec();
        let report = ctx.execute().unwrap();
        // 1000 events, size 100 slide 50: full windows at 100, 150, ... 1000
        // = 19 full windows, plus a 50-event partial at EOS
        assert_eq!(report.collected.len(), 20);
    }
}
