//! Typed data-plane support: the [`StreamData`] bridge re-export, the
//! [`Features`] feature-row newtype, and the runtime decode-failure
//! accumulator behind the typed layer's no-panic guarantee.

pub use crate::value::{decode_mismatch, StreamData};

use crate::error::{Error, Result};
use crate::value::Value;
use std::sync::atomic::{AtomicU64, Ordering};
use std::sync::Mutex;

/// A dense `f32` feature row, mapped onto [`Value::F32s`] — the shape
/// produced by `WindowAgg::FeatureStats` and consumed (and re-emitted) by
/// the XLA inference operator.
///
/// `Vec<f32>` itself cannot implement [`StreamData`] (it would overlap
/// with the generic `Vec<T>` → `List` mapping), so feature rows travel
/// under this newtype.
#[derive(Clone, Debug, PartialEq)]
pub struct Features(pub Vec<f32>);

impl StreamData for Features {
    fn into_value(self) -> Value {
        Value::F32s(self.0)
    }
    fn try_from_value(v: Value) -> Result<Features> {
        match v {
            Value::F32s(x) => Ok(Features(x)),
            other => Err(decode_mismatch::<Features>(&other)),
        }
    }
}

/// Shared accumulator for typed-layer decode failures at runtime.
///
/// Typed operator shims never panic on a value that fails to decode as
/// the expected native type (possible when `api::raw` escape hatches are
/// mixed in): the event is suppressed, the failure is recorded here, and
/// [`StreamContext::execute`](crate::api::raw::StreamContext::execute)
/// surfaces the first failure as
/// [`Error::Decode`](crate::error::Error::Decode) once the run completes.
/// For deployed jobs, poll
/// [`StreamContext::decode_failures`](crate::api::raw::StreamContext::decode_failures).
#[derive(Debug, Default)]
pub struct DecodeErrors {
    first: Mutex<Option<String>>,
    count: AtomicU64,
}

impl DecodeErrors {
    /// Records one failed decode (`op` names the operator shim).
    pub fn record(&self, op: &str, err: &Error) {
        self.count.fetch_add(1, Ordering::Relaxed);
        let mut slot = self.first.lock().unwrap();
        if slot.is_none() {
            *slot = Some(format!("{op}: {err}"));
        }
    }

    /// Number of events that failed to decode so far.
    pub fn count(&self) -> u64 {
        self.count.load(Ordering::Relaxed)
    }

    /// `Err(Error::Decode)` if any event failed to decode.
    pub fn check(&self) -> Result<()> {
        let n = self.count();
        if n == 0 {
            return Ok(());
        }
        let first = self
            .first
            .lock()
            .unwrap()
            .clone()
            .unwrap_or_else(|| "unknown".into());
        Err(Error::Decode(format!(
            "{n} event(s) failed a typed decode; first failure at {first}"
        )))
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn features_roundtrip_and_mismatch() {
        let f = Features(vec![1.0, -2.5]);
        let v = f.clone().into_value();
        assert_eq!(Features::try_from_value(v).unwrap(), f);
        assert!(Features::try_from_value(Value::I64(1)).is_err());
    }

    #[test]
    fn decode_errors_keep_first_and_count_all() {
        let d = DecodeErrors::default();
        assert!(d.check().is_ok());
        d.record("map", &Error::Decode("expected i64, got Value::Bool".into()));
        d.record("filter", &Error::Decode("expected i64, got Value::Str".into()));
        assert_eq!(d.count(), 2);
        let err = d.check().unwrap_err();
        assert!(matches!(err, Error::Decode(_)));
        assert!(err.to_string().contains("2 event(s)"), "got {err}");
        assert!(err.to_string().contains("map"), "first failure kept: {err}");
    }
}
