//! The typed stream front-end: phantom-typed [`Stream<T>`] and
//! type-state [`KeyedStream<K, V>`] over the dynamic `Value` engine.
//!
//! Operator closures take and return **native Rust types** — `i64`,
//! `f64`, `bool`, `String`, tuples, `Vec<T>`, [`Features`] — and the
//! conversion to the engine's dynamic `Value` representation happens in
//! thin adapter shims at the graph boundary (the [`StreamData`] trait).
//! Channels, planners, placement, the zero-copy batch data plane, and
//! dynamic updates are untouched.
//!
//! **Columnar lowering.** When the element types have a fixed columnar
//! [`Layout`](crate::columnar::Layout) (scalars and tuples of scalars)
//! and [`JobConfig::columnar`](crate::coordinator::JobConfig::columnar)
//! is on (the default), `map`/`filter`/`filter_map`/`key_by` and the
//! keyed `fold`/`reduce`/`window` lower to **monomorphized column
//! operators** ([`runtime::col_exec`](crate::runtime::col_exec)) that
//! iterate native column slices directly — no per-record `Value` is
//! allocated between the source and the first fallback point. Types
//! without a layout (`Vec<T>`, [`Features`], raw `Value`), operators
//! without a columnar form (`flat_map`, `inspect`, `map_values`,
//! `xla_map`), and `columnar: false` all take the classic `Value`
//! closure path; either way the pipeline produces identical results —
//! the representation is an execution detail, not a semantic one.
//!
//! **Type-state keying.** [`Stream::key_by`] is the only way to obtain a
//! [`KeyedStream`], and the keyed stateful operators (`fold`, `reduce`,
//! `window`, `sliding_window`) exist *only* on [`KeyedStream`] — calling
//! them on an unkeyed stream is a compile error, not a runtime surprise
//! (see the `compile_fail` examples below). Likewise
//! [`Stream::union`] requires both sides to carry the same element type.
//!
//! **No panics.** A value that fails to decode as the expected native
//! type (possible only when `api::raw` escape hatches are mixed in) is
//! suppressed and counted; `execute()` then surfaces
//! [`Error::Decode`](crate::error::Error::Decode). Typed collect sinks
//! return a [`CollectHandle<T>`] redeemed with
//! [`JobReport::take`](crate::coordinator::JobReport::take), which
//! decodes into `Vec<T>` — again `Error::Decode`, never a panic.
//!
//! ```no_run
//! use flowunits::prelude::*;
//!
//! let cluster = flowunits::config::fig2_cluster();
//! let mut ctx = StreamContext::new(cluster, JobConfig::default());
//! let counts = ctx
//!     .stream(Source::synthetic(100_000, |_, i| i as i64))
//!     .to_layer("edge")
//!     .filter(|v| v % 3 == 0)
//!     .to_layer("cloud")
//!     .key_by(|v| v % 8)
//!     .window::<i64>(100, WindowAgg::Count)
//!     .collect();
//! let mut report = ctx.execute().unwrap();
//! let windows: Vec<(i64, i64)> = report.take(counts).unwrap();
//! ```
//!
//! Stateful keyed operators do not exist on unkeyed streams — `window`
//! before `key_by` does not compile:
//!
//! ```compile_fail
//! use flowunits::prelude::*;
//!
//! let cluster = flowunits::config::fig2_cluster();
//! let mut ctx = StreamContext::new(cluster, JobConfig::default());
//! ctx.stream(Source::synthetic(100, |_, i| i as i64))
//!     .window::<i64>(10, WindowAgg::Count) // error: no `window` on Stream<i64>
//!     .collect();
//! ```
//!
//! ... and neither does `fold`:
//!
//! ```compile_fail
//! use flowunits::prelude::*;
//!
//! let cluster = flowunits::config::fig2_cluster();
//! let mut ctx = StreamContext::new(cluster, JobConfig::default());
//! ctx.stream(Source::synthetic(100, |_, i| i as i64))
//!     .fold(0i64, |acc, v| *acc += v) // error: no `fold` on Stream<i64>
//!     .collect();
//! ```
//!
//! Unioning streams of different element types does not compile:
//!
//! ```compile_fail
//! use flowunits::prelude::*;
//!
//! let cluster = flowunits::config::fig2_cluster();
//! let mut ctx = StreamContext::new(cluster, JobConfig::default());
//! let ints = ctx.stream(Source::synthetic(100, |_, i| i as i64));
//! let floats = ctx.stream(Source::synthetic(100, |_, i| i as f64));
//! ints.union(floats).collect(); // error: Stream<i64> ∪ Stream<f64>
//! ```

use super::data::{DecodeErrors, Features};
use super::raw;
use super::OpenStream;
use crate::coordinator::CollectHandle;
use crate::error::Error;
use crate::graph::{ColumnarOp, Replication, SinkKind, SourceKind, WindowAgg};
use crate::runtime::col_exec::{
    column_batch_of, ColumnAssignTsExec, ColumnFilterExec, ColumnFilterMapExec, ColumnFoldExec,
    ColumnKeyByExec, ColumnMapExec, ColumnReduceExec, ColumnWindowExec,
};
use crate::time::{TsFn, WatermarkGen, WindowAssigner};
use crate::value::{StreamData, Value};
use std::marker::PhantomData;
use std::sync::Arc;

/// Wraps a monomorphized executor factory as a graph-level
/// [`ColumnarOp`]; the call site's closure pins the concrete types.
fn columnar_op(
    factory: impl Fn() -> Box<dyn crate::runtime::OpExec> + Send + Sync + 'static,
    keys: bool,
    stateful: bool,
    label: &'static str,
) -> ColumnarOp {
    ColumnarOp {
        factory: Arc::new(factory),
        keys,
        stateful,
        label,
    }
}

/// A typed source: like [`raw::Source`], but its generator/vector works
/// in the native element type `T`.
pub struct Source<T: StreamData> {
    def: SourceDef<T>,
}

/// Synthetic sources keep the native-typed generator until `open()`,
/// where the context's columnar setting picks the engine form: batches
/// born columnar ([`SourceKind::SyntheticColumns`]) when `T` has a
/// layout, else per-event `Value`s. Both forms enumerate the same
/// global event indices, so the generator sees identical inputs.
enum SourceDef<T: StreamData> {
    /// Already in engine form (vectors, files).
    Lowered(SourceKind),
    /// Deferred synthetic generator.
    Synthetic {
        total: u64,
        gen: Arc<dyn Fn(u64, u64) -> T + Send + Sync>,
        rate: Option<f64>,
    },
}

impl<T: StreamData> Source<T> {
    /// Synthetic generator: `total` events split across source instances,
    /// each produced by `gen(instance_index, event_index)`.
    pub fn synthetic(
        total: u64,
        gen: impl Fn(u64, u64) -> T + Send + Sync + 'static,
    ) -> Source<T> {
        Source {
            def: SourceDef::Synthetic {
                total,
                gen: Arc::new(gen),
                rate: None,
            },
        }
    }

    /// Rate-limited synthetic generator (events/second per instance);
    /// pair with `Deployment::stop_sources` for unbounded streams.
    pub fn synthetic_rated(
        total: u64,
        rate: f64,
        gen: impl Fn(u64, u64) -> T + Send + Sync + 'static,
    ) -> Source<T> {
        Source {
            def: SourceDef::Synthetic {
                total,
                gen: Arc::new(gen),
                rate: Some(rate),
            },
        }
    }

    /// A pre-materialised vector.
    pub fn vector(values: Vec<T>) -> Source<T> {
        Source {
            def: SourceDef::Lowered(SourceKind::Vector(Arc::new(
                values.into_iter().map(StreamData::into_value).collect(),
            ))),
        }
    }
}

impl Source<String> {
    /// Lines of a text file as `String` events. An unreadable file is a
    /// job-level error from `execute()`/`deploy()`, not a panic.
    pub fn file_lines(path: impl Into<std::path::PathBuf>) -> Source<String> {
        Source {
            def: SourceDef::Lowered(SourceKind::FileLines(path.into())),
        }
    }
}

impl<T: StreamData> OpenStream for Source<T> {
    type Handle = Stream<T>;
    fn open(self, ctx: &mut raw::StreamContext) -> Stream<T> {
        let errs = ctx.decode_errors();
        let kind = match self.def {
            SourceDef::Lowered(kind) => kind,
            SourceDef::Synthetic { total, gen, rate } => match T::layout() {
                Some(layout) if ctx.columnar_enabled() => SourceKind::SyntheticColumns {
                    total,
                    gen: Arc::new(move |inst, range| {
                        column_batch_of(&layout, range.map(|i| gen(inst, i)))
                    }),
                    rate,
                },
                _ => SourceKind::Synthetic {
                    total,
                    gen: Arc::new(move |inst, i| gen(inst, i).into_value()),
                    rate,
                },
            },
        };
        wrap(ctx.open_source(kind), errs)
    }
}

/// An owned, phantom-typed handle onto one path of the DAG under
/// construction: every event on this stream is a `T`. Obtained from
/// [`StreamContext::stream`](raw::StreamContext::stream) with a typed
/// [`Source<T>`]; compiles down to a [`raw::Stream`].
pub struct Stream<T: StreamData> {
    raw: raw::Stream,
    errs: Arc<DecodeErrors>,
    _t: PhantomData<T>,
}

fn wrap<T: StreamData>(raw: raw::Stream, errs: Arc<DecodeErrors>) -> Stream<T> {
    Stream {
        raw,
        errs,
        _t: PhantomData,
    }
}

fn wrap_keyed<K: StreamData, V: StreamData>(
    raw: raw::Stream,
    errs: Arc<DecodeErrors>,
) -> KeyedStream<K, V> {
    KeyedStream {
        raw,
        errs,
        _p: PhantomData,
    }
}

/// Decodes `v` as `T`, recording a failure against `op` instead of
/// panicking.
fn decode_or_record<T: StreamData>(errs: &DecodeErrors, op: &str, v: Value) -> Option<T> {
    match T::try_from_value(v) {
        Ok(t) => Some(t),
        Err(e) => {
            errs.record(op, &e);
            None
        }
    }
}

fn record_unkeyed(errs: &DecodeErrors, op: &str) {
    errs.record(
        op,
        &Error::Decode("expected a keyed Pair(key, value) record".into()),
    );
}

/// Erases a native-typed timestamp extractor to the engine's [`TsFn`].
/// A record that fails to decode as `V` gets `i64::MIN` — already behind
/// any watermark, so the event-time operators count it late instead of
/// polluting a window — and the failure is recorded for `execute()`.
fn value_ts<V: StreamData>(
    errs: Arc<DecodeErrors>,
    op: &'static str,
    ts: impl Fn(&V) -> i64 + Send + Sync + 'static,
) -> TsFn {
    Arc::new(move |v: &Value| match V::try_from_value(v.clone()) {
        Ok(t) => ts(&t),
        Err(e) => {
            errs.record(op, &e);
            i64::MIN
        }
    })
}

impl<T: StreamData> Stream<T> {
    /// Escape hatch: adopts an untyped [`raw::Stream`] as carrying `T`.
    /// The claim is checked at runtime — events that fail to decode as
    /// `T` in downstream typed closures (or at
    /// [`JobReport::take`](crate::coordinator::JobReport::take)) are
    /// counted and surfaced as
    /// [`Error::Decode`](crate::error::Error::Decode), never panics.
    pub fn from_raw(raw: raw::Stream) -> Stream<T> {
        let errs = raw.decode_errors();
        wrap(raw, errs)
    }

    /// Escape hatch: drops down to the untyped builder (closures over
    /// `Value`). Re-adopt with [`Stream::from_raw`].
    pub fn into_raw(self) -> raw::Stream {
        self.raw
    }

    /// Opens (or names) a FlowUnit — the unit of placement, replication,
    /// and dynamic update. See [`raw::Stream::unit`].
    pub fn unit(self, name: &str) -> Self {
        wrap(self.raw.unit(name), self.errs)
    }

    /// Moves the remainder of this stream to `layer`. Unknown layer names
    /// are builder errors surfaced from `execute()`/`deploy()`. See
    /// [`raw::Stream::to_layer`].
    pub fn to_layer(self, layer: &str) -> Self {
        wrap(self.raw.to_layer(layer), self.errs)
    }

    /// Declares a capability constraint for the current FlowUnit. See
    /// [`raw::Stream::add_constraint`].
    pub fn add_constraint(self, expr: &str) -> Self {
        wrap(self.raw.add_constraint(expr), self.errs)
    }

    /// Sets the current FlowUnit's in-zone replication policy.
    pub fn replicate(self, policy: Replication) -> Self {
        wrap(self.raw.replicate(policy), self.errs)
    }

    /// Merges this stream with `other` (from the same context). Both
    /// sides must carry the same element type — unioning differently
    /// typed streams is a compile error.
    pub fn union(self, other: Stream<T>) -> Stream<T> {
        wrap(self.raw.union(other.raw), self.errs)
    }

    /// Forks the stream: both handles continue from the same point and
    /// every downstream branch receives every event.
    pub fn split(self) -> (Stream<T>, Stream<T>) {
        let (a, b) = self.raw.split();
        (wrap(a, self.errs.clone()), wrap(b, self.errs))
    }

    /// Element-wise transform with a native-typed closure. An event that
    /// fails to decode as `T` is suppressed (and recorded), never
    /// forwarded as poison. When both `T` and `U` are columnar types (and
    /// [`JobConfig::columnar`](crate::coordinator::JobConfig::columnar)
    /// is on), lowers to a monomorphized column operator.
    pub fn map<U: StreamData>(
        self,
        f: impl Fn(T) -> U + Send + Sync + 'static,
    ) -> Stream<U> {
        let errs = self.errs.clone();
        if self.raw.columnar_enabled() && T::layout().is_some() && U::layout().is_some() {
            let f: Arc<dyn Fn(T) -> U + Send + Sync> = Arc::new(f);
            let op_errs = errs.clone();
            let raw = self.raw.push_columnar(columnar_op(
                move || Box::new(ColumnMapExec::<T, U>::new(f.clone(), op_errs.clone())),
                false,
                false,
                "map",
            ));
            return wrap(raw, errs);
        }
        let raw = self.raw.filter_map(move |v| {
            decode_or_record::<T>(&errs, "map", v).map(|t| f(t).into_value())
        });
        wrap(raw, self.errs)
    }

    /// Predicate filter with a native-typed closure. Events that fail to
    /// decode are dropped (and recorded). The decode consumes the event
    /// and re-encodes it on keep — payloads move, they are never
    /// deep-copied. Lowers to a monomorphized column operator when `T`
    /// is a columnar type.
    pub fn filter(self, f: impl Fn(&T) -> bool + Send + Sync + 'static) -> Self {
        let errs = self.errs.clone();
        if self.raw.columnar_enabled() && T::layout().is_some() {
            let f: Arc<dyn Fn(&T) -> bool + Send + Sync> = Arc::new(f);
            let op_errs = errs.clone();
            let raw = self.raw.push_columnar(columnar_op(
                move || Box::new(ColumnFilterExec::<T>::new(f.clone(), op_errs.clone())),
                false,
                false,
                "filter",
            ));
            return wrap(raw, errs);
        }
        let raw = self.raw.filter_map(move |v| {
            decode_or_record::<T>(&errs, "filter", v)
                .and_then(|t| if f(&t) { Some(t.into_value()) } else { None })
        });
        wrap(raw, self.errs)
    }

    /// Combined filter + transform: keep-and-convert in one pass. Events
    /// that fail to decode as `T` are dropped (and recorded). Lowers to
    /// a monomorphized column operator when both `T` and `U` are
    /// columnar types.
    pub fn filter_map<U: StreamData>(
        self,
        f: impl Fn(T) -> Option<U> + Send + Sync + 'static,
    ) -> Stream<U> {
        let errs = self.errs.clone();
        if self.raw.columnar_enabled() && T::layout().is_some() && U::layout().is_some() {
            let f: Arc<dyn Fn(T) -> Option<U> + Send + Sync> = Arc::new(f);
            let op_errs = errs.clone();
            let raw = self.raw.push_columnar(columnar_op(
                move || Box::new(ColumnFilterMapExec::<T, U>::new(f.clone(), op_errs.clone())),
                false,
                false,
                "filter_map",
            ));
            return wrap(raw, errs);
        }
        let raw = self.raw.filter_map(move |v| {
            decode_or_record::<T>(&errs, "filter_map", v)
                .and_then(|t| f(t).map(StreamData::into_value))
        });
        wrap(raw, self.errs)
    }

    /// One-to-many transform; the closure may return any iterable of the
    /// output type (`Vec`, arrays, iterators collected, ...).
    pub fn flat_map<U: StreamData, I: IntoIterator<Item = U>>(
        self,
        f: impl Fn(T) -> I + Send + Sync + 'static,
    ) -> Stream<U> {
        let errs = self.errs.clone();
        let raw = self
            .raw
            .flat_map(move |v| match decode_or_record::<T>(&errs, "flat_map", v) {
                Some(t) => f(t).into_iter().map(StreamData::into_value).collect(),
                None => Vec::new(),
            });
        wrap(raw, self.errs)
    }

    /// Observes every element without changing it (debugging/metrics
    /// tap): the original event passes through untouched — even one that
    /// fails to decode (which is recorded and skipped by the observer).
    /// The observed `T` is decoded from a clone of the event.
    pub fn inspect(self, f: impl Fn(&T) + Send + Sync + 'static) -> Self {
        let errs = self.errs.clone();
        let raw = self.raw.inspect(move |v| {
            if let Some(t) = decode_or_record::<T>(&errs, "inspect", v.clone()) {
                f(&t);
            }
        });
        wrap(raw, self.errs)
    }

    /// Keys the stream: downstream stateful operators group by the
    /// extracted key and the repartitioning edge is hash-routed. This is
    /// the *only* way to reach the keyed operators
    /// (`fold`/`reduce`/`window`) — the type system enforces the
    /// ordering. An event that fails to decode as `T` (a `from_raw`
    /// lie) is suppressed (and recorded); the job then fails with
    /// `Error::Decode` from `execute()`. Clone-free: the record is
    /// consumed, keyed, and re-emitted as `(key, value)` in one pass.
    pub fn key_by<K: StreamData>(
        self,
        f: impl Fn(&T) -> K + Send + Sync + 'static,
    ) -> KeyedStream<K, T> {
        let errs = self.errs.clone();
        if self.raw.columnar_enabled() && T::layout().is_some() && K::layout().is_some() {
            let f: Arc<dyn Fn(&T) -> K + Send + Sync> = Arc::new(f);
            let op_errs = errs.clone();
            let raw = self.raw.push_columnar(columnar_op(
                move || Box::new(ColumnKeyByExec::<T, K>::new(f.clone(), op_errs.clone())),
                true,
                false,
                "key_by",
            ));
            return wrap_keyed(raw, errs);
        }
        let raw = self.raw.key_by_fused(move |v| {
            decode_or_record::<T>(&errs, "key_by", v).map(|t| {
                let key = f(&t).into_value();
                Value::pair(key, t.into_value())
            })
        });
        wrap_keyed(raw, self.errs)
    }

    /// `group_by` is Renoir's name for [`Stream::key_by`].
    pub fn group_by<K: StreamData>(
        self,
        f: impl Fn(&T) -> K + Send + Sync + 'static,
    ) -> KeyedStream<K, T> {
        self.key_by(f)
    }

    /// Assigns each record's *event timestamp* (milliseconds, extracted
    /// by `ts`) and mints watermarks with the generator discipline `gen`
    /// — the entry point to event time. Watermarks flow downstream as
    /// control frames (broadcast across fan-out, merged min-of-inputs at
    /// fan-in, carried across socket transport) and drive
    /// [`KeyedStream::event_window`] and [`KeyedStream::interval_join`].
    /// An assigner *replaces* any upstream time domain. Lowers to a
    /// monomorphized column operator when `T` is a columnar type (where
    /// punctuated generators degrade to per-batch emission — the column
    /// scan has no per-row punctuation test).
    pub fn assign_timestamps(
        self,
        ts: impl Fn(&T) -> i64 + Send + Sync + 'static,
        gen: WatermarkGen,
    ) -> Self {
        let errs = self.errs.clone();
        if self.raw.columnar_enabled() && T::layout().is_some() {
            let ts: Arc<dyn Fn(&T) -> i64 + Send + Sync> = Arc::new(ts);
            let op_errs = errs.clone();
            let raw = self.raw.push_columnar(columnar_op(
                move || {
                    Box::new(ColumnAssignTsExec::<T>::new(
                        ts.clone(),
                        gen.clone(),
                        op_errs.clone(),
                    ))
                },
                false,
                true,
                "assign_timestamps",
            ));
            return wrap(raw, errs);
        }
        let raw = self.raw.assign_timestamps(
            move |v: &Value| match T::try_from_value(v.clone()) {
                Ok(t) => ts(&t),
                Err(e) => {
                    errs.record("assign_timestamps", &e);
                    i64::MIN
                }
            },
            gen,
        );
        wrap(raw, self.errs)
    }

    /// Terminal: collect events, returning a receipt redeemed with
    /// [`JobReport::take`](crate::coordinator::JobReport::take) for a
    /// `Vec<T>`. The receipt is bound to this builder context — redeeming
    /// it against another job's report is an error, not silent data
    /// mix-up.
    pub fn collect(self) -> CollectHandle<T> {
        let origin = self.raw.graph_origin();
        let op = self.raw.terminal(SinkKind::CollectTagged, "collect");
        CollectHandle {
            op,
            origin,
            _t: PhantomData,
        }
    }

    /// Terminal: count events only (`JobReport::events_out`).
    pub fn collect_count(self) {
        self.raw.collect_count();
    }

    /// Terminal: discard events (benchmark sink).
    pub fn discard(self) {
        self.raw.discard();
    }
}

impl Stream<Features> {
    /// Batched inference through the AOT-compiled XLA artifact `name`;
    /// available only on feature-row streams — feeding the model
    /// anything but [`Features`] is a compile error.
    pub fn xla_map(self, name: &str, batch: usize, in_dim: usize) -> Stream<Features> {
        wrap(self.raw.xla_map(name, batch, in_dim), self.errs)
    }
}

/// A typed stream that has been keyed by [`Stream::key_by`]: every event
/// is a `(K, V)` record, the stateful keyed operators are available, and
/// repartitioning edges hash on `K`. Compiles down to the engine's
/// `Pair(key, value)` representation.
pub struct KeyedStream<K: StreamData, V: StreamData> {
    raw: raw::Stream,
    errs: Arc<DecodeErrors>,
    _p: PhantomData<(K, V)>,
}

impl<K: StreamData, V: StreamData> KeyedStream<K, V> {
    /// Opens (or names) a FlowUnit. See [`raw::Stream::unit`].
    pub fn unit(self, name: &str) -> Self {
        wrap_keyed(self.raw.unit(name), self.errs)
    }

    /// Moves the remainder of this stream to `layer`. See
    /// [`raw::Stream::to_layer`].
    pub fn to_layer(self, layer: &str) -> Self {
        wrap_keyed(self.raw.to_layer(layer), self.errs)
    }

    /// Declares a capability constraint for the current FlowUnit.
    pub fn add_constraint(self, expr: &str) -> Self {
        wrap_keyed(self.raw.add_constraint(expr), self.errs)
    }

    /// Sets the current FlowUnit's in-zone replication policy.
    pub fn replicate(self, policy: Replication) -> Self {
        wrap_keyed(self.raw.replicate(policy), self.errs)
    }

    /// Merges two keyed streams of identical key/value types.
    pub fn union(self, other: KeyedStream<K, V>) -> KeyedStream<K, V> {
        wrap_keyed(self.raw.union(other.raw), self.errs)
    }

    /// Forks the keyed stream.
    pub fn split(self) -> (KeyedStream<K, V>, KeyedStream<K, V>) {
        let (a, b) = self.raw.split();
        (
            wrap_keyed(a, self.errs.clone()),
            wrap_keyed(b, self.errs),
        )
    }

    /// Transforms the value of each record, keeping the key (and the
    /// hash routing on it) untouched. Records whose value fails to
    /// decode as `V` are suppressed (and recorded).
    pub fn map_values<U: StreamData>(
        self,
        f: impl Fn(V) -> U + Send + Sync + 'static,
    ) -> KeyedStream<K, U> {
        let errs = self.errs.clone();
        let raw = self.raw.filter_map(move |v| match v.into_pair() {
            Some((k, payload)) => decode_or_record::<V>(&errs, "map_values", payload)
                .map(|t| Value::pair(k, f(t).into_value())),
            None => {
                record_unkeyed(&errs, "map_values");
                None
            }
        });
        wrap_keyed(raw, self.errs)
    }

    /// Observes every `(key, value)` record without changing it.
    pub fn inspect(self, f: impl Fn(&K, &V) + Send + Sync + 'static) -> Self {
        let errs = self.errs.clone();
        let raw = self.raw.inspect(move |v| match v.as_pair() {
            Some((k, payload)) => {
                if let (Some(k), Some(p)) = (
                    decode_or_record::<K>(&errs, "inspect", k.clone()),
                    decode_or_record::<V>(&errs, "inspect", payload.clone()),
                ) {
                    f(&k, &p);
                }
            }
            None => record_unkeyed(&errs, "inspect"),
        });
        wrap_keyed(raw, self.errs)
    }

    /// Reinterprets the keyed stream as a plain stream of `(K, V)`
    /// records (a zero-cost relabelling — no operator is added).
    pub fn entries(self) -> Stream<(K, V)> {
        wrap(self.raw, self.errs)
    }

    /// Keyed fold with a native-typed accumulator; emits one `(K, A)`
    /// record per key at end-of-stream. A payload that fails to decode
    /// as `V` is skipped (the accumulator is untouched); an accumulator
    /// that fails to decode (possible only with a `StreamData` impl
    /// whose encode/decode are not inverses) is reset to `init` —
    /// recorded either way, so `execute()` reports `Error::Decode`.
    ///
    /// The accumulator crosses the `Value` boundary once per event; for
    /// large composite accumulators (`Vec<T>`, long `String`s) that
    /// conversion is O(|accumulator|) per event — prefer a scalar/tuple
    /// accumulator, or drop to [`raw::Stream::fold`] via
    /// [`Stream::into_raw`] for heavyweight fold state.
    pub fn fold<A: StreamData>(
        self,
        init: A,
        step: impl Fn(&mut A, V) + Send + Sync + 'static,
    ) -> KeyedStream<K, A> {
        let errs = self.errs.clone();
        let init_value = init.into_value();
        if self.raw.columnar_enabled() && K::layout().is_some() && V::layout().is_some() {
            let step: Arc<dyn Fn(&mut A, V) + Send + Sync> = Arc::new(step);
            let op_errs = errs.clone();
            let raw = self.raw.push_columnar(columnar_op(
                move || {
                    Box::new(ColumnFoldExec::<K, V, A>::from_init_value(
                        init_value.clone(),
                        step.clone(),
                        op_errs.clone(),
                    ))
                },
                false,
                true,
                "fold",
            ));
            return wrap_keyed(raw, errs);
        }
        let reset = init_value.clone();
        let raw = self.raw.fold(init_value, move |acc, payload| {
            let cur = std::mem::replace(acc, Value::Null);
            let a = match decode_or_record::<A>(&errs, "fold", cur) {
                Some(a) => a,
                None => {
                    *acc = reset.clone();
                    return;
                }
            };
            match decode_or_record::<V>(&errs, "fold", payload) {
                Some(p) => {
                    let mut a = a;
                    step(&mut a, p);
                    *acc = a.into_value();
                }
                // keep the accumulator on a bad payload
                None => *acc = a.into_value(),
            }
        });
        wrap_keyed(raw, self.errs)
    }

    /// Keyed reduction with a native-typed combiner; emits one `(K, V)`
    /// record per key at end-of-stream. Both operands are decoded from
    /// clones per combine step (the combiner borrows them) — keep reduce
    /// payloads small, or drop to [`raw::Stream::reduce`] for
    /// heavyweight values.
    pub fn reduce(
        self,
        f: impl Fn(&V, &V) -> V + Send + Sync + 'static,
    ) -> KeyedStream<K, V> {
        let errs = self.errs.clone();
        if self.raw.columnar_enabled() && K::layout().is_some() && V::layout().is_some() {
            let f: Arc<dyn Fn(&V, &V) -> V + Send + Sync> = Arc::new(f);
            let op_errs = errs.clone();
            let raw = self.raw.push_columnar(columnar_op(
                move || Box::new(ColumnReduceExec::<K, V>::new(f.clone(), op_errs.clone())),
                false,
                true,
                "reduce",
            ));
            return wrap_keyed(raw, errs);
        }
        let raw = self.raw.reduce(move |a, b| {
            match (
                decode_or_record::<V>(&errs, "reduce", a.clone()),
                decode_or_record::<V>(&errs, "reduce", b.clone()),
            ) {
                (Some(x), Some(y)) => f(&x, &y).into_value(),
                // keep the accumulated side on a bad payload
                _ => a.clone(),
            }
        });
        wrap_keyed(raw, self.errs)
    }

    /// Tumbling count window of `size` events with aggregate `agg`. `R`
    /// names the aggregate's native type: `i64` for `Count`, `f64` for
    /// `Mean`/`Sum`/`Max`/`Min`, `Vec<V>` for `Collect`, [`Features`]
    /// for `FeatureStats` (an `R` that does not match what `agg`
    /// produces surfaces as `Error::Decode` downstream, never a panic).
    pub fn window<R: StreamData>(self, size: usize, agg: WindowAgg) -> KeyedStream<K, R> {
        self.sliding_window(size, size, agg)
    }

    /// Sliding count window; see [`KeyedStream::window`] for `R`.
    pub fn sliding_window<R: StreamData>(
        self,
        size: usize,
        slide: usize,
        agg: WindowAgg,
    ) -> KeyedStream<K, R> {
        if self.raw.columnar_enabled() {
            if let (Some(kl), Some(vl)) = (K::layout(), V::layout()) {
                let raw = self.raw.push_columnar(columnar_op(
                    move || {
                        Box::new(ColumnWindowExec::new(
                            size,
                            slide,
                            agg.clone(),
                            kl.clone(),
                            vl.clone(),
                        ))
                    },
                    false,
                    true,
                    "window",
                ));
                return wrap_keyed(raw, self.errs);
            }
        }
        wrap_keyed(self.raw.sliding_window(size, slide, agg), self.errs)
    }

    /// Event-time window: buffers `(K, V)` records into windows by the
    /// event timestamp `ts` extracts from the value, firing each window
    /// exactly once when the watermark passes its end plus `lateness_ms`.
    /// `assigner` picks the window shape (tumbling / sliding / session);
    /// `R` names the aggregate's native type exactly as in
    /// [`KeyedStream::window`]. Records arriving after every window they
    /// belong to has fired are counted in the `late_records` metric (use
    /// [`KeyedStream::event_window_with_late`] to also capture them).
    /// Needs watermarks: put a [`Stream::assign_timestamps`] upstream.
    /// Runs on the row plane — an upstream columnar chain falls back to
    /// materialized rows at the window, exactly like any aggregate
    /// without a static layout.
    pub fn event_window<R: StreamData>(
        self,
        ts: impl Fn(&V) -> i64 + Send + Sync + 'static,
        assigner: WindowAssigner,
        agg: WindowAgg,
        lateness_ms: i64,
    ) -> KeyedStream<K, R> {
        let errs = self.errs.clone();
        let raw = self.raw.event_window_cfg(
            value_ts::<V>(errs, "event_window", ts),
            assigner,
            agg,
            lateness_ms,
            false,
        );
        wrap_keyed(raw, self.errs)
    }

    /// [`KeyedStream::event_window`] with a late-record side output: the
    /// second return is a receipt redeemed with
    /// [`JobReport::take`](crate::coordinator::JobReport::take) for the
    /// `Vec<(K, V)>` of records that arrived after their window fired —
    /// late data stays observable instead of silently dropped.
    pub fn event_window_with_late<R: StreamData>(
        self,
        ts: impl Fn(&V) -> i64 + Send + Sync + 'static,
        assigner: WindowAssigner,
        agg: WindowAgg,
        lateness_ms: i64,
    ) -> (KeyedStream<K, R>, CollectHandle<(K, V)>) {
        let errs = self.errs.clone();
        let origin = self.raw.graph_origin();
        let raw = self.raw.event_window_cfg(
            value_ts::<V>(errs, "event_window", ts),
            assigner,
            agg,
            lateness_ms,
            true,
        );
        let handle = CollectHandle {
            op: raw.head_op(),
            origin,
            _t: PhantomData,
        };
        (wrap_keyed(raw, self.errs), handle)
    }

    /// Keyed stream-stream interval join: matches records of this (left)
    /// stream with records of `other` (right) that share the same key and
    /// whose event timestamps satisfy
    /// `ts_right ∈ [ts_left + lower_ms, ts_left + upper_ms]`, emitting
    /// one `(K, (V, V2))` record per match. Both sides buffer until the
    /// merged watermark (min across both inputs) evicts them; records
    /// arriving past their own eviction horizon are counted in
    /// `late_records`. Needs watermarks on *both* inputs
    /// ([`Stream::assign_timestamps`]).
    pub fn interval_join<V2: StreamData>(
        self,
        other: KeyedStream<K, V2>,
        ts_left: impl Fn(&V) -> i64 + Send + Sync + 'static,
        ts_right: impl Fn(&V2) -> i64 + Send + Sync + 'static,
        lower_ms: i64,
        upper_ms: i64,
    ) -> KeyedStream<K, (V, V2)> {
        let errs = self.errs.clone();
        let raw = self.raw.interval_join_cfg(
            other.raw,
            value_ts::<V>(errs.clone(), "interval_join", ts_left),
            value_ts::<V2>(errs, "interval_join", ts_right),
            lower_ms,
            upper_ms,
        );
        wrap_keyed(raw, self.errs)
    }

    /// Terminal: collect `(key, value)` records, returning a receipt
    /// redeemed with
    /// [`JobReport::take`](crate::coordinator::JobReport::take) for a
    /// `Vec<(K, V)>`. Bound to this builder context like
    /// [`Stream::collect`].
    pub fn collect(self) -> CollectHandle<(K, V)> {
        let origin = self.raw.graph_origin();
        let op = self.raw.terminal(SinkKind::CollectTagged, "collect");
        CollectHandle {
            op,
            origin,
            _t: PhantomData,
        }
    }

    /// Terminal: count events only (`JobReport::events_out`).
    pub fn collect_count(self) {
        self.raw.collect_count();
    }

    /// Terminal: discard events (benchmark sink).
    pub fn discard(self) {
        self.raw.discard();
    }
}

impl<K: StreamData> KeyedStream<K, Features> {
    /// Batched inference through the AOT-compiled XLA artifact `name`;
    /// the key rides along unchanged, the feature row is replaced by the
    /// model's output row.
    pub fn xla_map(self, name: &str, batch: usize, in_dim: usize) -> KeyedStream<K, Features> {
        wrap_keyed(self.raw.xla_map(name, batch, in_dim), self.errs)
    }
}
