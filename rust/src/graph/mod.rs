//! Logical dataflow graph: operators, first-class FlowUnits, and the
//! stage partitioning algorithm (paper §III).
//!
//! A job is a **DAG** of operators. Multiple sources, `union` merge
//! points, `split` fan-outs, and multiple sinks are all first-class; the
//! classic linear chain is just the degenerate case. Every operator
//! belongs to exactly one **FlowUnit** — a named group of operators that
//! is independently placed, replicated, and dynamically updated. The unit
//! (not the operator) carries:
//!
//! * a **layer** annotation — the continuum layer whose zones host the
//!   unit's instances;
//! * an optional **constraint** — a conjunction of capability predicates
//!   restricting which hosts may run the unit;
//! * a **replication policy** — how densely the unit is instantiated
//!   inside each zone.
//!
//! Within a FlowUnit, operators are further grouped into **stages**:
//! maximal linear runs of operators that contain no repartitioning point,
//! no branching, and no source. Stages are the unit of operator fusion —
//! one stage instance is one worker thread running the fused chain.

use crate::error::{Error, Result};
use crate::topology::{ConstraintExpr, LayerId};
use crate::value::Value;
use std::collections::BTreeSet;
use std::sync::Arc;

/// Identifier of a logical operator (index into [`LogicalGraph::ops`]).
pub type OpId = usize;

/// Identifier of a FlowUnit (index into [`LogicalGraph::units`]).
pub type UnitId = usize;

/// Unary transform.
pub type MapFn = Arc<dyn Fn(Value) -> Value + Send + Sync>;
/// Predicate.
pub type FilterFn = Arc<dyn Fn(&Value) -> bool + Send + Sync>;
/// Filtering transform: `None` drops the record.
pub type FilterMapFn = Arc<dyn Fn(Value) -> Option<Value> + Send + Sync>;
/// One-to-many transform.
pub type FlatMapFn = Arc<dyn Fn(Value) -> Vec<Value> + Send + Sync>;
/// Key extractor.
pub type KeyFn = Arc<dyn Fn(&Value) -> Value + Send + Sync>;
/// Fold step: accumulator ← step(accumulator, element payload).
pub type FoldFn = Arc<dyn Fn(&mut Value, Value) + Send + Sync>;
/// Reduction combiner: `(accumulated, next) -> accumulated`.
pub type ReduceFn = Arc<dyn Fn(&Value, &Value) -> Value + Send + Sync>;
/// Synthetic event generator: `(instance_index, event_index) -> event`.
pub type GenFn = Arc<dyn Fn(u64, u64) -> Value + Send + Sync>;
/// Synthetic columnar generator: `(instance_index, global_index_range) ->
/// one column batch covering the range`.
pub type ColGenFn =
    Arc<dyn Fn(u64, std::ops::Range<u64>) -> crate::columnar::ColumnBatch + Send + Sync>;
/// Factory building a fresh monomorphized columnar executor per stage
/// instance (each instance owns its state, so executors cannot be shared).
pub type ColumnOpFactory = Arc<dyn Fn() -> Box<dyn crate::runtime::OpExec> + Send + Sync>;
/// Custom window aggregate over the buffered payloads.
pub type WindowFn = Arc<dyn Fn(&[Value]) -> Value + Send + Sync>;

/// How densely a FlowUnit is instantiated inside each zone it is
/// deployed to (the FlowUnits planner only; the Renoir baseline always
/// replicates per core).
#[derive(Debug, Clone, Copy, PartialEq, Eq, Default)]
pub enum Replication {
    /// One instance per core of every capability-satisfying host.
    #[default]
    PerCore,
    /// One instance per capability-satisfying host.
    PerHost,
    /// A single instance per zone (on the first satisfying host).
    PerZone,
    /// Exactly `n` instances per zone (at least one), spread round-robin
    /// across the satisfying hosts' cores. The autoscaler steps a unit's
    /// replication through this policy; it is equally usable by hand.
    Fixed(usize),
}

/// A first-class FlowUnit: the unit of placement, replication, and
/// dynamic update. Operators reference their unit by [`UnitId`].
#[derive(Debug, Clone)]
pub struct UnitDef {
    /// Unit id (index into [`LogicalGraph::units`]).
    pub index: UnitId,
    /// Unique unit name (auto-derived from the layer unless set through
    /// the builder's `unit(name)`).
    pub name: String,
    /// Layer annotation: the unit's instances run in zones of this layer.
    pub layer: LayerId,
    /// Capability requirement for every host running this unit.
    pub constraint: Option<ConstraintExpr>,
    /// In-zone replication policy.
    pub replication: Replication,
    /// Whether the name was auto-derived (true) or user-chosen (false).
    pub auto: bool,
}

/// Built-in window aggregations (applied to window payloads; keyed windows
/// emit `Pair(key, aggregate)`).
#[derive(Clone)]
pub enum WindowAgg {
    /// Arithmetic mean of numeric payloads.
    Mean,
    /// Sum of numeric payloads.
    Sum,
    /// Window length.
    Count,
    /// Maximum numeric payload.
    Max,
    /// Minimum numeric payload.
    Min,
    /// The raw window as a `Value::List`.
    Collect,
    /// Feature vector `[mean, std, min, max, last]` as `Value::F32s` —
    /// the shape consumed by the AOT-compiled anomaly model.
    FeatureStats,
    /// Arbitrary aggregate.
    Custom(WindowFn),
}

impl std::fmt::Debug for WindowAgg {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        let name = match self {
            WindowAgg::Mean => "Mean",
            WindowAgg::Sum => "Sum",
            WindowAgg::Count => "Count",
            WindowAgg::Max => "Max",
            WindowAgg::Min => "Min",
            WindowAgg::Collect => "Collect",
            WindowAgg::FeatureStats => "FeatureStats",
            WindowAgg::Custom(_) => "Custom(..)",
        };
        write!(f, "{name}")
    }
}

/// Source definitions.
#[derive(Clone)]
pub enum SourceKind {
    /// Synthetic generator producing `total` events split evenly across
    /// source instances, optionally rate-limited (events/s per instance).
    Synthetic {
        /// Total events across all instances.
        total: u64,
        /// Generator closure.
        gen: GenFn,
        /// Optional per-instance rate limit (events/second).
        rate: Option<f64>,
    },
    /// A materialised vector, split across instances by round robin.
    Vector(Arc<Vec<Value>>),
    /// Lines of a text file as `Value::Str`, split across instances by
    /// line index modulo instance count.
    FileLines(std::path::PathBuf),
    /// Synthetic generator that emits ready-made [`crate::columnar::ColumnBatch`]es:
    /// the typed layer's columnar lowering of [`SourceKind::Synthetic`].
    /// Splits `total` events evenly across instances like `Synthetic`.
    SyntheticColumns {
        /// Total events across all instances.
        total: u64,
        /// Column-batch generator closure.
        gen: ColGenFn,
        /// Optional per-instance rate limit (events/second).
        rate: Option<f64>,
    },
}

impl std::fmt::Debug for SourceKind {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        match self {
            SourceKind::Synthetic { total, rate, .. } => {
                write!(f, "Synthetic(total={total}, rate={rate:?})")
            }
            SourceKind::Vector(v) => write!(f, "Vector(len={})", v.len()),
            SourceKind::FileLines(p) => write!(f, "FileLines({})", p.display()),
            SourceKind::SyntheticColumns { total, rate, .. } => {
                write!(f, "SyntheticColumns(total={total}, rate={rate:?})")
            }
        }
    }
}

/// Sink definitions.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum SinkKind {
    /// Collect events into the job report (`JobReport::collected`).
    Collect,
    /// Collect events tagged by sink operator id — the typed layer's
    /// collect sink, redeemed per-handle through `JobReport::take`.
    CollectTagged,
    /// Count events only.
    Count,
    /// Drop events (pure benchmark sink).
    Discard,
}

/// Logical operator kinds.
#[derive(Clone)]
pub enum OpKind {
    /// Stream source (a DAG root; has no inputs).
    Source(SourceKind),
    /// Unary transform.
    Map(MapFn),
    /// Predicate filter.
    Filter(FilterFn),
    /// Filtering transform (`map` + `filter` in one pass; `None` drops
    /// the record — also the typed layer's suppress-on-decode-failure
    /// lowering).
    FilterMap(FilterMapFn),
    /// One-to-many transform.
    FlatMap(FlatMapFn),
    /// Key extraction; the outgoing edge is hash-partitioned.
    KeyBy(KeyFn),
    /// Fused key extraction over the owned record: the closure emits the
    /// complete `Pair(key, value)` (or `None` to drop the record) in one
    /// pass — the typed layer's clone-free `key_by` lowering. Routes and
    /// breaks stages exactly like [`OpKind::KeyBy`].
    KeyByFused(FilterMapFn),
    /// Keyed fold, emitting `Pair(key, acc)` per key at end-of-stream.
    Fold {
        /// Initial accumulator (cloned per key).
        init: Value,
        /// Folding step.
        step: FoldFn,
    },
    /// Keyed reduction with a first-element initializer (explicit empty
    /// accumulator — a stream containing `Value::Null` reduces correctly).
    Reduce {
        /// Combiner.
        f: ReduceFn,
    },
    /// Count-based window over the (keyed) stream.
    Window {
        /// Window length in events.
        size: usize,
        /// Slide in events (`slide == size` ⇒ tumbling).
        slide: usize,
        /// Aggregate emitted per full window.
        agg: WindowAgg,
    },
    /// Batched inference through an AOT-compiled XLA artifact. Input events
    /// are `F32s` feature rows (or `Pair(key, F32s)`); outputs preserve the
    /// key and replace the payload with the model's output row.
    XlaMap {
        /// Artifact name (resolved under the artifacts directory).
        artifact: String,
        /// Inference batch size (rows per PJRT call).
        batch: usize,
        /// Input feature dimension.
        in_dim: usize,
    },
    /// Merge point of two or more streams (pass-through; the merge itself
    /// happens in the channel wiring feeding this operator's stage).
    Union,
    /// Event-time assignment: extracts each record's event timestamp and
    /// generates watermarks per the configured discipline. A pass-through
    /// on the data plane; the watermark control frames it emits travel
    /// alongside the data (see [`crate::channels::Msg::Watermark`]).
    AssignTimestamps {
        /// Event-timestamp extractor (milliseconds).
        ts: crate::time::TsFn,
        /// Watermark generation discipline.
        gen: crate::time::WatermarkGen,
    },
    /// Event-time window over a keyed stream: panes buffer per key and
    /// fire when the merged watermark passes each window's end plus the
    /// allowed lateness. Records arriving after that horizon are counted
    /// in `late_records` (and optionally routed to the typed side output
    /// under this operator's id).
    EventWindow {
        /// Event-timestamp extractor applied to the pair's *payload*.
        ts: crate::time::TsFn,
        /// Window shape (tumbling / sliding / session).
        assigner: crate::time::WindowAssigner,
        /// Aggregate emitted per fired pane.
        agg: WindowAgg,
        /// Grace period after the window end during which late records
        /// are still incorporated (milliseconds).
        lateness_ms: i64,
        /// Route late-beyond-lateness records into the tagged collector
        /// under this operator's id (typed side output) instead of only
        /// counting them.
        late_side: bool,
    },
    /// Tags keyed records with their interval-join side: `Pair(k, v)`
    /// becomes `Pair(k, Pair(I64(side), v))`. Counts as a key extractor
    /// (the key is unchanged, so the outgoing edge stays hash-routed) and
    /// — uniquely — fuses *after* a key extractor, so tagging rides in
    /// the keying stage instead of costing an extra shuffle hop.
    SideTag(u8),
    /// Keyed stream-stream interval join: left records at time `t` match
    /// right records (same key) in `[t + lower_ms, t + upper_ms]`. Both
    /// sides buffer until the merged watermark proves no further match
    /// can arrive; inputs are the two [`OpKind::SideTag`]-wrapped keyed
    /// streams (left = side 0, right = side 1).
    IntervalJoin {
        /// Left-payload event-timestamp extractor.
        ts_left: crate::time::TsFn,
        /// Right-payload event-timestamp extractor.
        ts_right: crate::time::TsFn,
        /// Interval lower bound relative to the left timestamp (ms).
        lower_ms: i64,
        /// Interval upper bound relative to the left timestamp (ms).
        upper_ms: i64,
    },
    /// A monomorphized columnar operator emitted by the typed layer: the
    /// factory builds one fresh executor per stage instance. Key-extracting
    /// columnar operators (`keys: true`) route and break stages exactly
    /// like [`OpKind::KeyBy`].
    Columnar(ColumnarOp),
    /// Terminal sink (a DAG leaf; has no consumers).
    Sink(SinkKind),
}

/// A typed columnar operator carried opaquely through the logical graph.
/// The closure inside `factory` captures the monomorphized executor type;
/// the graph layer only needs the routing/fusion metadata alongside it.
#[derive(Clone)]
pub struct ColumnarOp {
    /// Builds a fresh executor (state included) for one stage instance.
    pub factory: ColumnOpFactory,
    /// True for key extraction: the outgoing edge is hash-partitioned and
    /// the stage breaks after this operator.
    pub keys: bool,
    /// True for keyed state holders (fold/reduce/window).
    pub stateful: bool,
    /// Operator kind label for Debug/describe output.
    pub label: &'static str,
}

impl std::fmt::Debug for OpKind {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        match self {
            OpKind::Source(s) => write!(f, "Source({s:?})"),
            OpKind::Map(_) => write!(f, "Map"),
            OpKind::Filter(_) => write!(f, "Filter"),
            OpKind::FilterMap(_) => write!(f, "FilterMap"),
            OpKind::FlatMap(_) => write!(f, "FlatMap"),
            OpKind::KeyBy(_) => write!(f, "KeyBy"),
            OpKind::KeyByFused(_) => write!(f, "KeyByFused"),
            OpKind::Fold { .. } => write!(f, "Fold"),
            OpKind::Reduce { .. } => write!(f, "Reduce"),
            OpKind::Window { size, slide, agg } => {
                write!(f, "Window(size={size}, slide={slide}, agg={agg:?})")
            }
            OpKind::XlaMap {
                artifact, batch, ..
            } => write!(f, "XlaMap({artifact}, batch={batch})"),
            OpKind::Union => write!(f, "Union"),
            OpKind::AssignTimestamps { gen, .. } => write!(f, "AssignTimestamps({gen:?})"),
            OpKind::EventWindow {
                assigner,
                agg,
                lateness_ms,
                ..
            } => write!(
                f,
                "EventWindow({assigner:?}, agg={agg:?}, lateness={lateness_ms}ms)"
            ),
            OpKind::SideTag(side) => write!(f, "SideTag({side})"),
            OpKind::IntervalJoin {
                lower_ms, upper_ms, ..
            } => write!(f, "IntervalJoin([{lower_ms}, {upper_ms}]ms)"),
            OpKind::Columnar(c) => write!(f, "Columnar({})", c.label),
            OpKind::Sink(s) => write!(f, "Sink({s:?})"),
        }
    }
}

impl OpKind {
    /// Whether this operator holds keyed/windowed state.
    pub fn is_stateful(&self) -> bool {
        match self {
            OpKind::Fold { .. } | OpKind::Reduce { .. } | OpKind::Window { .. } => true,
            OpKind::AssignTimestamps { .. }
            | OpKind::EventWindow { .. }
            | OpKind::IntervalJoin { .. } => true,
            OpKind::Columnar(c) => c.stateful,
            _ => false,
        }
    }

    /// Whether the operator extracts keys, hash-partitioning its outgoing
    /// edge and breaking the stage after itself.
    pub fn is_key_extractor(&self) -> bool {
        match self {
            OpKind::KeyBy(_) | OpKind::KeyByFused(_) => true,
            OpKind::SideTag(_) => true,
            OpKind::Columnar(c) => c.keys,
            _ => false,
        }
    }
}

/// One logical operator in the DAG.
#[derive(Clone, Debug)]
pub struct LogicalOp {
    /// Operator id (topological position; inputs always have smaller ids).
    pub id: OpId,
    /// Kind and user logic.
    pub kind: OpKind,
    /// FlowUnit this operator belongs to.
    pub unit: UnitId,
    /// Upstream operators feeding this one (empty for sources).
    pub inputs: Vec<OpId>,
    /// Human-readable operator name for metrics/reports.
    pub name: String,
}

/// The logical job graph: an operator DAG plus the FlowUnit table.
#[derive(Clone, Debug, Default)]
pub struct LogicalGraph {
    /// Operators in topological (insertion) order.
    pub ops: Vec<LogicalOp>,
    /// FlowUnits referenced by the operators.
    pub units: Vec<UnitDef>,
    /// Identity of the builder context that produced this graph (0 when
    /// the graph was constructed directly). Stamped onto typed
    /// `CollectHandle`s so a handle cannot silently redeem against
    /// another job's report.
    pub origin: u64,
}

impl LogicalGraph {
    /// Adds a FlowUnit, returning its id. `name: None` auto-derives a
    /// unique name from the layer.
    pub fn add_unit(
        &mut self,
        name: Option<&str>,
        layer: LayerId,
        constraint: Option<ConstraintExpr>,
        replication: Replication,
    ) -> UnitId {
        let index = self.units.len();
        let (name, auto) = match name {
            Some(n) => (n.to_string(), false),
            None => (self.auto_unit_name(&layer, None), true),
        };
        self.units.push(UnitDef {
            index,
            name,
            layer,
            constraint,
            replication,
            auto,
        });
        index
    }

    /// Derives a unique auto-name for a unit on `layer`, ignoring the unit
    /// at `exclude` (used when re-scoping a unit in place).
    pub fn auto_unit_name(&self, layer: &str, exclude: Option<UnitId>) -> String {
        let taken = |n: &str| {
            self.units
                .iter()
                .any(|u| Some(u.index) != exclude && u.name == n)
        };
        if !taken(layer) {
            return layer.to_string();
        }
        let mut i = self.units.len();
        loop {
            let candidate = format!("{layer}:{i}");
            if !taken(&candidate) {
                return candidate;
            }
            i += 1;
        }
    }

    /// Appends an operator to `unit` with the given inputs, returning its
    /// id. Inputs must already exist (ids are topological by construction).
    pub fn add_op(
        &mut self,
        kind: OpKind,
        unit: UnitId,
        inputs: Vec<OpId>,
        name: impl Into<String>,
    ) -> OpId {
        let id = self.ops.len();
        self.ops.push(LogicalOp {
            id,
            kind,
            unit,
            inputs,
            name: name.into(),
        });
        id
    }

    /// Legacy linear append: chains the operator after the previously
    /// pushed one, reusing the last unit when layer and constraint match
    /// and opening a new unit otherwise. Kept so linear pipelines (and the
    /// bulk of the test suite) can build graphs without the fluent API.
    pub fn push(
        &mut self,
        kind: OpKind,
        layer: LayerId,
        constraint: Option<ConstraintExpr>,
        name: impl Into<String>,
    ) -> OpId {
        let reuse_last = self
            .units
            .last()
            .map_or(false, |u| u.layer == layer && u.constraint == constraint);
        let unit = if reuse_last {
            self.units.len() - 1
        } else {
            self.add_unit(None, layer, constraint, Replication::PerCore)
        };
        let inputs = if matches!(kind, OpKind::Source(_)) || self.ops.is_empty() {
            Vec::new()
        } else {
            vec![self.ops.len() - 1]
        };
        self.add_op(kind, unit, inputs, name)
    }

    /// The unit a given operator belongs to.
    pub fn unit_of(&self, op: OpId) -> &UnitDef {
        &self.units[self.ops[op].unit]
    }

    /// True when `unit` holds no processing operators yet (only sources
    /// or unions) — such a unit can still be renamed or re-layered in
    /// place by the builder sugar instead of opening a new unit.
    pub fn unit_is_fresh(&self, unit: UnitId) -> bool {
        self.ops
            .iter()
            .filter(|o| o.unit == unit)
            .all(|o| matches!(o.kind, OpKind::Source(_) | OpKind::Union))
    }

    /// Resolves a FlowUnit by name.
    pub fn unit_named(&self, name: &str) -> Option<UnitId> {
        self.units.iter().position(|u| u.name == name)
    }

    /// All FlowUnit names, in unit-id order.
    pub fn unit_names(&self) -> Vec<String> {
        self.units.iter().map(|u| u.name.clone()).collect()
    }

    /// Number of consumers of each operator.
    fn consumer_counts(&self) -> Vec<usize> {
        let mut counts = vec![0usize; self.ops.len()];
        for op in &self.ops {
            for &i in &op.inputs {
                if i < counts.len() {
                    counts[i] += 1;
                }
            }
        }
        counts
    }

    /// Validates DAG shape and layer monotonicity against `layers`
    /// (periphery→centre order): data may only flow inward along the zone
    /// tree, matching the paper's collection pattern.
    pub fn validate(&self, layers: &[LayerId]) -> Result<()> {
        if self.ops.is_empty() {
            return Err(Error::Graph("empty graph".into()));
        }
        for op in &self.ops {
            for &i in &op.inputs {
                if i >= op.id {
                    return Err(Error::Graph(format!(
                        "operator '{}' input {i} is not upstream of it (graph must be topologically ordered)",
                        op.name
                    )));
                }
            }
        }
        let mut names = BTreeSet::new();
        for u in &self.units {
            if !names.insert(u.name.as_str()) {
                return Err(Error::Graph(format!(
                    "duplicate FlowUnit name '{}'",
                    u.name
                )));
            }
        }
        let consumers = self.consumer_counts();
        for op in &self.ops {
            match &op.kind {
                OpKind::Source(_) => {
                    if !op.inputs.is_empty() {
                        return Err(Error::Graph(format!(
                            "source '{}' cannot have inputs",
                            op.name
                        )));
                    }
                }
                OpKind::Sink(_) => {
                    if op.inputs.is_empty() {
                        return Err(Error::Graph(format!("sink '{}' has no input", op.name)));
                    }
                    if consumers[op.id] > 0 {
                        return Err(Error::Graph(format!(
                            "sink '{}' cannot feed downstream operators",
                            op.name
                        )));
                    }
                }
                OpKind::Union => {
                    if op.inputs.len() < 2 {
                        return Err(Error::Graph(format!(
                            "union '{}' needs at least two inputs",
                            op.name
                        )));
                    }
                    let distinct: BTreeSet<OpId> = op.inputs.iter().copied().collect();
                    if distinct.len() != op.inputs.len() {
                        return Err(Error::Graph(format!(
                            "union '{}' has duplicate inputs (each event would be \
                             delivered once, not per-input)",
                            op.name
                        )));
                    }
                }
                OpKind::IntervalJoin {
                    lower_ms, upper_ms, ..
                } => {
                    if op.inputs.len() != 2 {
                        return Err(Error::Graph(format!(
                            "interval join '{}' needs exactly two inputs (left, right)",
                            op.name
                        )));
                    }
                    if op.inputs[0] == op.inputs[1] {
                        return Err(Error::Graph(format!(
                            "interval join '{}' has the same stream on both sides",
                            op.name
                        )));
                    }
                    if lower_ms > upper_ms {
                        return Err(Error::Graph(format!(
                            "interval join '{}' bounds invalid: need lower <= upper, \
                             got [{lower_ms}, {upper_ms}]",
                            op.name
                        )));
                    }
                }
                _ => {
                    if op.inputs.len() != 1 {
                        return Err(Error::Graph(format!(
                            "operator '{}' has {} inputs (expected exactly 1)",
                            op.name,
                            op.inputs.len()
                        )));
                    }
                }
            }
            if !matches!(op.kind, OpKind::Sink(_)) && consumers[op.id] == 0 {
                return Err(Error::Graph(format!(
                    "operator '{}' is not terminated by a sink (dangling stream)",
                    op.name
                )));
            }
            if let OpKind::Window { size, slide, .. } = &op.kind {
                if *size == 0 || *slide == 0 || *slide > *size {
                    return Err(Error::Graph(format!(
                        "window(size={size}, slide={slide}) invalid: need 0 < slide <= size"
                    )));
                }
            }
            if let OpKind::EventWindow {
                assigner,
                lateness_ms,
                ..
            } = &op.kind
            {
                assigner.validate().map_err(Error::Graph)?;
                if *lateness_ms < 0 {
                    return Err(Error::Graph(format!(
                        "event window '{}' has negative allowed lateness ({lateness_ms}ms)",
                        op.name
                    )));
                }
            }
            if op.unit >= self.units.len() {
                return Err(Error::Graph(format!(
                    "operator '{}' references unknown unit {}",
                    op.name, op.unit
                )));
            }
        }
        // layer monotonicity along every edge, periphery → centre
        let pos_of = |unit: UnitId, op_name: &str| -> Result<usize> {
            let layer = &self.units[unit].layer;
            layers.iter().position(|l| l == layer).ok_or_else(|| {
                Error::Graph(format!(
                    "operator '{op_name}' on unknown layer '{layer}'"
                ))
            })
        };
        for op in &self.ops {
            let here = pos_of(op.unit, &op.name)?;
            for &i in &op.inputs {
                let upstream = pos_of(self.ops[i].unit, &self.ops[i].name)?;
                if here < upstream {
                    return Err(Error::Graph(format!(
                        "operator '{}' moves outward ({} after {}); FlowUnits pipelines flow periphery → centre",
                        op.name,
                        self.units[op.unit].layer,
                        self.units[self.ops[i].unit].layer
                    )));
                }
            }
        }
        Ok(())
    }

    /// Splits the DAG into [`Stage`]s (fusion units). An operator fuses
    /// into its (single) input's stage unless a break is required:
    /// * after the `Source` — data origin is physical (sensors live at the
    ///   edge), so the source is its own stage, pinned to its data-origin
    ///   zones under *every* planner; replicating it would move where data
    ///   is *born*, not where it is processed;
    /// * after a `KeyBy` (the outgoing edge is hash-partitioned);
    /// * at a FlowUnit boundary;
    /// * at a fan-in (`union` inputs) or fan-out (`split` consumers).
    pub fn stages(&self) -> Vec<Stage> {
        let consumers = self.consumer_counts();
        let mut stage_of = vec![usize::MAX; self.ops.len()];
        let mut stages: Vec<Stage> = Vec::new();
        for op in &self.ops {
            let fused = if op.inputs.len() == 1 {
                let p = op.inputs[0];
                let prev = &self.ops[p];
                // SideTag rewrites `Pair(k, v)` into `Pair(k, Pair(side, v))`
                // without touching the key, so it may ride in a key-extractor
                // stage: the hash break moves after the tag (itself a key
                // extractor) and routing is unchanged.
                let after_key_ok = !prev.kind.is_key_extractor()
                    || matches!(op.kind, OpKind::SideTag(_));
                prev.unit == op.unit
                    && consumers[p] == 1
                    && !matches!(prev.kind, OpKind::Source(_))
                    && after_key_ok
            } else {
                false
            };
            if fused {
                let s = stage_of[op.inputs[0]];
                stages[s].ops.push(op.id);
                stage_of[op.id] = s;
            } else {
                let u = &self.units[op.unit];
                stage_of[op.id] = stages.len();
                stages.push(Stage {
                    index: stages.len(),
                    unit_index: op.unit,
                    layer: u.layer.clone(),
                    constraint: u.constraint.clone(),
                    replication: u.replication,
                    source: matches!(op.kind, OpKind::Source(_)),
                    ops: vec![op.id],
                });
            }
        }
        stages
    }

    /// Stage-to-stage edges of the DAG, derived from operator inputs.
    /// Sorted and deduplicated for deterministic plans.
    pub fn stage_edges(&self, stages: &[Stage]) -> Vec<(usize, usize)> {
        let mut stage_of = vec![0usize; self.ops.len()];
        for s in stages {
            for &o in &s.ops {
                stage_of[o] = s.index;
            }
        }
        let mut edges = BTreeSet::new();
        for op in &self.ops {
            for &i in &op.inputs {
                let (a, b) = (stage_of[i], stage_of[op.id]);
                if a != b {
                    edges.insert((a, b));
                }
            }
        }
        edges.into_iter().collect()
    }

    /// Routing required on edges *out of* `stage`: hash-partitioned iff
    /// the stage ends with `KeyBy`.
    pub fn edge_routing(&self, stage: &Stage) -> crate::channels::Routing {
        let last = &self.ops[*stage.ops.last().unwrap()];
        if last.kind.is_key_extractor() {
            crate::channels::Routing::Hash
        } else {
            crate::channels::Routing::RoundRobin
        }
    }

    /// Render a compact description of the DAG, grouped by FlowUnit.
    pub fn describe(&self) -> String {
        self.units
            .iter()
            .filter_map(|u| {
                let ops: Vec<&str> = self
                    .ops
                    .iter()
                    .filter(|o| o.unit == u.index)
                    .map(|o| o.name.as_str())
                    .collect();
                if ops.is_empty() {
                    None
                } else {
                    Some(format!("[{} @ {}] {}", u.name, u.layer, ops.join(" -> ")))
                }
            })
            .collect::<Vec<_>>()
            .join(" | ")
    }
}

/// A fusion unit: a maximal linear run of operators inside one FlowUnit
/// with no internal repartitioning or branching.
#[derive(Clone, Debug, PartialEq)]
pub struct Stage {
    /// Stage index in topological order.
    pub index: usize,
    /// FlowUnit this stage belongs to.
    pub unit_index: UnitId,
    /// Layer annotation (from the unit).
    pub layer: LayerId,
    /// Effective constraint (from the unit).
    pub constraint: Option<ConstraintExpr>,
    /// In-zone replication policy (from the unit).
    pub replication: Replication,
    /// Whether this stage's (single) operator is a stream source.
    pub source: bool,
    /// Logical operators fused into this stage.
    pub ops: Vec<OpId>,
}

impl Stage {
    /// True if the stage's operator is a job source.
    pub fn is_source(&self) -> bool {
        self.source
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn layers() -> Vec<LayerId> {
        vec!["edge".into(), "site".into(), "cloud".into()]
    }

    /// Builds the paper's evaluation pipeline shape:
    /// source@edge -> filter@edge -> key_by@site -> window@site -> map@cloud -> sink@cloud
    fn eval_graph() -> LogicalGraph {
        let mut g = LogicalGraph::default();
        g.push(
            OpKind::Source(SourceKind::Synthetic {
                total: 100,
                gen: Arc::new(|_, i| Value::I64(i as i64)),
                rate: None,
            }),
            "edge".into(),
            None,
            "source",
        );
        g.push(
            OpKind::Filter(Arc::new(|v| v.as_i64().unwrap() % 3 == 0)),
            "edge".into(),
            None,
            "O1-filter",
        );
        g.push(
            OpKind::KeyBy(Arc::new(|v| Value::I64(v.as_i64().unwrap() % 4))),
            "site".into(),
            None,
            "key_by",
        );
        g.push(
            OpKind::Window {
                size: 10,
                slide: 10,
                agg: WindowAgg::Mean,
            },
            "site".into(),
            None,
            "O2-window",
        );
        g.push(
            OpKind::Map(Arc::new(|v| v)),
            "cloud".into(),
            None,
            "O3-map",
        );
        g.push(OpKind::Sink(SinkKind::Collect), "cloud".into(), None, "sink");
        g
    }

    #[test]
    fn eval_graph_validates() {
        eval_graph().validate(&layers()).unwrap();
    }

    #[test]
    fn push_assigns_layer_named_units() {
        let g = eval_graph();
        assert_eq!(g.unit_names(), vec!["edge", "site", "cloud"]);
        assert_eq!(g.unit_named("site"), Some(1));
        assert_eq!(g.unit_named("fog"), None);
    }

    #[test]
    fn stage_partitioning_breaks_at_source_layers_and_keyby() {
        let g = eval_graph();
        let stages = g.stages();
        // [source]@edge | [filter]@edge | [key_by]@site | [window]@site | [map, sink]@cloud
        assert_eq!(stages.len(), 5);
        assert_eq!(stages[0].ops, vec![0]);
        assert!(stages[0].is_source());
        assert_eq!(stages[1].ops, vec![1]);
        assert_eq!(stages[1].layer, "edge");
        assert_eq!(stages[2].ops, vec![2]);
        assert_eq!(stages[3].ops, vec![3]);
        assert_eq!(stages[4].ops, vec![4, 5]);
        // FlowUnit indices: edge=0, site=1, cloud=2
        assert_eq!(stages[0].unit_index, 0);
        assert_eq!(stages[1].unit_index, 0);
        assert_eq!(stages[2].unit_index, 1);
        assert_eq!(stages[3].unit_index, 1);
        assert_eq!(stages[4].unit_index, 2);
    }

    #[test]
    fn stage_edges_of_linear_chain_are_consecutive() {
        let g = eval_graph();
        let stages = g.stages();
        assert_eq!(
            g.stage_edges(&stages),
            vec![(0, 1), (1, 2), (2, 3), (3, 4)]
        );
    }

    #[test]
    fn keyby_edge_is_hash_routed() {
        let g = eval_graph();
        let stages = g.stages();
        assert_eq!(g.edge_routing(&stages[0]), crate::channels::Routing::RoundRobin);
        assert_eq!(g.edge_routing(&stages[1]), crate::channels::Routing::RoundRobin);
        assert_eq!(g.edge_routing(&stages[2]), crate::channels::Routing::Hash);
        assert_eq!(g.edge_routing(&stages[3]), crate::channels::Routing::RoundRobin);
    }

    #[test]
    fn constraint_opens_a_new_unit() {
        // constraints are unit-scoped: a constrained operator run lives in
        // its own FlowUnit even inside one layer
        let mut g = LogicalGraph::default();
        g.push(
            OpKind::Source(SourceKind::Synthetic {
                total: 1,
                gen: Arc::new(|_, _| Value::Null),
                rate: None,
            }),
            "cloud".into(),
            None,
            "src",
        );
        g.push(OpKind::Map(Arc::new(|v| v)), "cloud".into(), None, "m1");
        let c = ConstraintExpr::parse("gpu = yes").unwrap();
        g.push(OpKind::Map(Arc::new(|v| v)), "cloud".into(), Some(c), "m2-gpu");
        g.push(OpKind::Sink(SinkKind::Discard), "cloud".into(), None, "sink");
        let stages = g.stages();
        assert_eq!(stages.len(), 4); // [src] [m1] [m2-gpu] [sink]
        assert_eq!(stages[2].constraint.as_ref().unwrap().to_string(), "gpu = yes");
        assert_eq!(
            stages.iter().map(|s| s.unit_index).collect::<Vec<_>>(),
            vec![0, 0, 1, 2]
        );
        g.validate(&layers()).unwrap();
    }

    #[test]
    fn union_and_split_partition_into_stages() {
        // two sources union into one unit, then split into two sinks
        let mut g = LogicalGraph::default();
        let ua = g.add_unit(Some("north"), "edge".into(), None, Replication::PerCore);
        let ub = g.add_unit(Some("south"), "edge".into(), None, Replication::PerCore);
        let uc = g.add_unit(Some("detect"), "cloud".into(), None, Replication::PerCore);
        let sa = g.add_op(
            OpKind::Source(SourceKind::Vector(Arc::new(vec![Value::I64(1)]))),
            ua,
            vec![],
            "srcA",
        );
        let sb = g.add_op(
            OpKind::Source(SourceKind::Vector(Arc::new(vec![Value::I64(2)]))),
            ub,
            vec![],
            "srcB",
        );
        let un = g.add_op(OpKind::Union, uc, vec![sa, sb], "union");
        let m = g.add_op(OpKind::Map(Arc::new(|v| v)), uc, vec![un], "map");
        g.add_op(OpKind::Sink(SinkKind::Collect), uc, vec![m], "sinkA");
        g.add_op(OpKind::Sink(SinkKind::Count), uc, vec![m], "sinkB");
        g.validate(&layers()).unwrap();
        let stages = g.stages();
        // [srcA] [srcB] [union, map] [sinkA] [sinkB]
        assert_eq!(stages.len(), 5);
        assert_eq!(stages[2].ops, vec![un, m]);
        assert_eq!(
            g.stage_edges(&stages),
            vec![(0, 2), (1, 2), (2, 3), (2, 4)]
        );
    }

    fn ts_fn() -> crate::time::TsFn {
        Arc::new(|v: &Value| v.as_i64().unwrap_or(0))
    }

    /// Two keyed sides tagged and interval-joined:
    /// srcL -> key_by -> tag(0) \
    ///                            join -> sink
    /// srcR -> key_by -> tag(1) /
    fn join_graph() -> LogicalGraph {
        let mut g = LogicalGraph::default();
        let ul = g.add_unit(Some("left"), "edge".into(), None, Replication::PerCore);
        let ur = g.add_unit(Some("right"), "edge".into(), None, Replication::PerCore);
        let uj = g.add_unit(Some("join"), "cloud".into(), None, Replication::PerCore);
        let sl = g.add_op(
            OpKind::Source(SourceKind::Vector(Arc::new(vec![Value::I64(1)]))),
            ul,
            vec![],
            "srcL",
        );
        let sr = g.add_op(
            OpKind::Source(SourceKind::Vector(Arc::new(vec![Value::I64(2)]))),
            ur,
            vec![],
            "srcR",
        );
        let kl = g.add_op(
            OpKind::KeyBy(Arc::new(|v| Value::I64(v.as_i64().unwrap() % 2))),
            ul,
            vec![sl],
            "keyL",
        );
        let kr = g.add_op(
            OpKind::KeyBy(Arc::new(|v| Value::I64(v.as_i64().unwrap() % 2))),
            ur,
            vec![sr],
            "keyR",
        );
        let tl = g.add_op(OpKind::SideTag(0), ul, vec![kl], "tagL");
        let tr = g.add_op(OpKind::SideTag(1), ur, vec![kr], "tagR");
        let j = g.add_op(
            OpKind::IntervalJoin {
                ts_left: ts_fn(),
                ts_right: ts_fn(),
                lower_ms: -10,
                upper_ms: 10,
            },
            uj,
            vec![tl, tr],
            "join",
        );
        g.add_op(OpKind::Sink(SinkKind::Collect), uj, vec![j], "sink");
        g
    }

    #[test]
    fn side_tag_fuses_into_keyby_stage_and_stays_hash_routed() {
        let g = join_graph();
        g.validate(&layers()).unwrap();
        let stages = g.stages();
        // [srcL] [srcR] [keyL, tagL] [keyR, tagR] [join, sink]
        assert_eq!(stages.len(), 5);
        assert_eq!(stages[2].ops, vec![2, 4]);
        assert_eq!(stages[3].ops, vec![3, 5]);
        assert_eq!(stages[4].ops, vec![6, 7]);
        // the tag stage ends with a key extractor, so both join input
        // edges stay hash-partitioned
        assert_eq!(g.edge_routing(&stages[2]), crate::channels::Routing::Hash);
        assert_eq!(g.edge_routing(&stages[3]), crate::channels::Routing::Hash);
    }

    #[test]
    fn interval_join_rejects_bad_shapes() {
        // same stream on both sides
        let mut g = LogicalGraph::default();
        let u = g.add_unit(None, "edge".into(), None, Replication::PerCore);
        let s = g.add_op(
            OpKind::Source(SourceKind::Vector(Arc::new(vec![Value::I64(1)]))),
            u,
            vec![],
            "src",
        );
        let k = g.add_op(OpKind::KeyBy(Arc::new(|v| v)), u, vec![s], "k");
        let t = g.add_op(OpKind::SideTag(0), u, vec![k], "t");
        let j = g.add_op(
            OpKind::IntervalJoin {
                ts_left: ts_fn(),
                ts_right: ts_fn(),
                lower_ms: 0,
                upper_ms: 10,
            },
            u,
            vec![t, t],
            "join",
        );
        g.add_op(OpKind::Sink(SinkKind::Discard), u, vec![j], "sink");
        assert!(g.validate(&layers()).is_err());

        // inverted bounds
        let mut g = join_graph();
        if let OpKind::IntervalJoin {
            lower_ms, upper_ms, ..
        } = &mut g.ops[6].kind
        {
            *lower_ms = 5;
            *upper_ms = -5;
        }
        assert!(g.validate(&layers()).is_err());
    }

    #[test]
    fn event_window_validates_assigner_and_lateness() {
        let mut base = eval_graph();
        // replace the processing-time window with an event-time one
        base.ops[3].kind = OpKind::EventWindow {
            ts: ts_fn(),
            assigner: crate::time::WindowAssigner::Tumbling { size_ms: 100 },
            agg: WindowAgg::Sum,
            lateness_ms: 50,
            late_side: false,
        };
        base.validate(&layers()).unwrap();
        assert!(base.ops[3].kind.is_stateful());

        base.ops[3].kind = OpKind::EventWindow {
            ts: ts_fn(),
            assigner: crate::time::WindowAssigner::Tumbling { size_ms: 0 },
            agg: WindowAgg::Sum,
            lateness_ms: 0,
            late_side: false,
        };
        assert!(base.validate(&layers()).is_err());

        base.ops[3].kind = OpKind::EventWindow {
            ts: ts_fn(),
            assigner: crate::time::WindowAssigner::Tumbling { size_ms: 100 },
            agg: WindowAgg::Sum,
            lateness_ms: -1,
            late_side: false,
        };
        assert!(base.validate(&layers()).is_err());
    }

    #[test]
    fn rejects_outward_flow() {
        let mut g = LogicalGraph::default();
        g.push(
            OpKind::Source(SourceKind::Synthetic {
                total: 1,
                gen: Arc::new(|_, _| Value::Null),
                rate: None,
            }),
            "cloud".into(),
            None,
            "src",
        );
        g.push(OpKind::Sink(SinkKind::Discard), "edge".into(), None, "sink");
        assert!(g.validate(&layers()).is_err());
    }

    #[test]
    fn rejects_missing_source_or_sink() {
        let mut g = LogicalGraph::default();
        g.push(OpKind::Map(Arc::new(|v| v)), "edge".into(), None, "m");
        assert!(g.validate(&layers()).is_err());

        let mut g = LogicalGraph::default();
        g.push(
            OpKind::Source(SourceKind::Synthetic {
                total: 1,
                gen: Arc::new(|_, _| Value::Null),
                rate: None,
            }),
            "edge".into(),
            None,
            "src",
        );
        g.push(OpKind::Map(Arc::new(|v| v)), "edge".into(), None, "m");
        assert!(g.validate(&layers()).is_err());
    }

    #[test]
    fn rejects_duplicate_unit_names() {
        let mut g = LogicalGraph::default();
        let ua = g.add_unit(Some("dup"), "edge".into(), None, Replication::PerCore);
        let ub = g.add_unit(Some("dup"), "cloud".into(), None, Replication::PerCore);
        let s = g.add_op(
            OpKind::Source(SourceKind::Vector(Arc::new(vec![]))),
            ua,
            vec![],
            "src",
        );
        g.add_op(OpKind::Sink(SinkKind::Count), ub, vec![s], "sink");
        let err = g.validate(&layers()).unwrap_err();
        assert!(err.to_string().contains("duplicate FlowUnit name"));
    }

    #[test]
    fn rejects_bad_window() {
        let mut g = LogicalGraph::default();
        g.push(
            OpKind::Source(SourceKind::Synthetic {
                total: 1,
                gen: Arc::new(|_, _| Value::Null),
                rate: None,
            }),
            "edge".into(),
            None,
            "src",
        );
        g.push(
            OpKind::Window {
                size: 4,
                slide: 8,
                agg: WindowAgg::Mean,
            },
            "edge".into(),
            None,
            "w",
        );
        g.push(OpKind::Sink(SinkKind::Discard), "edge".into(), None, "sink");
        assert!(g.validate(&layers()).is_err());
    }

    #[test]
    fn rejects_unknown_layer() {
        let mut g = LogicalGraph::default();
        g.push(
            OpKind::Source(SourceKind::Synthetic {
                total: 1,
                gen: Arc::new(|_, _| Value::Null),
                rate: None,
            }),
            "fog".into(),
            None,
            "src",
        );
        g.push(OpKind::Sink(SinkKind::Discard), "fog".into(), None, "sink");
        assert!(g.validate(&layers()).is_err());
    }

    #[test]
    fn single_layer_graph_is_one_unit() {
        let mut g = LogicalGraph::default();
        g.push(
            OpKind::Source(SourceKind::Synthetic {
                total: 1,
                gen: Arc::new(|_, _| Value::Null),
                rate: None,
            }),
            "cloud".into(),
            None,
            "src",
        );
        g.push(OpKind::Map(Arc::new(|v| v)), "cloud".into(), None, "m");
        g.push(OpKind::Sink(SinkKind::Collect), "cloud".into(), None, "sink");
        g.validate(&layers()).unwrap();
        let stages = g.stages();
        assert_eq!(stages.len(), 2); // [src] | [m, sink]
        assert!(stages.iter().all(|s| s.unit_index == 0));
    }
}
