//! Logical dataflow graph: operators, layer/constraint annotations, and the
//! FlowUnit/stage partitioning algorithm (paper §III).
//!
//! A job is a linear chain of operators (the paper's evaluation pipeline
//! and running example are linear; fan-in arises from repartitioning, not
//! from graph branches). Each operator carries:
//!
//! * a **layer** annotation (`to_layer`) — contiguous same-layer operators
//!   form a **FlowUnit**;
//! * an optional **constraint** (`add_constraint`) — a conjunction of
//!   capability predicates restricting which hosts may run it.
//!
//! Within a FlowUnit, operators are further grouped into **stages**:
//! maximal runs of operators that share a layer *and* an effective
//! constraint and contain no repartitioning point. Stages are the unit of
//! operator fusion — one stage instance is one worker thread running the
//! fused operator chain.

use crate::error::{Error, Result};
use crate::topology::{ConstraintExpr, LayerId};
use crate::value::Value;
use std::sync::Arc;

/// Identifier of a logical operator (index into [`LogicalGraph::ops`]).
pub type OpId = usize;

/// Unary transform.
pub type MapFn = Arc<dyn Fn(Value) -> Value + Send + Sync>;
/// Predicate.
pub type FilterFn = Arc<dyn Fn(&Value) -> bool + Send + Sync>;
/// One-to-many transform.
pub type FlatMapFn = Arc<dyn Fn(Value) -> Vec<Value> + Send + Sync>;
/// Key extractor.
pub type KeyFn = Arc<dyn Fn(&Value) -> Value + Send + Sync>;
/// Fold step: accumulator ← step(accumulator, element payload).
pub type FoldFn = Arc<dyn Fn(&mut Value, Value) + Send + Sync>;
/// Synthetic event generator: `(instance_index, event_index) -> event`.
pub type GenFn = Arc<dyn Fn(u64, u64) -> Value + Send + Sync>;
/// Custom window aggregate over the buffered payloads.
pub type WindowFn = Arc<dyn Fn(&[Value]) -> Value + Send + Sync>;

/// Built-in window aggregations (applied to window payloads; keyed windows
/// emit `Pair(key, aggregate)`).
#[derive(Clone)]
pub enum WindowAgg {
    /// Arithmetic mean of numeric payloads.
    Mean,
    /// Sum of numeric payloads.
    Sum,
    /// Window length.
    Count,
    /// Maximum numeric payload.
    Max,
    /// Minimum numeric payload.
    Min,
    /// The raw window as a `Value::List`.
    Collect,
    /// Feature vector `[mean, std, min, max, last]` as `Value::F32s` —
    /// the shape consumed by the AOT-compiled anomaly model.
    FeatureStats,
    /// Arbitrary aggregate.
    Custom(WindowFn),
}

impl std::fmt::Debug for WindowAgg {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        let name = match self {
            WindowAgg::Mean => "Mean",
            WindowAgg::Sum => "Sum",
            WindowAgg::Count => "Count",
            WindowAgg::Max => "Max",
            WindowAgg::Min => "Min",
            WindowAgg::Collect => "Collect",
            WindowAgg::FeatureStats => "FeatureStats",
            WindowAgg::Custom(_) => "Custom(..)",
        };
        write!(f, "{name}")
    }
}

/// Source definitions.
#[derive(Clone)]
pub enum SourceKind {
    /// Synthetic generator producing `total` events split evenly across
    /// source instances, optionally rate-limited (events/s per instance).
    Synthetic {
        /// Total events across all instances.
        total: u64,
        /// Generator closure.
        gen: GenFn,
        /// Optional per-instance rate limit (events/second).
        rate: Option<f64>,
    },
    /// A materialised vector, split across instances by round robin.
    Vector(Arc<Vec<Value>>),
    /// Lines of a text file as `Value::Str`, split across instances by
    /// line index modulo instance count.
    FileLines(std::path::PathBuf),
}

impl std::fmt::Debug for SourceKind {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        match self {
            SourceKind::Synthetic { total, rate, .. } => {
                write!(f, "Synthetic(total={total}, rate={rate:?})")
            }
            SourceKind::Vector(v) => write!(f, "Vector(len={})", v.len()),
            SourceKind::FileLines(p) => write!(f, "FileLines({})", p.display()),
        }
    }
}

/// Sink definitions.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum SinkKind {
    /// Collect events into the job report.
    Collect,
    /// Count events only.
    Count,
    /// Drop events (pure benchmark sink).
    Discard,
}

/// Logical operator kinds.
#[derive(Clone)]
pub enum OpKind {
    /// Stream source (first operator only).
    Source(SourceKind),
    /// Unary transform.
    Map(MapFn),
    /// Predicate filter.
    Filter(FilterFn),
    /// One-to-many transform.
    FlatMap(FlatMapFn),
    /// Key extraction; the outgoing edge is hash-partitioned.
    KeyBy(KeyFn),
    /// Keyed fold, emitting `Pair(key, acc)` per key at end-of-stream.
    Fold {
        /// Initial accumulator (cloned per key).
        init: Value,
        /// Folding step.
        step: FoldFn,
    },
    /// Count-based window over the (keyed) stream.
    Window {
        /// Window length in events.
        size: usize,
        /// Slide in events (`slide == size` ⇒ tumbling).
        slide: usize,
        /// Aggregate emitted per full window.
        agg: WindowAgg,
    },
    /// Batched inference through an AOT-compiled XLA artifact. Input events
    /// are `F32s` feature rows (or `Pair(key, F32s)`); outputs preserve the
    /// key and replace the payload with the model's output row.
    XlaMap {
        /// Artifact name (resolved under the artifacts directory).
        artifact: String,
        /// Inference batch size (rows per PJRT call).
        batch: usize,
        /// Input feature dimension.
        in_dim: usize,
    },
    /// Terminal sink (last operator only).
    Sink(SinkKind),
}

impl std::fmt::Debug for OpKind {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        match self {
            OpKind::Source(s) => write!(f, "Source({s:?})"),
            OpKind::Map(_) => write!(f, "Map"),
            OpKind::Filter(_) => write!(f, "Filter"),
            OpKind::FlatMap(_) => write!(f, "FlatMap"),
            OpKind::KeyBy(_) => write!(f, "KeyBy"),
            OpKind::Fold { .. } => write!(f, "Fold"),
            OpKind::Window { size, slide, agg } => {
                write!(f, "Window(size={size}, slide={slide}, agg={agg:?})")
            }
            OpKind::XlaMap {
                artifact, batch, ..
            } => write!(f, "XlaMap({artifact}, batch={batch})"),
            OpKind::Sink(s) => write!(f, "Sink({s:?})"),
        }
    }
}

impl OpKind {
    /// Whether this operator holds keyed/windowed state.
    pub fn is_stateful(&self) -> bool {
        matches!(self, OpKind::Fold { .. } | OpKind::Window { .. })
    }
}

/// One logical operator with its annotations.
#[derive(Clone, Debug)]
pub struct LogicalOp {
    /// Operator id (chain position).
    pub id: OpId,
    /// Kind and user logic.
    pub kind: OpKind,
    /// Layer annotation (from `to_layer`).
    pub layer: LayerId,
    /// Capability requirement (from `add_constraint`).
    pub constraint: Option<ConstraintExpr>,
    /// Human-readable operator name for metrics/reports.
    pub name: String,
}

/// The logical job graph: a linear operator chain plus job-wide notes.
#[derive(Clone, Debug, Default)]
pub struct LogicalGraph {
    /// Operators in chain order.
    pub ops: Vec<LogicalOp>,
}

impl LogicalGraph {
    /// Appends an operator, returning its id.
    pub fn push(
        &mut self,
        kind: OpKind,
        layer: LayerId,
        constraint: Option<ConstraintExpr>,
        name: impl Into<String>,
    ) -> OpId {
        let id = self.ops.len();
        self.ops.push(LogicalOp {
            id,
            kind,
            layer,
            constraint,
            name: name.into(),
        });
        id
    }

    /// Validates chain shape and layer monotonicity against `layers`
    /// (periphery→centre order): data may only flow inward along the zone
    /// tree, matching the paper's collection pattern.
    pub fn validate(&self, layers: &[LayerId]) -> Result<()> {
        if self.ops.is_empty() {
            return Err(Error::Graph("empty graph".into()));
        }
        if !matches!(self.ops[0].kind, OpKind::Source(_)) {
            return Err(Error::Graph("first operator must be a Source".into()));
        }
        for (i, op) in self.ops.iter().enumerate() {
            if i > 0 && matches!(op.kind, OpKind::Source(_)) {
                return Err(Error::Graph(format!("Source at position {i} (must be first)")));
            }
            if matches!(op.kind, OpKind::Sink(_)) && i + 1 != self.ops.len() {
                return Err(Error::Graph(format!("Sink at position {i} (must be last)")));
            }
            if let OpKind::Window { size, slide, .. } = &op.kind {
                if *size == 0 || *slide == 0 || *slide > *size {
                    return Err(Error::Graph(format!(
                        "window(size={size}, slide={slide}) invalid: need 0 < slide <= size"
                    )));
                }
            }
        }
        if !matches!(self.ops.last().unwrap().kind, OpKind::Sink(_)) {
            return Err(Error::Graph("last operator must be a Sink".into()));
        }
        let mut prev_idx = 0usize;
        for op in &self.ops {
            let idx = layers
                .iter()
                .position(|l| l == &op.layer)
                .ok_or_else(|| Error::Graph(format!("operator '{}' on unknown layer '{}'", op.name, op.layer)))?;
            if idx < prev_idx {
                return Err(Error::Graph(format!(
                    "operator '{}' moves outward ({} after {}); FlowUnits pipelines flow periphery → centre",
                    op.name, op.layer, layers[prev_idx]
                )));
            }
            prev_idx = idx;
        }
        Ok(())
    }

    /// Splits the chain into [`Stage`]s (fusion units) and labels each with
    /// its FlowUnit index. Breaks occur:
    /// * after the `Source` — data origin is physical (sensors live at the
    ///   edge), so the source is its own stage, pinned to its data-origin
    ///   zones under *every* planner; replicating it would move where data
    ///   is *born*, not where it is processed;
    /// * after a `KeyBy` (the outgoing edge is hash-partitioned);
    /// * at a layer change (FlowUnit boundary);
    /// * at an effective-constraint change (operators with different
    ///   requirements run on different host subsets — paper's red/yellow
    ///   cloud node example).
    pub fn stages(&self) -> Vec<Stage> {
        let mut stages: Vec<Stage> = Vec::new();
        let mut unit_index = 0usize;
        for op in &self.ops {
            let break_before = match stages.last() {
                None => true,
                Some(prev) => {
                    let prev_last = &self.ops[*prev.ops.last().unwrap()];
                    let layer_change = prev_last.layer != op.layer;
                    let constraint_change = prev_last.constraint != op.constraint;
                    let after_keyby = matches!(prev_last.kind, OpKind::KeyBy(_));
                    let after_source = matches!(prev_last.kind, OpKind::Source(_));
                    layer_change || constraint_change || after_keyby || after_source
                }
            };
            if break_before {
                if let Some(prev) = stages.last() {
                    let prev_last = &self.ops[*prev.ops.last().unwrap()];
                    if prev_last.layer != op.layer {
                        unit_index += 1;
                    }
                }
                stages.push(Stage {
                    index: stages.len(),
                    unit_index,
                    layer: op.layer.clone(),
                    constraint: op.constraint.clone(),
                    ops: vec![op.id],
                });
            } else {
                stages.last_mut().unwrap().ops.push(op.id);
            }
        }
        stages
    }

    /// Routing required on the edge *out of* `stage` (into the next stage):
    /// hash-partitioned iff the stage ends with `KeyBy`.
    pub fn edge_routing(&self, stage: &Stage) -> crate::channels::Routing {
        let last = &self.ops[*stage.ops.last().unwrap()];
        if matches!(last.kind, OpKind::KeyBy(_)) {
            crate::channels::Routing::Hash
        } else {
            crate::channels::Routing::RoundRobin
        }
    }

    /// Render a compact description of the chain.
    pub fn describe(&self) -> String {
        self.ops
            .iter()
            .map(|o| format!("{}@{}", o.name, o.layer))
            .collect::<Vec<_>>()
            .join(" -> ")
    }
}

/// A fusion unit: a maximal run of chained operators sharing layer and
/// constraint with no internal repartitioning.
#[derive(Clone, Debug, PartialEq)]
pub struct Stage {
    /// Stage index in chain order.
    pub index: usize,
    /// FlowUnit this stage belongs to (contiguous same-layer stages share
    /// a unit index).
    pub unit_index: usize,
    /// Layer annotation.
    pub layer: LayerId,
    /// Effective constraint.
    pub constraint: Option<ConstraintExpr>,
    /// Logical operators fused into this stage.
    pub ops: Vec<OpId>,
}

impl Stage {
    /// True if the stage's first operator is the job source.
    pub fn is_source(&self) -> bool {
        self.ops.first() == Some(&0)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn layers() -> Vec<LayerId> {
        vec!["edge".into(), "site".into(), "cloud".into()]
    }

    /// Builds the paper's evaluation pipeline shape:
    /// source@edge -> filter@edge -> key_by@site -> window@site -> map@cloud -> sink@cloud
    fn eval_graph() -> LogicalGraph {
        let mut g = LogicalGraph::default();
        g.push(
            OpKind::Source(SourceKind::Synthetic {
                total: 100,
                gen: Arc::new(|_, i| Value::I64(i as i64)),
                rate: None,
            }),
            "edge".into(),
            None,
            "source",
        );
        g.push(
            OpKind::Filter(Arc::new(|v| v.as_i64().unwrap() % 3 == 0)),
            "edge".into(),
            None,
            "O1-filter",
        );
        g.push(
            OpKind::KeyBy(Arc::new(|v| Value::I64(v.as_i64().unwrap() % 4))),
            "site".into(),
            None,
            "key_by",
        );
        g.push(
            OpKind::Window {
                size: 10,
                slide: 10,
                agg: WindowAgg::Mean,
            },
            "site".into(),
            None,
            "O2-window",
        );
        g.push(
            OpKind::Map(Arc::new(|v| v)),
            "cloud".into(),
            None,
            "O3-map",
        );
        g.push(OpKind::Sink(SinkKind::Collect), "cloud".into(), None, "sink");
        g
    }

    #[test]
    fn eval_graph_validates() {
        eval_graph().validate(&layers()).unwrap();
    }

    #[test]
    fn stage_partitioning_breaks_at_source_layers_and_keyby() {
        let g = eval_graph();
        let stages = g.stages();
        // [source]@edge | [filter]@edge | [key_by]@site | [window]@site | [map, sink]@cloud
        assert_eq!(stages.len(), 5);
        assert_eq!(stages[0].ops, vec![0]);
        assert!(stages[0].is_source());
        assert_eq!(stages[1].ops, vec![1]);
        assert_eq!(stages[1].layer, "edge");
        assert_eq!(stages[2].ops, vec![2]);
        assert_eq!(stages[3].ops, vec![3]);
        assert_eq!(stages[4].ops, vec![4, 5]);
        // FlowUnit indices: edge=0, site=1, cloud=2
        assert_eq!(stages[0].unit_index, 0);
        assert_eq!(stages[1].unit_index, 0);
        assert_eq!(stages[2].unit_index, 1);
        assert_eq!(stages[3].unit_index, 1);
        assert_eq!(stages[4].unit_index, 2);
    }

    #[test]
    fn keyby_edge_is_hash_routed() {
        let g = eval_graph();
        let stages = g.stages();
        assert_eq!(g.edge_routing(&stages[0]), crate::channels::Routing::RoundRobin);
        assert_eq!(g.edge_routing(&stages[1]), crate::channels::Routing::RoundRobin);
        assert_eq!(g.edge_routing(&stages[2]), crate::channels::Routing::Hash);
        assert_eq!(g.edge_routing(&stages[3]), crate::channels::Routing::RoundRobin);
    }

    #[test]
    fn constraint_change_breaks_stage() {
        let mut g = LogicalGraph::default();
        g.push(
            OpKind::Source(SourceKind::Synthetic {
                total: 1,
                gen: Arc::new(|_, _| Value::Null),
                rate: None,
            }),
            "cloud".into(),
            None,
            "src",
        );
        g.push(OpKind::Map(Arc::new(|v| v)), "cloud".into(), None, "m1");
        let c = ConstraintExpr::parse("gpu = yes").unwrap();
        g.push(OpKind::Map(Arc::new(|v| v)), "cloud".into(), Some(c), "m2-gpu");
        g.push(OpKind::Sink(SinkKind::Discard), "cloud".into(), None, "sink");
        let stages = g.stages();
        assert_eq!(stages.len(), 4); // [src] [m1] [m2-gpu] [sink]
        assert_eq!(stages[2].constraint.as_ref().unwrap().to_string(), "gpu = yes");
        // all same layer -> one FlowUnit
        assert!(stages.iter().all(|s| s.unit_index == 0));
    }

    #[test]
    fn rejects_outward_flow() {
        let mut g = LogicalGraph::default();
        g.push(
            OpKind::Source(SourceKind::Synthetic {
                total: 1,
                gen: Arc::new(|_, _| Value::Null),
                rate: None,
            }),
            "cloud".into(),
            None,
            "src",
        );
        g.push(OpKind::Sink(SinkKind::Discard), "edge".into(), None, "sink");
        assert!(g.validate(&layers()).is_err());
    }

    #[test]
    fn rejects_missing_source_or_sink() {
        let mut g = LogicalGraph::default();
        g.push(OpKind::Map(Arc::new(|v| v)), "edge".into(), None, "m");
        assert!(g.validate(&layers()).is_err());

        let mut g = LogicalGraph::default();
        g.push(
            OpKind::Source(SourceKind::Synthetic {
                total: 1,
                gen: Arc::new(|_, _| Value::Null),
                rate: None,
            }),
            "edge".into(),
            None,
            "src",
        );
        g.push(OpKind::Map(Arc::new(|v| v)), "edge".into(), None, "m");
        assert!(g.validate(&layers()).is_err());
    }

    #[test]
    fn rejects_bad_window() {
        let mut g = LogicalGraph::default();
        g.push(
            OpKind::Source(SourceKind::Synthetic {
                total: 1,
                gen: Arc::new(|_, _| Value::Null),
                rate: None,
            }),
            "edge".into(),
            None,
            "src",
        );
        g.push(
            OpKind::Window {
                size: 4,
                slide: 8,
                agg: WindowAgg::Mean,
            },
            "edge".into(),
            None,
            "w",
        );
        g.push(OpKind::Sink(SinkKind::Discard), "edge".into(), None, "sink");
        assert!(g.validate(&layers()).is_err());
    }

    #[test]
    fn rejects_unknown_layer() {
        let mut g = LogicalGraph::default();
        g.push(
            OpKind::Source(SourceKind::Synthetic {
                total: 1,
                gen: Arc::new(|_, _| Value::Null),
                rate: None,
            }),
            "fog".into(),
            None,
            "src",
        );
        g.push(OpKind::Sink(SinkKind::Discard), "fog".into(), None, "sink");
        assert!(g.validate(&layers()).is_err());
    }

    #[test]
    fn single_layer_graph_is_one_unit() {
        let mut g = LogicalGraph::default();
        g.push(
            OpKind::Source(SourceKind::Synthetic {
                total: 1,
                gen: Arc::new(|_, _| Value::Null),
                rate: None,
            }),
            "cloud".into(),
            None,
            "src",
        );
        g.push(OpKind::Map(Arc::new(|v| v)), "cloud".into(), None, "m");
        g.push(OpKind::Sink(SinkKind::Collect), "cloud".into(), None, "sink");
        g.validate(&layers()).unwrap();
        let stages = g.stages();
        assert_eq!(stages.len(), 2); // [src] | [m, sink]
        assert!(stages.iter().all(|s| s.unit_index == 0));
    }
}
