//! FlowUnits CLI — single-process leader and distributed entrypoints.
//!
//! ```text
//! flowunits plan        --cluster cluster.fu [--planner flowunits|renoir] [--locations L1,L2]
//! flowunits run         --pipeline eval|acme|wordcount [--planner ...] [--events N] [--bw 100Mbit] [--lat 10ms]
//! flowunits fig3        [--events N]            # full Fig. 3 heatmap sweep
//! flowunits coordinator --listen /tmp/fu.sock --workers 2 --pipeline wordcount [--events N]
//! flowunits worker      --connect /tmp/fu.sock --id w1 [--zone cloud] [--hosts h1,h2]
//! ```
//!
//! `coordinator` + `worker` run one logical job across real OS processes:
//! see the transport module docs and the README's "Distributed
//! deployment" section.

use flowunits::api::raw::{JobConfig, PlannerKind, StreamContext};
use flowunits::config::{eval_cluster, ClusterSpec};
use flowunits::metrics::MetricsRegistry;
use flowunits::netsim::LinkSpec;
use flowunits::pipelines;
use flowunits::transport::daemon::CoordinatorDaemon;
use flowunits::transport::socket::Addr;
use flowunits::transport::worker::{run_worker, WorkerOpts};
use std::time::Duration;

fn main() {
    let args: Vec<String> = std::env::args().skip(1).collect();
    let cmd = args.first().map(|s| s.as_str()).unwrap_or("help");
    let result = match cmd {
        "plan" => cmd_plan(&args[1..]),
        "run" => cmd_run(&args[1..]),
        "fig3" => cmd_fig3(&args[1..]),
        "coordinator" => cmd_coordinator(&args[1..]),
        "worker" => cmd_worker(&args[1..]),
        _ => {
            print_help();
            Ok(())
        }
    };
    if let Err(e) = result {
        eprintln!("error: {e}");
        std::process::exit(1);
    }
}

fn print_help() {
    println!(
        "flowunits — dataflow for the edge-to-cloud continuum\n\n\
         USAGE:\n  flowunits plan --cluster <file> [--planner flowunits|renoir] [--locations L1,L2]\n  \
         flowunits run  --pipeline {names} [--planner ...] [--events N] [--bw 100Mbit] [--lat 10ms] [--show-collected]\n  \
         flowunits fig3 [--events N]\n  \
         flowunits coordinator --listen <addr> [--workers N] [--pipeline {names}] [--events N]\n                        \
         [--heartbeat-ms MS] [--checkpoint-ms MS] [--timeout-s S] [--data-dir DIR] [--show-collected]\n  \
         flowunits worker --connect <addr> --id <worker-id> [--zone Z] [--hosts h1,h2] [--state-dir DIR]\n\n\
         Addresses containing '/' are Unix domain socket paths; anything else is host:port TCP.\n",
        names = pipelines::NAMES.join("|"),
    );
}

fn flag<'a>(args: &'a [String], name: &str) -> Option<&'a str> {
    args.iter()
        .position(|a| a == name)
        .and_then(|i| args.get(i + 1))
        .map(|s| s.as_str())
}

fn has_flag(args: &[String], name: &str) -> bool {
    args.iter().any(|a| a == name)
}

fn parse_planner(args: &[String]) -> PlannerKind {
    match flag(args, "--planner") {
        Some("renoir") => PlannerKind::Renoir,
        _ => PlannerKind::FlowUnits,
    }
}

fn parse_link(args: &[String]) -> LinkSpec {
    LinkSpec {
        bandwidth_bps: flag(args, "--bw")
            .and_then(flowunits::util::parse_bandwidth)
            .unwrap_or(None),
        latency: flag(args, "--lat")
            .and_then(flowunits::util::parse_duration)
            .unwrap_or(Duration::ZERO),
    }
}

fn cmd_plan(args: &[String]) -> flowunits::error::Result<()> {
    let cluster = match flag(args, "--cluster") {
        Some(path) => ClusterSpec::load(path)?,
        None => eval_cluster(None, Duration::ZERO),
    };
    let planner = parse_planner(args);
    let locations: Vec<String> = flag(args, "--locations")
        .map(|s| s.split(',').map(|x| x.trim().to_string()).collect())
        .unwrap_or_default();
    let mut ctx = StreamContext::new(cluster.clone(), JobConfig::default());
    pipelines::build(&mut ctx, "eval", 1_000_000)?;
    let graph = ctx.into_graph()?;
    let plan = flowunits::placement::plan(&graph, &cluster, planner, &locations, false)?;
    println!("{}", plan.describe(&graph));
    Ok(())
}

fn cmd_run(args: &[String]) -> flowunits::error::Result<()> {
    let planner = parse_planner(args);
    let events: u64 = flag(args, "--events")
        .and_then(|s| s.parse().ok())
        .unwrap_or(1_000_000);
    let link = parse_link(args);
    let pipeline = flag(args, "--pipeline").unwrap_or("eval");
    let mut cluster = match flag(args, "--cluster") {
        Some(path) => ClusterSpec::load(path)?,
        None => eval_cluster(link.bandwidth_bps, link.latency),
    };
    cluster.set_uniform_links(link.clone());
    let config = JobConfig {
        planner,
        ..Default::default()
    };
    let mut ctx = StreamContext::new(cluster.clone(), config);
    pipelines::build(&mut ctx, pipeline, events)?;
    let report = ctx.execute()?;
    println!(
        "pipeline={pipeline} planner={planner:?} link={} events={events}",
        link.describe()
    );
    println!("{}", report.render());
    if has_flag(args, "--show-collected") {
        for line in pipelines::render_collected(&report.collected) {
            println!("{line}");
        }
    }
    Ok(())
}

fn cmd_fig3(args: &[String]) -> flowunits::error::Result<()> {
    let events: u64 = flag(args, "--events")
        .and_then(|s| s.parse().ok())
        .unwrap_or(200_000);
    let bandwidths: [(Option<u64>, &str); 4] = [
        (None, "unlimited"),
        (Some(1_000_000_000), "1Gbit"),
        (Some(100_000_000), "100Mbit"),
        (Some(10_000_000), "10Mbit"),
    ];
    let latencies = [
        (Duration::ZERO, "0ms"),
        (Duration::from_millis(10), "10ms"),
        (Duration::from_millis(100), "100ms"),
    ];
    println!("Fig. 3 — execution time ratio Renoir/FlowUnits, {events} events");
    println!("{:<12} {:<8} {:>10} {:>12} {:>8}", "bandwidth", "latency", "renoir(s)", "flowunits(s)", "ratio");
    for (bw, bwname) in bandwidths {
        for (lat, latname) in latencies {
            let mut times = [0.0f64; 2];
            for (i, planner) in [PlannerKind::Renoir, PlannerKind::FlowUnits].iter().enumerate() {
                let cluster = eval_cluster(bw, lat);
                let config = JobConfig {
                    planner: *planner,
                    ..Default::default()
                };
                let mut ctx = StreamContext::new(cluster, config);
                pipelines::build(&mut ctx, "eval", events)?;
                let report = ctx.execute()?;
                times[i] = report.wall_time.as_secs_f64();
            }
            println!(
                "{:<12} {:<8} {:>10.3} {:>12.3} {:>8.2}",
                bwname,
                latname,
                times[0],
                times[1],
                times[0] / times[1]
            );
        }
    }
    Ok(())
}

fn cmd_coordinator(args: &[String]) -> flowunits::error::Result<()> {
    let listen = flag(args, "--listen").ok_or_else(|| {
        flowunits::error::Error::Transport("coordinator requires --listen <addr>".into())
    })?;
    let mut workers: usize = flag(args, "--workers")
        .and_then(|s| s.parse().ok())
        .unwrap_or(2);
    let mut pipeline = flag(args, "--pipeline").unwrap_or("wordcount").to_string();
    let mut events: u64 = flag(args, "--events")
        .and_then(|s| s.parse().ok())
        .unwrap_or(60_000);
    let heartbeat = Duration::from_millis(
        flag(args, "--heartbeat-ms")
            .and_then(|s| s.parse().ok())
            .unwrap_or(500),
    );
    let timeout = Duration::from_secs(
        flag(args, "--timeout-s")
            .and_then(|s| s.parse().ok())
            .unwrap_or(60),
    );
    let mut checkpoint = flag(args, "--checkpoint-ms")
        .and_then(|s| s.parse().ok())
        .filter(|&ms: &u64| ms > 0)
        .map(Duration::from_millis);
    let mut daemon =
        CoordinatorDaemon::start(Addr::parse(listen), heartbeat, MetricsRegistry::new())?;
    if let Some(dir) = flag(args, "--data-dir") {
        daemon.set_data_dir(dir);
        // a manifest here means a previous coordinator died mid-job:
        // resume that job (its parameters win over the flags)
        if let Some(m) = daemon.pending_job() {
            println!(
                "resuming interrupted job from {dir}: pipeline={} events={} workers={}",
                m.pipeline, m.events, m.workers
            );
            pipeline = m.pipeline;
            events = m.events;
            workers = m.workers;
            checkpoint = (m.checkpoint_ms > 0).then(|| Duration::from_millis(m.checkpoint_ms));
        }
    }
    daemon.set_checkpoint_interval(checkpoint);
    println!("coordinator listening on {} — waiting for {workers} worker(s)", daemon.addr());
    let outcome = daemon.run_job(&pipeline, events, workers, timeout);
    daemon.shutdown_workers();
    // give GOODBYEs a moment to land before tearing the listener down
    std::thread::sleep(Duration::from_millis(200));
    daemon.shutdown();
    let report = outcome?;
    println!("pipeline={pipeline} events={events}");
    print!("{}", report.render());
    if has_flag(args, "--show-collected") {
        for line in pipelines::render_collected(&report.collected) {
            println!("{line}");
        }
    }
    Ok(())
}

fn cmd_worker(args: &[String]) -> flowunits::error::Result<()> {
    let connect = flag(args, "--connect").ok_or_else(|| {
        flowunits::error::Error::Transport("worker requires --connect <addr>".into())
    })?;
    let id = flag(args, "--id")
        .map(str::to_string)
        .unwrap_or_else(|| format!("worker-{}", std::process::id()));
    let mut opts = WorkerOpts::new(Addr::parse(connect), &id);
    if let Some(zone) = flag(args, "--zone") {
        opts.zone = zone.to_string();
    }
    if let Some(hosts) = flag(args, "--hosts") {
        opts.hosts = hosts.split(',').map(|h| h.trim().to_string()).collect();
    }
    if let Some(dir) = flag(args, "--state-dir") {
        opts.state_dir = dir.into();
    }
    opts.install_signals = true;
    eprintln!("worker '{id}' connecting to {connect}");
    run_worker(opts)?;
    eprintln!("worker '{id}' exited cleanly");
    Ok(())
}
