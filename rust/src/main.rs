//! FlowUnits CLI — the leader entrypoint.
//!
//! ```text
//! flowunits plan   --cluster cluster.fu [--planner flowunits|renoir] [--locations L1,L2]
//! flowunits run    --pipeline eval|acme|wordcount [--planner ...] [--events N] [--bw 100Mbit] [--lat 10ms]
//! flowunits fig3   [--events N]            # full Fig. 3 heatmap sweep
//! ```

use flowunits::api::raw::{JobConfig, PlannerKind, Source, StreamContext, WindowAgg};
use flowunits::config::{eval_cluster, ClusterSpec};
use flowunits::netsim::LinkSpec;
use flowunits::value::Value;
use std::time::Duration;

fn main() {
    let args: Vec<String> = std::env::args().skip(1).collect();
    let cmd = args.first().map(|s| s.as_str()).unwrap_or("help");
    let result = match cmd {
        "plan" => cmd_plan(&args[1..]),
        "run" => cmd_run(&args[1..]),
        "fig3" => cmd_fig3(&args[1..]),
        _ => {
            print_help();
            Ok(())
        }
    };
    if let Err(e) = result {
        eprintln!("error: {e}");
        std::process::exit(1);
    }
}

fn print_help() {
    println!(
        "flowunits — dataflow for the edge-to-cloud continuum\n\n\
         USAGE:\n  flowunits plan --cluster <file> [--planner flowunits|renoir] [--locations L1,L2]\n  \
         flowunits run  --pipeline eval|acme|wordcount [--planner ...] [--events N] [--bw 100Mbit] [--lat 10ms]\n  \
         flowunits fig3 [--events N]\n"
    );
}

fn flag<'a>(args: &'a [String], name: &str) -> Option<&'a str> {
    args.iter()
        .position(|a| a == name)
        .and_then(|i| args.get(i + 1))
        .map(|s| s.as_str())
}

fn parse_planner(args: &[String]) -> PlannerKind {
    match flag(args, "--planner") {
        Some("renoir") => PlannerKind::Renoir,
        _ => PlannerKind::FlowUnits,
    }
}

fn parse_link(args: &[String]) -> LinkSpec {
    LinkSpec {
        bandwidth_bps: flag(args, "--bw")
            .and_then(flowunits::util::parse_bandwidth)
            .unwrap_or(None),
        latency: flag(args, "--lat")
            .and_then(flowunits::util::parse_duration)
            .unwrap_or(Duration::ZERO),
    }
}

fn cmd_plan(args: &[String]) -> flowunits::error::Result<()> {
    let cluster = match flag(args, "--cluster") {
        Some(path) => ClusterSpec::load(path)?,
        None => eval_cluster(None, Duration::ZERO),
    };
    let planner = parse_planner(args);
    let locations: Vec<String> = flag(args, "--locations")
        .map(|s| s.split(',').map(|x| x.trim().to_string()).collect())
        .unwrap_or_default();
    let graph = eval_pipeline_graph(&cluster, 1_000_000)?;
    let plan = flowunits::placement::plan(&graph, &cluster, planner, &locations, false)?;
    println!("{}", plan.describe(&graph));
    Ok(())
}

fn eval_pipeline_graph(
    cluster: &ClusterSpec,
    events: u64,
) -> flowunits::error::Result<flowunits::graph::LogicalGraph> {
    let mut ctx = StreamContext::new(cluster.clone(), JobConfig::default());
    build_eval_pipeline(&mut ctx, events);
    ctx.into_graph()
}

/// The paper's §V pipeline: O1 filters 67% at the edge, O2 windows+averages
/// at the site, O3 computes Collatz convergence steps in the cloud.
pub fn build_eval_pipeline(ctx: &mut StreamContext, events: u64) {
    ctx.stream(Source::synthetic(events, |inst, i| {
        Value::I64((inst as i64) << 32 | (i as i64 & 0xffff_ffff))
    }))
    .to_layer("edge")
    .filter(|v| v.as_i64().unwrap() % 3 == 0) // O1: keep 33%
    .to_layer("site")
    .key_by(|v| Value::I64(v.as_i64().unwrap() % 16))
    .window(100, WindowAgg::Mean) // O2
    .to_layer("cloud")
    .map(|v| {
        // O3: Collatz convergence steps of the window average
        let (_k, mean) = v.as_pair().expect("keyed window output");
        let mut n = (mean.as_f64().unwrap().abs() as u64).max(1);
        let mut steps = 0i64;
        while n != 1 {
            n = if n % 2 == 0 { n / 2 } else { 3 * n + 1 };
            steps += 1;
        }
        Value::I64(steps)
    })
    .collect_count();
}

fn cmd_run(args: &[String]) -> flowunits::error::Result<()> {
    let planner = parse_planner(args);
    let events: u64 = flag(args, "--events")
        .and_then(|s| s.parse().ok())
        .unwrap_or(1_000_000);
    let link = parse_link(args);
    let pipeline = flag(args, "--pipeline").unwrap_or("eval");
    let mut cluster = match flag(args, "--cluster") {
        Some(path) => ClusterSpec::load(path)?,
        None => eval_cluster(link.bandwidth_bps, link.latency),
    };
    cluster.set_uniform_links(link.clone());
    let config = JobConfig {
        planner,
        ..Default::default()
    };
    let mut ctx = StreamContext::new(cluster.clone(), config);
    match pipeline {
        "eval" => build_eval_pipeline(&mut ctx, events),
        "wordcount" => {
            let words = ["stream", "edge", "cloud", "site", "data", "flow"];
            ctx.stream(Source::synthetic(events, move |_, i| {
                Value::Str(words[(i % words.len() as u64) as usize].to_string())
            }))
            .to_layer("cloud")
            .group_by(|w| w.clone())
            .fold(Value::I64(0), |acc, _| {
                *acc = Value::I64(acc.as_i64().unwrap() + 1)
            })
            .collect_vec();
        }
        "acme" => {
            // Fig. 1 pipeline with the XLA anomaly model at the cloud
            ctx.stream(Source::synthetic(events, |inst, i| {
                let t = i as f64 * 0.01;
                let v = (t.sin() * 10.0 + 50.0) + ((i % 97) as f64) * 0.1 + inst as f64;
                Value::F64(v)
            }))
            .to_layer("edge")
            .filter(|v| v.as_f64().unwrap().is_finite())
            .to_layer("site")
            .key_by(|v| Value::I64((v.as_f64().unwrap() * 10.0) as i64 % 4))
            .window(32, WindowAgg::FeatureStats)
            .to_layer("cloud")
            .xla_map("anomaly_v1", 64, 5)
            .add_constraint("xla = yes")
            .collect_count();
        }
        other => {
            return Err(flowunits::error::Error::Runtime(format!(
                "unknown pipeline '{other}'"
            )))
        }
    }
    let report = ctx.execute()?;
    println!(
        "pipeline={pipeline} planner={planner:?} link={} events={events}",
        link.describe()
    );
    println!("{}", report.render());
    Ok(())
}

fn cmd_fig3(args: &[String]) -> flowunits::error::Result<()> {
    let events: u64 = flag(args, "--events")
        .and_then(|s| s.parse().ok())
        .unwrap_or(200_000);
    let bandwidths: [(Option<u64>, &str); 4] = [
        (None, "unlimited"),
        (Some(1_000_000_000), "1Gbit"),
        (Some(100_000_000), "100Mbit"),
        (Some(10_000_000), "10Mbit"),
    ];
    let latencies = [
        (Duration::ZERO, "0ms"),
        (Duration::from_millis(10), "10ms"),
        (Duration::from_millis(100), "100ms"),
    ];
    println!("Fig. 3 — execution time ratio Renoir/FlowUnits, {events} events");
    println!("{:<12} {:<8} {:>10} {:>12} {:>8}", "bandwidth", "latency", "renoir(s)", "flowunits(s)", "ratio");
    for (bw, bwname) in bandwidths {
        for (lat, latname) in latencies {
            let mut times = [0.0f64; 2];
            for (i, planner) in [PlannerKind::Renoir, PlannerKind::FlowUnits].iter().enumerate() {
                let cluster = eval_cluster(bw, lat);
                let config = JobConfig {
                    planner: *planner,
                    ..Default::default()
                };
                let mut ctx = StreamContext::new(cluster, config);
                build_eval_pipeline(&mut ctx, events);
                let report = ctx.execute()?;
                times[i] = report.wall_time.as_secs_f64();
            }
            println!(
                "{:<12} {:<8} {:>10.3} {:>12.3} {:>8.2}",
                bwname,
                latname,
                times[0],
                times[1],
                times[0] / times[1]
            );
        }
    }
    Ok(())
}
