//! The coordinator daemon: the control-plane half of distributed mode.
//!
//! One daemon process listens on a socket ([`Addr`]); worker processes
//! connect and REGISTER (worker id, zone, advertised hosts, pid). The
//! daemon plans a named pipeline over the shared evaluation cluster, maps
//! the plan's hosts onto registered workers, streams each worker a DEPLOY
//! frame, relays data-plane frames (DATA/EOS/EPOCH) between workers by
//! destination-instance ownership, and aggregates the per-worker REPORT
//! frames into one [`DistReport`].
//!
//! Liveness: every worker heartbeats at the interval the daemon announces
//! in WELCOME. A worker is declared dead when its socket closes (reader
//! EOF — immediate) or when it misses three heartbeats (tick loop); a
//! death mid-job fails the active attempt with an error naming the worker
//! and broadcasts JOB_ERROR to the survivors, rather than hanging the
//! job. [`CoordinatorDaemon::run_job`] then redispatches the job over the
//! surviving workers (fresh job id, dead worker's hosts reassigned)
//! instead of surfacing the failure, as long as the deadline and attempt
//! budget allow.
//!
//! Coordinator death: with a data dir configured
//! ([`CoordinatorDaemon::set_data_dir`]), every dispatch persists a
//! [`JobManifest`] next to the durable queue segments and removes it when
//! the job completes. A coordinator that is killed mid-job leaves the
//! manifest behind; on restart, [`JobManifest::load`] recovers the
//! interrupted job's parameters, the workers reconnect with backoff and
//! re-REGISTER (the dead-id re-adoption path), and the job is re-run —
//! pipelines are deterministic, so the rerun's output is identical, and
//! queue-backed units resume from their last committed checkpoint via the
//! durable broker (see [`crate::coordinator`]).

use super::socket::{Addr, Conn, ConnHandle, Listener, PeerSender};
use super::wire::{self, kv, kv_get};
use crate::api::raw::{JobConfig, StreamContext};
use crate::config::eval_cluster;
use crate::error::{Error, Result};
use crate::metrics::{Metrics, MetricsRegistry};
use crate::placement::{plan as make_plan, PlannerKind};
use crate::value::Value;
use std::collections::{BTreeSet, HashMap};
use std::path::{Path, PathBuf};
use std::sync::atomic::{AtomicBool, AtomicU64, Ordering};
use std::sync::{Arc, Condvar, Mutex, MutexGuard};
use std::thread::JoinHandle;
use std::time::{Duration, Instant};

/// One registered worker, as the daemon tracks it.
struct WorkerEntry {
    zone: String,
    hosts: Vec<String>,
    sender: PeerSender,
    handle: ConnHandle,
    last_seen: Instant,
    alive: bool,
}

/// Per-worker slice of a finished job.
struct WorkerReport {
    events_in: u64,
    events_out: u64,
    collected: Vec<Value>,
}

/// The one active job (the daemon runs jobs serially).
struct JobState {
    id: u64,
    /// Destination instance → owning worker id (drives the relay).
    owner_of: HashMap<usize, String>,
    /// Workers that own at least one instance.
    expected: BTreeSet<String>,
    reports: HashMap<String, WorkerReport>,
    failed: Option<String>,
}

/// Total dispatch attempts per [`CoordinatorDaemon::run_job`] call: the
/// initial deploy plus up to two redispatches after worker deaths.
const DISPATCH_ATTEMPTS: u32 = 3;

struct Shared {
    metrics: Metrics,
    heartbeat: Duration,
    /// Checkpoint interval shipped to workers inside DEPLOY (0 = off).
    checkpoint_ms: AtomicU64,
    stop: AtomicBool,
    workers: Mutex<HashMap<String, WorkerEntry>>,
    reg_cv: Condvar,
    job: Mutex<Option<JobState>>,
    job_cv: Condvar,
    next_job: AtomicU64,
    readers: Mutex<Vec<JoinHandle<()>>>,
    handles: Mutex<Vec<ConnHandle>>,
}

impl Shared {
    fn lock_workers(&self) -> MutexGuard<'_, HashMap<String, WorkerEntry>> {
        self.workers.lock().unwrap_or_else(|p| p.into_inner())
    }

    fn lock_job(&self) -> MutexGuard<'_, Option<JobState>> {
        self.job.lock().unwrap_or_else(|p| p.into_inner())
    }

    /// Marks the active job failed (if `job` is still the active one) and
    /// broadcasts JOB_ERROR to its surviving workers.
    fn fail_active_job(&self, job: u64, reason: String) {
        let expected: Vec<String> = {
            let mut st = self.lock_job();
            match st.as_mut() {
                Some(j) if j.id == job && j.failed.is_none() => {
                    j.failed = Some(reason.clone());
                    j.expected.iter().cloned().collect()
                }
                _ => return,
            }
        };
        self.job_cv.notify_all();
        MetricsRegistry::add(&self.metrics.transport_errors, 1);
        let payload = kv(vec![
            ("job", Value::I64(job as i64)),
            ("reason", Value::Str(reason)),
        ]);
        let senders: Vec<PeerSender> = {
            let ws = self.lock_workers();
            expected
                .iter()
                .filter_map(|id| ws.get(id).filter(|e| e.alive).map(|e| e.sender.clone()))
                .collect()
        };
        for s in senders {
            let _ = s.send_ctl(wire::kind::JOB_ERROR, &payload);
        }
    }

    /// Handles a worker's socket closing (EOF, error, GOODBYE, or severed
    /// by the tick loop): marks it dead and fails the active job if the
    /// worker still owed a report.
    fn worker_disconnected(&self, id: &str) {
        {
            let mut ws = self.lock_workers();
            match ws.get_mut(id) {
                Some(e) if e.alive => {
                    e.alive = false;
                    e.handle.shutdown();
                }
                _ => return,
            }
        }
        self.reg_cv.notify_all();
        let owing = {
            let st = self.lock_job();
            match &*st {
                Some(j)
                    if j.failed.is_none()
                        && j.expected.contains(id)
                        && !j.reports.contains_key(id) =>
                {
                    Some(j.id)
                }
                _ => None,
            }
        };
        if let Some(job) = owing {
            self.fail_active_job(
                job,
                format!("worker '{id}' died mid-job (socket closed or heartbeats missed)"),
            );
        }
    }

    fn note_recv(&self, payload_len: usize) {
        MetricsRegistry::add(&self.metrics.transport_frames_recv, 1);
        MetricsRegistry::add(
            &self.metrics.transport_bytes_recv,
            wire::frame_len(payload_len) as u64,
        );
    }
}

/// Aggregated result of one distributed job.
#[derive(Debug)]
pub struct DistReport {
    /// Wall-clock time from deploy to the last report.
    pub wall_time: Duration,
    /// Events produced by sources, summed over workers.
    pub events_in: u64,
    /// Events delivered to sinks, summed over workers.
    pub events_out: u64,
    /// Values gathered by collect sinks, concatenated over workers.
    pub collected: Vec<Value>,
    /// Sorted ids of the workers that participated.
    pub workers: Vec<String>,
}

impl DistReport {
    /// Renders the report (collected values are rendered separately via
    /// [`crate::pipelines::render_collected`] so they stay diffable).
    pub fn render(&self) -> String {
        format!(
            "distributed job: {} worker(s) [{}]\nwall time        : {:?}\nevents in / out  : {} / {}\ncollected values : {}\n",
            self.workers.len(),
            self.workers.join(", "),
            self.wall_time,
            self.events_in,
            self.events_out,
            self.collected.len()
        )
    }
}

/// On-disk record of a dispatched-but-unfinished job, written into the
/// coordinator's data dir (next to any durable queue segments) at every
/// dispatch and removed when the job completes. A restarted coordinator
/// finds the file, re-adopts the reconnecting workers, and re-runs the
/// interrupted job with these parameters.
///
/// The format is deliberately plain — one `key=value` per line — so an
/// operator can read it with `cat` while deciding whether to resume.
#[derive(Debug, Clone, PartialEq, Eq)]
pub struct JobManifest {
    /// Named pipeline being run (see [`crate::pipelines::NAMES`]).
    pub pipeline: String,
    /// Source event budget.
    pub events: u64,
    /// Checkpoint interval shipped to workers in DEPLOY (0 = off).
    pub checkpoint_ms: u64,
    /// Number of workers the job was dispatched over.
    pub workers: usize,
    /// host→worker assignment at dispatch time (informational: a resumed
    /// run recomputes the assignment over whichever workers re-register).
    pub assign: Vec<(String, String)>,
}

impl JobManifest {
    /// The manifest file inside `dir`.
    pub fn path(dir: &Path) -> PathBuf {
        dir.join("job.manifest")
    }

    /// Loads the manifest from `dir`, if one exists and parses.
    pub fn load(dir: &Path) -> Option<JobManifest> {
        let s = std::fs::read_to_string(Self::path(dir)).ok()?;
        let mut m = JobManifest {
            pipeline: String::new(),
            events: 0,
            checkpoint_ms: 0,
            workers: 0,
            assign: Vec::new(),
        };
        for line in s.lines() {
            let Some((k, v)) = line.split_once('=') else { continue };
            match k {
                "pipeline" => m.pipeline = v.to_string(),
                "events" => m.events = v.parse().ok()?,
                "checkpoint_ms" => m.checkpoint_ms = v.parse().ok()?,
                "workers" => m.workers = v.parse().ok()?,
                "assign" => {
                    for pair in v.split(',').filter(|p| !p.is_empty()) {
                        let (h, w) = pair.split_once(':')?;
                        m.assign.push((h.to_string(), w.to_string()));
                    }
                }
                _ => {}
            }
        }
        if m.pipeline.is_empty() || m.workers == 0 {
            return None;
        }
        Some(m)
    }

    /// Writes the manifest into `dir` (creating it). Write-then-rename,
    /// so a crash mid-save leaves either the old manifest or the new one,
    /// never a torn file.
    pub fn save(&self, dir: &Path) -> Result<()> {
        std::fs::create_dir_all(dir)
            .map_err(|e| Error::Transport(format!("create data dir {}: {e}", dir.display())))?;
        let body = format!(
            "pipeline={}\nevents={}\ncheckpoint_ms={}\nworkers={}\nassign={}\n",
            self.pipeline,
            self.events,
            self.checkpoint_ms,
            self.workers,
            self.assign
                .iter()
                .map(|(h, w)| format!("{h}:{w}"))
                .collect::<Vec<_>>()
                .join(","),
        );
        let tmp = dir.join("job.manifest.tmp");
        std::fs::write(&tmp, body)
            .map_err(|e| Error::Transport(format!("write {}: {e}", tmp.display())))?;
        std::fs::rename(&tmp, Self::path(dir))
            .map_err(|e| Error::Transport(format!("publish job manifest: {e}")))
    }

    /// Removes the manifest (the job completed). Missing files are fine.
    pub fn remove(dir: &Path) {
        let _ = std::fs::remove_file(Self::path(dir));
    }
}

/// The coordinator daemon. See the module docs for the protocol.
pub struct CoordinatorDaemon {
    addr: Addr,
    shared: Arc<Shared>,
    /// When set, dispatches persist a [`JobManifest`] here.
    data_dir: Option<PathBuf>,
    accept: Option<JoinHandle<()>>,
    tick: Option<JoinHandle<()>>,
}

impl CoordinatorDaemon {
    /// Binds `addr` and starts the accept and liveness-tick threads.
    pub fn start(addr: Addr, heartbeat: Duration, metrics: Metrics) -> Result<CoordinatorDaemon> {
        let listener = Listener::bind(&addr)?;
        // Job ids are seeded from the wall clock so they never collide
        // across coordinator incarnations: after a restart, a worker's
        // stale in-flight frames (tagged with the dead predecessor's job
        // id) must not demux into the successor's deterministically
        // identical instance ids. Masked to 31 bits because data-plane
        // frames carry the id as a u32 (leaving 2^31 increments of
        // headroom before any truncation mismatch with DEPLOY's u64).
        let job_seed = std::time::SystemTime::now()
            .duration_since(std::time::UNIX_EPOCH)
            .map(|d| d.as_millis() as u32 & 0x7fff_ffff)
            .unwrap_or(1)
            .max(1) as u64;
        let shared = Arc::new(Shared {
            metrics,
            heartbeat,
            checkpoint_ms: AtomicU64::new(0),
            stop: AtomicBool::new(false),
            workers: Mutex::new(HashMap::new()),
            reg_cv: Condvar::new(),
            job: Mutex::new(None),
            job_cv: Condvar::new(),
            next_job: AtomicU64::new(job_seed),
            readers: Mutex::new(Vec::new()),
            handles: Mutex::new(Vec::new()),
        });
        let s2 = shared.clone();
        let accept = std::thread::Builder::new()
            .name("daemon-accept".into())
            .spawn(move || accept_loop(listener, s2))
            .map_err(|e| Error::Transport(format!("spawn accept thread: {e}")))?;
        let s3 = shared.clone();
        let tick = std::thread::Builder::new()
            .name("daemon-tick".into())
            .spawn(move || tick_loop(s3))
            .map_err(|e| Error::Transport(format!("spawn tick thread: {e}")))?;
        Ok(CoordinatorDaemon {
            addr,
            shared,
            data_dir: None,
            accept: Some(accept),
            tick: Some(tick),
        })
    }

    /// Sets the directory where dispatches persist a [`JobManifest`]
    /// (and where a prior incarnation may have left one behind). Takes
    /// effect for jobs dispatched after the call.
    pub fn set_data_dir(&mut self, dir: impl Into<PathBuf>) {
        self.data_dir = Some(dir.into());
    }

    /// The interrupted job a dead predecessor left behind in the data
    /// dir, if any. Re-run it with [`CoordinatorDaemon::run_job`] to
    /// resume; completion removes the manifest.
    pub fn pending_job(&self) -> Option<JobManifest> {
        JobManifest::load(self.data_dir.as_deref()?)
    }

    /// The address the daemon listens on.
    pub fn addr(&self) -> &Addr {
        &self.addr
    }

    /// The daemon's metrics registry (socket traffic, reconnects, errors).
    pub fn metrics(&self) -> Metrics {
        self.shared.metrics.clone()
    }

    /// Sets the checkpoint interval shipped to workers inside DEPLOY
    /// (`None` disables periodic checkpoints). Takes effect for jobs
    /// dispatched after the call.
    pub fn set_checkpoint_interval(&self, interval: Option<Duration>) {
        let ms = interval.map_or(0, |d| d.as_millis() as u64);
        self.shared.checkpoint_ms.store(ms, Ordering::SeqCst);
    }

    /// Registered workers as `(id, zone, alive)`, sorted by id.
    pub fn workers(&self) -> Vec<(String, String, bool)> {
        let ws = self.shared.lock_workers();
        let mut out: Vec<(String, String, bool)> = ws
            .iter()
            .map(|(id, e)| (id.clone(), e.zone.clone(), e.alive))
            .collect();
        out.sort();
        out
    }

    /// Blocks until at least `n` workers are registered and alive.
    pub fn wait_for_workers(&self, n: usize, timeout: Duration) -> Result<()> {
        let deadline = Instant::now() + timeout;
        let mut ws = self.shared.lock_workers();
        loop {
            let alive = ws.values().filter(|e| e.alive).count();
            if alive >= n {
                return Ok(());
            }
            let now = Instant::now();
            if now >= deadline {
                return Err(Error::Transport(format!(
                    "only {alive}/{n} workers registered within {timeout:?}"
                )));
            }
            ws = match self.shared.reg_cv.wait_timeout(ws, deadline - now) {
                Ok((g, _)) => g,
                Err(_) => return Err(Error::Transport("worker registry poisoned".into())),
            };
        }
    }

    /// Plans `pipeline` over the shared evaluation cluster, deploys it
    /// across `n_workers` registered workers, and waits for every report.
    ///
    /// The host→worker assignment honors hosts a worker advertised at
    /// registration; unclaimed hosts are assigned round-robin. The same
    /// assignment ships to every worker inside DEPLOY, so all processes
    /// agree on instance ownership without a second round-trip.
    ///
    /// A worker death mid-job does not fail the run outright: the job is
    /// redispatched under a fresh id over the surviving workers, with the
    /// dead worker's hosts reassigned. Pipelines are deterministic, so a
    /// rerun produces identical output. Up to three total attempts are
    /// made within the original `timeout`.
    pub fn run_job(
        &self,
        pipeline: &str,
        events: u64,
        n_workers: usize,
        timeout: Duration,
    ) -> Result<DistReport> {
        self.wait_for_workers(n_workers, timeout)?;
        let deadline = Instant::now() + timeout;
        let mut attempt = 0;
        loop {
            attempt += 1;
            let err = match self.run_job_attempt(pipeline, events, deadline) {
                Ok(report) => {
                    if let Some(dir) = &self.data_dir {
                        JobManifest::remove(dir);
                    }
                    return Ok(report);
                }
                Err(e) => e,
            };
            let msg = err.to_string();
            let retryable = msg.contains("died mid-job") || msg.contains("deploy to worker");
            let survivors = self
                .shared
                .lock_workers()
                .values()
                .filter(|e| e.alive)
                .count();
            if !retryable
                || survivors == 0
                || attempt >= DISPATCH_ATTEMPTS
                || Instant::now() >= deadline
            {
                return Err(err);
            }
            MetricsRegistry::add(&self.shared.metrics.recoveries, 1);
            // let survivors process the JOB_ERROR abort before redeploying
            std::thread::sleep(Duration::from_millis(100));
        }
    }

    /// One dispatch attempt: plan, assign, deploy, wait for reports.
    fn run_job_attempt(
        &self,
        pipeline: &str,
        events: u64,
        deadline: Instant,
    ) -> Result<DistReport> {
        let started = Instant::now();
        let cluster = eval_cluster(None, Duration::ZERO);
        let mut ctx = StreamContext::new(cluster.clone(), JobConfig::default());
        crate::pipelines::build(&mut ctx, pipeline, events)?;
        let graph = ctx.into_graph()?;
        let plan = make_plan(&graph, &cluster, PlannerKind::FlowUnits, &[], false)?;

        // host → worker assignment over the currently-alive workers
        let hosts: Vec<String> = plan
            .instances
            .iter()
            .map(|i| i.host.clone())
            .collect::<BTreeSet<String>>()
            .into_iter()
            .collect();
        let (assign, owner_of, expected, deploy_to) = {
            let ws = self.shared.lock_workers();
            let ids: Vec<String> = ws
                .iter()
                .filter(|(_, e)| e.alive)
                .map(|(id, _)| id.clone())
                .collect::<BTreeSet<String>>()
                .into_iter()
                .collect();
            if ids.is_empty() {
                return Err(Error::Transport("no live workers to deploy to".into()));
            }
            let mut assign: Vec<(String, String)> = Vec::new();
            for (i, h) in hosts.iter().enumerate() {
                let claimed = ids
                    .iter()
                    .find(|id| ws.get(*id).is_some_and(|e| e.hosts.iter().any(|x| x == h)));
                let w = claimed.unwrap_or(&ids[i % ids.len()]).clone();
                assign.push((h.clone(), w));
            }
            let by_host: HashMap<&str, &str> = assign
                .iter()
                .map(|(h, w)| (h.as_str(), w.as_str()))
                .collect();
            let mut owner_of = HashMap::new();
            let mut expected = BTreeSet::new();
            for inst in &plan.instances {
                let w = by_host[inst.host.as_str()].to_string();
                expected.insert(w.clone());
                owner_of.insert(inst.id, w);
            }
            let deploy_to: Vec<(String, PeerSender)> = expected
                .iter()
                .filter_map(|id| ws.get(id).map(|e| (id.clone(), e.sender.clone())))
                .collect();
            (assign, owner_of, expected, deploy_to)
        };

        // persist the dispatch before any worker sees it: if we die after
        // this point, our successor finds the manifest and re-runs the job
        if let Some(dir) = &self.data_dir {
            JobManifest {
                pipeline: pipeline.to_string(),
                events,
                checkpoint_ms: self.shared.checkpoint_ms.load(Ordering::SeqCst),
                workers: expected.len(),
                assign: assign.clone(),
            }
            .save(dir)?;
        }

        let job = self.shared.next_job.fetch_add(1, Ordering::SeqCst);
        *self.shared.lock_job() = Some(JobState {
            id: job,
            owner_of,
            expected: expected.clone(),
            reports: HashMap::new(),
            failed: None,
        });
        let payload = kv(vec![
            ("job", Value::I64(job as i64)),
            ("pipeline", Value::Str(pipeline.to_string())),
            ("events", Value::I64(events as i64)),
            (
                "checkpoint_ms",
                Value::I64(self.shared.checkpoint_ms.load(Ordering::SeqCst) as i64),
            ),
            (
                "assign",
                Value::List(
                    assign
                        .iter()
                        .map(|(h, w)| {
                            Value::pair(Value::Str(h.clone()), Value::Str(w.clone()))
                        })
                        .collect(),
                ),
            ),
        ]);
        for (id, sender) in &deploy_to {
            if sender.send_ctl(wire::kind::DEPLOY, &payload).is_err() {
                self.shared
                    .fail_active_job(job, format!("deploy to worker '{id}' failed"));
                break;
            }
        }

        // wait for every expected report (or failure, or timeout)
        let mut st = self.shared.lock_job();
        loop {
            let done = match &*st {
                Some(j) if j.id == job => {
                    j.failed.is_some() || j.expected.iter().all(|w| j.reports.contains_key(w))
                }
                _ => true,
            };
            if done {
                break;
            }
            let now = Instant::now();
            if now >= deadline {
                drop(st);
                self.shared
                    .fail_active_job(job, format!("job {job} timed out"));
                st = self.shared.lock_job();
                break;
            }
            st = match self.shared.job_cv.wait_timeout(st, deadline - now) {
                Ok((g, _)) => g,
                Err(_) => return Err(Error::Transport("job state poisoned".into())),
            };
        }
        let state = st.take();
        drop(st);
        let Some(mut state) = state else {
            return Err(Error::Transport("job state vanished mid-run".into()));
        };
        if let Some(reason) = state.failed {
            return Err(Error::Transport(reason));
        }
        let mut report = DistReport {
            wall_time: started.elapsed(),
            events_in: 0,
            events_out: 0,
            collected: Vec::new(),
            workers: expected.into_iter().collect(),
        };
        for id in &report.workers {
            if let Some(r) = state.reports.remove(id) {
                report.events_in += r.events_in;
                report.events_out += r.events_out;
                report.collected.extend(r.collected);
            }
        }
        Ok(report)
    }

    /// Sends SHUTDOWN to every live worker (graceful fleet teardown).
    pub fn shutdown_workers(&self) {
        let senders: Vec<PeerSender> = {
            let ws = self.shared.lock_workers();
            ws.values()
                .filter(|e| e.alive)
                .map(|e| e.sender.clone())
                .collect()
        };
        let empty = kv(vec![]);
        for s in senders {
            let _ = s.send_ctl(wire::kind::SHUTDOWN, &empty);
        }
    }

    /// Stops the daemon: severs every connection, unblocks the accept
    /// loop, and joins all service threads. Idempotent.
    pub fn shutdown(&mut self) {
        if self.shared.stop.swap(true, Ordering::SeqCst) {
            return;
        }
        for h in self
            .shared
            .handles
            .lock()
            .unwrap_or_else(|p| p.into_inner())
            .drain(..)
        {
            h.shutdown();
        }
        // unblock the accept loop with a throwaway connection
        let _ = Conn::connect(&self.addr, None);
        if let Some(h) = self.accept.take() {
            let _ = h.join();
        }
        if let Some(h) = self.tick.take() {
            let _ = h.join();
        }
        let readers: Vec<JoinHandle<()>> = self
            .shared
            .readers
            .lock()
            .unwrap_or_else(|p| p.into_inner())
            .drain(..)
            .collect();
        for h in readers {
            let _ = h.join();
        }
        #[cfg(unix)]
        if let Addr::Unix(p) = &self.addr {
            let _ = std::fs::remove_file(p);
        }
    }
}

impl Drop for CoordinatorDaemon {
    fn drop(&mut self) {
        self.shutdown();
    }
}

fn accept_loop(listener: Listener, shared: Arc<Shared>) {
    loop {
        match listener.accept(Some(shared.metrics.clone())) {
            Ok(conn) => {
                if shared.stop.load(Ordering::SeqCst) {
                    break;
                }
                if let Ok(h) = conn.handle() {
                    shared
                        .handles
                        .lock()
                        .unwrap_or_else(|p| p.into_inner())
                        .push(h);
                }
                let s2 = shared.clone();
                if let Ok(jh) = std::thread::Builder::new()
                    .name("daemon-conn".into())
                    .spawn(move || handle_conn(&s2, conn))
                {
                    shared
                        .readers
                        .lock()
                        .unwrap_or_else(|p| p.into_inner())
                        .push(jh);
                }
            }
            Err(_) => {
                if shared.stop.load(Ordering::SeqCst) {
                    break;
                }
                std::thread::sleep(Duration::from_millis(20));
            }
        }
    }
}

/// Liveness tick: a worker that misses three heartbeat intervals is
/// severed (its reader thread then runs the disconnect path). Lag past
/// one interval is recorded per worker in the labelled metrics.
fn tick_loop(shared: Arc<Shared>) {
    let step = Duration::from_millis(50);
    loop {
        let mut waited = Duration::ZERO;
        while waited < shared.heartbeat {
            if shared.stop.load(Ordering::SeqCst) {
                return;
            }
            std::thread::sleep(step.min(shared.heartbeat - waited));
            waited += step;
        }
        let mut dead = Vec::new();
        {
            let mut ws = shared.lock_workers();
            for (id, e) in ws.iter_mut() {
                if !e.alive {
                    continue;
                }
                let lag = e.last_seen.elapsed();
                if lag > shared.heartbeat {
                    MetricsRegistry::add(
                        &shared.metrics.counter(&format!("transport.hb_lag.{id}")),
                        1,
                    );
                }
                if lag > shared.heartbeat * 3 {
                    e.handle.shutdown();
                    dead.push(id.clone());
                }
            }
        }
        for id in dead {
            shared.worker_disconnected(&id);
        }
    }
}

/// Per-connection reader: handshake, then serve frames until the peer
/// disconnects.
fn handle_conn(shared: &Arc<Shared>, mut conn: Conn) {
    // --- handshake: first frame must be REGISTER ---------------------
    let first = match conn.reader.next_frame() {
        Ok(Some(f)) => f,
        _ => return,
    };
    shared.note_recv(first.payload.len());
    if first.kind != wire::kind::REGISTER {
        return;
    }
    let Ok(v) = wire::parse_ctl(&first.payload) else {
        let _ = conn.sender.send_ctl(
            wire::kind::REJECT,
            &kv(vec![("reason", Value::Str("malformed REGISTER".into()))]),
        );
        return;
    };
    let Some(id) = kv_get(&v, "worker").and_then(Value::as_str).map(String::from) else {
        let _ = conn.sender.send_ctl(
            wire::kind::REJECT,
            &kv(vec![("reason", Value::Str("REGISTER without worker id".into()))]),
        );
        return;
    };
    let zone = kv_get(&v, "zone")
        .and_then(Value::as_str)
        .unwrap_or("")
        .to_string();
    let hosts: Vec<String> = kv_get(&v, "hosts")
        .and_then(Value::as_list)
        .map(|l| l.iter().filter_map(|h| h.as_str().map(String::from)).collect())
        .unwrap_or_default();
    {
        let mut ws = shared.lock_workers();
        if ws.get(&id).is_some_and(|e| e.alive) {
            drop(ws);
            let _ = conn.sender.send_ctl(
                wire::kind::REJECT,
                &kv(vec![(
                    "reason",
                    Value::Str(format!("worker id '{id}' is already registered and alive")),
                )]),
            );
            return;
        }
        let readopted = ws.remove(&id).is_some();
        if readopted {
            MetricsRegistry::add(&shared.metrics.transport_reconnects, 1);
        }
        let Ok(handle) = conn.handle() else { return };
        ws.insert(
            id.clone(),
            WorkerEntry {
                zone,
                hosts,
                sender: conn.sender.clone(),
                handle,
                last_seen: Instant::now(),
                alive: true,
            },
        );
    }
    shared.reg_cv.notify_all();
    if conn
        .sender
        .send_ctl(
            wire::kind::WELCOME,
            &kv(vec![(
                "heartbeat_ms",
                Value::I64(shared.heartbeat.as_millis() as i64),
            )]),
        )
        .is_err()
    {
        shared.worker_disconnected(&id);
        return;
    }

    // --- serve -------------------------------------------------------
    loop {
        let f = match conn.reader.next_frame() {
            Ok(Some(f)) => f,
            _ => break,
        };
        shared.note_recv(f.payload.len());
        if let Some(e) = shared.lock_workers().get_mut(&id) {
            e.last_seen = Instant::now();
        }
        match f.kind {
            wire::kind::HEARTBEAT => {}
            wire::kind::DATA | wire::kind::EOS | wire::kind::EPOCH | wire::kind::WATERMARK => {
                relay(shared, f.kind, &f.payload);
            }
            wire::kind::REPORT => {
                if let Ok(v) = wire::parse_ctl(&f.payload) {
                    accept_report(shared, &v);
                }
            }
            wire::kind::JOB_ERROR => {
                if let Ok(v) = wire::parse_ctl(&f.payload) {
                    if let Some(job) = kv_get(&v, "job").and_then(Value::as_i64) {
                        let reason = kv_get(&v, "reason")
                            .and_then(Value::as_str)
                            .unwrap_or("worker-side job error")
                            .to_string();
                        shared.fail_active_job(job as u64, reason);
                    }
                }
            }
            wire::kind::GOODBYE => break,
            _ => {}
        }
    }
    shared.worker_disconnected(&id);
}

/// Relays one data-plane frame to the worker owning its destination
/// instance. Frames for a job that is no longer active are dropped.
fn relay(shared: &Arc<Shared>, kind: u8, payload: &[u8]) {
    let Ok((job, to, _rest)) = wire::parse_data(payload) else {
        MetricsRegistry::add(&shared.metrics.transport_errors, 1);
        return;
    };
    let owner = {
        let st = shared.lock_job();
        match &*st {
            Some(j) if j.id == job && j.failed.is_none() => j.owner_of.get(&to).cloned(),
            _ => None, // stale or unknown job: drop
        }
    };
    let Some(owner) = owner else { return };
    let sender = {
        let ws = shared.lock_workers();
        ws.get(&owner).filter(|e| e.alive).map(|e| e.sender.clone())
    };
    match sender {
        Some(s) => {
            if s.send(kind, payload).is_err() {
                MetricsRegistry::add(&shared.metrics.transport_errors, 1);
            }
        }
        None => MetricsRegistry::add(&shared.metrics.transport_errors, 1),
    }
}

fn accept_report(shared: &Arc<Shared>, v: &Value) {
    let (Some(job), Some(worker)) = (
        kv_get(v, "job").and_then(Value::as_i64),
        kv_get(v, "worker").and_then(Value::as_str),
    ) else {
        return;
    };
    let report = WorkerReport {
        events_in: kv_get(v, "events_in").and_then(Value::as_i64).unwrap_or(0) as u64,
        events_out: kv_get(v, "events_out").and_then(Value::as_i64).unwrap_or(0) as u64,
        collected: kv_get(v, "collected")
            .and_then(Value::as_list)
            .map(|l| l.to_vec())
            .unwrap_or_default(),
    };
    {
        let mut st = shared.lock_job();
        if let Some(j) = st.as_mut() {
            if j.id == job as u64 {
                j.reports.insert(worker.to_string(), report);
            }
        }
    }
    shared.job_cv.notify_all();
}

#[cfg(test)]
mod tests {
    use super::*;

    fn test_addr(tag: &str) -> Addr {
        let dir = std::env::temp_dir().join(format!("fu-daemon-{}-{tag}", std::process::id()));
        std::fs::create_dir_all(&dir).unwrap();
        Addr::parse(&dir.join("d.sock").to_string_lossy())
    }

    fn register(conn: &Conn, id: &str) {
        conn.sender
            .send_ctl(
                wire::kind::REGISTER,
                &kv(vec![
                    ("worker", Value::Str(id.into())),
                    ("zone", Value::Str("cloud".into())),
                    ("pid", Value::I64(std::process::id() as i64)),
                ]),
            )
            .unwrap();
    }

    #[cfg(unix)]
    #[test]
    fn duplicate_registration_is_rejected_and_death_reenables_the_id() {
        let metrics = MetricsRegistry::new();
        let mut daemon = CoordinatorDaemon::start(
            test_addr("dup"),
            Duration::from_millis(100),
            metrics.clone(),
        )
        .unwrap();
        let mut c1 = Conn::connect(daemon.addr(), None).unwrap();
        register(&c1, "w1");
        let f = c1.reader.next_frame().unwrap().unwrap();
        assert_eq!(f.kind, wire::kind::WELCOME);
        let hb = wire::parse_ctl(&f.payload).unwrap();
        assert_eq!(
            kv_get(&hb, "heartbeat_ms").and_then(Value::as_i64),
            Some(100)
        );

        // same id, live connection: rejected
        let mut c2 = Conn::connect(daemon.addr(), None).unwrap();
        register(&c2, "w1");
        let f = c2.reader.next_frame().unwrap().unwrap();
        assert_eq!(f.kind, wire::kind::REJECT);

        // first connection dies -> id becomes re-adoptable
        c1.shutdown();
        let t0 = Instant::now();
        while daemon.workers().iter().any(|(id, _, alive)| id == "w1" && *alive) {
            assert!(t0.elapsed() < Duration::from_secs(5), "death not detected");
            std::thread::sleep(Duration::from_millis(10));
        }
        let mut c3 = Conn::connect(daemon.addr(), None).unwrap();
        register(&c3, "w1");
        let f = c3.reader.next_frame().unwrap().unwrap();
        assert_eq!(f.kind, wire::kind::WELCOME, "dead id is re-adopted");
        assert_eq!(metrics.transport_reconnects.load(Ordering::Relaxed), 1);
        daemon.shutdown();
    }

    #[test]
    fn job_manifest_roundtrips_and_rejects_garbage() {
        let dir = std::env::temp_dir().join(format!("fu-manifest-{}", std::process::id()));
        let _ = std::fs::remove_dir_all(&dir);
        let m = JobManifest {
            pipeline: "wordcount".into(),
            events: 60_000,
            checkpoint_ms: 250,
            workers: 2,
            assign: vec![
                ("h1".into(), "w1".into()),
                ("h2".into(), "w2".into()),
            ],
        };
        m.save(&dir).unwrap();
        assert_eq!(JobManifest::load(&dir), Some(m.clone()));

        // an empty assignment still roundtrips
        let bare = JobManifest {
            assign: Vec::new(),
            ..m.clone()
        };
        bare.save(&dir).unwrap();
        assert_eq!(JobManifest::load(&dir), Some(bare));

        // garbage or incomplete manifests read as "no pending job"
        std::fs::write(JobManifest::path(&dir), "not a manifest at all\n").unwrap();
        assert_eq!(JobManifest::load(&dir), None);
        std::fs::write(JobManifest::path(&dir), "pipeline=wc\nevents=nope\n").unwrap();
        assert_eq!(JobManifest::load(&dir), None);

        m.save(&dir).unwrap();
        JobManifest::remove(&dir);
        assert_eq!(JobManifest::load(&dir), None, "removed manifest stays gone");
        let _ = std::fs::remove_dir_all(&dir);
    }

    #[cfg(unix)]
    #[test]
    fn run_job_without_workers_times_out_cleanly() {
        let mut daemon = CoordinatorDaemon::start(
            test_addr("nowork"),
            Duration::from_millis(100),
            MetricsRegistry::new(),
        )
        .unwrap();
        let err = daemon
            .run_job("wordcount", 60, 1, Duration::from_millis(200))
            .unwrap_err();
        assert!(err.to_string().contains("0/1 workers"), "{err}");
        daemon.shutdown();
    }
}
