//! The worker process: the execution half of distributed mode.
//!
//! `flowunits worker --connect <addr>` runs [`run_worker`]: it connects
//! to the coordinator daemon, REGISTERs (worker id, zone, advertised
//! hosts, pid), heartbeats at the interval the daemon announces, and
//! waits for DEPLOY frames. A DEPLOY names a pipeline and ships the
//! host→worker assignment; the worker rebuilds the identical logical
//! graph through [`crate::pipelines::build`], re-runs the deterministic
//! planner, and executes exactly the instances whose hosts are assigned
//! to it. Worker-local edges stay on in-process (unbounded) channels;
//! edges to instances owned by other workers go through the
//! [`SocketTransport`] — encoded frames relayed by the daemon.
//!
//! Survivability: the worker persists a `worker-<id>.state` file (pid,
//! coordinator address, zone) so a restarted coordinator can re-adopt it
//! — the connect loop reconnects with backoff and re-REGISTERs. SIGTERM
//! and SIGINT flip a flag the serve loop polls between frames (the socket
//! read carries a timeout); in-flight jobs drain before the worker sends
//! GOODBYE and removes its state file.

use super::socket::{Addr, Conn, PeerSender, SocketTransport};
use super::wire::{self, kv, kv_get, ReadEvent};
use super::{Endpoint, InProcessLane, Transport};
use crate::api::raw::{JobConfig, StreamContext};
use crate::channels::{FanOut, Inbox, Msg, OutPort, Target};
use crate::config::eval_cluster;
use crate::coordinator::build_stage_ops;
use crate::error::{Error, Result};
use crate::graph::OpKind;
use crate::metrics::{Metrics, MetricsRegistry};
use crate::placement::{plan as make_plan, PlannerKind};
use crate::runtime::{exec::Collector, run_instance, InputKind, InstanceRuntime, SourceRuntime};
use crate::value::Value;
use std::collections::HashMap;
use std::path::{Path, PathBuf};
use std::sync::atomic::{AtomicBool, Ordering};
use std::sync::mpsc::Sender;
use std::sync::Arc;
use std::thread::JoinHandle;
use std::time::{Duration, Instant};

/// Process-wide flag flipped by the SIGINT/SIGTERM handler.
static SIGNALLED: AtomicBool = AtomicBool::new(false);

#[cfg(unix)]
extern "C" fn on_signal(_sig: i32) {
    SIGNALLED.store(true, Ordering::SeqCst);
}

/// Installs SIGINT (2) and SIGTERM (15) handlers that request a graceful
/// worker shutdown: drain in-flight batches, deregister, exit. No-op on
/// non-Unix targets.
pub fn install_signal_handlers() {
    #[cfg(unix)]
    unsafe {
        extern "C" {
            fn signal(signum: i32, handler: usize) -> usize;
        }
        signal(2, on_signal as usize);
        signal(15, on_signal as usize);
    }
}

/// True once a termination signal was received.
pub fn signalled() -> bool {
    SIGNALLED.load(Ordering::SeqCst)
}

/// Options for [`run_worker`].
pub struct WorkerOpts {
    /// Coordinator address to connect to.
    pub addr: Addr,
    /// Worker id (must be unique per coordinator).
    pub id: String,
    /// Zone label advertised at registration.
    pub zone: String,
    /// Simulated-cluster hosts this worker claims (empty ⇒ the daemon
    /// assigns hosts round-robin).
    pub hosts: Vec<String>,
    /// Directory for the pid/state file.
    pub state_dir: PathBuf,
    /// Reconnect (with backoff) when the coordinator goes away.
    pub reconnect: bool,
    /// Give up after this many consecutive failed connection attempts.
    pub max_reconnects: u32,
    /// Install SIGINT/SIGTERM handlers (CLI mode; tests use `stop`).
    pub install_signals: bool,
    /// External stop flag (tests); signals always work in addition.
    pub stop: Option<Arc<AtomicBool>>,
}

impl WorkerOpts {
    /// Defaults: zone `cloud`, no advertised hosts, state under the
    /// system temp dir, reconnect up to 30 times, no signal handlers.
    pub fn new(addr: Addr, id: &str) -> WorkerOpts {
        WorkerOpts {
            addr,
            id: id.to_string(),
            zone: "cloud".into(),
            hosts: Vec::new(),
            state_dir: std::env::temp_dir().join("flowunits"),
            reconnect: true,
            max_reconnects: 30,
            install_signals: false,
            stop: None,
        }
    }
}

/// How one coordinator session ended.
#[derive(Clone, Copy, PartialEq, Eq)]
enum Exit {
    /// Connection lost — reconnect and re-REGISTER.
    Reconnect,
    /// Coordinator sent SHUTDOWN.
    Shutdown,
    /// Local stop (signal or external flag) after draining.
    Stopped,
}

/// One deployed job executing on this worker.
struct ActiveJob {
    id: u64,
    /// Destination instance → inbox sender (socket demultiplexer).
    demux: HashMap<usize, Sender<Msg>>,
    source_stop: Arc<AtomicBool>,
    aborted: Arc<AtomicBool>,
    /// Set by the watcher once every instance thread joined.
    done: Arc<AtomicBool>,
    watcher: Option<JoinHandle<()>>,
}

impl ActiveJob {
    fn abort(&mut self) {
        self.aborted.store(true, Ordering::SeqCst);
        self.source_stop.store(true, Ordering::SeqCst);
        // dropping the demux senders disconnects remote-fed inboxes so
        // their EOS fallback fires instead of waiting forever
        self.demux.clear();
    }

    fn join_watcher(&mut self) {
        if let Some(h) = self.watcher.take() {
            let _ = h.join();
        }
    }
}

/// Runs a worker until it is shut down (by the coordinator, a signal, or
/// the external stop flag) or its connection attempts are exhausted.
pub fn run_worker(opts: WorkerOpts) -> Result<()> {
    if opts.install_signals {
        install_signal_handlers();
    }
    let stop = opts
        .stop
        .clone()
        .unwrap_or_else(|| Arc::new(AtomicBool::new(false)));
    let metrics = MetricsRegistry::new();
    let state_path = state_file_path(&opts.state_dir, &opts.id);
    let had_state = check_and_write_state(&state_path, &opts)?;

    let mut active: Option<ActiveJob> = None;
    let mut registered_before = had_state;
    let mut attempts: u32 = 0;
    let result = loop {
        if stopped(&stop) {
            break Ok(());
        }
        let conn = match Conn::connect(&opts.addr, Some(metrics.clone())) {
            Ok(c) => c,
            Err(e) => {
                attempts += 1;
                if !opts.reconnect || attempts > opts.max_reconnects {
                    break Err(e);
                }
                std::thread::sleep(backoff(attempts));
                continue;
            }
        };
        attempts = 0;
        match session(
            &opts,
            conn,
            &metrics,
            &stop,
            &mut active,
            registered_before,
        ) {
            Ok(Exit::Reconnect) => {
                registered_before = true;
                if !opts.reconnect {
                    break Err(Error::Transport("coordinator connection lost".into()));
                }
                MetricsRegistry::add(&metrics.transport_reconnects, 1);
                std::thread::sleep(Duration::from_millis(100));
            }
            Ok(_) => break Ok(()),
            Err(e) => break Err(e),
        }
    };
    if let Some(mut j) = active.take() {
        j.abort();
        j.join_watcher();
    }
    let _ = std::fs::remove_file(&state_path);
    result
}

fn stopped(stop: &Arc<AtomicBool>) -> bool {
    stop.load(Ordering::SeqCst) || signalled()
}

fn backoff(attempt: u32) -> Duration {
    Duration::from_millis(50 * u64::from(attempt.min(20)))
}

fn state_file_path(dir: &Path, id: &str) -> PathBuf {
    dir.join(format!("worker-{id}.state"))
}

#[cfg(target_os = "linux")]
fn pid_alive(pid: u32) -> bool {
    Path::new(&format!("/proc/{pid}")).exists()
}

#[cfg(not(target_os = "linux"))]
fn pid_alive(_pid: u32) -> bool {
    false
}

/// Validates and (re)writes the worker's state file. Returns whether a
/// prior incarnation's state existed (its pid dead) — the re-adoption
/// hint sent with REGISTER. A state file naming a *live* other pid is an
/// error: two workers must not share an id.
fn check_and_write_state(path: &Path, opts: &WorkerOpts) -> Result<bool> {
    let mut had_state = false;
    if let Ok(s) = std::fs::read_to_string(path) {
        let mut pid = None;
        for line in s.lines() {
            if let Some(v) = line.strip_prefix("pid=") {
                pid = v.trim().parse::<u32>().ok();
            }
        }
        if let Some(p) = pid {
            if p != std::process::id() && pid_alive(p) {
                return Err(Error::Transport(format!(
                    "state file {} names live pid {p}: worker id '{}' is already running",
                    path.display(),
                    opts.id
                )));
            }
            had_state = true;
        }
    }
    if let Some(dir) = path.parent() {
        let _ = std::fs::create_dir_all(dir);
    }
    std::fs::write(
        path,
        format!(
            "pid={}\naddr={}\nworker_id={}\nzone={}\n",
            std::process::id(),
            opts.addr,
            opts.id,
            opts.zone
        ),
    )
    .map_err(|e| Error::Transport(format!("write state file {}: {e}", path.display())))?;
    Ok(had_state)
}

/// One connection's lifetime: REGISTER, await WELCOME, heartbeat, serve.
fn session(
    opts: &WorkerOpts,
    mut conn: Conn,
    metrics: &Metrics,
    stop: &Arc<AtomicBool>,
    active: &mut Option<ActiveJob>,
    readopt: bool,
) -> Result<Exit> {
    conn.sender.send_ctl(
        wire::kind::REGISTER,
        &kv(vec![
            ("worker", Value::Str(opts.id.clone())),
            ("zone", Value::Str(opts.zone.clone())),
            (
                "hosts",
                Value::List(opts.hosts.iter().map(|h| Value::Str(h.clone())).collect()),
            ),
            ("pid", Value::I64(std::process::id() as i64)),
            ("readopt", Value::Bool(readopt)),
        ]),
    )?;
    conn.set_read_timeout(Some(Duration::from_millis(100)))?;
    let heartbeat = loop {
        if stopped(stop) {
            return Ok(Exit::Stopped);
        }
        match conn.reader.poll() {
            Ok(ReadEvent::Frame(f)) => {
                note_recv(metrics, f.payload.len());
                match f.kind {
                    wire::kind::WELCOME => {
                        let ms = wire::parse_ctl(&f.payload)
                            .ok()
                            .and_then(|v| kv_get(&v, "heartbeat_ms").and_then(Value::as_i64))
                            .unwrap_or(500);
                        break Duration::from_millis(ms.max(10) as u64);
                    }
                    wire::kind::REJECT => {
                        let reason = wire::parse_ctl(&f.payload)
                            .ok()
                            .and_then(|v| {
                                kv_get(&v, "reason").and_then(Value::as_str).map(String::from)
                            })
                            .unwrap_or_else(|| "no reason given".into());
                        return Err(Error::Transport(format!(
                            "registration rejected: {reason}"
                        )));
                    }
                    _ => {}
                }
            }
            Ok(ReadEvent::Idle) => {}
            Ok(ReadEvent::Eof) | Err(_) => return Ok(Exit::Reconnect),
        }
    };

    // heartbeat thread: ticks until the session ends or a send fails
    let session_alive = Arc::new(AtomicBool::new(true));
    let hb_handle = {
        let alive = session_alive.clone();
        let sender = conn.sender.clone();
        let id = opts.id.clone();
        std::thread::Builder::new()
            .name(format!("hb-{id}"))
            .spawn(move || heartbeat_loop(sender, id, heartbeat, alive))
            .map_err(|e| Error::Transport(format!("spawn heartbeat thread: {e}")))?
    };
    let out = serve(opts, &mut conn, metrics, stop, active);
    session_alive.store(false, Ordering::SeqCst);
    conn.shutdown();
    let _ = hb_handle.join();
    out
}

fn heartbeat_loop(sender: PeerSender, id: String, interval: Duration, alive: Arc<AtomicBool>) {
    let step = Duration::from_millis(50);
    let mut seq: i64 = 0;
    loop {
        let mut waited = Duration::ZERO;
        while waited < interval {
            if !alive.load(Ordering::SeqCst) {
                return;
            }
            std::thread::sleep(step.min(interval - waited));
            waited += step;
        }
        seq += 1;
        let beat = kv(vec![
            ("worker", Value::Str(id.clone())),
            ("seq", Value::I64(seq)),
        ]);
        if sender.send_ctl(wire::kind::HEARTBEAT, &beat).is_err() {
            return;
        }
    }
}

fn note_recv(metrics: &Metrics, payload_len: usize) {
    MetricsRegistry::add(&metrics.transport_frames_recv, 1);
    MetricsRegistry::add(
        &metrics.transport_bytes_recv,
        wire::frame_len(payload_len) as u64,
    );
}

/// Frame loop after WELCOME: deploys jobs, demultiplexes relayed data
/// frames into instance inboxes, and drives graceful shutdown.
fn serve(
    opts: &WorkerOpts,
    conn: &mut Conn,
    metrics: &Metrics,
    stop: &Arc<AtomicBool>,
    active: &mut Option<ActiveJob>,
) -> Result<Exit> {
    let mut exit_after_drain: Option<Exit> = None;
    let mut drain_deadline = Instant::now();
    loop {
        // reap a finished job (watcher already sent REPORT or JOB_ERROR)
        if active.as_ref().is_some_and(|j| j.done.load(Ordering::SeqCst)) {
            if let Some(mut j) = active.take() {
                j.join_watcher();
            }
        }
        if exit_after_drain.is_none() && stopped(stop) {
            if let Some(j) = active.as_ref() {
                j.source_stop.store(true, Ordering::SeqCst);
            }
            exit_after_drain = Some(Exit::Stopped);
            drain_deadline = Instant::now() + Duration::from_secs(10);
        }
        if let Some(exit) = exit_after_drain {
            if active.is_none() || Instant::now() >= drain_deadline {
                let _ = conn.sender.send_ctl(
                    wire::kind::GOODBYE,
                    &kv(vec![("worker", Value::Str(opts.id.clone()))]),
                );
                return Ok(exit);
            }
        }
        let f = match conn.reader.poll() {
            Ok(ReadEvent::Frame(f)) => f,
            Ok(ReadEvent::Idle) => continue,
            Ok(ReadEvent::Eof) | Err(_) => return Ok(Exit::Reconnect),
        };
        note_recv(metrics, f.payload.len());
        match f.kind {
            wire::kind::DATA | wire::kind::EOS | wire::kind::EPOCH | wire::kind::WATERMARK => {
                demux(active, f.kind, &f.payload, metrics);
            }
            wire::kind::DEPLOY => {
                let Ok(v) = wire::parse_ctl(&f.payload) else { continue };
                let job = kv_get(&v, "job").and_then(Value::as_i64).unwrap_or(0) as u64;
                // a redispatched job supersedes any still-active one:
                // tear the old one down so its instances don't race the
                // replacement (aborted jobs never REPORT)
                if let Some(mut old) = active.take() {
                    old.abort();
                }
                match launch_job(opts, &conn.sender, job, &v) {
                    Ok(j) => *active = Some(j),
                    Err(e) => {
                        let _ = conn.sender.send_ctl(
                            wire::kind::JOB_ERROR,
                            &kv(vec![
                                ("job", Value::I64(job as i64)),
                                ("reason", Value::Str(format!("deploy failed: {e}"))),
                            ]),
                        );
                    }
                }
            }
            wire::kind::JOB_ERROR => {
                let job = wire::parse_ctl(&f.payload)
                    .ok()
                    .and_then(|v| kv_get(&v, "job").and_then(Value::as_i64));
                if let (Some(job), Some(j)) = (job, active.as_mut()) {
                    if j.id == job as u64 {
                        j.abort();
                    }
                }
            }
            wire::kind::SHUTDOWN => {
                if let Some(j) = active.as_ref() {
                    j.source_stop.store(true, Ordering::SeqCst);
                }
                exit_after_drain = Some(Exit::Shutdown);
                drain_deadline = Instant::now() + Duration::from_secs(10);
            }
            _ => {}
        }
    }
}

/// Routes one relayed data-plane frame into the owning instance's inbox.
/// Frames for a job other than the active one are dropped (late frames
/// from a torn-down job must not corrupt a successor).
fn demux(active: &mut Option<ActiveJob>, kind: u8, payload: &[u8], metrics: &Metrics) {
    let Ok((job, to, rest)) = wire::parse_data(payload) else {
        MetricsRegistry::add(&metrics.transport_errors, 1);
        return;
    };
    let Some(j) = active.as_ref().filter(|j| j.id == job) else {
        return;
    };
    let Some(tx) = j.demux.get(&to) else {
        MetricsRegistry::add(&metrics.transport_errors, 1);
        return;
    };
    let msg = match kind {
        wire::kind::DATA => Msg::Frame(rest.to_vec().into()),
        wire::kind::EOS => Msg::Eos,
        wire::kind::EPOCH => {
            let Ok(bytes) = <[u8; 8]>::try_from(rest) else {
                MetricsRegistry::add(&metrics.transport_errors, 1);
                return;
            };
            Msg::Epoch(u64::from_le_bytes(bytes))
        }
        wire::kind::WATERMARK => {
            let Ok(wm) = wire::parse_watermark(rest) else {
                MetricsRegistry::add(&metrics.transport_errors, 1);
                return;
            };
            Msg::Watermark(wm)
        }
        _ => return,
    };
    if tx.send(msg).is_err() {
        MetricsRegistry::add(&metrics.transport_errors, 1);
    }
}

/// Materialises the worker's share of a DEPLOY: rebuilds the pipeline's
/// graph, re-runs the deterministic planner, and spawns the instances
/// whose hosts the shipped assignment maps to this worker.
fn launch_job(
    opts: &WorkerOpts,
    sender: &PeerSender,
    job: u64,
    v: &Value,
) -> Result<ActiveJob> {
    let pipeline = kv_get(v, "pipeline")
        .and_then(Value::as_str)
        .ok_or_else(|| Error::Transport("DEPLOY without pipeline".into()))?;
    let events = kv_get(v, "events")
        .and_then(Value::as_i64)
        .ok_or_else(|| Error::Transport("DEPLOY without events".into()))? as u64;
    let assign = kv_get(v, "assign")
        .and_then(Value::as_list)
        .ok_or_else(|| Error::Transport("DEPLOY without assignment".into()))?;
    let mut owner_of_host: HashMap<String, String> = HashMap::new();
    for entry in assign {
        if let Some((h, w)) = entry.as_pair() {
            if let (Some(h), Some(w)) = (h.as_str(), w.as_str()) {
                owner_of_host.insert(h.to_string(), w.to_string());
            }
        }
    }

    // identical graph + plan on every process (see pipelines module docs)
    let cluster = eval_cluster(None, Duration::ZERO);
    let mut config = JobConfig::default();
    // the daemon threads its --checkpoint-ms knob through DEPLOY; 0 = off
    let checkpoint_ms = kv_get(v, "checkpoint_ms").and_then(Value::as_i64).unwrap_or(0);
    if checkpoint_ms > 0 {
        config.checkpoint_interval = Some(Duration::from_millis(checkpoint_ms as u64));
    }
    let mut ctx = StreamContext::new(cluster.clone(), config.clone());
    crate::pipelines::build(&mut ctx, pipeline, events)?;
    let graph = ctx.into_graph()?;
    let plan = make_plan(&graph, &cluster, PlannerKind::FlowUnits, &[], false)?;
    let topo = cluster.topology;
    let owned_by_me =
        |host: &str| owner_of_host.get(host).map(String::as_str) == Some(opts.id.as_str());
    let mine: Vec<crate::placement::InstancePlan> = plan
        .instances
        .iter()
        .filter(|i| owned_by_me(&i.host))
        .cloned()
        .collect();

    let job_metrics = MetricsRegistry::new();
    let collector = Arc::new(Collector::default());
    let source_stop = Arc::new(AtomicBool::new(false));

    // unbounded inboxes for my non-source instances: the serve loop's
    // demultiplexer must never block on one slow instance
    let mut demux_tx: HashMap<usize, Sender<Msg>> = HashMap::new();
    let mut inst_rx = HashMap::new();
    for inst in &mine {
        if plan.stages[inst.stage].is_source() {
            continue;
        }
        let (tx, rx) = std::sync::mpsc::channel();
        demux_tx.insert(inst.id, tx);
        inst_rx.insert(inst.id, rx);
    }

    // producers are counted over ALL instances, local and remote: a
    // remote producer's EOS arrives per lane through the relay, exactly
    // like a local one
    let mut producer_count: HashMap<usize, usize> = HashMap::new();
    for edge in &plan.edges {
        for from in plan.instances_of(edge.from_stage) {
            for t in plan.allowed_targets(&topo, from, edge) {
                *producer_count.entry(t).or_default() += 1;
            }
        }
    }

    let mut socket = SocketTransport::new(sender.clone(), job);
    let mut threads = Vec::new();
    for inst in mine {
        let stage = plan.stages[inst.stage].clone();
        let input = if stage.is_source() {
            let OpKind::Source(kind) = &graph.ops[stage.ops[0]].kind else {
                return Err(Error::Runtime("source stage op is not a source".into()));
            };
            InputKind::Source(SourceRuntime {
                kind: kind.clone(),
                share: inst.source_share.unwrap_or((0, 1)),
                batch_size: config.batch_size,
                stop: source_stop.clone(),
            })
        } else {
            let rx = inst_rx
                .remove(&inst.id)
                .ok_or_else(|| Error::Runtime(format!("instance {} missing inbox", inst.id)))?;
            InputKind::Inbox(
                Inbox::new(rx, *producer_count.get(&inst.id).unwrap_or(&0))
                    .with_metrics(job_metrics.clone()),
            )
        };
        let mut ports = Vec::new();
        for edge in plan.edges.iter().filter(|e| e.from_stage == inst.stage) {
            let from_ep = Endpoint::of(&inst);
            let mut targets = Vec::new();
            for t in plan.allowed_targets(&topo, inst.id, edge) {
                let tgt = &plan.instances[t];
                let crossing = tgt.zone != inst.zone;
                if owned_by_me(&tgt.host) {
                    let tx = demux_tx
                        .get(&t)
                        .ok_or_else(|| {
                            Error::Runtime(format!("local target {t} missing inbox"))
                        })?
                        .clone();
                    targets.push(Target::over(
                        Box::new(InProcessLane::unbounded(tx)),
                        crossing,
                    ));
                } else {
                    let lane = socket.open(&from_ep, &Endpoint::of(tgt))?;
                    targets.push(Target::over(lane, crossing));
                }
            }
            ports.push(OutPort::new(
                targets,
                edge.routing,
                config.batch_size,
                Some(job_metrics.clone()),
            ));
        }
        let ops = build_stage_ops(&graph, &stage, &collector, &job_metrics)?;
        let rt = InstanceRuntime {
            id: inst.id,
            ops,
            input,
            outputs: FanOut::new(ports),
            metrics: job_metrics.clone(),
            handoff: None,
            restore: Vec::new(),
        };
        let h = std::thread::Builder::new()
            .name(format!("winst-{}-s{}-{}", inst.id, inst.stage, inst.host))
            .spawn(move || run_instance(rt))
            .map_err(|e| Error::Runtime(format!("spawn instance thread: {e}")))?;
        threads.push(h);
    }

    // watcher: joins the instances, then reports this worker's slice
    let aborted = Arc::new(AtomicBool::new(false));
    let done = Arc::new(AtomicBool::new(false));
    let watcher = {
        let sender = sender.clone();
        let worker_id = opts.id.clone();
        let collector = collector.clone();
        let jm = job_metrics.clone();
        let aborted = aborted.clone();
        let done = done.clone();
        std::thread::Builder::new()
            .name(format!("job-{job}-watch"))
            .spawn(move || {
                let mut panicked = false;
                for h in threads {
                    if h.join().is_err() {
                        panicked = true;
                    }
                }
                done.store(true, Ordering::SeqCst);
                if aborted.load(Ordering::SeqCst) {
                    return;
                }
                if panicked {
                    let _ = sender.send_ctl(
                        wire::kind::JOB_ERROR,
                        &kv(vec![
                            ("job", Value::I64(job as i64)),
                            (
                                "reason",
                                Value::Str(format!(
                                    "instance thread panicked on worker '{worker_id}'"
                                )),
                            ),
                        ]),
                    );
                    return;
                }
                let collected = std::mem::take(
                    &mut *collector.values.lock().unwrap_or_else(|p| p.into_inner()),
                );
                let _ = sender.send_ctl(
                    wire::kind::REPORT,
                    &kv(vec![
                        ("job", Value::I64(job as i64)),
                        ("worker", Value::Str(worker_id)),
                        (
                            "events_in",
                            Value::I64(jm.events_in.load(Ordering::Relaxed) as i64),
                        ),
                        (
                            "events_out",
                            Value::I64(jm.events_out.load(Ordering::Relaxed) as i64),
                        ),
                        ("collected", Value::List(collected)),
                    ]),
                );
            })
            .map_err(|e| Error::Runtime(format!("spawn watcher thread: {e}")))?
    };

    Ok(ActiveJob {
        id: job,
        demux: demux_tx,
        source_stop,
        aborted,
        done,
        watcher: Some(watcher),
    })
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn state_file_detects_live_duplicates_and_readopts_dead_ones() {
        let dir = std::env::temp_dir().join(format!("fu-worker-state-{}", std::process::id()));
        std::fs::create_dir_all(&dir).unwrap();
        let opts = WorkerOpts::new(Addr::parse("127.0.0.1:1"), "wstate");
        let path = state_file_path(&dir, &opts.id);

        // no prior state: written fresh, not a re-adoption
        let _ = std::fs::remove_file(&path);
        assert!(!check_and_write_state(&path, &opts).unwrap());
        let s = std::fs::read_to_string(&path).unwrap();
        assert!(s.contains(&format!("pid={}", std::process::id())), "{s}");
        assert!(s.contains("worker_id=wstate"), "{s}");

        // prior state with a dead pid: re-adoption
        std::fs::write(&path, "pid=4000000000\naddr=x\nworker_id=wstate\n").unwrap();
        assert!(check_and_write_state(&path, &opts).unwrap());

        // prior state naming a live *other* pid: refused
        #[cfg(target_os = "linux")]
        {
            std::fs::write(&path, "pid=1\naddr=x\nworker_id=wstate\n").unwrap();
            let err = check_and_write_state(&path, &opts).unwrap_err();
            assert!(err.to_string().contains("already running"), "{err}");
        }
        let _ = std::fs::remove_dir_all(&dir);
    }

    #[test]
    fn worker_gives_up_when_coordinator_never_appears() {
        let dir = std::env::temp_dir().join(format!("fu-worker-noc-{}", std::process::id()));
        let mut opts = WorkerOpts::new(
            Addr::parse(&dir.join("absent.sock").to_string_lossy()),
            "wnoc",
        );
        opts.state_dir = dir.clone();
        opts.max_reconnects = 2;
        let err = run_worker(opts).unwrap_err();
        assert!(matches!(err, Error::Transport(_)));
        assert!(
            !state_file_path(&dir, "wnoc").exists(),
            "state file removed on exit"
        );
        let _ = std::fs::remove_dir_all(&dir);
    }
}
