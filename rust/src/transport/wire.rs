//! Length-prefixed frame codec for the real (socket) transport.
//!
//! Every frame on a socket is `[u32 le length][u8 kind][payload]`, where
//! `length` covers the kind byte plus the payload. Data-plane frames
//! carry the engine's existing encode-once wire format — the refcounted
//! bytes behind [`Msg::Frame`](crate::channels::Msg::Frame) — prefixed
//! with a job id and destination instance id so the coordinator can relay
//! them to the owning worker. Control-plane frames (register, deploy,
//! heartbeat, report, ...) carry an encoded [`Value`] tree, reusing the
//! crate's codec instead of introducing a serialization dependency.
//!
//! Reading is resumable: [`FrameReader`] preserves partial progress
//! across short reads *and* read timeouts (`WouldBlock`/`TimedOut`), so a
//! worker can poll its socket with a timeout — to notice SIGTERM between
//! frames — without ever tearing a frame in half.

use crate::error::{Error, Result};
use crate::value::Value;
use std::io::{self, Read, Write};

/// Upper bound on one frame (kind byte + payload). Large enough for any
/// realistic batch, small enough to reject garbage length prefixes from a
/// corrupt or hostile stream before allocating.
pub const MAX_FRAME: usize = 64 << 20;

/// Frame kinds. Data-plane kinds mirror [`Msg`](crate::channels::Msg);
/// control-plane kinds drive the coordinator/worker handshake.
pub mod kind {
    /// Batch bytes: `[u32 job][u32 to_instance][batch wire bytes]`.
    pub const DATA: u8 = 0x01;
    /// One producer finished: `[u32 job][u32 to_instance]`.
    pub const EOS: u8 = 0x02;
    /// Drain-and-handoff marker: `[u32 job][u32 to_instance][u64 epoch]`.
    pub const EPOCH: u8 = 0x03;
    /// Event-time watermark:
    /// `[u32 job][u32 to_instance][u32 from][i64 ts][u64 origin_ms]`.
    pub const WATERMARK: u8 = 0x04;
    /// Worker → coordinator hello (Value payload).
    pub const REGISTER: u8 = 0x10;
    /// Coordinator → worker registration accepted (Value payload).
    pub const WELCOME: u8 = 0x11;
    /// Coordinator → worker registration refused (Value payload: reason).
    pub const REJECT: u8 = 0x12;
    /// Coordinator → worker instance-plan assignment (Value payload).
    pub const DEPLOY: u8 = 0x13;
    /// Worker → coordinator liveness beacon (Value payload).
    pub const HEARTBEAT: u8 = 0x14;
    /// Worker → coordinator per-job results (Value payload).
    pub const REPORT: u8 = 0x15;
    /// Worker → coordinator graceful deregistration (Value payload).
    pub const GOODBYE: u8 = 0x16;
    /// Coordinator → worker: a peer died; abort the named job.
    pub const JOB_ERROR: u8 = 0x17;
    /// Coordinator → worker: drain and exit.
    pub const SHUTDOWN: u8 = 0x18;
}

/// One decoded frame.
#[derive(Debug, Clone, PartialEq)]
pub struct Frame {
    /// Frame kind (see [`kind`]).
    pub kind: u8,
    /// Payload bytes (everything after the kind byte).
    pub payload: Vec<u8>,
}

/// Bytes one frame occupies on the wire (length prefix included).
pub fn frame_len(payload_len: usize) -> usize {
    4 + 1 + payload_len
}

/// Writes one frame and flushes the writer (frames are the unit of
/// progress; a buffered half-frame helps nobody).
pub fn write_frame(w: &mut impl Write, kind: u8, payload: &[u8]) -> io::Result<()> {
    let len = 1 + payload.len();
    if len > MAX_FRAME {
        return Err(io::Error::new(
            io::ErrorKind::InvalidData,
            format!("frame of {len} bytes exceeds MAX_FRAME"),
        ));
    }
    w.write_all(&(len as u32).to_le_bytes())?;
    w.write_all(&[kind])?;
    w.write_all(payload)?;
    w.flush()
}

/// What one [`FrameReader::poll`] produced.
#[derive(Debug)]
pub enum ReadEvent {
    /// A complete frame.
    Frame(Frame),
    /// Clean end of stream (EOF on a frame boundary).
    Eof,
    /// The read timed out (`WouldBlock`/`TimedOut`) — partial progress, if
    /// any, is preserved; call `poll` again.
    Idle,
}

/// Incremental frame reader: survives short reads and read timeouts
/// without losing partial progress (a frame torn across two `poll` calls
/// is reassembled, never dropped or misparsed).
pub struct FrameReader<R> {
    r: R,
    hdr: [u8; 4],
    hdr_got: usize,
    body: Vec<u8>,
    body_got: usize,
    /// Total payload+kind bytes of the frame being read; 0 ⇒ reading the
    /// length prefix.
    body_need: usize,
}

impl<R: Read> FrameReader<R> {
    /// Wraps a readable stream.
    pub fn new(r: R) -> Self {
        FrameReader {
            r,
            hdr: [0; 4],
            hdr_got: 0,
            body: Vec::new(),
            body_got: 0,
            body_need: 0,
        }
    }

    /// Reads until a full frame, EOF, or a read timeout. EOF in the
    /// middle of a frame is an `UnexpectedEof` error (a peer died
    /// mid-send), EOF on a boundary is the clean [`ReadEvent::Eof`].
    pub fn poll(&mut self) -> io::Result<ReadEvent> {
        loop {
            if self.body_need == 0 {
                // length prefix
                match self.r.read(&mut self.hdr[self.hdr_got..]) {
                    Ok(0) => {
                        return if self.hdr_got == 0 {
                            Ok(ReadEvent::Eof)
                        } else {
                            Err(io::Error::new(
                                io::ErrorKind::UnexpectedEof,
                                "eof inside a frame length prefix",
                            ))
                        };
                    }
                    Ok(n) => {
                        self.hdr_got += n;
                        if self.hdr_got == 4 {
                            let len = u32::from_le_bytes(self.hdr) as usize;
                            if len == 0 || len > MAX_FRAME {
                                return Err(io::Error::new(
                                    io::ErrorKind::InvalidData,
                                    format!("bad frame length {len}"),
                                ));
                            }
                            self.body = vec![0u8; len];
                            self.body_got = 0;
                            self.body_need = len;
                        }
                    }
                    Err(e) if e.kind() == io::ErrorKind::Interrupted => continue,
                    Err(e)
                        if e.kind() == io::ErrorKind::WouldBlock
                            || e.kind() == io::ErrorKind::TimedOut =>
                    {
                        return Ok(ReadEvent::Idle)
                    }
                    Err(e) => return Err(e),
                }
            } else {
                // kind byte + payload
                match self.r.read(&mut self.body[self.body_got..]) {
                    Ok(0) => {
                        return Err(io::Error::new(
                            io::ErrorKind::UnexpectedEof,
                            "eof inside a frame body",
                        ))
                    }
                    Ok(n) => {
                        self.body_got += n;
                        if self.body_got == self.body_need {
                            let body = std::mem::take(&mut self.body);
                            self.hdr_got = 0;
                            self.body_got = 0;
                            self.body_need = 0;
                            let frame = Frame {
                                kind: body[0],
                                payload: body[1..].to_vec(),
                            };
                            return Ok(ReadEvent::Frame(frame));
                        }
                    }
                    Err(e) if e.kind() == io::ErrorKind::Interrupted => continue,
                    Err(e)
                        if e.kind() == io::ErrorKind::WouldBlock
                            || e.kind() == io::ErrorKind::TimedOut =>
                    {
                        return Ok(ReadEvent::Idle)
                    }
                    Err(e) => return Err(e),
                }
            }
        }
    }

    /// Blocking convenience: polls until a frame or EOF (a stream without
    /// a read timeout never yields `Idle`, but looping is harmless).
    pub fn next_frame(&mut self) -> io::Result<Option<Frame>> {
        loop {
            match self.poll()? {
                ReadEvent::Frame(f) => return Ok(Some(f)),
                ReadEvent::Eof => return Ok(None),
                ReadEvent::Idle => continue,
            }
        }
    }
}

/// Encodes a watermark frame body (the bytes after the routing header):
/// `[u32 from][i64 ts][u64 origin_ms]`.
pub fn watermark_body(wm: &crate::channels::Watermark) -> Vec<u8> {
    let mut b = Vec::with_capacity(20);
    b.extend_from_slice(&wm.from.to_le_bytes());
    b.extend_from_slice(&wm.ts.to_le_bytes());
    b.extend_from_slice(&wm.origin_ms.to_le_bytes());
    b
}

/// Decodes a watermark frame body.
pub fn parse_watermark(rest: &[u8]) -> Result<crate::channels::Watermark> {
    if rest.len() != 20 {
        return Err(Error::Transport(format!(
            "watermark body of {} bytes (expected 20)",
            rest.len()
        )));
    }
    Ok(crate::channels::Watermark {
        from: u32::from_le_bytes(rest[0..4].try_into().unwrap()),
        ts: i64::from_le_bytes(rest[4..12].try_into().unwrap()),
        origin_ms: u64::from_le_bytes(rest[12..20].try_into().unwrap()),
    })
}

/// Builds a data-plane payload: `[u32 job][u32 to][rest]`.
pub fn data_payload(job: u64, to: usize, rest: &[u8]) -> Vec<u8> {
    let mut p = Vec::with_capacity(8 + rest.len());
    p.extend_from_slice(&(job as u32).to_le_bytes());
    p.extend_from_slice(&(to as u32).to_le_bytes());
    p.extend_from_slice(rest);
    p
}

/// Splits a data-plane payload into `(job, to_instance, rest)`.
pub fn parse_data(payload: &[u8]) -> Result<(u64, usize, &[u8])> {
    if payload.len() < 8 {
        return Err(Error::Transport(format!(
            "data frame of {} bytes is shorter than its routing header",
            payload.len()
        )));
    }
    let job = u32::from_le_bytes([payload[0], payload[1], payload[2], payload[3]]) as u64;
    let to = u32::from_le_bytes([payload[4], payload[5], payload[6], payload[7]]) as usize;
    Ok((job, to, &payload[8..]))
}

/// Encodes a control payload (a `Value` tree).
pub fn ctl_payload(v: &Value) -> Vec<u8> {
    v.encode()
}

/// Decodes a control payload.
pub fn parse_ctl(payload: &[u8]) -> Result<Value> {
    Value::decode_exact(payload)
        .map_err(|e| Error::Transport(format!("malformed control frame: {e}")))
}

/// Builds a control-plane record: a list of `(key, value)` pairs. Keys
/// are looked up with [`kv_get`]; unknown keys are ignored by receivers,
/// which keeps the handshake forward-compatible.
pub fn kv(pairs: Vec<(&str, Value)>) -> Value {
    Value::List(
        pairs
            .into_iter()
            .map(|(k, v)| Value::pair(Value::Str(k.to_string()), v))
            .collect(),
    )
}

/// Looks a key up in a [`kv`] record.
pub fn kv_get<'a>(v: &'a Value, key: &str) -> Option<&'a Value> {
    let items = v.as_list()?;
    for item in items {
        if let Some((k, val)) = item.as_pair() {
            if k.as_str() == Some(key) {
                return Some(val);
            }
        }
    }
    None
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn frame_roundtrip() {
        let mut buf = Vec::new();
        write_frame(&mut buf, kind::DATA, b"hello").unwrap();
        write_frame(&mut buf, kind::EOS, b"").unwrap();
        let mut r = FrameReader::new(&buf[..]);
        let f1 = r.next_frame().unwrap().unwrap();
        assert_eq!((f1.kind, f1.payload.as_slice()), (kind::DATA, &b"hello"[..]));
        let f2 = r.next_frame().unwrap().unwrap();
        assert_eq!((f2.kind, f2.payload.as_slice()), (kind::EOS, &b""[..]));
        assert!(r.next_frame().unwrap().is_none());
    }

    #[test]
    fn watermark_body_roundtrip() {
        let wm = crate::channels::Watermark {
            from: 9,
            ts: -125,
            origin_ms: 17,
        };
        let b = watermark_body(&wm);
        assert_eq!(parse_watermark(&b).unwrap(), wm);
        assert!(parse_watermark(&b[..10]).is_err(), "truncated body rejected");
    }

    #[test]
    fn data_payload_roundtrip() {
        let p = data_payload(7, 42, b"bytes");
        let (job, to, rest) = parse_data(&p).unwrap();
        assert_eq!((job, to, rest), (7, 42, &b"bytes"[..]));
        assert!(parse_data(&p[..5]).is_err(), "truncated header rejected");
    }

    #[test]
    fn zero_and_oversized_lengths_rejected() {
        let mut r = FrameReader::new(&[0u8, 0, 0, 0][..]);
        assert!(r.next_frame().is_err(), "zero-length frame is malformed");
        let huge = (MAX_FRAME as u32 + 1).to_le_bytes();
        let mut r = FrameReader::new(&huge[..]);
        assert!(r.next_frame().is_err(), "oversized frame rejected early");
    }

    #[test]
    fn truncated_stream_is_clean_error_not_panic() {
        let mut buf = Vec::new();
        write_frame(&mut buf, kind::DATA, b"payload").unwrap();
        buf.truncate(buf.len() - 3);
        let mut r = FrameReader::new(&buf[..]);
        let err = r.next_frame().unwrap_err();
        assert_eq!(err.kind(), io::ErrorKind::UnexpectedEof);
    }
}
