//! Real sockets for the distributed runtime: Unix domain sockets for
//! local deployments, TCP across hosts. One bidirectional connection per
//! worker carries both planes — control frames (register, deploy,
//! heartbeat, report) and relayed data frames — multiplexed by the frame
//! kind byte ([`wire`]).

use super::wire;
use super::{Endpoint, Lane, Transport};
use crate::channels::Msg;
use crate::error::{Error, Result};
use crate::metrics::{Metrics, MetricsRegistry};
use crate::value::Value;
use std::fmt;
use std::io::{BufWriter, Read, Write};
use std::net::{Shutdown, TcpListener, TcpStream};
#[cfg(unix)]
use std::os::unix::net::{UnixListener, UnixStream};
#[cfg(unix)]
use std::path::PathBuf;
use std::sync::{Arc, Mutex};
use std::time::Duration;

/// A transport address: a Unix socket path or a TCP `host:port`.
#[derive(Debug, Clone, PartialEq, Eq)]
pub enum Addr {
    /// Unix domain socket path (local coordinator + workers).
    #[cfg(unix)]
    Unix(PathBuf),
    /// TCP address (`host:port`) for cross-host deployments.
    Tcp(String),
}

impl Addr {
    /// Parses an address: anything containing `/` (or starting with `.`)
    /// is a Unix socket path, everything else is `host:port` TCP.
    pub fn parse(s: &str) -> Addr {
        #[cfg(unix)]
        if s.contains('/') || s.starts_with('.') {
            return Addr::Unix(PathBuf::from(s));
        }
        Addr::Tcp(s.to_string())
    }
}

impl fmt::Display for Addr {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        match self {
            #[cfg(unix)]
            Addr::Unix(p) => write!(f, "{}", p.display()),
            Addr::Tcp(s) => write!(f, "{s}"),
        }
    }
}

/// Raw stream handle — kept alongside the split reader/writer so timeouts
/// and shutdowns can be applied from another thread (clones share the
/// underlying socket).
enum StreamCtl {
    #[cfg(unix)]
    Unix(UnixStream),
    Tcp(TcpStream),
}

impl StreamCtl {
    fn try_clone(&self) -> std::io::Result<StreamCtl> {
        Ok(match self {
            #[cfg(unix)]
            StreamCtl::Unix(s) => StreamCtl::Unix(s.try_clone()?),
            StreamCtl::Tcp(s) => StreamCtl::Tcp(s.try_clone()?),
        })
    }

    fn reader(&self) -> std::io::Result<Box<dyn Read + Send>> {
        Ok(match self {
            #[cfg(unix)]
            StreamCtl::Unix(s) => Box::new(s.try_clone()?),
            StreamCtl::Tcp(s) => Box::new(s.try_clone()?),
        })
    }

    fn writer(&self) -> std::io::Result<Box<dyn Write + Send>> {
        Ok(match self {
            #[cfg(unix)]
            StreamCtl::Unix(s) => Box::new(BufWriter::new(s.try_clone()?)),
            StreamCtl::Tcp(s) => Box::new(BufWriter::new(s.try_clone()?)),
        })
    }

    fn set_read_timeout(&self, d: Option<Duration>) -> std::io::Result<()> {
        match self {
            #[cfg(unix)]
            StreamCtl::Unix(s) => s.set_read_timeout(d),
            StreamCtl::Tcp(s) => s.set_read_timeout(d),
        }
    }

    fn shutdown(&self) {
        match self {
            #[cfg(unix)]
            StreamCtl::Unix(s) => {
                let _ = s.shutdown(Shutdown::Both);
            }
            StreamCtl::Tcp(s) => {
                let _ = s.shutdown(Shutdown::Both);
            }
        }
    }
}

/// Shareable handle to a connection's socket: lets the daemon's tick loop
/// sever a dead peer (unblocking its reader thread) without owning the
/// connection.
pub struct ConnHandle(StreamCtl);

impl ConnHandle {
    /// Severs the connection (both directions).
    pub fn shutdown(&self) {
        self.0.shutdown();
    }
}

/// Clonable, thread-safe writer half of a connection. All frame writes go
/// through one mutex so interleaved senders never tear a frame; a
/// poisoned or closed writer surfaces as [`Error::Transport`], never a
/// panic.
#[derive(Clone)]
pub struct PeerSender(Arc<PeerShared>);

struct PeerShared {
    w: Mutex<Box<dyn Write + Send>>,
    desc: String,
    metrics: Option<Metrics>,
}

impl PeerSender {
    fn new(w: Box<dyn Write + Send>, desc: String, metrics: Option<Metrics>) -> PeerSender {
        PeerSender(Arc::new(PeerShared {
            w: Mutex::new(w),
            desc,
            metrics,
        }))
    }

    /// Peer description (diagnostics).
    pub fn desc(&self) -> &str {
        &self.0.desc
    }

    /// Writes one frame.
    pub fn send(&self, kind: u8, payload: &[u8]) -> Result<()> {
        let mut w = self
            .0
            .w
            .lock()
            .map_err(|_| Error::Transport(format!("writer to {} poisoned", self.0.desc)))?;
        wire::write_frame(&mut *w, kind, payload)
            .map_err(|e| Error::Transport(format!("send to {}: {e}", self.0.desc)))?;
        if let Some(m) = &self.0.metrics {
            MetricsRegistry::add(
                &m.transport_bytes_sent,
                wire::frame_len(payload.len()) as u64,
            );
            MetricsRegistry::add(&m.transport_frames_sent, 1);
        }
        Ok(())
    }

    /// Writes one control frame carrying a `Value` tree.
    pub fn send_ctl(&self, kind: u8, v: &Value) -> Result<()> {
        self.send(kind, &wire::ctl_payload(v))
    }
}

/// One established connection: a resumable frame reader plus a shareable
/// frame writer over the same socket.
pub struct Conn {
    /// Peer description (diagnostics).
    pub desc: String,
    ctl: StreamCtl,
    /// Incremental frame reader (partial reads and timeouts preserved).
    pub reader: wire::FrameReader<Box<dyn Read + Send>>,
    /// Shareable writer half.
    pub sender: PeerSender,
}

impl Conn {
    fn from_ctl(ctl: StreamCtl, desc: String, metrics: Option<Metrics>) -> Result<Conn> {
        let r = ctl
            .reader()
            .map_err(|e| Error::Transport(format!("clone reader for {desc}: {e}")))?;
        let w = ctl
            .writer()
            .map_err(|e| Error::Transport(format!("clone writer for {desc}: {e}")))?;
        Ok(Conn {
            desc: desc.clone(),
            ctl,
            reader: wire::FrameReader::new(r),
            sender: PeerSender::new(w, desc, metrics),
        })
    }

    /// Connects to a coordinator or worker.
    pub fn connect(addr: &Addr, metrics: Option<Metrics>) -> Result<Conn> {
        let ctl = match addr {
            #[cfg(unix)]
            Addr::Unix(p) => StreamCtl::Unix(
                UnixStream::connect(p)
                    .map_err(|e| Error::Transport(format!("connect {}: {e}", p.display())))?,
            ),
            Addr::Tcp(s) => StreamCtl::Tcp(
                TcpStream::connect(s)
                    .map_err(|e| Error::Transport(format!("connect {s}: {e}")))?,
            ),
        };
        Conn::from_ctl(ctl, format!("{addr}"), metrics)
    }

    /// Sets (or clears) the read timeout; the frame reader preserves
    /// partial progress across timeouts, so polling is safe mid-frame.
    pub fn set_read_timeout(&self, d: Option<Duration>) -> Result<()> {
        self.ctl
            .set_read_timeout(d)
            .map_err(|e| Error::Transport(format!("set_read_timeout on {}: {e}", self.desc)))
    }

    /// A shareable control handle (for shutdown from another thread).
    pub fn handle(&self) -> Result<ConnHandle> {
        Ok(ConnHandle(self.ctl.try_clone().map_err(|e| {
            Error::Transport(format!("clone handle for {}: {e}", self.desc))
        })?))
    }

    /// Severs the connection.
    pub fn shutdown(&self) {
        self.ctl.shutdown();
    }
}

/// A bound listening socket.
pub enum Listener {
    /// Unix domain socket listener.
    #[cfg(unix)]
    Unix(UnixListener),
    /// TCP listener.
    Tcp(TcpListener),
}

impl Listener {
    /// Binds `addr`. A stale Unix socket file left by a dead coordinator
    /// is removed first — workers reconnect with backoff, so reclaiming
    /// the path is always safe.
    pub fn bind(addr: &Addr) -> Result<Listener> {
        match addr {
            #[cfg(unix)]
            Addr::Unix(p) => {
                if let Some(dir) = p.parent() {
                    if !dir.as_os_str().is_empty() {
                        let _ = std::fs::create_dir_all(dir);
                    }
                }
                let _ = std::fs::remove_file(p);
                Ok(Listener::Unix(UnixListener::bind(p).map_err(|e| {
                    Error::Transport(format!("bind {}: {e}", p.display()))
                })?))
            }
            Addr::Tcp(s) => Ok(Listener::Tcp(
                TcpListener::bind(s).map_err(|e| Error::Transport(format!("bind {s}: {e}")))?,
            )),
        }
    }

    /// Accepts one connection.
    pub fn accept(&self, metrics: Option<Metrics>) -> Result<Conn> {
        match self {
            #[cfg(unix)]
            Listener::Unix(l) => {
                let (s, _) = l
                    .accept()
                    .map_err(|e| Error::Transport(format!("accept: {e}")))?;
                Conn::from_ctl(StreamCtl::Unix(s), "unix-peer".into(), metrics)
            }
            Listener::Tcp(l) => {
                let (s, peer) = l
                    .accept()
                    .map_err(|e| Error::Transport(format!("accept: {e}")))?;
                Conn::from_ctl(StreamCtl::Tcp(s), format!("{peer}"), metrics)
            }
        }
    }
}

/// Real-socket transport: every lane writes `DATA`/`EOS`/`EPOCH` frames
/// tagged with the job and destination instance through the worker's one
/// coordinator connection; the coordinator relays each frame to the
/// worker owning the destination. See the module docs on
/// [`transport`](crate::transport) for when this is selected.
pub struct SocketTransport {
    peer: PeerSender,
    job: u64,
}

impl SocketTransport {
    /// Transport over an established peer connection, scoped to one job
    /// (frames carry the job id so late frames from a torn-down job are
    /// dropped, not misdelivered).
    pub fn new(peer: PeerSender, job: u64) -> Self {
        SocketTransport { peer, job }
    }
}

impl Transport for SocketTransport {
    fn name(&self) -> &'static str {
        "socket"
    }

    fn open(&mut self, _from: &Endpoint, to: &Endpoint) -> Result<Box<dyn Lane>> {
        Ok(Box::new(PeerLane {
            peer: self.peer.clone(),
            job: self.job,
            to: to.instance,
        }))
    }
}

/// Lane to a remote instance: encoded frames through the peer socket.
pub struct PeerLane {
    peer: PeerSender,
    job: u64,
    to: usize,
}

impl Lane for PeerLane {
    fn framed(&self) -> bool {
        true
    }

    fn deliver(&mut self, msg: Msg) -> Result<()> {
        match msg {
            Msg::Frame(bytes) => self.peer.send(
                wire::kind::DATA,
                &wire::data_payload(self.job, self.to, &bytes),
            ),
            // unreachable through OutPort (framed lanes receive frames),
            // but a direct caller still gets correct behavior
            Msg::Batch(b) => {
                let bytes = b.wire();
                self.peer.send(
                    wire::kind::DATA,
                    &wire::data_payload(self.job, self.to, &bytes),
                )
            }
            Msg::Columns(cb) => {
                let bytes = cb.wire();
                self.peer.send(
                    wire::kind::DATA,
                    &wire::data_payload(self.job, self.to, &bytes),
                )
            }
            Msg::Eos => self
                .peer
                .send(wire::kind::EOS, &wire::data_payload(self.job, self.to, &[])),
            Msg::Epoch(e) => self.peer.send(
                wire::kind::EPOCH,
                &wire::data_payload(self.job, self.to, &e.to_le_bytes()),
            ),
            Msg::Watermark(wm) => self.peer.send(
                wire::kind::WATERMARK,
                &wire::data_payload(self.job, self.to, &wire::watermark_body(&wm)),
            ),
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn addr_parse_distinguishes_unix_and_tcp() {
        #[cfg(unix)]
        assert!(matches!(Addr::parse("/tmp/fu.sock"), Addr::Unix(_)));
        #[cfg(unix)]
        assert!(matches!(Addr::parse("./fu.sock"), Addr::Unix(_)));
        assert!(matches!(Addr::parse("127.0.0.1:7070"), Addr::Tcp(_)));
        assert!(matches!(Addr::parse("edge-host:9000"), Addr::Tcp(_)));
    }

    #[cfg(unix)]
    #[test]
    fn unix_roundtrip_and_relay_framing() {
        let dir = std::env::temp_dir().join(format!("fu-sock-test-{}", std::process::id()));
        std::fs::create_dir_all(&dir).unwrap();
        let addr = Addr::Unix(dir.join("t.sock"));
        let listener = Listener::bind(&addr).unwrap();
        let addr2 = addr.clone();
        let client = std::thread::spawn(move || {
            let conn = Conn::connect(&addr2, None).unwrap();
            conn.sender
                .send_ctl(wire::kind::REGISTER, &Value::Str("w1".into()))
                .unwrap();
            let mut conn = conn;
            let f = conn.reader.next_frame().unwrap().unwrap();
            assert_eq!(f.kind, wire::kind::WELCOME);
        });
        let mut server = listener.accept(None).unwrap();
        let f = server.reader.next_frame().unwrap().unwrap();
        assert_eq!(f.kind, wire::kind::REGISTER);
        assert_eq!(wire::parse_ctl(&f.payload).unwrap(), Value::Str("w1".into()));
        server
            .sender
            .send_ctl(wire::kind::WELCOME, &Value::I64(500))
            .unwrap();
        client.join().unwrap();
        let _ = std::fs::remove_dir_all(&dir);
    }
}
