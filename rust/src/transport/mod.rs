//! Transport subsystem: how operator instances exchange [`Msg`] frames.
//!
//! The engine ran, until this subsystem, as one process: every
//! zone/host/instance a thread, every "network" hop an emulated
//! [`Link`](crate::netsim::Link). The paper's claim, though, is about a
//! *real* edge-to-cloud continuum — so message delivery is now abstracted
//! behind the [`Transport`] trait (who can I reach, and how do I get a
//! [`Lane`] to them), with three implementations:
//!
//! * [`ChannelTransport`] — the existing in-process channels. **Default.**
//!   Selected whenever sender and receiver live in the same OS process
//!   (the single-process engine, and worker-local edges in distributed
//!   mode). Delivery is a refcount bump through a bounded (or, on
//!   workers, unbounded) `mpsc` channel; tier-1 tests stay deterministic
//!   because nothing else is in the loop.
//! * [`NetsimTransport`] — the emulated network, re-homed behind the
//!   trait. Selected by the single-process [`Coordinator`]
//!   (crate::coordinator::Coordinator) for edges between *simulated*
//!   hosts: same-host edges degrade to an in-process lane, cross-host
//!   edges encode once and traverse the shared per-egress-hop uplink
//!   [`Link`](crate::netsim::Link) with the route's bandwidth/latency
//!   shaping. This is what the paper-reproduction benchmarks (Fig. 3)
//!   run on.
//! * [`SocketTransport`](socket::SocketTransport) — the real thing:
//!   length-prefixed [`Msg::Frame`] bytes over a Unix domain socket
//!   (local deployments) or TCP (across hosts), relayed by the
//!   coordinator daemon to the worker process owning the destination
//!   instance. Selected in distributed mode (`flowunits coordinator` /
//!   `flowunits worker`) for every edge whose endpoints live in
//!   different worker processes.
//!
//! The submodules build the distributed runtime on top of the trait:
//! [`wire`] (frame codec), [`socket`] (addresses, connections, peers),
//! [`daemon`] (the coordinator daemon: registry, heartbeats, relay,
//! deploy/report), and [`worker`] (the worker process: handshake,
//! re-adoption state file, graceful shutdown, local instance execution).

pub mod daemon;
pub mod socket;
pub mod wire;
pub mod worker;

use crate::channels::{Msg, FRAME_OVERHEAD};
use crate::config::ClusterSpec;
use crate::error::{Error, Result};
use crate::metrics::Metrics;
use crate::netsim::Link;
use std::collections::HashMap;
use std::sync::mpsc::{Sender, SyncSender};
use std::sync::Arc;
use std::time::Duration;

/// One end of an edge, as a transport sees it: the planned instance id
/// plus where the plan put it (zone and host labels drive lane
/// selection — same host ⇒ in-process, different zone ⇒ shaped uplink,
/// different process ⇒ socket).
#[derive(Debug, Clone, PartialEq, Eq)]
pub struct Endpoint {
    /// Planned instance id.
    pub instance: usize,
    /// Zone the instance is placed in.
    pub zone: String,
    /// Host the instance is pinned to.
    pub host: String,
}

impl Endpoint {
    /// Endpoint of a planned instance.
    pub fn of(inst: &crate::placement::InstancePlan) -> Endpoint {
        Endpoint {
            instance: inst.id,
            zone: inst.zone.clone(),
            host: inst.host.clone(),
        }
    }
}

/// A one-way delivery path from one instance to one downstream inbox.
///
/// `framed()` tells the sender whether to pay the encode-once wire
/// serialization ([`Msg::Frame`]) or hand the batch over by refcount
/// ([`Msg::Batch`]); `deliver` never panics — a closed, full-and-shutdown,
/// or poisoned endpoint surfaces as [`Error::Transport`] for the caller
/// to count.
pub trait Lane: Send {
    /// True if batches must cross this lane as encoded frames.
    fn framed(&self) -> bool;
    /// Delivers one message.
    fn deliver(&mut self, msg: Msg) -> Result<()>;
}

/// Hands out lanes between instances. See the module docs for the three
/// implementations and when each is selected.
pub trait Transport: Send {
    /// Implementation name (diagnostics).
    fn name(&self) -> &'static str;
    /// Opens a lane from `from` to `to`.
    fn open(&mut self, from: &Endpoint, to: &Endpoint) -> Result<Box<dyn Lane>>;
}

/// Sender half of an in-process inbox: the engine's bounded channels, or
/// the unbounded ones worker processes use (their inboxes are fed by the
/// socket demultiplexer, which must never block on one slow instance).
pub enum LocalSender {
    /// Bounded channel (backpressure; the single-process default).
    Bounded(SyncSender<Msg>),
    /// Unbounded channel (worker-local inboxes).
    Unbounded(Sender<Msg>),
}

impl LocalSender {
    fn send(&self, msg: Msg) -> Result<()> {
        let sent = match self {
            LocalSender::Bounded(tx) => tx.send(msg).is_ok(),
            LocalSender::Unbounded(tx) => tx.send(msg).is_ok(),
        };
        if sent {
            Ok(())
        } else {
            Err(Error::Transport("local inbox disconnected".into()))
        }
    }
}

impl Clone for LocalSender {
    fn clone(&self) -> Self {
        match self {
            LocalSender::Bounded(tx) => LocalSender::Bounded(tx.clone()),
            LocalSender::Unbounded(tx) => LocalSender::Unbounded(tx.clone()),
        }
    }
}

/// Same-process lane: a refcount bump through an in-memory channel.
pub struct InProcessLane {
    tx: LocalSender,
}

impl InProcessLane {
    /// Lane over a bounded channel.
    pub fn new(tx: SyncSender<Msg>) -> Self {
        InProcessLane {
            tx: LocalSender::Bounded(tx),
        }
    }

    /// Lane over an unbounded channel.
    pub fn unbounded(tx: Sender<Msg>) -> Self {
        InProcessLane {
            tx: LocalSender::Unbounded(tx),
        }
    }
}

impl Lane for InProcessLane {
    fn framed(&self) -> bool {
        false
    }

    fn deliver(&mut self, msg: Msg) -> Result<()> {
        self.tx.send(msg)
    }
}

/// Emulated-network lane: frames traverse a shared uplink [`Link`] with
/// bandwidth/latency shaping before landing in the destination inbox.
pub struct NetsimLane {
    link: Arc<Link<Msg>>,
    latency: Duration,
    tx: SyncSender<Msg>,
}

impl NetsimLane {
    /// Lane through `link` (route latency stamped per frame) into `tx`.
    pub fn new(link: Arc<Link<Msg>>, latency: Duration, tx: SyncSender<Msg>) -> Self {
        NetsimLane { link, latency, tx }
    }
}

impl Lane for NetsimLane {
    fn framed(&self) -> bool {
        true
    }

    fn deliver(&mut self, msg: Msg) -> Result<()> {
        let size = match &msg {
            Msg::Frame(bytes) => bytes.len() + FRAME_OVERHEAD,
            _ => FRAME_OVERHEAD,
        };
        if self.link.send(size, self.latency, msg, &self.tx) {
            Ok(())
        } else {
            Err(Error::Transport(
                "emulated link closed or destination disconnected".into(),
            ))
        }
    }
}

/// In-process transport: a registry of instance inboxes in this process.
/// The default — and the only transport in tier-1 test runs.
#[derive(Default)]
pub struct ChannelTransport {
    inboxes: HashMap<usize, LocalSender>,
}

impl ChannelTransport {
    /// Empty registry.
    pub fn new() -> Self {
        Self::default()
    }

    /// Registers an instance's bounded inbox sender.
    pub fn register(&mut self, instance: usize, tx: SyncSender<Msg>) {
        self.inboxes.insert(instance, LocalSender::Bounded(tx));
    }

    /// Registers an instance's unbounded inbox sender (worker processes).
    pub fn register_unbounded(&mut self, instance: usize, tx: Sender<Msg>) {
        self.inboxes.insert(instance, LocalSender::Unbounded(tx));
    }
}

impl Transport for ChannelTransport {
    fn name(&self) -> &'static str {
        "channels"
    }

    fn open(&mut self, _from: &Endpoint, to: &Endpoint) -> Result<Box<dyn Lane>> {
        let tx = self.inboxes.get(&to.instance).cloned().ok_or_else(|| {
            Error::Transport(format!("instance {} has no registered inbox", to.instance))
        })?;
        Ok(Box::new(InProcessLane { tx }))
    }
}

/// Emulated-network transport: owns the per-egress-hop uplink cache the
/// coordinator previously kept inline, and selects per edge between an
/// in-process lane (same simulated host) and a shaped [`NetsimLane`].
pub struct NetsimTransport {
    cluster: ClusterSpec,
    metrics: Metrics,
    links: HashMap<String, Arc<Link<Msg>>>,
    inboxes: HashMap<usize, SyncSender<Msg>>,
}

impl NetsimTransport {
    /// Transport over `cluster`'s emulated topology.
    pub fn new(cluster: ClusterSpec, metrics: Metrics) -> Self {
        NetsimTransport {
            cluster,
            metrics,
            links: HashMap::new(),
            inboxes: HashMap::new(),
        }
    }

    /// Registers an instance's inbox sender.
    pub fn register(&mut self, instance: usize, tx: SyncSender<Msg>) {
        self.inboxes.insert(instance, tx);
    }

    /// Drops every registered inbox sender. Called once wiring is done so
    /// the only live senders are the ones inside lanes — a producer panic
    /// must disconnect its consumers' channels, which a lingering registry
    /// clone would prevent.
    pub fn clear_inboxes(&mut self) {
        self.inboxes.clear();
    }

    /// Returns (creating if needed) the shared uplink for the route
    /// `za → zb` plus the route latency to stamp on each frame. Links are
    /// keyed by the route's egress hop so all routes leaving a zone
    /// contend for the same uplink.
    pub fn route(&mut self, za: &str, zb: &str) -> Result<(Arc<Link<Msg>>, Duration)> {
        if za == zb {
            let name = format!("intra-{za}");
            let link = self
                .links
                .entry(name.clone())
                .or_insert_with(|| Link::new(&name, None, false, Some(self.metrics.clone())))
                .clone();
            return Ok((link, Duration::ZERO));
        }
        let spec = crate::placement::route_spec(&self.cluster, za, zb)?;
        let first_hop = first_hop_of_route(&self.cluster, za, zb)?;
        let name = format!("up-{}->{}", first_hop.0, first_hop.1);
        let needs_delay = !spec.latency.is_zero();
        let metrics = self.metrics.clone();
        let link = self
            .links
            .entry(name.clone())
            .or_insert_with(|| Link::new(&name, spec.bandwidth_bps, needs_delay, Some(metrics)))
            .clone();
        Ok((link, spec.latency))
    }

    /// Shuts down every cached link's service threads (teardown).
    pub fn shutdown_links(&self) {
        for link in self.links.values() {
            link.shutdown();
        }
    }
}

impl Transport for NetsimTransport {
    fn name(&self) -> &'static str {
        "netsim"
    }

    fn open(&mut self, from: &Endpoint, to: &Endpoint) -> Result<Box<dyn Lane>> {
        let tx = self.inboxes.get(&to.instance).cloned().ok_or_else(|| {
            Error::Transport(format!("instance {} has no registered inbox", to.instance))
        })?;
        if from.host == to.host {
            return Ok(Box::new(InProcessLane::new(tx)));
        }
        let (link, latency) = self.route(&from.zone, &to.zone)?;
        Ok(Box::new(NetsimLane::new(link, latency, tx)))
    }
}

/// First hop of the tree route from `za` toward `zb` (used to key shared
/// uplinks).
pub fn first_hop_of_route(cluster: &ClusterSpec, za: &str, zb: &str) -> Result<(String, String)> {
    let topo = &cluster.topology;
    // ascend from za; if zb is not on that path, the first hop is still
    // za -> parent(za) (all inter-zone routes leave through the uplink),
    // except when za is an ancestor of zb — then descend toward zb.
    if crate::placement::ancestor_at_layer(topo, zb, &topo.zones[za].layer).as_deref() == Some(za) {
        // za is an ancestor of zb: first hop descends toward zb
        let mut cur = zb.to_string();
        loop {
            let parent = topo.zones[&cur]
                .parent
                .clone()
                .ok_or_else(|| Error::Topology(format!("no path from {za} down to {zb}")))?;
            if parent == za {
                return Ok((za.to_string(), cur));
            }
            cur = parent;
        }
    }
    let parent = topo.zones[za]
        .parent
        .clone()
        .ok_or_else(|| Error::Topology(format!("root zone {za} has no uplink")))?;
    Ok((za.to_string(), parent))
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::config::fig2_cluster;
    use crate::metrics::MetricsRegistry;
    use crate::value::Value;
    use std::sync::mpsc::sync_channel;

    #[test]
    fn first_hop_keys_shared_uplinks() {
        let cluster = fig2_cluster();
        // upward routes leave through the child's uplink
        assert_eq!(
            first_hop_of_route(&cluster, "E1", "S1").unwrap(),
            ("E1".into(), "S1".into())
        );
        assert_eq!(
            first_hop_of_route(&cluster, "E1", "C1").unwrap(),
            ("E1".into(), "S1".into()),
            "E1->C1 and E1->S1 share the E1 uplink"
        );
        // sibling routes also leave through the uplink
        assert_eq!(
            first_hop_of_route(&cluster, "E1", "E4").unwrap(),
            ("E1".into(), "S1".into())
        );
        // downward route from an ancestor descends toward the target
        assert_eq!(
            first_hop_of_route(&cluster, "C1", "E1").unwrap(),
            ("C1".into(), "S1".into())
        );
    }

    #[test]
    fn channel_transport_opens_unframed_lanes() {
        let mut t = ChannelTransport::new();
        let (tx, rx) = sync_channel(4);
        t.register(7, tx);
        let from = Endpoint {
            instance: 0,
            zone: "E1".into(),
            host: "a".into(),
        };
        let to = Endpoint {
            instance: 7,
            zone: "E1".into(),
            host: "a".into(),
        };
        let mut lane = t.open(&from, &to).unwrap();
        assert!(!lane.framed());
        lane.deliver(Msg::Batch(vec![Value::I64(1)].into())).unwrap();
        assert!(matches!(rx.recv().unwrap(), Msg::Batch(_)));
        // unknown destination is an error, not a panic
        let missing = Endpoint {
            instance: 99,
            zone: "E1".into(),
            host: "a".into(),
        };
        assert!(t.open(&from, &missing).is_err());
    }

    #[test]
    fn closed_lane_is_counted_error_not_panic() {
        let mut t = ChannelTransport::new();
        let (tx, rx) = sync_channel(4);
        t.register(1, tx);
        drop(rx);
        let ep = |i: usize| Endpoint {
            instance: i,
            zone: "z".into(),
            host: "h".into(),
        };
        let mut lane = t.open(&ep(0), &ep(1)).unwrap();
        let err = lane.deliver(Msg::Eos).unwrap_err();
        assert!(matches!(err, Error::Transport(_)));
    }

    #[test]
    fn netsim_transport_selects_lane_by_host_and_caches_uplinks() {
        let cluster = fig2_cluster();
        let m = MetricsRegistry::new();
        let mut t = NetsimTransport::new(cluster, m);
        let (tx1, rx1) = sync_channel(4);
        let (tx2, rx2) = sync_channel(4);
        t.register(1, tx1);
        t.register(2, tx2);
        let e1 = Endpoint {
            instance: 0,
            zone: "E1".into(),
            host: "e1a".into(),
        };
        let same_host = Endpoint {
            instance: 1,
            zone: "E1".into(),
            host: "e1a".into(),
        };
        let cloud = Endpoint {
            instance: 2,
            zone: "C1".into(),
            host: "c1cpu".into(),
        };
        let mut local = t.open(&e1, &same_host).unwrap();
        assert!(!local.framed(), "same simulated host stays in-process");
        let mut shaped = t.open(&e1, &cloud).unwrap();
        assert!(shaped.framed(), "cross-host edges are framed");
        // same egress hop -> same cached Link
        let (a, _) = t.route("E1", "S1").unwrap();
        let (b, _) = t.route("E1", "C1").unwrap();
        assert!(Arc::ptr_eq(&a, &b));
        // deliveries work through the trait object
        local
            .deliver(Msg::Batch(vec![Value::I64(5)].into()))
            .unwrap();
        assert!(matches!(rx1.recv().unwrap(), Msg::Batch(_)));
        let batch: crate::value::Batch = vec![Value::I64(6)].into();
        shaped.deliver(Msg::Frame(batch.wire())).unwrap();
        match rx2.recv().unwrap() {
            Msg::Frame(bytes) => {
                let decoded = crate::value::Batch::from_wire(bytes).unwrap();
                assert_eq!(decoded, vec![Value::I64(6)]);
            }
            other => panic!("expected frame, got {other:?}"),
        }
        t.shutdown_links();
    }
}
