use super::*;

#[test]
fn crc32_known_vector() {
    // IEEE CRC32 of "123456789" is 0xCBF43926.
    assert_eq!(crc32(b"123456789"), 0xcbf4_3926);
}

#[test]
fn append_poll_roundtrip() {
    let broker = QueueBroker::in_memory(None);
    let t = broker.topic("t", 2).unwrap();
    t.register_producer();
    for i in 0..10u64 {
        t.append(i, &i.to_le_bytes()).unwrap();
    }
    t.producer_done();
    let mut seen = Vec::new();
    for p in 0..2 {
        let mut off = 0;
        while let Some((recs, next)) = t.partition(p).poll(off, 4, Duration::from_millis(10)) {
            for r in &recs {
                seen.push(u64::from_le_bytes(r.as_ref().try_into().unwrap()));
            }
            off = next;
            if recs.is_empty() {
                break;
            }
        }
    }
    seen.sort_unstable();
    assert_eq!(seen, (0..10).collect::<Vec<_>>());
}

#[test]
fn append_batch_shares_the_encoded_buffer() {
    let broker = QueueBroker::in_memory(None);
    let t = broker.topic("t", 1).unwrap();
    t.register_producer();
    let batch = Batch::new(vec![crate::value::Value::I64(42)]);
    t.append_batch(0, &batch).unwrap();
    t.producer_done();
    let (recs, _) = t.partition(0).poll(0, 10, Duration::from_millis(10)).unwrap();
    assert_eq!(recs.len(), 1);
    let wire = batch.wire_cached().expect("append populated the cache");
    assert!(
        Arc::ptr_eq(&recs[0], &wire),
        "the log holds the producer's buffer, not a copy"
    );
    assert_eq!(Batch::from_wire(recs[0].clone()).unwrap(), batch);
}

#[test]
fn key_hash_partitions_consistently() {
    let broker = QueueBroker::in_memory(None);
    let t = broker.topic("t", 4).unwrap();
    t.register_producer();
    t.append(13, b"a").unwrap();
    t.append(13, b"b").unwrap();
    t.producer_done();
    let p = (13 % 4) as usize;
    assert_eq!(t.partition(p).len(), 2);
}

#[test]
fn poll_blocks_until_append() {
    let broker = QueueBroker::in_memory(None);
    let t = broker.topic("t", 1).unwrap();
    t.register_producer();
    let t2 = t.clone();
    let h = std::thread::spawn(move || {
        std::thread::sleep(Duration::from_millis(30));
        t2.append(0, b"late").unwrap();
    });
    let (recs, next) = t
        .partition(0)
        .poll(0, 10, Duration::from_secs(2))
        .expect("open partition");
    assert_eq!(recs.len(), 1);
    assert_eq!(next, 1);
    h.join().unwrap();
}

#[test]
fn poll_with_zero_or_elapsed_timeout_never_panics() {
    let broker = QueueBroker::in_memory(None);
    let t = broker.topic("t", 1).unwrap();
    t.register_producer();
    // zero timeout on an open, empty partition: immediate timed-out
    // return (regression: the deadline math used to underflow)
    let r = t.partition(0).poll(0, 10, Duration::ZERO);
    assert!(matches!(r, Some((v, 0)) if v.is_empty()));
    let r = t.partition(0).poll(0, 10, Duration::from_nanos(1));
    assert!(matches!(r, Some((v, 0)) if v.is_empty()));
    // with data present, a zero timeout still returns the records
    t.append(0, b"x").unwrap();
    let r = t.partition(0).poll(0, 10, Duration::ZERO).unwrap();
    assert_eq!(r.0.len(), 1);
}

#[test]
fn poll_many_drains_ready_partitions_and_ends_when_all_closed() {
    let broker = QueueBroker::in_memory(None);
    let t = broker.topic("t", 4).unwrap();
    t.register_producer();
    t.append(0, b"a").unwrap();
    t.append(2, b"c").unwrap();
    let parts: Vec<usize> = (0..4).collect();
    let mut offsets = vec![0; 4];
    let drained = t
        .poll_many(&parts, &mut offsets, 16, Duration::from_millis(10))
        .unwrap();
    let slots: Vec<usize> = drained.iter().map(|(s, _)| *s).collect();
    assert_eq!(slots, vec![0, 2], "one wakeup drains every ready partition");
    assert_eq!(offsets, vec![1, 0, 1, 0]);
    // timeout with every partition still open: empty drain, not EOS
    let r = t
        .poll_many(&parts, &mut offsets, 16, Duration::from_millis(5))
        .unwrap();
    assert!(r.is_empty());
    t.producer_done(); // closes all partitions
    assert!(t
        .poll_many(&parts, &mut offsets, 16, Duration::from_millis(10))
        .is_none());
}

#[test]
fn poll_many_wakes_on_single_append_across_many_partitions() {
    let m = crate::metrics::MetricsRegistry::new();
    let broker = QueueBroker::in_memory(Some(m.clone()));
    let t = broker.topic("t", 16).unwrap();
    t.register_producer();
    let t2 = t.clone();
    let h = std::thread::spawn(move || {
        std::thread::sleep(Duration::from_millis(150));
        t2.append(11, b"late").unwrap();
    });
    let parts: Vec<usize> = (0..16).collect();
    let mut offsets = vec![0; 16];
    let t0 = Instant::now();
    let drained = loop {
        let d = t
            .poll_many(&parts, &mut offsets, 16, Duration::from_secs(30))
            .unwrap();
        if !d.is_empty() {
            break d;
        }
    };
    h.join().unwrap();
    assert!(
        t0.elapsed() < Duration::from_secs(10),
        "woken by the append, not the timeout"
    );
    assert_eq!(drained.len(), 1);
    assert_eq!(drained[0].0, 11, "slot of the appended partition");
    assert_eq!(drained[0].1[0].as_ref(), b"late");
    assert_eq!(offsets[11], 1);
    assert!(
        m.queue_wakeups.load(std::sync::atomic::Ordering::Relaxed) >= 1,
        "consumption was wakeup-driven"
    );
    assert_eq!(
        m.queue_wait_timeouts
            .load(std::sync::atomic::Ordering::Relaxed),
        0,
        "no timed-poll floor in the path"
    );
}

#[test]
fn kick_wakes_a_parked_consumer_without_data() {
    let broker = QueueBroker::in_memory(None);
    let t = broker.topic("t", 2).unwrap();
    t.register_producer();
    let t2 = t.clone();
    let h = std::thread::spawn(move || {
        std::thread::sleep(Duration::from_millis(30));
        t2.kick();
    });
    let mut offsets = vec![0, 0];
    let t0 = Instant::now();
    let r = t
        .poll_many(&[0, 1], &mut offsets, 16, Duration::from_secs(30))
        .unwrap();
    h.join().unwrap();
    assert!(r.is_empty(), "a kick hands back control, not data");
    assert!(t0.elapsed() < Duration::from_secs(10));
}

#[test]
fn poll_many_with_no_partitions_is_end_of_stream() {
    let broker = QueueBroker::in_memory(None);
    let t = broker.topic("t", 1).unwrap();
    let mut offsets: Vec<usize> = Vec::new();
    assert!(t
        .poll_many(&[], &mut offsets, 16, Duration::from_millis(5))
        .is_none());
}

#[test]
fn close_signals_end_of_stream_after_drain() {
    let broker = QueueBroker::in_memory(None);
    let t = broker.topic("t", 1).unwrap();
    t.register_producer();
    t.append(0, b"x").unwrap();
    t.producer_done();
    let (recs, next) = t.partition(0).poll(0, 10, Duration::from_millis(10)).unwrap();
    assert_eq!(recs.len(), 1);
    assert!(t.partition(0).poll(next, 10, Duration::from_millis(10)).is_none());
}

#[test]
fn multi_producer_close_requires_all() {
    let broker = QueueBroker::in_memory(None);
    let t = broker.topic("t", 1).unwrap();
    t.register_producer();
    t.register_producer();
    t.producer_done();
    // still open: one producer remains
    let r = t.partition(0).poll(0, 10, Duration::from_millis(10));
    assert!(matches!(r, Some((v, 0)) if v.is_empty()));
    t.producer_done();
    assert!(t.partition(0).poll(0, 10, Duration::from_millis(10)).is_none());
}

#[test]
fn commits_are_monotonic() {
    let broker = QueueBroker::in_memory(None);
    let t = broker.topic("t", 1).unwrap();
    let p = t.partition(0);
    p.commit("g", 5);
    p.commit("g", 3); // must not regress
    assert_eq!(p.committed("g"), 5);
    assert_eq!(p.committed("other"), 0);
}

#[test]
fn lag_tracks_appends_minus_commits() {
    let broker = QueueBroker::in_memory(None);
    let t = broker.topic("t", 2).unwrap();
    t.register_producer();
    for i in 0..6u64 {
        t.append(i, b"r").unwrap();
    }
    assert_eq!(t.lag("g"), 6, "nothing committed yet");
    t.partition(0).commit("g", 2);
    assert_eq!(t.lag("g"), 4);
    assert_eq!(t.partition(0).lag("g"), 1);
    // a foreign group's commits don't affect this group's lag
    t.partition(1).commit("other", 3);
    assert_eq!(t.lag("g"), 4);
}

#[test]
fn compact_before_tombstones_in_place_and_preserves_offsets() {
    let m = crate::metrics::MetricsRegistry::new();
    let broker = QueueBroker::in_memory(Some(m.clone()));
    let t = broker.topic("state", 1).unwrap();
    t.register_producer();
    for i in 0..6u64 {
        t.append(0, &i.to_le_bytes()).unwrap();
    }
    let p = t.partition(0);
    assert_eq!(p.compact_before(4), 4);
    // offsets are stable: the log is the same length, survivors sit at
    // their original positions, the prefix reads back as empty records
    assert_eq!(p.len(), 6);
    let (recs, next) = p.poll(0, 10, Duration::from_millis(10)).unwrap();
    assert_eq!(next, 6);
    assert!(recs[..4].iter().all(|r| r.is_empty()));
    assert_eq!(recs[4].as_ref(), &4u64.to_le_bytes());
    assert_eq!(recs[5].as_ref(), &5u64.to_le_bytes());
    // idempotent: a second pass finds nothing new to tombstone
    assert_eq!(p.compact_before(4), 0);
    assert_eq!(
        m.state_compactions.load(std::sync::atomic::Ordering::Relaxed),
        4
    );
    // appends continue past the compacted prefix
    t.append(0, &6u64.to_le_bytes()).unwrap();
    assert_eq!(p.len(), 7);
}

#[test]
fn durable_compaction_survives_recovery() {
    let dir = std::env::temp_dir().join(format!("fuq-compact-{}", std::process::id()));
    let _ = std::fs::remove_dir_all(&dir);
    {
        let broker = QueueBroker::durable(&dir, None).unwrap();
        let t = broker.topic("state", 1).unwrap();
        t.register_producer();
        for i in 0..5u32 {
            t.append(0, format!("rec{i}").as_bytes()).unwrap();
        }
        assert_eq!(t.partition(0).compact_before(3), 3);
    }
    {
        let broker = QueueBroker::durable(&dir, None).unwrap();
        let t = broker.topic("state", 1).unwrap();
        let p = t.partition(0);
        assert_eq!(p.len(), 5, "tombstones recover at their indices");
        let (recs, _) = p.poll(0, 10, Duration::from_millis(10)).unwrap();
        assert!(recs[..3].iter().all(|r| r.is_empty()));
        assert_eq!(recs[3].as_ref(), b"rec3");
        assert_eq!(recs[4].as_ref(), b"rec4");
    }
    std::fs::remove_dir_all(&dir).unwrap();
}

#[test]
fn durable_topic_recovers_records_and_supports_resume() {
    let dir = std::env::temp_dir().join(format!("fuq-{}", std::process::id()));
    let _ = std::fs::remove_dir_all(&dir);
    {
        let broker = QueueBroker::durable(&dir, None).unwrap();
        let t = broker.topic("sensor", 1).unwrap();
        t.register_producer();
        for i in 0..5u32 {
            t.append(0, format!("rec{i}").as_bytes()).unwrap();
        }
        // no producer_done: simulate crash
    }
    {
        let broker = QueueBroker::durable(&dir, None).unwrap();
        let t = broker.topic("sensor", 1).unwrap();
        assert_eq!(t.partition(0).len(), 5);
        let (recs, _) = t.partition(0).poll(0, 10, Duration::from_millis(10)).unwrap();
        assert_eq!(recs[4].as_ref(), b"rec4");
        // appends continue after recovery
        t.register_producer();
        t.append(0, b"rec5").unwrap();
        assert_eq!(t.partition(0).len(), 6);
    }
    std::fs::remove_dir_all(&dir).unwrap();
}

#[test]
fn recovery_tolerates_torn_tail() {
    let dir = std::env::temp_dir().join(format!("fuq-torn-{}", std::process::id()));
    let _ = std::fs::remove_dir_all(&dir);
    std::fs::create_dir_all(&dir).unwrap();
    let path = dir.join("t-0.log");
    {
        let mut f = File::create(&path).unwrap();
        let body = b"good";
        f.write_all(&(body.len() as u32).to_le_bytes()).unwrap();
        f.write_all(&crc32(body).to_le_bytes()).unwrap();
        f.write_all(body).unwrap();
        // torn record: header promises 100 bytes, body truncated
        f.write_all(&100u32.to_le_bytes()).unwrap();
        f.write_all(&0u32.to_le_bytes()).unwrap();
        f.write_all(b"short").unwrap();
    }
    let broker = QueueBroker::durable(&dir, None).unwrap();
    let t = broker.topic("t", 1).unwrap();
    assert_eq!(t.partition(0).len(), 1);
    std::fs::remove_dir_all(&dir).unwrap();
}

#[test]
fn torn_tail_is_truncated_and_appends_continue_cleanly() {
    // regression: recovery used to leave the torn bytes in the file, so a
    // post-recovery append landed after garbage and the *next* recovery
    // failed mid-log
    let dir = std::env::temp_dir().join(format!("fuq-torn2-{}", std::process::id()));
    let _ = std::fs::remove_dir_all(&dir);
    std::fs::create_dir_all(&dir).unwrap();
    let path = dir.join("t-0.log");
    {
        let mut f = File::create(&path).unwrap();
        let body = b"good";
        f.write_all(&(body.len() as u32).to_le_bytes()).unwrap();
        f.write_all(&crc32(body).to_le_bytes()).unwrap();
        f.write_all(body).unwrap();
        f.write_all(&100u32.to_le_bytes()).unwrap();
        f.write_all(&7u32.to_le_bytes()).unwrap();
        f.write_all(b"garbage").unwrap();
    }
    let m = crate::metrics::MetricsRegistry::new();
    {
        let broker = QueueBroker::durable(&dir, Some(m.clone())).unwrap();
        let t = broker.topic("t", 1).unwrap();
        assert_eq!(t.partition(0).len(), 1);
        assert_eq!(
            m.torn_tails_truncated
                .load(std::sync::atomic::Ordering::Relaxed),
            1
        );
        t.register_producer();
        t.append(0, b"after-crash").unwrap();
    }
    {
        let broker = QueueBroker::durable(&dir, None).unwrap();
        let t = broker.topic("t", 1).unwrap();
        assert_eq!(t.partition(0).len(), 2, "the log recovered both records");
        let (recs, _) = t.partition(0).poll(0, 10, Duration::from_millis(10)).unwrap();
        assert_eq!(recs[0].as_ref(), b"good");
        assert_eq!(recs[1].as_ref(), b"after-crash");
    }
    std::fs::remove_dir_all(&dir).unwrap();
}

#[test]
fn crc_failed_final_frame_truncates_like_a_torn_tail() {
    // a kill mid-write can flush the full frame length with stale bytes in
    // the body; a CRC failure on the *final* frame is that artifact, not
    // corruption
    let dir = std::env::temp_dir().join(format!("fuq-tailcrc-{}", std::process::id()));
    let _ = std::fs::remove_dir_all(&dir);
    std::fs::create_dir_all(&dir).unwrap();
    let path = dir.join("t-0.log");
    {
        let mut f = File::create(&path).unwrap();
        let body = b"good";
        f.write_all(&(body.len() as u32).to_le_bytes()).unwrap();
        f.write_all(&crc32(body).to_le_bytes()).unwrap();
        f.write_all(body).unwrap();
        let torn = b"torn";
        f.write_all(&(torn.len() as u32).to_le_bytes()).unwrap();
        f.write_all(&0xdeadbeefu32.to_le_bytes()).unwrap();
        f.write_all(torn).unwrap();
    }
    let m = crate::metrics::MetricsRegistry::new();
    let broker = QueueBroker::durable(&dir, Some(m.clone())).unwrap();
    let t = broker.topic("t", 1).unwrap();
    assert_eq!(t.partition(0).len(), 1, "only the valid prefix survives");
    assert_eq!(
        m.torn_tails_truncated
            .load(std::sync::atomic::Ordering::Relaxed),
        1
    );
    std::fs::remove_dir_all(&dir).unwrap();
}

#[test]
fn recovery_rejects_mid_log_corruption() {
    let dir = std::env::temp_dir().join(format!("fuq-crc-{}", std::process::id()));
    let _ = std::fs::remove_dir_all(&dir);
    std::fs::create_dir_all(&dir).unwrap();
    let path = dir.join("t-0.log");
    {
        let mut f = File::create(&path).unwrap();
        // corrupt frame *followed by a valid one*: this is not a torn
        // tail, it is real corruption and must refuse to open
        let body = b"evil";
        f.write_all(&(body.len() as u32).to_le_bytes()).unwrap();
        f.write_all(&0xdeadbeefu32.to_le_bytes()).unwrap();
        f.write_all(body).unwrap();
        let good = b"fine";
        f.write_all(&(good.len() as u32).to_le_bytes()).unwrap();
        f.write_all(&crc32(good).to_le_bytes()).unwrap();
        f.write_all(good).unwrap();
    }
    let broker = QueueBroker::durable(&dir, None).unwrap();
    assert!(broker.topic("t", 1).is_err());
    std::fs::remove_dir_all(&dir).unwrap();
}

#[test]
fn rejected_append_is_never_persisted() {
    let dir = std::env::temp_dir().join(format!("fuq-closed-{}", std::process::id()));
    let _ = std::fs::remove_dir_all(&dir);
    {
        let broker = QueueBroker::durable(&dir, None).unwrap();
        let t = broker.topic("t", 1).unwrap();
        t.register_producer();
        t.append(0, b"kept").unwrap();
        t.producer_done(); // closes the partition
        assert!(t.append(0, b"rejected").is_err());
    }
    let broker = QueueBroker::durable(&dir, None).unwrap();
    let t = broker.topic("t", 1).unwrap();
    assert_eq!(
        t.partition(0).len(),
        1,
        "a rejected append must not reappear after recovery"
    );
    std::fs::remove_dir_all(&dir).unwrap();
}

#[test]
fn append_to_closed_partition_fails() {
    let broker = QueueBroker::in_memory(None);
    let t = broker.topic("t", 1).unwrap();
    t.register_producer();
    t.producer_done();
    assert!(t.append(0, b"x").is_err());
    t.reopen();
    t.register_producer();
    assert!(t.append(0, b"x").is_ok());
}

#[test]
fn watermark_records_roundtrip_and_reject_other_payloads() {
    let wm = Watermark {
        from: 3,
        ts: 123_456,
        origin_ms: 99,
    };
    let rec = watermark_record(&wm);
    assert_eq!(rec.len(), 24);
    assert_eq!(decode_watermark(&rec), Some(wm));
    assert_eq!(decode_watermark(b""), None, "tombstones are not watermarks");
    let batch = Batch::new(vec![crate::value::Value::I64(7)]);
    let wire = batch.wire_with(|| {});
    assert_eq!(decode_watermark(&wire), None, "batch wire is not a watermark");
}

#[test]
fn bounded_durable_broker_spills_and_rereads_beyond_budget() {
    let dir = std::env::temp_dir().join(format!("fuq-spill-{}", std::process::id()));
    let _ = std::fs::remove_dir_all(&dir);
    let m = crate::metrics::MetricsRegistry::new();
    let broker = QueueBroker::durable_bounded(&dir, 4 * 1024, Some(m.clone())).unwrap();
    broker.set_resident_tail(4);
    let t = broker.topic("hot", 1).unwrap();
    t.register_producer();
    let body = [7u8; 256];
    for _ in 0..200 {
        // 200 × 256 B = 50 KiB ingested through a 4 KiB budget
        t.append(0, &body).unwrap();
    }
    assert!(
        broker.resident_bytes() <= 4 * 1024,
        "resident bytes stay under budget, got {}",
        broker.resident_bytes()
    );
    t.producer_done();
    let p = t.partition(0);
    let mut off = 0;
    let mut seen = 0;
    while let Some((recs, next)) = p.poll(off, 64, Duration::from_millis(10)) {
        if recs.is_empty() {
            break;
        }
        for r in &recs {
            assert_eq!(r.as_ref(), &body[..]);
            seen += 1;
        }
        off = next;
    }
    assert_eq!(seen, 200, "evicted records are transparently re-read");
    assert!(m.spill_reads.load(std::sync::atomic::Ordering::Relaxed) > 0);
    std::fs::remove_dir_all(&dir).unwrap();
}

#[test]
fn bounded_durable_topic_survives_recovery_with_spills() {
    let dir = std::env::temp_dir().join(format!("fuq-spillrec-{}", std::process::id()));
    let _ = std::fs::remove_dir_all(&dir);
    {
        let broker = QueueBroker::durable_bounded(&dir, 512, None).unwrap();
        broker.set_resident_tail(2);
        let t = broker.topic("hot", 1).unwrap();
        t.register_producer();
        for i in 0..20u8 {
            t.append(0, &[i; 64]).unwrap();
        }
    }
    {
        // recovery charges the recovered records then sweeps back under
        // the budget; every record still reads back
        let broker = QueueBroker::durable_bounded(&dir, 512, None).unwrap();
        broker.set_resident_tail(2);
        let t = broker.topic("hot", 1).unwrap();
        assert!(broker.resident_bytes() <= 512);
        let (recs, next) = t.partition(0).poll(0, 32, Duration::from_millis(10)).unwrap();
        assert_eq!(next, 20);
        for (i, r) in recs.iter().enumerate() {
            assert_eq!(r.as_ref(), &[i as u8; 64]);
        }
    }
    std::fs::remove_dir_all(&dir).unwrap();
}

#[test]
fn backpressure_blocks_producer_until_consumer_commits() {
    let broker = QueueBroker::in_memory_bounded(1024, None);
    broker.set_default_policy(OverloadPolicy::Backpressure {
        deadline: Duration::from_secs(10),
    });
    let t = broker.topic("t", 1).unwrap();
    t.register_producer();
    let body = [1u8; 256];
    for _ in 0..4 {
        t.append(0, &body).unwrap(); // budget exactly full
    }
    let t2 = t.clone();
    let h = std::thread::spawn(move || {
        std::thread::sleep(Duration::from_millis(60));
        let p = t2.partition(0);
        let (recs, next) = p.poll(0, 16, Duration::from_millis(500)).unwrap();
        assert_eq!(recs.len(), 4);
        p.commit("g", next); // frees the committed prefix
    });
    let t0 = Instant::now();
    t.append(0, &body).unwrap(); // blocks until the commit frees memory
    assert!(
        t0.elapsed() >= Duration::from_millis(30),
        "the append waited for the consumer"
    );
    h.join().unwrap();
    assert_eq!(t.partition(0).len(), 5, "zero loss under backpressure");
}

#[test]
fn backpressure_deadline_refuses_instead_of_growing() {
    let broker = QueueBroker::in_memory_bounded(512, None);
    broker.set_default_policy(OverloadPolicy::Backpressure {
        deadline: Duration::from_millis(50),
    });
    let t = broker.topic("t", 1).unwrap();
    t.register_producer();
    let body = [1u8; 256];
    t.append(0, &body).unwrap();
    t.append(0, &body).unwrap();
    let err = t.append(0, &body).unwrap_err();
    assert!(format!("{err}").contains("backpressure"));
    assert_eq!(t.partition(0).len(), 2, "the refused record never enqueued");
    assert!(broker.resident_bytes() <= 512);
}

#[test]
fn oversize_record_is_admitted_when_memory_is_empty() {
    let broker = QueueBroker::in_memory_bounded(64, None);
    broker.set_default_policy(OverloadPolicy::Backpressure {
        deadline: Duration::from_millis(50),
    });
    let t = broker.topic("t", 1).unwrap();
    t.register_producer();
    // larger than the whole budget: admitted alone rather than deadlocked
    t.append(0, &[5u8; 256]).unwrap();
    let err = t.append(0, b"next").unwrap_err();
    assert!(format!("{err}").contains("backpressure"));
}

#[test]
fn shed_drop_oldest_tombstones_with_exact_accounting() {
    let m = crate::metrics::MetricsRegistry::new();
    let broker = QueueBroker::in_memory_bounded(1024, Some(m.clone()));
    broker.set_default_policy(OverloadPolicy::Shed(ShedMode::DropOldest));
    let t = broker.topic("t", 1).unwrap();
    t.register_producer();
    let body = [9u8; 64];
    for _ in 0..100 {
        t.append(0, &body).unwrap();
    }
    t.producer_done();
    let p = t.partition(0);
    assert_eq!(p.len(), 100, "offsets stay stable; shed records tombstone");
    let (recs, _) = p.poll(0, 200, Duration::from_millis(10)).unwrap();
    let live = recs.iter().filter(|r| !r.is_empty()).count() as u64;
    let shed = m.records_shed.load(std::sync::atomic::Ordering::Relaxed);
    assert!(shed > 0, "overload forced shedding");
    assert_eq!(live + shed, 100, "every record delivered or accounted shed");
    assert!(broker.resident_bytes() <= 1024);
    assert!(!recs[99].is_empty(), "the newest record survives drop-oldest");
}

#[test]
fn shed_sample_retains_a_thinned_history() {
    let m = crate::metrics::MetricsRegistry::new();
    let broker = QueueBroker::in_memory_bounded(1024, Some(m.clone()));
    broker.set_default_policy(OverloadPolicy::Shed(ShedMode::Sample));
    let t = broker.topic("t", 1).unwrap();
    t.register_producer();
    let body = [3u8; 64];
    for _ in 0..100 {
        t.append(0, &body).unwrap();
    }
    t.producer_done();
    let (recs, _) = t
        .partition(0)
        .poll(0, 200, Duration::from_millis(10))
        .unwrap();
    let shed = m.records_shed.load(std::sync::atomic::Ordering::Relaxed);
    assert!(shed > 0);
    // unlike drop-oldest, sampling keeps survivors inside the shed region
    let oldest_quarter_live = recs[..25].iter().filter(|r| !r.is_empty()).count();
    assert!(
        oldest_quarter_live > 0,
        "sampling retains part of the old history"
    );
}

#[test]
fn compaction_materializes_evicted_survivors() {
    let dir = std::env::temp_dir().join(format!("fuq-cspill-{}", std::process::id()));
    let _ = std::fs::remove_dir_all(&dir);
    {
        let broker = QueueBroker::durable_bounded(&dir, 512, None).unwrap();
        broker.set_resident_tail(0); // evict everything evictable
        let t = broker.topic("state", 1).unwrap();
        t.register_producer();
        for i in 0..8u8 {
            t.append(0, &[i; 128]).unwrap();
        }
        let p = t.partition(0);
        assert_eq!(p.compact_before(5), 5);
        let (recs, _) = p.poll(0, 16, Duration::from_millis(10)).unwrap();
        assert!(recs[..5].iter().all(|r| r.is_empty()));
        assert_eq!(recs[5].as_ref(), &[5u8; 128]);
        assert_eq!(recs[7].as_ref(), &[7u8; 128]);
    }
    {
        let broker = QueueBroker::durable(&dir, None).unwrap();
        let t = broker.topic("state", 1).unwrap();
        let p = t.partition(0);
        assert_eq!(p.len(), 8, "the rewritten segment keeps every offset");
        let (recs, _) = p.poll(0, 16, Duration::from_millis(10)).unwrap();
        assert!(recs[..5].iter().all(|r| r.is_empty()));
        assert_eq!(recs[6].as_ref(), &[6u8; 128]);
    }
    std::fs::remove_dir_all(&dir).unwrap();
}

#[test]
fn unbounded_broker_reports_zero_resident_and_no_budget() {
    let broker = QueueBroker::in_memory(None);
    assert_eq!(broker.resident_bytes(), 0);
    assert_eq!(broker.memory_budget(), None);
    let bounded = QueueBroker::in_memory_bounded(2048, None);
    assert_eq!(bounded.memory_budget(), Some(2048));
    let t = bounded.topic("t", 1).unwrap();
    t.register_producer();
    t.append(0, &[0u8; 100]).unwrap();
    assert_eq!(bounded.resident_bytes(), 100, "the gauge tracks live bytes");
}
