//! Persistent queue substrate — the stand-in for the Kafka queues the paper
//! places between FlowUnits to decouple them for dynamic updates (§III–IV).
//!
//! Semantics mirror the Kafka subset the paper relies on:
//! * a **topic** is split into **partitions**, each an append-only record
//!   log;
//! * **producers** append records; appends are durable when the broker is
//!   opened with a data directory (length- and CRC32-framed segment files,
//!   recovered on open). A record is one encoded *batch*: producers append
//!   at batch granularity ([`Topic::append_batch`] /
//!   [`Partition::append_shared`]) re-using the batch's cached wire
//!   encoding, and the in-memory log holds the same refcounted buffer the
//!   sender encoded — one encode, zero copies, per batch;
//! * **consumer groups** track a committed offset per partition; consumers
//!   poll from their offset and commit after processing, giving
//!   at-least-once delivery across FlowUnit restarts — exactly what the
//!   dynamic-update path needs;
//! * producers register with a topic; when all registered producers have
//!   called [`Topic::producer_done`], the partitions are *closed* and
//!   drained consumers observe end-of-stream.
//!
//! # Bounded memory and overload
//!
//! A broker opened with a memory budget ([`QueueBroker::durable_bounded`] /
//! [`QueueBroker::in_memory_bounded`]) keeps total resident record bytes
//! under the budget. Durable partitions keep a resident tail window
//! ([`QueueBroker::set_resident_tail`]) and evict older payloads to their
//! segment files — the log keeps only the record's byte position, and a
//! poll of an evicted record transparently re-reads it (`spill_reads`
//! metric). In-memory partitions cannot spill; they reclaim prefixes every
//! consumer group has committed, and beyond that the topic's
//! [`OverloadPolicy`] decides:
//!
//! * [`OverloadPolicy::Backpressure`] — the producer's `append` blocks
//!   until memory frees up, failing with a queue error after the deadline.
//!   Nothing is ever dropped; the slowdown propagates upstream.
//! * [`OverloadPolicy::Shed`] — the oldest resident records are replaced
//!   with tombstones (offset-stably, so commits never shift), counted in
//!   the `records_shed` metric — shedding is never silent.
//!
//! The `resident_bytes` metric records the high-water mark of charged
//! bytes; [`QueueBroker::resident_bytes`] reads the live gauge.
//!
//! # Crash tolerance
//!
//! Segment recovery truncates a torn tail — a partial final frame or a
//! final frame whose CRC fails (the normal `kill -9` artifact) — back to
//! the last valid frame boundary (`torn_tails_truncated` metric) so later
//! appends land on a clean log; corruption *before* the final frame is an
//! error. The segment I/O runs behind the [`SegmentFs`] trait so tests can
//! inject faults ([`fault::FaultFs`]): short writes, ENOSPC at a chosen
//! byte, failing truncates.
//!
//! Watermarks cross queue-decoupled boundaries as in-band sentinel records
//! ([`watermark_record`] / [`decode_watermark`]) so event-time progress
//! survives the same replay path as data.

pub mod fault;

use crate::channels::Watermark;
use crate::error::{Error, Result};
use crate::metrics::{Metrics, MetricsRegistry};
use crate::value::Batch;
use std::collections::BTreeMap;
use std::fs::{File, OpenOptions};
use std::io::{self, Read, Write};
use std::path::{Path, PathBuf};
use std::sync::atomic::{AtomicU64, AtomicUsize, Ordering};
use std::sync::{Arc, Condvar, Mutex, OnceLock, Weak};
use std::time::{Duration, Instant};

/// A shared broker handle.
pub type Broker = Arc<QueueBroker>;

/// What a bounded broker does when a topic's appends would exceed the
/// memory budget and nothing is left to spill or reclaim.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum OverloadPolicy {
    /// Block the producer's `append` until memory frees up; fail with a
    /// queue error once `deadline` has elapsed. Zero loss — the slowdown
    /// propagates through ingest to the upstream producer.
    Backpressure {
        /// How long an append may block before it is refused.
        deadline: Duration,
    },
    /// Drop resident records (offset-stable tombstones) to stay under the
    /// budget, counted in the `records_shed` metric.
    Shed(ShedMode),
}

/// Which records a shedding topic sacrifices under overload.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum ShedMode {
    /// Tombstone the oldest resident records first.
    DropOldest,
    /// Tombstone every other record among the oldest, retaining a thinned
    /// sample of the history.
    Sample,
}

impl Default for OverloadPolicy {
    fn default() -> Self {
        OverloadPolicy::Backpressure {
            deadline: Duration::from_secs(30),
        }
    }
}

/// Append/read/truncate interface of one segment file. Implemented by the
/// real filesystem and by the [`fault`] shim for crash-injection tests.
pub trait SegmentIo: Send {
    /// Appends `buf` at the end of the segment. A failed append may leave
    /// a partial frame behind — recovery truncates it.
    fn append(&mut self, buf: &[u8]) -> io::Result<()>;
    /// Reads exactly `out.len()` bytes starting at byte `pos`.
    fn read_at(&self, pos: u64, out: &mut [u8]) -> io::Result<()>;
    /// Truncates the segment to `len` bytes.
    fn truncate(&mut self, len: u64) -> io::Result<()>;
}

/// Factory for segment files, keyed by path. The broker routes all segment
/// I/O through this trait so tests can substitute [`fault::FaultFs`].
pub trait SegmentFs: Send + Sync {
    /// Returns the full contents of the segment at `path`, or `None` if it
    /// does not exist (used once, for recovery on open).
    fn read(&self, path: &Path) -> io::Result<Option<Vec<u8>>>;
    /// Opens (creating if missing) the segment at `path` for appending.
    fn open(&self, path: &Path) -> io::Result<Box<dyn SegmentIo>>;
}

/// The real filesystem: one append-mode file handle per segment,
/// positional reads via `pread`.
struct RealFs;

struct RealSegment(File);

impl SegmentFs for RealFs {
    fn read(&self, path: &Path) -> io::Result<Option<Vec<u8>>> {
        match File::open(path) {
            Ok(mut f) => {
                let mut buf = Vec::new();
                f.read_to_end(&mut buf)?;
                Ok(Some(buf))
            }
            Err(e) if e.kind() == io::ErrorKind::NotFound => Ok(None),
            Err(e) => Err(e),
        }
    }

    fn open(&self, path: &Path) -> io::Result<Box<dyn SegmentIo>> {
        let f = OpenOptions::new()
            .create(true)
            .read(true)
            .append(true)
            .open(path)?;
        Ok(Box::new(RealSegment(f)))
    }
}

impl SegmentIo for RealSegment {
    fn append(&mut self, buf: &[u8]) -> io::Result<()> {
        self.0.write_all(buf)
    }

    fn read_at(&self, pos: u64, out: &mut [u8]) -> io::Result<()> {
        std::os::unix::fs::FileExt::read_exact_at(&self.0, out, pos)
    }

    fn truncate(&mut self, len: u64) -> io::Result<()> {
        self.0.set_len(len)
    }
}

/// One open segment: the I/O handle plus the byte offset of its end (where
/// the next frame lands). `broken` latches on the first I/O error — the
/// partition stops writing and keeps records resident instead of trusting
/// a segment whose tail state is unknown.
struct SegmentFile {
    io: Box<dyn SegmentIo>,
    end: u64,
    broken: bool,
}

/// Default resident tail window per durable partition (records kept in
/// memory even when over budget, so the hot path rarely touches disk).
const DEFAULT_RESIDENT_TAIL: usize = 64;

/// Per-broker memory accounting: total resident record bytes charged
/// against a fixed limit, plus the machinery to get back under it
/// (spilling durable partitions, reclaiming committed prefixes, shedding)
/// and to park backpressured producers.
struct Budget {
    limit: u64,
    resident: AtomicU64,
    /// Records kept resident at the tail of each durable partition.
    tail: AtomicUsize,
    /// Every topic of the broker, for [`Budget::sweep`].
    topics: Mutex<Vec<Weak<Topic>>>,
    /// Parked backpressured producers; uncharges skip the lock + notify
    /// when none are waiting.
    waiters: AtomicUsize,
    lock: Mutex<()>,
    cv: Condvar,
    metrics: Option<Metrics>,
}

impl Budget {
    fn new(limit: u64, metrics: Option<Metrics>) -> Budget {
        Budget {
            limit,
            resident: AtomicU64::new(0),
            tail: AtomicUsize::new(DEFAULT_RESIDENT_TAIL),
            topics: Mutex::new(Vec::new()),
            waiters: AtomicUsize::new(0),
            lock: Mutex::new(()),
            cv: Condvar::new(),
            metrics,
        }
    }

    fn register(&self, topic: &Arc<Topic>) {
        self.topics.lock().unwrap().push(Arc::downgrade(topic));
    }

    /// Unconditional charge (shed-policy appends, recovery, compaction
    /// re-materialization) — the caller follows up with a sweep.
    fn charge(&self, n: u64) {
        let cur = self.resident.fetch_add(n, Ordering::SeqCst) + n;
        self.high_water(cur);
    }

    /// Charges `n` bytes only if it fits the limit. An oversize record is
    /// admitted when nothing else is resident (`cur == 0`) — refusing it
    /// forever would deadlock the producer on a budget it can never meet.
    fn try_charge(&self, n: u64) -> bool {
        let mut cur = self.resident.load(Ordering::SeqCst);
        loop {
            if cur + n > self.limit && cur != 0 {
                return false;
            }
            match self
                .resident
                .compare_exchange(cur, cur + n, Ordering::SeqCst, Ordering::SeqCst)
            {
                Ok(_) => {
                    self.high_water(cur + n);
                    return true;
                }
                Err(seen) => cur = seen,
            }
        }
    }

    fn uncharge(&self, n: u64) {
        self.resident.fetch_sub(n, Ordering::SeqCst);
        if self.waiters.load(Ordering::SeqCst) != 0 {
            let _g = self.lock.lock().unwrap();
            self.cv.notify_all();
        }
    }

    fn excess(&self) -> u64 {
        self.resident.load(Ordering::SeqCst).saturating_sub(self.limit)
    }

    fn high_water(&self, v: u64) {
        if let Some(m) = &self.metrics {
            MetricsRegistry::fetch_max(&m.resident_bytes, v);
        }
    }

    /// Parks a backpressured producer. The wait is capped short by the
    /// caller because commits (which free memory on in-memory partitions)
    /// do not notify this condvar — the periodic re-sweep is load-bearing.
    fn park(&self, d: Duration) {
        self.waiters.fetch_add(1, Ordering::SeqCst);
        {
            let g = self.lock.lock().unwrap();
            let _ = self.cv.wait_timeout(g, d).unwrap();
        }
        self.waiters.fetch_sub(1, Ordering::SeqCst);
    }

    /// Gets resident bytes back under the limit, cheapest sacrifice first:
    /// (1) evict durable payloads beyond each partition's resident tail
    /// (re-readable from the segment), (2) reclaim in-memory prefixes every
    /// group has committed (never re-read: polls resume at the commit),
    /// (3) shed on topics that opted into it, (4) evict the durable tails
    /// too. Callers must hold no partition locks.
    fn sweep(&self) {
        if self.excess() == 0 {
            return;
        }
        let topics: Vec<Arc<Topic>> = {
            let mut reg = self.topics.lock().unwrap();
            reg.retain(|w| w.strong_count() > 0);
            reg.iter().filter_map(|w| w.upgrade()).collect()
        };
        let tail = self.tail.load(Ordering::Relaxed);
        for t in &topics {
            for p in &t.partitions {
                if !p.durable {
                    continue;
                }
                p.spill(tail, self);
                if self.excess() == 0 {
                    return;
                }
            }
        }
        for t in &topics {
            for p in &t.partitions {
                if p.durable {
                    continue;
                }
                p.reclaim_committed(self);
                if self.excess() == 0 {
                    return;
                }
            }
        }
        for t in &topics {
            for p in &t.partitions {
                if p.durable {
                    continue;
                }
                if let OverloadPolicy::Shed(mode) = p.policy {
                    p.shed(mode, self);
                    if self.excess() == 0 {
                        return;
                    }
                }
            }
        }
        for t in &topics {
            for p in &t.partitions {
                if !p.durable {
                    continue;
                }
                p.spill(0, self);
                if self.excess() == 0 {
                    return;
                }
            }
        }
    }
}

/// In-process queue broker managing all topics of a deployment.
pub struct QueueBroker {
    dir: Option<PathBuf>,
    fs: Arc<dyn SegmentFs>,
    topics: Mutex<BTreeMap<String, Arc<Topic>>>,
    budget: Option<Arc<Budget>>,
    default_policy: Mutex<OverloadPolicy>,
    metrics: Option<Metrics>,
}

impl QueueBroker {
    /// Creates an in-memory broker (no durability, no memory bound).
    pub fn in_memory(metrics: Option<Metrics>) -> Broker {
        Self::build(None, Arc::new(RealFs), None, metrics)
    }

    /// Creates an in-memory broker with a resident-byte budget; topics
    /// over budget apply their [`OverloadPolicy`].
    pub fn in_memory_bounded(budget_bytes: u64, metrics: Option<Metrics>) -> Broker {
        Self::build(None, Arc::new(RealFs), Some(budget_bytes), metrics)
    }

    /// Creates (or reopens) a durable broker rooted at `dir`; existing
    /// topic segments found under it are recovered.
    pub fn durable(dir: impl Into<PathBuf>, metrics: Option<Metrics>) -> Result<Broker> {
        let dir = dir.into();
        std::fs::create_dir_all(&dir)?;
        Ok(Self::build(Some(dir), Arc::new(RealFs), None, metrics))
    }

    /// Creates (or reopens) a durable broker with a resident-byte budget:
    /// partitions keep a resident tail window and evict older payloads to
    /// their segment files, re-reading them transparently on poll.
    pub fn durable_bounded(
        dir: impl Into<PathBuf>,
        budget_bytes: u64,
        metrics: Option<Metrics>,
    ) -> Result<Broker> {
        let dir = dir.into();
        std::fs::create_dir_all(&dir)?;
        Ok(Self::build(Some(dir), Arc::new(RealFs), Some(budget_bytes), metrics))
    }

    /// Creates a durable broker whose segment I/O runs through `fs`
    /// (test-only entry point for [`fault::FaultFs`] crash injection; no
    /// real directory is created).
    pub fn durable_with_fs(
        dir: impl Into<PathBuf>,
        fs: Arc<dyn SegmentFs>,
        budget_bytes: Option<u64>,
        metrics: Option<Metrics>,
    ) -> Broker {
        Self::build(Some(dir.into()), fs, budget_bytes, metrics)
    }

    fn build(
        dir: Option<PathBuf>,
        fs: Arc<dyn SegmentFs>,
        budget_bytes: Option<u64>,
        metrics: Option<Metrics>,
    ) -> Broker {
        let budget = budget_bytes.map(|limit| Arc::new(Budget::new(limit, metrics.clone())));
        Arc::new(QueueBroker {
            dir,
            fs,
            topics: Mutex::new(BTreeMap::new()),
            budget,
            default_policy: Mutex::new(OverloadPolicy::default()),
            metrics,
        })
    }

    /// Returns the topic, creating it with `partitions` partitions and the
    /// broker's default [`OverloadPolicy`] if new. Reopening an existing
    /// topic ignores the partition hint.
    pub fn topic(&self, name: &str, partitions: usize) -> Result<Arc<Topic>> {
        let policy = *self.default_policy.lock().unwrap();
        self.topic_with_policy(name, partitions, policy)
    }

    /// Like [`Self::topic`] with an explicit overload policy for the new
    /// topic (state topics pin `Backpressure` so checkpoints are never
    /// shed). An already-open topic keeps its original policy.
    pub fn topic_with_policy(
        &self,
        name: &str,
        partitions: usize,
        policy: OverloadPolicy,
    ) -> Result<Arc<Topic>> {
        let mut topics = self.topics.lock().unwrap();
        if let Some(t) = topics.get(name) {
            return Ok(t.clone());
        }
        let topic = Arc::new(Topic::open(
            name,
            partitions.max(1),
            self.dir.as_deref(),
            &self.fs,
            self.budget.clone(),
            policy,
            self.metrics.clone(),
        )?);
        topics.insert(name.to_string(), topic.clone());
        if let Some(b) = &self.budget {
            b.register(&topic);
            drop(topics);
            if b.excess() > 0 {
                // recovery charged the recovered records; evict back under
                // the budget before handing the topic out
                b.sweep();
            }
        }
        Ok(topic)
    }

    /// Names of all open topics.
    pub fn topic_names(&self) -> Vec<String> {
        self.topics.lock().unwrap().keys().cloned().collect()
    }

    /// Sets the [`OverloadPolicy`] applied to topics created afterwards.
    pub fn set_default_policy(&self, policy: OverloadPolicy) {
        *self.default_policy.lock().unwrap() = policy;
    }

    /// Sets how many records each durable partition keeps resident at its
    /// tail when the broker is over budget (default 64).
    pub fn set_resident_tail(&self, records: usize) {
        if let Some(b) = &self.budget {
            b.tail.store(records, Ordering::Relaxed);
        }
    }

    /// The data directory of a durable broker.
    pub fn data_dir(&self) -> Option<&Path> {
        self.dir.as_deref()
    }

    /// Live gauge of resident record bytes (0 for unbounded brokers, which
    /// do not account).
    pub fn resident_bytes(&self) -> u64 {
        self.budget
            .as_ref()
            .map(|b| b.resident.load(Ordering::SeqCst))
            .unwrap_or(0)
    }

    /// The configured memory budget, if any.
    pub fn memory_budget(&self) -> Option<u64> {
        self.budget.as_ref().map(|b| b.limit)
    }
}

/// Tag prefix of an in-band watermark sentinel record.
const WM_TAG: [u8; 4] = *b"FUWM";

/// Encodes a watermark as a 24-byte sentinel record for in-band transport
/// through a queue topic: `"FUWM"` tag, producer id, event-time watermark,
/// origin wall-clock. The tag cannot collide with batch wire (a batch
/// starting with byte `0x46` would declare 70 values, which cannot encode
/// in 24 bytes), and consumers check sentinels before batch decode anyway.
pub fn watermark_record(wm: &Watermark) -> Arc<[u8]> {
    let mut b = Vec::with_capacity(24);
    b.extend_from_slice(&WM_TAG);
    b.extend_from_slice(&wm.from.to_le_bytes());
    b.extend_from_slice(&wm.ts.to_le_bytes());
    b.extend_from_slice(&wm.origin_ms.to_le_bytes());
    Arc::from(b.as_slice())
}

/// Decodes a record produced by [`watermark_record`]; `None` for anything
/// else (data batches, tombstones).
pub fn decode_watermark(rec: &[u8]) -> Option<Watermark> {
    if rec.len() != 24 || rec[..4] != WM_TAG {
        return None;
    }
    Some(Watermark {
        from: u32::from_le_bytes(rec[4..8].try_into().unwrap()),
        ts: i64::from_le_bytes(rec[8..16].try_into().unwrap()),
        origin_ms: u64::from_le_bytes(rec[16..24].try_into().unwrap()),
    })
}

/// Topic-level wait-set: one `Condvar` every consumer of the topic parks
/// on, bumped by any partition append or close (and by coordinator
/// [`Topic::kick`]s). A consumer owning N partitions blocks **once**
/// across all of them and is woken by the first event on any — replacing
/// the per-partition timed-poll staircase (1 ms floor × N partitions of
/// serialized blocking) with event-driven consumption.
///
/// Producers stay lock-free: `bump` is one atomic increment plus an
/// atomic load, and the mutex + notify are only touched when a consumer
/// is actually parked — appends to distinct partitions of one topic
/// never serialize on the wait-set.
#[derive(Default)]
struct WaitSet {
    /// Event sequence number (atomic: bumped without locking).
    seq: AtomicU64,
    /// Parked-consumer count; producers skip the lock + notify when 0.
    waiters: AtomicUsize,
    /// Park lock for the condvar (holds no data — `seq` carries the
    /// state; re-checked under this lock before parking so a bump
    /// between a consumer's scan and its park is never lost).
    lock: Mutex<()>,
    cv: Condvar,
}

impl WaitSet {
    fn bump(&self) {
        self.seq.fetch_add(1, Ordering::SeqCst);
        // SeqCst total order: if this load sees 0, the consumer's
        // waiters-increment had not happened yet, so its subsequent seq
        // re-check is guaranteed to observe the bump and skip the park.
        if self.waiters.load(Ordering::SeqCst) != 0 {
            // taking the lock orders the notify after the consumer's
            // park (a consumer past its re-check holds the lock until
            // the condvar releases it)
            let _g = self.lock.lock().unwrap();
            self.cv.notify_all();
        }
    }
}

/// A named topic: a set of partitions.
pub struct Topic {
    /// Topic name.
    pub name: String,
    partitions: Vec<Partition>,
    producers: Mutex<ProducerCount>,
    /// Shared wait-set all partitions bump (see [`WaitSet`]).
    notify: Arc<WaitSet>,
    metrics: Option<Metrics>,
}

#[derive(Default)]
struct ProducerCount {
    registered: usize,
    done: usize,
}

impl Topic {
    fn open(
        name: &str,
        partitions: usize,
        dir: Option<&Path>,
        fs: &Arc<dyn SegmentFs>,
        budget: Option<Arc<Budget>>,
        policy: OverloadPolicy,
        metrics: Option<Metrics>,
    ) -> Result<Topic> {
        let notify = Arc::new(WaitSet::default());
        let mut parts = Vec::with_capacity(partitions);
        for p in 0..partitions {
            let path = dir.map(|d| d.join(format!("{name}-{p}.log")));
            parts.push(Partition::open(
                path,
                fs,
                notify.clone(),
                budget.clone(),
                policy,
                format!("{name}[{p}]"),
                metrics.clone(),
            )?);
        }
        Ok(Topic {
            name: name.to_string(),
            partitions: parts,
            producers: Mutex::new(ProducerCount::default()),
            notify,
            metrics,
        })
    }

    /// Number of partitions.
    pub fn partitions(&self) -> usize {
        self.partitions.len()
    }

    /// Accessor for one partition.
    pub fn partition(&self, p: usize) -> &Partition {
        &self.partitions[p]
    }

    /// Registers a producer; must be paired with [`Self::producer_done`].
    pub fn register_producer(&self) {
        self.producers.lock().unwrap().registered += 1;
    }

    /// Appends a record to the partition chosen by `key_hash % partitions`.
    pub fn append(&self, key_hash: u64, record: &[u8]) -> Result<()> {
        let p = (key_hash % self.partitions.len() as u64) as usize;
        self.partitions[p].append(record)
    }

    /// Appends a whole batch as one record on the partition chosen by
    /// `key_hash % partitions`, re-using the batch's cached wire encoding:
    /// one encode per batch (or zero, if a crossing edge already paid it),
    /// and the in-memory log shares the encoded buffer by refcount.
    pub fn append_batch(&self, key_hash: u64, batch: &Batch) -> Result<()> {
        let p = (key_hash % self.partitions.len() as u64) as usize;
        self.partitions[p].append_batch(batch)
    }

    /// Drains every ready partition among `parts` in one wakeup: up to
    /// `max_per_partition` records per partition, starting at the
    /// matching `offsets` slot (advanced in place to the next offset).
    /// Blocks on the topic wait-set — woken by any append or close on
    /// any partition, no timed-poll staircase — for at most `timeout`.
    ///
    /// Returns `None` once every listed partition is closed **and** fully
    /// consumed (end of stream). Otherwise `Some(drained)`, a vec of
    /// `(slot, records)` pairs where `slot` indexes into
    /// `parts`/`offsets`; an empty vec means the wait ended without data
    /// (timeout, [`Topic::kick`], or an event on a partition owned by a
    /// different consumer) — callers re-check control flags and call
    /// again. At most one park per call, so stop-flag latency is bounded
    /// by `timeout` even without a kick.
    pub fn poll_many(
        &self,
        parts: &[usize],
        offsets: &mut [usize],
        max_per_partition: usize,
        timeout: Duration,
    ) -> Option<Vec<(usize, Vec<Arc<[u8]>>)>> {
        if parts.is_empty() {
            return None;
        }
        debug_assert_eq!(parts.len(), offsets.len());
        // a zero cap would drain zero-record slices forever; one record
        // per partition per wakeup is the useful floor
        let max_per_partition = max_per_partition.max(1);
        let deadline = Instant::now() + timeout;
        let mut waited = false;
        loop {
            // the sequence read precedes the scan: an append that the scan
            // misses bumps the sequence afterwards, so the pre-park
            // equality check below catches it and rescans instead of
            // parking past it
            let seen = self.notify.seq.load(Ordering::SeqCst);
            let mut drained: Vec<(usize, Vec<Arc<[u8]>>)> = Vec::new();
            let mut all_done = true;
            for (slot, &p) in parts.iter().enumerate() {
                let part = &self.partitions[p];
                let st = part.state.lock().unwrap();
                if offsets[slot] < st.records.len() {
                    let end = (offsets[slot] + max_per_partition).min(st.records.len());
                    let recs = part.fetch_range(&st, offsets[slot], end);
                    if let Some(m) = &self.metrics {
                        MetricsRegistry::add(&m.queue_reads, recs.len() as u64);
                    }
                    if !st.closed || end < st.records.len() {
                        all_done = false;
                    }
                    offsets[slot] = end;
                    drained.push((slot, recs));
                } else if !st.closed {
                    all_done = false;
                }
            }
            if !drained.is_empty() {
                if waited {
                    if let Some(m) = &self.metrics {
                        MetricsRegistry::add(&m.queue_wakeups, 1);
                    }
                }
                return Some(drained);
            }
            if all_done {
                return None;
            }
            if waited {
                // one park per call: hand control back so the caller can
                // observe stop flags after any wakeup
                return Some(Vec::new());
            }
            let remaining = deadline.saturating_duration_since(Instant::now());
            if remaining.is_zero() {
                if let Some(m) = &self.metrics {
                    MetricsRegistry::add(&m.queue_wait_timeouts, 1);
                }
                return Some(Vec::new());
            }
            // register as a parked waiter *before* the under-lock seq
            // re-check: a producer bumping after the re-check is then
            // guaranteed to observe the registration and take the notify
            // path (see WaitSet::bump)
            self.notify.waiters.fetch_add(1, Ordering::SeqCst);
            let timed_out = {
                let g = self.notify.lock.lock().unwrap();
                if self.notify.seq.load(Ordering::SeqCst) == seen {
                    let (_g, res) = self.notify.cv.wait_timeout(g, remaining).unwrap();
                    res.timed_out()
                } else {
                    false // the sequence moved between scan and park
                }
            };
            self.notify.waiters.fetch_sub(1, Ordering::SeqCst);
            if timed_out {
                if let Some(m) = &self.metrics {
                    MetricsRegistry::add(&m.queue_wait_timeouts, 1);
                }
                return Some(Vec::new());
            }
            // woken (or the sequence moved): rescan
            waited = true;
        }
    }

    /// Wakes every consumer parked on the topic's wait-set without
    /// appending — the coordinator kicks topics after raising stop flags
    /// so quiescing consumers react immediately instead of riding out
    /// their poll timeout.
    pub fn kick(&self) {
        self.notify.bump();
    }

    /// Marks one producer as finished; when the last registered producer
    /// finishes, all partitions are closed (consumers see end-of-stream).
    pub fn producer_done(&self) {
        let close = {
            let mut c = self.producers.lock().unwrap();
            c.done += 1;
            c.done >= c.registered
        };
        if close {
            for p in &self.partitions {
                p.close();
            }
        }
    }

    /// Consumer lag of `group` across all partitions: records appended but
    /// not yet committed. The autoscaler samples this to detect sustained
    /// overload, and [`crate::coordinator::JobReport`] exposes it per topic.
    pub fn lag(&self, group: &str) -> u64 {
        self.partitions.iter().map(|p| p.lag(group) as u64).sum()
    }

    /// Force-reopens the topic for new producers after a close (used when a
    /// new location joins a finished epoch — not needed on the normal path).
    pub fn reopen(&self) {
        let mut c = self.producers.lock().unwrap();
        c.done = 0;
        for p in &self.partitions {
            p.reopen();
        }
    }
}

/// Sentinel "no segment position" for records not (yet) durably framed.
const NO_POS: u64 = u64::MAX;

/// One log slot. Resident records hold their payload; evicted records hold
/// only the byte position of their frame in the segment file (`pos` points
/// at the frame header; the body starts 8 bytes in). Tombstones are
/// zero-length and always "resident" (the shared empty body).
struct Rec {
    data: Option<Arc<[u8]>>,
    pos: u64,
    len: u32,
}

impl Rec {
    fn resident(data: Arc<[u8]>) -> Rec {
        let len = data.len() as u32;
        Rec {
            data: Some(data),
            pos: NO_POS,
            len,
        }
    }

    fn tomb() -> Rec {
        Rec {
            data: Some(empty_body()),
            pos: NO_POS,
            len: 0,
        }
    }

    fn is_tombstone(&self) -> bool {
        self.len == 0
    }
}

fn empty_body() -> Arc<[u8]> {
    static EMPTY: OnceLock<Arc<[u8]>> = OnceLock::new();
    EMPTY.get_or_init(|| Arc::from(&[][..])).clone()
}

struct PartState {
    records: Vec<Rec>,
    committed: BTreeMap<String, usize>,
    closed: bool,
    /// Sweep cursor: records below this index have been considered by
    /// spill/shed and are skipped on later sweeps (amortizing sweeps to
    /// O(1) per record over the partition's lifetime). Spill stalls it at
    /// an in-flight durable write so nothing stays resident by accident;
    /// compaction resets it (re-materialized survivors are resident again).
    swept_to: usize,
    /// Reclaim cursor: everything below is already tombstoned by
    /// [`Partition::reclaim_committed`].
    reclaimed_to: usize,
}

/// One append-only partition log.
pub struct Partition {
    state: Mutex<PartState>,
    cv: Condvar,
    file: Mutex<Option<SegmentFile>>,
    /// Topic-level wait-set bumped on every append/close so
    /// [`Topic::poll_many`] consumers wake without per-partition polling.
    notify: Arc<WaitSet>,
    budget: Option<Arc<Budget>>,
    policy: OverloadPolicy,
    durable: bool,
    /// `topic[partition]`, for error messages.
    label: String,
    metrics: Option<Metrics>,
}

impl Partition {
    fn open(
        path: Option<PathBuf>,
        fs: &Arc<dyn SegmentFs>,
        notify: Arc<WaitSet>,
        budget: Option<Arc<Budget>>,
        policy: OverloadPolicy,
        label: String,
        metrics: Option<Metrics>,
    ) -> Result<Partition> {
        let mut records = Vec::new();
        let mut recovered_bytes = 0u64;
        let file = match path {
            None => None,
            Some(p) => {
                let existing = fs.read(&p)?;
                let mut seg_io = fs.open(&p)?;
                let mut end = 0u64;
                if let Some(buf) = existing {
                    let parsed = parse_segment(&buf).map_err(|pos| {
                        Error::Queue(format!("corrupt record at byte {pos} of {}", p.display()))
                    })?;
                    if parsed.torn {
                        // cut the partial final frame off *the file*, not
                        // just the parse: later appends must land on a
                        // valid frame boundary or the log becomes
                        // unrecoverable mid-log corruption
                        seg_io.truncate(parsed.valid_end)?;
                        if let Some(m) = &metrics {
                            MetricsRegistry::add(&m.torn_tails_truncated, 1);
                        }
                    }
                    end = parsed.valid_end;
                    for (body, pos) in parsed.frames {
                        recovered_bytes += body.len() as u64;
                        let len = body.len() as u32;
                        records.push(Rec {
                            data: Some(body),
                            pos,
                            len,
                        });
                    }
                }
                Some(SegmentFile {
                    io: seg_io,
                    end,
                    broken: false,
                })
            }
        };
        if recovered_bytes > 0 {
            if let Some(b) = &budget {
                // charged unconditionally; the broker sweeps right after
                // topic open to evict back under the limit
                b.charge(recovered_bytes);
            }
        }
        let durable = file.is_some();
        Ok(Partition {
            state: Mutex::new(PartState {
                records,
                committed: BTreeMap::new(),
                closed: false,
                swept_to: 0,
                reclaimed_to: 0,
            }),
            cv: Condvar::new(),
            file: Mutex::new(file),
            notify,
            budget,
            policy,
            durable,
            label,
            metrics,
        })
    }

    /// Admits `n` bytes against the broker budget per the partition's
    /// policy, before the record enters the log. Shed charges
    /// unconditionally (the post-append sweep evicts); backpressure blocks
    /// until the charge fits or the deadline passes.
    fn admit(&self, n: u64) -> Result<()> {
        let Some(b) = &self.budget else {
            return Ok(());
        };
        if n == 0 {
            return Ok(());
        }
        match self.policy {
            OverloadPolicy::Shed(_) => {
                b.charge(n);
                Ok(())
            }
            OverloadPolicy::Backpressure { deadline } => {
                if b.try_charge(n) {
                    return Ok(());
                }
                let dl = Instant::now() + deadline;
                loop {
                    b.sweep();
                    if b.try_charge(n) {
                        return Ok(());
                    }
                    let remaining = dl.saturating_duration_since(Instant::now());
                    if remaining.is_zero() {
                        return Err(Error::Queue(format!(
                            "backpressure: append of {n} bytes to {} refused after {:?} (budget {} bytes)",
                            self.label, deadline, b.limit
                        )));
                    }
                    // capped park: commits that free memory don't notify
                    // the budget condvar, so re-sweep periodically
                    b.park(remaining.min(Duration::from_millis(50)));
                }
            }
        }
    }

    /// Appends one record (durable if the partition is file-backed).
    pub fn append(&self, record: &[u8]) -> Result<()> {
        self.append_shared(Arc::from(record))
    }

    /// Appends a whole batch as one record, re-using its cached wire
    /// encoding; an encode actually paid here (same-host producer whose
    /// batch never crossed a link) is counted in `batch_encodes`.
    pub fn append_batch(&self, batch: &Batch) -> Result<()> {
        let record = batch.wire_with(|| {
            if let Some(m) = &self.metrics {
                MetricsRegistry::add(&m.batch_encodes, 1);
            }
        });
        self.append_shared(record)
    }

    /// Appends an already-refcounted record: the in-memory log stores the
    /// same buffer (no copy); only the durable file write, if any, pays a
    /// memcpy. This is the hot path for batch frames arriving from the
    /// channel layer, whose bytes are shared with the sender's encode
    /// cache.
    ///
    /// The closed check and the in-memory append are atomic with respect
    /// to [`Partition::close`], so a rejected append is never persisted
    /// (it would silently reappear after recovery otherwise) — but the
    /// durable write itself happens *outside* the state lock, so pollers
    /// and committers never block behind disk I/O. The file guard is
    /// acquired before the state lock is released, keeping segment order
    /// aligned with log order. On a bounded broker the record's bytes are
    /// admitted against the budget first (see [`OverloadPolicy`]).
    pub fn append_shared(&self, record: Arc<[u8]>) -> Result<()> {
        let n = record.len() as u64;
        self.admit(n)?;
        let (idx, mut file) = {
            let mut st = self.state.lock().unwrap();
            if st.closed {
                if let Some(b) = &self.budget {
                    b.uncharge(n);
                }
                return Err(Error::Queue("append to closed partition".into()));
            }
            let file = self.file.lock().unwrap();
            st.records.push(Rec::resident(record.clone()));
            let idx = st.records.len() - 1;
            if let Some(m) = &self.metrics {
                MetricsRegistry::add(&m.queue_appends, 1);
            }
            self.cv.notify_all();
            (idx, file)
        };
        // wake topic-level wait-set consumers (outside the state lock;
        // before the durable write, matching the partition condvar's
        // visibility: the in-memory record is already readable)
        self.notify.bump();
        let mut wrote_at = NO_POS;
        let mut write_err = None;
        if let Some(seg) = file.as_mut() {
            if !seg.broken {
                let mut framed = Vec::with_capacity(8 + record.len());
                framed.extend_from_slice(&(record.len() as u32).to_le_bytes());
                framed.extend_from_slice(&crc32(&record).to_le_bytes());
                framed.extend_from_slice(&record);
                match seg.io.append(&framed) {
                    Ok(()) => {
                        wrote_at = seg.end;
                        seg.end += framed.len() as u64;
                    }
                    Err(e) => {
                        // the segment tail may hold a torn frame now; stop
                        // trusting it — records stay resident-only and
                        // recovery truncates whatever prefix reached disk
                        seg.broken = true;
                        write_err = Some(e);
                    }
                }
            }
        }
        drop(file);
        if let Some(e) = write_err {
            return Err(Error::Queue(format!(
                "segment append to {} failed: {e}",
                self.label
            )));
        }
        if wrote_at != NO_POS {
            let mut st = self.state.lock().unwrap();
            if let Some(r) = st.records.get_mut(idx) {
                // a compaction racing between our write and this re-lock
                // rewrote the segment and owns the position (or tombstoned
                // the record); its coordinates win
                if r.pos == NO_POS && !r.is_tombstone() {
                    r.pos = wrote_at;
                }
            }
        }
        if let Some(b) = &self.budget {
            if b.excess() > 0 {
                b.sweep();
            }
        }
        Ok(())
    }

    /// Resolves `records[from..to]` to payload buffers under the caller's
    /// state lock, re-reading evicted records from the segment file
    /// (`spill_reads` metric). An unreadable evicted record degrades to an
    /// empty body and counts in `corrupt_records` — the log stays
    /// offset-stable either way.
    fn fetch_range(&self, st: &PartState, from: usize, to: usize) -> Vec<Arc<[u8]>> {
        let mut out = Vec::with_capacity(to.saturating_sub(from));
        let mut file = None;
        for rec in &st.records[from..to] {
            if let Some(d) = &rec.data {
                out.push(d.clone());
                continue;
            }
            let guard = file.get_or_insert_with(|| self.file.lock().unwrap());
            let body = match guard.as_ref() {
                Some(seg) if rec.pos != NO_POS => {
                    let mut buf = vec![0u8; rec.len as usize];
                    match seg.io.read_at(rec.pos + 8, &mut buf) {
                        Ok(()) => {
                            if let Some(m) = &self.metrics {
                                MetricsRegistry::add(&m.spill_reads, 1);
                            }
                            Arc::from(buf.as_slice())
                        }
                        Err(_) => {
                            if let Some(m) = &self.metrics {
                                MetricsRegistry::add(&m.corrupt_records, 1);
                            }
                            empty_body()
                        }
                    }
                }
                _ => {
                    if let Some(m) = &self.metrics {
                        MetricsRegistry::add(&m.corrupt_records, 1);
                    }
                    empty_body()
                }
            };
            out.push(body);
        }
        out
    }

    /// Evicts resident payloads to the segment file, keeping the newest
    /// `keep_tail` records resident. Only durably-framed records (position
    /// known) are evicted; an in-flight durable write stalls the sweep
    /// cursor so the record is revisited once its position lands.
    fn spill(&self, keep_tail: usize, budget: &Budget) {
        let mut freed = 0u64;
        {
            let mut st = self.state.lock().unwrap();
            let stop = st.records.len().saturating_sub(keep_tail);
            let start = st.swept_to.min(stop);
            let mut next = st.swept_to;
            let mut blocked = false;
            for (i, rec) in st.records.iter_mut().enumerate().take(stop).skip(start) {
                let evictable = rec.data.is_some() && !rec.is_tombstone();
                if evictable && rec.pos == NO_POS {
                    blocked = true;
                } else if evictable {
                    rec.data = None;
                    freed += rec.len as u64;
                }
                if !blocked {
                    next = i + 1;
                }
            }
            st.swept_to = st.swept_to.max(next);
        }
        if freed > 0 {
            budget.uncharge(freed);
        }
    }

    /// Tombstones the prefix every consumer group has committed (in-memory
    /// partitions only — these records are never polled again: every
    /// group's reads resume at or past its commit). Not counted as shed;
    /// nothing observable is lost.
    fn reclaim_committed(&self, budget: &Budget) {
        let mut freed = 0u64;
        {
            let mut st = self.state.lock().unwrap();
            if st.committed.is_empty() {
                return;
            }
            let min = st.committed.values().copied().min().unwrap_or(0);
            let end = min.min(st.records.len());
            let start = st.reclaimed_to.min(end);
            for rec in st.records.iter_mut().take(end).skip(start) {
                if !rec.is_tombstone() {
                    if rec.data.is_some() {
                        freed += rec.len as u64;
                    }
                    *rec = Rec::tomb();
                }
            }
            st.reclaimed_to = st.reclaimed_to.max(end);
        }
        if freed > 0 {
            budget.uncharge(freed);
        }
    }

    /// Sheds resident records under overload per `mode`, oldest first,
    /// until the broker is back under budget. Offset-stable: shed records
    /// become tombstones, so commits and poll offsets never shift. Every
    /// dropped record counts in `records_shed`.
    fn shed(&self, mode: ShedMode, budget: &Budget) {
        let target = budget.excess();
        if target == 0 {
            return;
        }
        let mut freed = 0u64;
        let mut count = 0u64;
        {
            let mut st = self.state.lock().unwrap();
            let start = st.swept_to;
            let mut keep = false;
            let mut next = start;
            for (i, rec) in st.records.iter_mut().enumerate().skip(start) {
                if freed >= target {
                    break;
                }
                next = i + 1;
                if rec.is_tombstone() || rec.data.is_none() {
                    continue;
                }
                if matches!(mode, ShedMode::Sample) {
                    keep = !keep;
                    if keep {
                        continue; // sampled in: retained for good
                    }
                }
                freed += rec.len as u64;
                count += 1;
                *rec = Rec::tomb();
            }
            st.swept_to = st.swept_to.max(next);
        }
        if freed > 0 {
            budget.uncharge(freed);
        }
        if count > 0 {
            if let Some(m) = &self.metrics {
                MetricsRegistry::add(&m.records_shed, count);
            }
        }
    }

    /// Polls up to `max` records starting at `offset`, blocking up to
    /// `timeout` for new data. Returns the records and the next offset;
    /// `None` means the partition is closed *and* fully consumed. Evicted
    /// records are transparently re-read from the segment file.
    pub fn poll(
        &self,
        offset: usize,
        max: usize,
        timeout: Duration,
    ) -> Option<(Vec<Arc<[u8]>>, usize)> {
        let mut st = self.state.lock().unwrap();
        let deadline = Instant::now() + timeout;
        loop {
            if offset < st.records.len() {
                let end = (offset + max).min(st.records.len());
                let recs = self.fetch_range(&st, offset, end);
                if let Some(m) = &self.metrics {
                    MetricsRegistry::add(&m.queue_reads, recs.len() as u64);
                }
                return Some((recs, end));
            }
            if st.closed {
                return None;
            }
            let now = Instant::now();
            // saturating: a condvar wake-up (or a zero timeout) can land
            // after the deadline, and `deadline - now` would panic on the
            // Duration underflow
            let remaining = deadline.saturating_duration_since(now);
            if remaining.is_zero() {
                return Some((Vec::new(), offset)); // timed out, still open
            }
            let (g, _) = self.cv.wait_timeout(st, remaining).unwrap();
            st = g;
        }
    }

    /// Compacts the log prefix `[0, before)`: superseded records are
    /// replaced **in place** with zero-length tombstones, so the absolute
    /// offsets of every surviving record are preserved (consumer commits,
    /// poll offsets, and checkpoint `scan_from` markers all index the
    /// same positions before and after). Readers that decode record
    /// payloads must skip empty records. Returns how many records were
    /// tombstoned for the first time (repeat calls are idempotent).
    ///
    /// File-backed partitions rewrite their segment under both guards
    /// (state, then file — the same order as appends): evicted survivors
    /// are re-materialized first so every body is resident before the old
    /// segment bytes are discarded, then the segment is truncated and
    /// every record re-framed at its new position. If the rewrite itself
    /// fails, the segment is marked broken and every record stays resident
    /// — nothing is lost, durability degrades to memory-only.
    pub fn compact_before(&self, before: usize) -> usize {
        let mut st = self.state.lock().unwrap();
        let end = before.min(st.records.len());
        let mut n = 0usize;
        let mut freed = 0u64;
        for r in &mut st.records[..end] {
            if r.is_tombstone() {
                continue;
            }
            if r.data.is_some() {
                freed += r.len as u64;
            }
            *r = Rec::tomb();
            n += 1;
        }
        if n == 0 {
            return 0;
        }
        if let Some(m) = &self.metrics {
            MetricsRegistry::add(&m.state_compactions, n as u64);
        }
        let mut recharged = 0u64;
        if self.durable {
            let mut file = self.file.lock().unwrap();
            if let Some(seg) = file.as_mut() {
                for r in st.records.iter_mut() {
                    if r.data.is_some() {
                        continue;
                    }
                    let mut buf = vec![0u8; r.len as usize];
                    match seg.io.read_at(r.pos + 8, &mut buf) {
                        Ok(()) => {
                            r.data = Some(Arc::from(buf.as_slice()));
                            recharged += r.len as u64;
                        }
                        Err(_) => {
                            // unreadable evicted record: degrade to a
                            // tombstone, keeping the log offset-stable
                            if let Some(m) = &self.metrics {
                                MetricsRegistry::add(&m.corrupt_records, 1);
                            }
                            *r = Rec::tomb();
                        }
                    }
                }
                let mut ok = seg.io.truncate(0).is_ok();
                seg.end = 0;
                if ok {
                    for r in st.records.iter_mut() {
                        let body: &[u8] = r.data.as_deref().unwrap_or(&[]);
                        let mut framed = Vec::with_capacity(8 + body.len());
                        framed.extend_from_slice(&(body.len() as u32).to_le_bytes());
                        framed.extend_from_slice(&crc32(body).to_le_bytes());
                        framed.extend_from_slice(body);
                        if seg.io.append(&framed).is_err() {
                            ok = false;
                            break;
                        }
                        r.pos = seg.end;
                        seg.end += framed.len() as u64;
                    }
                }
                if !ok {
                    seg.broken = true;
                    for r in st.records.iter_mut() {
                        r.pos = NO_POS;
                    }
                }
                // survivors are resident again; let the next sweep re-evict
                st.swept_to = 0;
            }
        }
        drop(st);
        if let Some(b) = &self.budget {
            if freed > 0 {
                b.uncharge(freed);
            }
            if recharged > 0 {
                b.charge(recharged);
            }
            if b.excess() > 0 {
                b.sweep();
            }
        }
        n
    }

    /// Records a consumer group's committed offset.
    pub fn commit(&self, group: &str, offset: usize) {
        let mut st = self.state.lock().unwrap();
        let e = st.committed.entry(group.to_string()).or_insert(0);
        if offset > *e {
            *e = offset;
        }
    }

    /// Last committed offset for a group (0 if none).
    pub fn committed(&self, group: &str) -> usize {
        *self
            .state
            .lock()
            .unwrap()
            .committed
            .get(group)
            .unwrap_or(&0)
    }

    /// Number of records currently in the log.
    pub fn len(&self) -> usize {
        self.state.lock().unwrap().records.len()
    }

    /// Records appended but not yet committed by `group` (consumer lag).
    /// Reads the log length and the committed offset under one lock so a
    /// concurrent append/commit never yields a torn reading.
    pub fn lag(&self, group: &str) -> usize {
        let st = self.state.lock().unwrap();
        st.records
            .len()
            .saturating_sub(*st.committed.get(group).unwrap_or(&0))
    }

    /// True when no records are present.
    pub fn is_empty(&self) -> bool {
        self.len() == 0
    }

    /// Closes the partition: consumers that drain it observe
    /// end-of-stream. Idempotent. Normally driven by
    /// [`Topic::producer_done`], but exposed for ingest pipelines that
    /// track per-partition producer EOS themselves.
    pub fn close(&self) {
        self.state.lock().unwrap().closed = true;
        self.cv.notify_all();
        self.notify.bump();
    }

    /// Reopens a closed partition for further appends.
    pub fn reopen(&self) {
        self.state.lock().unwrap().closed = false;
    }
}

/// A fully-parsed segment: frame bodies with their byte positions, the
/// offset of the last valid frame boundary, and whether a torn tail
/// (partial or CRC-failed final frame) was cut off at that boundary.
struct ParsedSegment {
    frames: Vec<(Arc<[u8]>, u64)>,
    valid_end: u64,
    torn: bool,
}

/// Parses segment bytes. A torn tail — truncated header, truncated body,
/// or a CRC failure on the *final* frame (all normal kill-mid-write
/// artifacts) — ends the parse at the last valid boundary with
/// `torn = true`. A CRC failure before the final frame is real corruption:
/// `Err(byte_offset)`.
fn parse_segment(buf: &[u8]) -> std::result::Result<ParsedSegment, usize> {
    let mut frames = Vec::new();
    let mut pos = 0usize;
    while pos < buf.len() {
        if pos + 8 > buf.len() {
            break; // torn header
        }
        let len = u32::from_le_bytes(buf[pos..pos + 4].try_into().unwrap()) as usize;
        let crc = u32::from_le_bytes(buf[pos + 4..pos + 8].try_into().unwrap());
        if pos + 8 + len > buf.len() {
            break; // torn body
        }
        let body = &buf[pos + 8..pos + 8 + len];
        if crc32(body) != crc {
            if pos + 8 + len == buf.len() {
                break; // torn final frame (partially-flushed bytes)
            }
            return Err(pos); // mid-log corruption
        }
        frames.push((Arc::from(body), pos as u64));
        pos += 8 + len;
    }
    Ok(ParsedSegment {
        frames,
        valid_end: pos as u64,
        torn: (pos as u64) < buf.len() as u64,
    })
}

/// CRC32 (IEEE, bitwise; cold path only — recovery and appends are
/// per-record, and records are batched).
pub fn crc32(data: &[u8]) -> u32 {
    let mut crc = 0xffff_ffffu32;
    for &b in data {
        crc ^= b as u32;
        for _ in 0..8 {
            let mask = (crc & 1).wrapping_neg();
            crc = (crc >> 1) ^ (0xedb8_8320 & mask);
        }
    }
    !crc
}

#[cfg(test)]
mod tests;
