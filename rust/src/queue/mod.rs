//! Persistent queue substrate — the stand-in for the Kafka queues the paper
//! places between FlowUnits to decouple them for dynamic updates (§III–IV).
//!
//! Semantics mirror the Kafka subset the paper relies on:
//! * a **topic** is split into **partitions**, each an append-only record
//!   log;
//! * **producers** append records; appends are durable when the broker is
//!   opened with a data directory (length- and CRC32-framed segment files,
//!   recovered on open). A record is one encoded *batch*: producers append
//!   at batch granularity ([`Topic::append_batch`] /
//!   [`Partition::append_shared`]) re-using the batch's cached wire
//!   encoding, and the in-memory log holds the same refcounted buffer the
//!   sender encoded — one encode, zero copies, per batch;
//! * **consumer groups** track a committed offset per partition; consumers
//!   poll from their offset and commit after processing, giving
//!   at-least-once delivery across FlowUnit restarts — exactly what the
//!   dynamic-update path needs;
//! * producers register with a topic; when all registered producers have
//!   called [`Topic::producer_done`], the partitions are *closed* and
//!   drained consumers observe end-of-stream.

use crate::error::{Error, Result};
use crate::metrics::{Metrics, MetricsRegistry};
use crate::value::Batch;
use std::collections::BTreeMap;
use std::fs::{File, OpenOptions};
use std::io::{Read, Write};
use std::path::PathBuf;
use std::sync::atomic::{AtomicU64, AtomicUsize, Ordering};
use std::sync::{Arc, Condvar, Mutex};
use std::time::Duration;

/// A shared broker handle.
pub type Broker = Arc<QueueBroker>;

/// In-process queue broker managing all topics of a deployment.
pub struct QueueBroker {
    dir: Option<PathBuf>,
    topics: Mutex<BTreeMap<String, Arc<Topic>>>,
    metrics: Option<Metrics>,
}

impl QueueBroker {
    /// Creates an in-memory broker (no durability).
    pub fn in_memory(metrics: Option<Metrics>) -> Broker {
        Arc::new(QueueBroker {
            dir: None,
            topics: Mutex::new(BTreeMap::new()),
            metrics,
        })
    }

    /// Creates (or reopens) a durable broker rooted at `dir`; existing
    /// topic segments found under it are recovered.
    pub fn durable(dir: impl Into<PathBuf>, metrics: Option<Metrics>) -> Result<Broker> {
        let dir = dir.into();
        std::fs::create_dir_all(&dir)?;
        Ok(Arc::new(QueueBroker {
            dir: Some(dir),
            topics: Mutex::new(BTreeMap::new()),
            metrics,
        }))
    }

    /// Returns the topic, creating it with `partitions` partitions if new.
    /// Reopening an existing topic ignores the partition hint.
    pub fn topic(&self, name: &str, partitions: usize) -> Result<Arc<Topic>> {
        let mut topics = self.topics.lock().unwrap();
        if let Some(t) = topics.get(name) {
            return Ok(t.clone());
        }
        let topic = Arc::new(Topic::open(
            name,
            partitions.max(1),
            self.dir.as_deref(),
            self.metrics.clone(),
        )?);
        topics.insert(name.to_string(), topic.clone());
        Ok(topic)
    }

    /// Names of all open topics.
    pub fn topic_names(&self) -> Vec<String> {
        self.topics.lock().unwrap().keys().cloned().collect()
    }
}

/// Topic-level wait-set: one `Condvar` every consumer of the topic parks
/// on, bumped by any partition append or close (and by coordinator
/// [`Topic::kick`]s). A consumer owning N partitions blocks **once**
/// across all of them and is woken by the first event on any — replacing
/// the per-partition timed-poll staircase (1 ms floor × N partitions of
/// serialized blocking) with event-driven consumption.
///
/// Producers stay lock-free: `bump` is one atomic increment plus an
/// atomic load, and the mutex + notify are only touched when a consumer
/// is actually parked — appends to distinct partitions of one topic
/// never serialize on the wait-set.
#[derive(Default)]
struct WaitSet {
    /// Event sequence number (atomic: bumped without locking).
    seq: AtomicU64,
    /// Parked-consumer count; producers skip the lock + notify when 0.
    waiters: AtomicUsize,
    /// Park lock for the condvar (holds no data — `seq` carries the
    /// state; re-checked under this lock before parking so a bump
    /// between a consumer's scan and its park is never lost).
    lock: Mutex<()>,
    cv: Condvar,
}

impl WaitSet {
    fn bump(&self) {
        self.seq.fetch_add(1, Ordering::SeqCst);
        // SeqCst total order: if this load sees 0, the consumer's
        // waiters-increment had not happened yet, so its subsequent seq
        // re-check is guaranteed to observe the bump and skip the park.
        if self.waiters.load(Ordering::SeqCst) != 0 {
            // taking the lock orders the notify after the consumer's
            // park (a consumer past its re-check holds the lock until
            // the condvar releases it)
            let _g = self.lock.lock().unwrap();
            self.cv.notify_all();
        }
    }
}

/// A named topic: a set of partitions.
pub struct Topic {
    /// Topic name.
    pub name: String,
    partitions: Vec<Partition>,
    producers: Mutex<ProducerCount>,
    /// Shared wait-set all partitions bump (see [`WaitSet`]).
    notify: Arc<WaitSet>,
    metrics: Option<Metrics>,
}

#[derive(Default)]
struct ProducerCount {
    registered: usize,
    done: usize,
}

impl Topic {
    fn open(
        name: &str,
        partitions: usize,
        dir: Option<&std::path::Path>,
        metrics: Option<Metrics>,
    ) -> Result<Topic> {
        let notify = Arc::new(WaitSet::default());
        let mut parts = Vec::with_capacity(partitions);
        for p in 0..partitions {
            let path = dir.map(|d| d.join(format!("{name}-{p}.log")));
            parts.push(Partition::open(path, notify.clone(), metrics.clone())?);
        }
        Ok(Topic {
            name: name.to_string(),
            partitions: parts,
            producers: Mutex::new(ProducerCount::default()),
            notify,
            metrics,
        })
    }

    /// Number of partitions.
    pub fn partitions(&self) -> usize {
        self.partitions.len()
    }

    /// Accessor for one partition.
    pub fn partition(&self, p: usize) -> &Partition {
        &self.partitions[p]
    }

    /// Registers a producer; must be paired with [`Self::producer_done`].
    pub fn register_producer(&self) {
        self.producers.lock().unwrap().registered += 1;
    }

    /// Appends a record to the partition chosen by `key_hash % partitions`.
    pub fn append(&self, key_hash: u64, record: &[u8]) -> Result<()> {
        let p = (key_hash % self.partitions.len() as u64) as usize;
        self.partitions[p].append(record)
    }

    /// Appends a whole batch as one record on the partition chosen by
    /// `key_hash % partitions`, re-using the batch's cached wire encoding:
    /// one encode per batch (or zero, if a crossing edge already paid it),
    /// and the in-memory log shares the encoded buffer by refcount.
    pub fn append_batch(&self, key_hash: u64, batch: &Batch) -> Result<()> {
        let p = (key_hash % self.partitions.len() as u64) as usize;
        self.partitions[p].append_batch(batch)
    }

    /// Drains every ready partition among `parts` in one wakeup: up to
    /// `max_per_partition` records per partition, starting at the
    /// matching `offsets` slot (advanced in place to the next offset).
    /// Blocks on the topic wait-set — woken by any append or close on
    /// any partition, no timed-poll staircase — for at most `timeout`.
    ///
    /// Returns `None` once every listed partition is closed **and** fully
    /// consumed (end of stream). Otherwise `Some(drained)`, a vec of
    /// `(slot, records)` pairs where `slot` indexes into
    /// `parts`/`offsets`; an empty vec means the wait ended without data
    /// (timeout, [`Topic::kick`], or an event on a partition owned by a
    /// different consumer) — callers re-check control flags and call
    /// again. At most one park per call, so stop-flag latency is bounded
    /// by `timeout` even without a kick.
    pub fn poll_many(
        &self,
        parts: &[usize],
        offsets: &mut [usize],
        max_per_partition: usize,
        timeout: Duration,
    ) -> Option<Vec<(usize, Vec<Arc<[u8]>>)>> {
        if parts.is_empty() {
            return None;
        }
        debug_assert_eq!(parts.len(), offsets.len());
        // a zero cap would drain zero-record slices forever; one record
        // per partition per wakeup is the useful floor
        let max_per_partition = max_per_partition.max(1);
        let deadline = std::time::Instant::now() + timeout;
        let mut waited = false;
        loop {
            // the sequence read precedes the scan: an append that the scan
            // misses bumps the sequence afterwards, so the pre-park
            // equality check below catches it and rescans instead of
            // parking past it
            let seen = self.notify.seq.load(Ordering::SeqCst);
            let mut drained: Vec<(usize, Vec<Arc<[u8]>>)> = Vec::new();
            let mut all_done = true;
            for (slot, &p) in parts.iter().enumerate() {
                let part = &self.partitions[p];
                let st = part.state.lock().unwrap();
                if offsets[slot] < st.records.len() {
                    let end = (offsets[slot] + max_per_partition).min(st.records.len());
                    let recs: Vec<Arc<[u8]>> = st.records[offsets[slot]..end].to_vec();
                    if let Some(m) = &self.metrics {
                        MetricsRegistry::add(&m.queue_reads, recs.len() as u64);
                    }
                    if !st.closed || end < st.records.len() {
                        all_done = false;
                    }
                    offsets[slot] = end;
                    drained.push((slot, recs));
                } else if !st.closed {
                    all_done = false;
                }
            }
            if !drained.is_empty() {
                if waited {
                    if let Some(m) = &self.metrics {
                        MetricsRegistry::add(&m.queue_wakeups, 1);
                    }
                }
                return Some(drained);
            }
            if all_done {
                return None;
            }
            if waited {
                // one park per call: hand control back so the caller can
                // observe stop flags after any wakeup
                return Some(Vec::new());
            }
            let remaining = deadline.saturating_duration_since(std::time::Instant::now());
            if remaining.is_zero() {
                if let Some(m) = &self.metrics {
                    MetricsRegistry::add(&m.queue_wait_timeouts, 1);
                }
                return Some(Vec::new());
            }
            // register as a parked waiter *before* the under-lock seq
            // re-check: a producer bumping after the re-check is then
            // guaranteed to observe the registration and take the notify
            // path (see WaitSet::bump)
            self.notify.waiters.fetch_add(1, Ordering::SeqCst);
            let timed_out = {
                let g = self.notify.lock.lock().unwrap();
                if self.notify.seq.load(Ordering::SeqCst) == seen {
                    let (_g, res) = self.notify.cv.wait_timeout(g, remaining).unwrap();
                    res.timed_out()
                } else {
                    false // the sequence moved between scan and park
                }
            };
            self.notify.waiters.fetch_sub(1, Ordering::SeqCst);
            if timed_out {
                if let Some(m) = &self.metrics {
                    MetricsRegistry::add(&m.queue_wait_timeouts, 1);
                }
                return Some(Vec::new());
            }
            // woken (or the sequence moved): rescan
            waited = true;
        }
    }

    /// Wakes every consumer parked on the topic's wait-set without
    /// appending — the coordinator kicks topics after raising stop flags
    /// so quiescing consumers react immediately instead of riding out
    /// their poll timeout.
    pub fn kick(&self) {
        self.notify.bump();
    }

    /// Marks one producer as finished; when the last registered producer
    /// finishes, all partitions are closed (consumers see end-of-stream).
    pub fn producer_done(&self) {
        let close = {
            let mut c = self.producers.lock().unwrap();
            c.done += 1;
            c.done >= c.registered
        };
        if close {
            for p in &self.partitions {
                p.close();
            }
        }
    }

    /// Consumer lag of `group` across all partitions: records appended but
    /// not yet committed. The autoscaler samples this to detect sustained
    /// overload, and [`crate::coordinator::JobReport`] exposes it per topic.
    pub fn lag(&self, group: &str) -> u64 {
        self.partitions.iter().map(|p| p.lag(group) as u64).sum()
    }

    /// Force-reopens the topic for new producers after a close (used when a
    /// new location joins a finished epoch — not needed on the normal path).
    pub fn reopen(&self) {
        let mut c = self.producers.lock().unwrap();
        c.done = 0;
        for p in &self.partitions {
            p.reopen();
        }
    }
}

struct PartState {
    records: Vec<Arc<[u8]>>,
    committed: BTreeMap<String, usize>,
    closed: bool,
}

/// One append-only partition log.
pub struct Partition {
    state: Mutex<PartState>,
    cv: Condvar,
    file: Mutex<Option<File>>,
    /// Topic-level wait-set bumped on every append/close so
    /// [`Topic::poll_many`] consumers wake without per-partition polling.
    notify: Arc<WaitSet>,
    metrics: Option<Metrics>,
}

impl Partition {
    fn open(
        path: Option<PathBuf>,
        notify: Arc<WaitSet>,
        metrics: Option<Metrics>,
    ) -> Result<Partition> {
        let mut records = Vec::new();
        let file = match path {
            None => None,
            Some(p) => {
                if p.exists() {
                    records = Self::recover(&p)?;
                }
                Some(OpenOptions::new().create(true).append(true).open(&p)?)
            }
        };
        Ok(Partition {
            state: Mutex::new(PartState {
                records,
                committed: BTreeMap::new(),
                closed: false,
            }),
            cv: Condvar::new(),
            file: Mutex::new(file),
            notify,
            metrics,
        })
    }

    /// Replays a segment file, verifying length framing and CRC32. A
    /// truncated tail (torn write) is tolerated and dropped; a corrupt CRC
    /// mid-log is an error.
    fn recover(path: &std::path::Path) -> Result<Vec<Arc<[u8]>>> {
        let mut buf = Vec::new();
        File::open(path)?.read_to_end(&mut buf)?;
        let mut records = Vec::new();
        let mut pos = 0usize;
        while pos < buf.len() {
            if pos + 8 > buf.len() {
                break; // torn header
            }
            let len = u32::from_le_bytes(buf[pos..pos + 4].try_into().unwrap()) as usize;
            let crc = u32::from_le_bytes(buf[pos + 4..pos + 8].try_into().unwrap());
            if pos + 8 + len > buf.len() {
                break; // torn body
            }
            let body = &buf[pos + 8..pos + 8 + len];
            if crc32(body) != crc {
                return Err(Error::Queue(format!(
                    "corrupt record at byte {pos} of {}",
                    path.display()
                )));
            }
            records.push(Arc::from(body));
            pos += 8 + len;
        }
        Ok(records)
    }

    /// Appends one record (durable if the partition is file-backed).
    pub fn append(&self, record: &[u8]) -> Result<()> {
        self.append_shared(Arc::from(record))
    }

    /// Appends a whole batch as one record, re-using its cached wire
    /// encoding; an encode actually paid here (same-host producer whose
    /// batch never crossed a link) is counted in `batch_encodes`.
    pub fn append_batch(&self, batch: &Batch) -> Result<()> {
        let record = batch.wire_with(|| {
            if let Some(m) = &self.metrics {
                MetricsRegistry::add(&m.batch_encodes, 1);
            }
        });
        self.append_shared(record)
    }

    /// Appends an already-refcounted record: the in-memory log stores the
    /// same buffer (no copy); only the durable file write, if any, pays a
    /// memcpy. This is the hot path for batch frames arriving from the
    /// channel layer, whose bytes are shared with the sender's encode
    /// cache.
    ///
    /// The closed check and the in-memory append are atomic with respect
    /// to [`Partition::close`], so a rejected append is never persisted
    /// (it would silently reappear after recovery otherwise) — but the
    /// durable write itself happens *outside* the state lock, so pollers
    /// and committers never block behind disk I/O. The file guard is
    /// acquired before the state lock is released, keeping segment order
    /// aligned with log order.
    pub fn append_shared(&self, record: Arc<[u8]>) -> Result<()> {
        let mut file = {
            let mut st = self.state.lock().unwrap();
            if st.closed {
                return Err(Error::Queue("append to closed partition".into()));
            }
            let file = self.file.lock().unwrap();
            st.records.push(record.clone());
            if let Some(m) = &self.metrics {
                MetricsRegistry::add(&m.queue_appends, 1);
            }
            self.cv.notify_all();
            file
        };
        // wake topic-level wait-set consumers (outside the state lock;
        // before the durable write, matching the partition condvar's
        // visibility: the in-memory record is already readable)
        self.notify.bump();
        if let Some(f) = file.as_mut() {
            let mut framed = Vec::with_capacity(8 + record.len());
            framed.extend_from_slice(&(record.len() as u32).to_le_bytes());
            framed.extend_from_slice(&crc32(&record).to_le_bytes());
            framed.extend_from_slice(&record);
            f.write_all(&framed)?;
        }
        Ok(())
    }

    /// Polls up to `max` records starting at `offset`, blocking up to
    /// `timeout` for new data. Returns the records and the next offset;
    /// `None` means the partition is closed *and* fully consumed.
    pub fn poll(
        &self,
        offset: usize,
        max: usize,
        timeout: Duration,
    ) -> Option<(Vec<Arc<[u8]>>, usize)> {
        let mut st = self.state.lock().unwrap();
        let deadline = std::time::Instant::now() + timeout;
        loop {
            if offset < st.records.len() {
                let end = (offset + max).min(st.records.len());
                let recs: Vec<Arc<[u8]>> = st.records[offset..end].to_vec();
                if let Some(m) = &self.metrics {
                    MetricsRegistry::add(&m.queue_reads, recs.len() as u64);
                }
                return Some((recs, end));
            }
            if st.closed {
                return None;
            }
            let now = std::time::Instant::now();
            // saturating: a condvar wake-up (or a zero timeout) can land
            // after the deadline, and `deadline - now` would panic on the
            // Duration underflow
            let remaining = deadline.saturating_duration_since(now);
            if remaining.is_zero() {
                return Some((Vec::new(), offset)); // timed out, still open
            }
            let (g, _) = self.cv.wait_timeout(st, remaining).unwrap();
            st = g;
        }
    }

    /// Compacts the log prefix `[0, before)`: superseded records are
    /// replaced **in place** with zero-length tombstones, so the absolute
    /// offsets of every surviving record are preserved (consumer commits,
    /// poll offsets, and checkpoint `scan_from` markers all index the
    /// same positions before and after). Readers that decode record
    /// payloads must skip empty records. Returns how many records were
    /// tombstoned for the first time (repeat calls are idempotent).
    ///
    /// File-backed partitions rewrite their segment under the file guard
    /// (acquired before the state lock is released, like appends, so
    /// segment order stays aligned with log order): tombstones persist as
    /// zero-length frames and recovery reproduces them at the same
    /// indices, so the reclaimed space is durable too.
    pub fn compact_before(&self, before: usize) -> usize {
        let tombstone: Arc<[u8]> = Arc::from(&[][..]);
        let mut st = self.state.lock().unwrap();
        let end = before.min(st.records.len());
        let mut n = 0usize;
        for r in &mut st.records[..end] {
            if !r.is_empty() {
                *r = tombstone.clone();
                n += 1;
            }
        }
        if n == 0 {
            return 0;
        }
        if let Some(m) = &self.metrics {
            MetricsRegistry::add(&m.state_compactions, n as u64);
        }
        let mut file = self.file.lock().unwrap();
        let snapshot = file.as_ref().map(|_| st.records.clone());
        drop(st); // disk I/O happens outside the state lock, like appends
        if let (Some(f), Some(records)) = (file.as_mut(), snapshot) {
            let _ = f.set_len(0);
            for r in &records {
                let mut framed = Vec::with_capacity(8 + r.len());
                framed.extend_from_slice(&(r.len() as u32).to_le_bytes());
                framed.extend_from_slice(&crc32(r).to_le_bytes());
                framed.extend_from_slice(r);
                let _ = f.write_all(&framed);
            }
        }
        n
    }

    /// Records a consumer group's committed offset.
    pub fn commit(&self, group: &str, offset: usize) {
        let mut st = self.state.lock().unwrap();
        let e = st.committed.entry(group.to_string()).or_insert(0);
        if offset > *e {
            *e = offset;
        }
    }

    /// Last committed offset for a group (0 if none).
    pub fn committed(&self, group: &str) -> usize {
        *self
            .state
            .lock()
            .unwrap()
            .committed
            .get(group)
            .unwrap_or(&0)
    }

    /// Number of records currently in the log.
    pub fn len(&self) -> usize {
        self.state.lock().unwrap().records.len()
    }

    /// Records appended but not yet committed by `group` (consumer lag).
    /// Reads the log length and the committed offset under one lock so a
    /// concurrent append/commit never yields a torn reading.
    pub fn lag(&self, group: &str) -> usize {
        let st = self.state.lock().unwrap();
        st.records
            .len()
            .saturating_sub(*st.committed.get(group).unwrap_or(&0))
    }

    /// True when no records are present.
    pub fn is_empty(&self) -> bool {
        self.len() == 0
    }

    /// Closes the partition: consumers that drain it observe
    /// end-of-stream. Idempotent. Normally driven by
    /// [`Topic::producer_done`], but exposed for ingest pipelines that
    /// track per-partition producer EOS themselves.
    pub fn close(&self) {
        self.state.lock().unwrap().closed = true;
        self.cv.notify_all();
        self.notify.bump();
    }

    /// Reopens a closed partition for further appends.
    pub fn reopen(&self) {
        self.state.lock().unwrap().closed = false;
    }
}

/// CRC32 (IEEE, bitwise; cold path only — recovery and appends are
/// per-record, and records are batched).
pub fn crc32(data: &[u8]) -> u32 {
    let mut crc = 0xffff_ffffu32;
    for &b in data {
        crc ^= b as u32;
        for _ in 0..8 {
            let mask = (crc & 1).wrapping_neg();
            crc = (crc >> 1) ^ (0xedb8_8320 & mask);
        }
    }
    !crc
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn crc32_known_vector() {
        // IEEE CRC32 of "123456789" is 0xCBF43926.
        assert_eq!(crc32(b"123456789"), 0xcbf4_3926);
    }

    #[test]
    fn append_poll_roundtrip() {
        let broker = QueueBroker::in_memory(None);
        let t = broker.topic("t", 2).unwrap();
        t.register_producer();
        for i in 0..10u64 {
            t.append(i, &i.to_le_bytes()).unwrap();
        }
        t.producer_done();
        let mut seen = Vec::new();
        for p in 0..2 {
            let mut off = 0;
            while let Some((recs, next)) = t.partition(p).poll(off, 4, Duration::from_millis(10)) {
                for r in &recs {
                    seen.push(u64::from_le_bytes(r.as_ref().try_into().unwrap()));
                }
                off = next;
                if recs.is_empty() {
                    break;
                }
            }
        }
        seen.sort_unstable();
        assert_eq!(seen, (0..10).collect::<Vec<_>>());
    }

    #[test]
    fn append_batch_shares_the_encoded_buffer() {
        let broker = QueueBroker::in_memory(None);
        let t = broker.topic("t", 1).unwrap();
        t.register_producer();
        let batch = Batch::new(vec![crate::value::Value::I64(42)]);
        t.append_batch(0, &batch).unwrap();
        t.producer_done();
        let (recs, _) = t.partition(0).poll(0, 10, Duration::from_millis(10)).unwrap();
        assert_eq!(recs.len(), 1);
        let wire = batch.wire_cached().expect("append populated the cache");
        assert!(
            Arc::ptr_eq(&recs[0], &wire),
            "the log holds the producer's buffer, not a copy"
        );
        assert_eq!(Batch::from_wire(recs[0].clone()).unwrap(), batch);
    }

    #[test]
    fn key_hash_partitions_consistently() {
        let broker = QueueBroker::in_memory(None);
        let t = broker.topic("t", 4).unwrap();
        t.register_producer();
        t.append(13, b"a").unwrap();
        t.append(13, b"b").unwrap();
        t.producer_done();
        let p = (13 % 4) as usize;
        assert_eq!(t.partition(p).len(), 2);
    }

    #[test]
    fn poll_blocks_until_append() {
        let broker = QueueBroker::in_memory(None);
        let t = broker.topic("t", 1).unwrap();
        t.register_producer();
        let t2 = t.clone();
        let h = std::thread::spawn(move || {
            std::thread::sleep(Duration::from_millis(30));
            t2.append(0, b"late").unwrap();
        });
        let (recs, next) = t
            .partition(0)
            .poll(0, 10, Duration::from_secs(2))
            .expect("open partition");
        assert_eq!(recs.len(), 1);
        assert_eq!(next, 1);
        h.join().unwrap();
    }

    #[test]
    fn poll_with_zero_or_elapsed_timeout_never_panics() {
        let broker = QueueBroker::in_memory(None);
        let t = broker.topic("t", 1).unwrap();
        t.register_producer();
        // zero timeout on an open, empty partition: immediate timed-out
        // return (regression: the deadline math used to underflow)
        let r = t.partition(0).poll(0, 10, Duration::ZERO);
        assert!(matches!(r, Some((v, 0)) if v.is_empty()));
        let r = t.partition(0).poll(0, 10, Duration::from_nanos(1));
        assert!(matches!(r, Some((v, 0)) if v.is_empty()));
        // with data present, a zero timeout still returns the records
        t.append(0, b"x").unwrap();
        let r = t.partition(0).poll(0, 10, Duration::ZERO).unwrap();
        assert_eq!(r.0.len(), 1);
    }

    #[test]
    fn poll_many_drains_ready_partitions_and_ends_when_all_closed() {
        let broker = QueueBroker::in_memory(None);
        let t = broker.topic("t", 4).unwrap();
        t.register_producer();
        t.append(0, b"a").unwrap();
        t.append(2, b"c").unwrap();
        let parts: Vec<usize> = (0..4).collect();
        let mut offsets = vec![0; 4];
        let drained = t
            .poll_many(&parts, &mut offsets, 16, Duration::from_millis(10))
            .unwrap();
        let slots: Vec<usize> = drained.iter().map(|(s, _)| *s).collect();
        assert_eq!(slots, vec![0, 2], "one wakeup drains every ready partition");
        assert_eq!(offsets, vec![1, 0, 1, 0]);
        // timeout with every partition still open: empty drain, not EOS
        let r = t
            .poll_many(&parts, &mut offsets, 16, Duration::from_millis(5))
            .unwrap();
        assert!(r.is_empty());
        t.producer_done(); // closes all partitions
        assert!(t
            .poll_many(&parts, &mut offsets, 16, Duration::from_millis(10))
            .is_none());
    }

    #[test]
    fn poll_many_wakes_on_single_append_across_many_partitions() {
        let m = crate::metrics::MetricsRegistry::new();
        let broker = QueueBroker::in_memory(Some(m.clone()));
        let t = broker.topic("t", 16).unwrap();
        t.register_producer();
        let t2 = t.clone();
        let h = std::thread::spawn(move || {
            std::thread::sleep(Duration::from_millis(150));
            t2.append(11, b"late").unwrap();
        });
        let parts: Vec<usize> = (0..16).collect();
        let mut offsets = vec![0; 16];
        let t0 = std::time::Instant::now();
        let drained = loop {
            let d = t
                .poll_many(&parts, &mut offsets, 16, Duration::from_secs(30))
                .unwrap();
            if !d.is_empty() {
                break d;
            }
        };
        h.join().unwrap();
        assert!(
            t0.elapsed() < Duration::from_secs(10),
            "woken by the append, not the timeout"
        );
        assert_eq!(drained.len(), 1);
        assert_eq!(drained[0].0, 11, "slot of the appended partition");
        assert_eq!(drained[0].1[0].as_ref(), b"late");
        assert_eq!(offsets[11], 1);
        assert!(
            m.queue_wakeups.load(std::sync::atomic::Ordering::Relaxed) >= 1,
            "consumption was wakeup-driven"
        );
        assert_eq!(
            m.queue_wait_timeouts
                .load(std::sync::atomic::Ordering::Relaxed),
            0,
            "no timed-poll floor in the path"
        );
    }

    #[test]
    fn kick_wakes_a_parked_consumer_without_data() {
        let broker = QueueBroker::in_memory(None);
        let t = broker.topic("t", 2).unwrap();
        t.register_producer();
        let t2 = t.clone();
        let h = std::thread::spawn(move || {
            std::thread::sleep(Duration::from_millis(30));
            t2.kick();
        });
        let mut offsets = vec![0, 0];
        let t0 = std::time::Instant::now();
        let r = t
            .poll_many(&[0, 1], &mut offsets, 16, Duration::from_secs(30))
            .unwrap();
        h.join().unwrap();
        assert!(r.is_empty(), "a kick hands back control, not data");
        assert!(t0.elapsed() < Duration::from_secs(10));
    }

    #[test]
    fn poll_many_with_no_partitions_is_end_of_stream() {
        let broker = QueueBroker::in_memory(None);
        let t = broker.topic("t", 1).unwrap();
        let mut offsets: Vec<usize> = Vec::new();
        assert!(t
            .poll_many(&[], &mut offsets, 16, Duration::from_millis(5))
            .is_none());
    }

    #[test]
    fn close_signals_end_of_stream_after_drain() {
        let broker = QueueBroker::in_memory(None);
        let t = broker.topic("t", 1).unwrap();
        t.register_producer();
        t.append(0, b"x").unwrap();
        t.producer_done();
        let (recs, next) = t.partition(0).poll(0, 10, Duration::from_millis(10)).unwrap();
        assert_eq!(recs.len(), 1);
        assert!(t.partition(0).poll(next, 10, Duration::from_millis(10)).is_none());
    }

    #[test]
    fn multi_producer_close_requires_all() {
        let broker = QueueBroker::in_memory(None);
        let t = broker.topic("t", 1).unwrap();
        t.register_producer();
        t.register_producer();
        t.producer_done();
        // still open: one producer remains
        let r = t.partition(0).poll(0, 10, Duration::from_millis(10));
        assert!(matches!(r, Some((v, 0)) if v.is_empty()));
        t.producer_done();
        assert!(t.partition(0).poll(0, 10, Duration::from_millis(10)).is_none());
    }

    #[test]
    fn commits_are_monotonic() {
        let broker = QueueBroker::in_memory(None);
        let t = broker.topic("t", 1).unwrap();
        let p = t.partition(0);
        p.commit("g", 5);
        p.commit("g", 3); // must not regress
        assert_eq!(p.committed("g"), 5);
        assert_eq!(p.committed("other"), 0);
    }

    #[test]
    fn lag_tracks_appends_minus_commits() {
        let broker = QueueBroker::in_memory(None);
        let t = broker.topic("t", 2).unwrap();
        t.register_producer();
        for i in 0..6u64 {
            t.append(i, b"r").unwrap();
        }
        assert_eq!(t.lag("g"), 6, "nothing committed yet");
        t.partition(0).commit("g", 2);
        assert_eq!(t.lag("g"), 4);
        assert_eq!(t.partition(0).lag("g"), 1);
        // a foreign group's commits don't affect this group's lag
        t.partition(1).commit("other", 3);
        assert_eq!(t.lag("g"), 4);
    }

    #[test]
    fn compact_before_tombstones_in_place_and_preserves_offsets() {
        let m = crate::metrics::MetricsRegistry::new();
        let broker = QueueBroker::in_memory(Some(m.clone()));
        let t = broker.topic("state", 1).unwrap();
        t.register_producer();
        for i in 0..6u64 {
            t.append(0, &i.to_le_bytes()).unwrap();
        }
        let p = t.partition(0);
        assert_eq!(p.compact_before(4), 4);
        // offsets are stable: the log is the same length, survivors sit at
        // their original positions, the prefix reads back as empty records
        assert_eq!(p.len(), 6);
        let (recs, next) = p.poll(0, 10, Duration::from_millis(10)).unwrap();
        assert_eq!(next, 6);
        assert!(recs[..4].iter().all(|r| r.is_empty()));
        assert_eq!(recs[4].as_ref(), &4u64.to_le_bytes());
        assert_eq!(recs[5].as_ref(), &5u64.to_le_bytes());
        // idempotent: a second pass finds nothing new to tombstone
        assert_eq!(p.compact_before(4), 0);
        assert_eq!(
            m.state_compactions.load(std::sync::atomic::Ordering::Relaxed),
            4
        );
        // appends continue past the compacted prefix
        t.append(0, &6u64.to_le_bytes()).unwrap();
        assert_eq!(p.len(), 7);
    }

    #[test]
    fn durable_compaction_survives_recovery() {
        let dir = std::env::temp_dir().join(format!("fuq-compact-{}", std::process::id()));
        let _ = std::fs::remove_dir_all(&dir);
        {
            let broker = QueueBroker::durable(&dir, None).unwrap();
            let t = broker.topic("state", 1).unwrap();
            t.register_producer();
            for i in 0..5u32 {
                t.append(0, format!("rec{i}").as_bytes()).unwrap();
            }
            assert_eq!(t.partition(0).compact_before(3), 3);
        }
        {
            let broker = QueueBroker::durable(&dir, None).unwrap();
            let t = broker.topic("state", 1).unwrap();
            let p = t.partition(0);
            assert_eq!(p.len(), 5, "tombstones recover at their indices");
            let (recs, _) = p.poll(0, 10, Duration::from_millis(10)).unwrap();
            assert!(recs[..3].iter().all(|r| r.is_empty()));
            assert_eq!(recs[3].as_ref(), b"rec3");
            assert_eq!(recs[4].as_ref(), b"rec4");
        }
        std::fs::remove_dir_all(&dir).unwrap();
    }

    #[test]
    fn durable_topic_recovers_records_and_supports_resume() {
        let dir = std::env::temp_dir().join(format!("fuq-{}", std::process::id()));
        let _ = std::fs::remove_dir_all(&dir);
        {
            let broker = QueueBroker::durable(&dir, None).unwrap();
            let t = broker.topic("sensor", 1).unwrap();
            t.register_producer();
            for i in 0..5u32 {
                t.append(0, format!("rec{i}").as_bytes()).unwrap();
            }
            // no producer_done: simulate crash
        }
        {
            let broker = QueueBroker::durable(&dir, None).unwrap();
            let t = broker.topic("sensor", 1).unwrap();
            assert_eq!(t.partition(0).len(), 5);
            let (recs, _) = t.partition(0).poll(0, 10, Duration::from_millis(10)).unwrap();
            assert_eq!(recs[4].as_ref(), b"rec4");
            // appends continue after recovery
            t.register_producer();
            t.append(0, b"rec5").unwrap();
            assert_eq!(t.partition(0).len(), 6);
        }
        std::fs::remove_dir_all(&dir).unwrap();
    }

    #[test]
    fn recovery_tolerates_torn_tail() {
        let dir = std::env::temp_dir().join(format!("fuq-torn-{}", std::process::id()));
        let _ = std::fs::remove_dir_all(&dir);
        std::fs::create_dir_all(&dir).unwrap();
        let path = dir.join("t-0.log");
        {
            let mut f = File::create(&path).unwrap();
            let body = b"good";
            f.write_all(&(body.len() as u32).to_le_bytes()).unwrap();
            f.write_all(&crc32(body).to_le_bytes()).unwrap();
            f.write_all(body).unwrap();
            // torn record: header promises 100 bytes, body truncated
            f.write_all(&100u32.to_le_bytes()).unwrap();
            f.write_all(&0u32.to_le_bytes()).unwrap();
            f.write_all(b"short").unwrap();
        }
        let broker = QueueBroker::durable(&dir, None).unwrap();
        let t = broker.topic("t", 1).unwrap();
        assert_eq!(t.partition(0).len(), 1);
        std::fs::remove_dir_all(&dir).unwrap();
    }

    #[test]
    fn recovery_rejects_corrupt_crc() {
        let dir = std::env::temp_dir().join(format!("fuq-crc-{}", std::process::id()));
        let _ = std::fs::remove_dir_all(&dir);
        std::fs::create_dir_all(&dir).unwrap();
        let path = dir.join("t-0.log");
        {
            let mut f = File::create(&path).unwrap();
            let body = b"evil";
            f.write_all(&(body.len() as u32).to_le_bytes()).unwrap();
            f.write_all(&0xdeadbeefu32.to_le_bytes()).unwrap();
            f.write_all(body).unwrap();
        }
        let broker = QueueBroker::durable(&dir, None).unwrap();
        assert!(broker.topic("t", 1).is_err());
        std::fs::remove_dir_all(&dir).unwrap();
    }

    #[test]
    fn rejected_append_is_never_persisted() {
        let dir = std::env::temp_dir().join(format!("fuq-closed-{}", std::process::id()));
        let _ = std::fs::remove_dir_all(&dir);
        {
            let broker = QueueBroker::durable(&dir, None).unwrap();
            let t = broker.topic("t", 1).unwrap();
            t.register_producer();
            t.append(0, b"kept").unwrap();
            t.producer_done(); // closes the partition
            assert!(t.append(0, b"rejected").is_err());
        }
        let broker = QueueBroker::durable(&dir, None).unwrap();
        let t = broker.topic("t", 1).unwrap();
        assert_eq!(
            t.partition(0).len(),
            1,
            "a rejected append must not reappear after recovery"
        );
        std::fs::remove_dir_all(&dir).unwrap();
    }

    #[test]
    fn append_to_closed_partition_fails() {
        let broker = QueueBroker::in_memory(None);
        let t = broker.topic("t", 1).unwrap();
        t.register_producer();
        t.producer_done();
        assert!(t.append(0, b"x").is_err());
        t.reopen();
        t.register_producer();
        assert!(t.append(0, b"x").is_ok());
    }
}
