//! Test-only I/O fault injection for the segment path.
//!
//! [`FaultFs`] is an in-memory [`SegmentFs`] whose writes can be cut off
//! at a chosen byte (short writes / ENOSPC) and whose truncates can be
//! made to fail, driving the property tests that any crash point leaves a
//! replayable log. Wire it in with [`QueueBroker::durable_with_fs`].
//!
//! [`QueueBroker::durable_with_fs`]: super::QueueBroker::durable_with_fs

use super::{SegmentFs, SegmentIo};
use std::collections::HashMap;
use std::io;
use std::path::{Path, PathBuf};
use std::sync::atomic::{AtomicBool, AtomicU64, Ordering};
use std::sync::{Arc, Mutex};

/// Shared fault switchboard: flip faults on and off while a broker runs.
pub struct FaultCtl {
    /// Bytes of segment writes still allowed across all files;
    /// `u64::MAX` means unlimited. A write crossing the cap lands its
    /// allowed prefix (a short write) and fails — the injected-ENOSPC
    /// artifact.
    write_cap: AtomicU64,
    /// When set, every truncate fails (recovery cannot cut a torn tail).
    fail_truncate: AtomicBool,
}

impl FaultCtl {
    /// Lifts all write limits.
    pub fn unlimited(&self) {
        self.write_cap.store(u64::MAX, Ordering::SeqCst);
    }

    /// Allows exactly `n` more bytes of segment writes before failing.
    pub fn set_write_cap(&self, n: u64) {
        self.write_cap.store(n, Ordering::SeqCst);
    }

    /// Makes truncates fail (or succeed again) from now on.
    pub fn set_fail_truncate(&self, on: bool) {
        self.fail_truncate.store(on, Ordering::SeqCst);
    }
}

/// In-memory segment store with injectable faults. One instance models
/// one "disk": files persist across broker instances sharing the
/// `Arc<FaultFs>`, which is how tests simulate a crash + restart.
pub struct FaultFs {
    files: Mutex<HashMap<PathBuf, Arc<Mutex<Vec<u8>>>>>,
    ctl: Arc<FaultCtl>,
}

impl FaultFs {
    /// A fresh fault-free store.
    pub fn new() -> Arc<FaultFs> {
        Arc::new(FaultFs {
            files: Mutex::new(HashMap::new()),
            ctl: Arc::new(FaultCtl {
                write_cap: AtomicU64::new(u64::MAX),
                fail_truncate: AtomicBool::new(false),
            }),
        })
    }

    /// The fault switchboard.
    pub fn ctl(&self) -> Arc<FaultCtl> {
        self.ctl.clone()
    }

    /// Current bytes of the file at `path`, if it exists.
    pub fn contents(&self, path: impl AsRef<Path>) -> Option<Vec<u8>> {
        self.files
            .lock()
            .unwrap()
            .get(path.as_ref())
            .map(|f| f.lock().unwrap().clone())
    }

    /// Overwrites (or creates) the file at `path` — used to replay a
    /// captured byte prefix as a simulated crash point.
    pub fn set_contents(&self, path: impl AsRef<Path>, bytes: Vec<u8>) {
        let mut files = self.files.lock().unwrap();
        match files.get(path.as_ref()) {
            Some(f) => *f.lock().unwrap() = bytes,
            None => {
                files.insert(path.as_ref().to_path_buf(), Arc::new(Mutex::new(bytes)));
            }
        }
    }
}

impl SegmentFs for FaultFs {
    fn read(&self, path: &Path) -> io::Result<Option<Vec<u8>>> {
        Ok(self
            .files
            .lock()
            .unwrap()
            .get(path)
            .map(|f| f.lock().unwrap().clone()))
    }

    fn open(&self, path: &Path) -> io::Result<Box<dyn SegmentIo>> {
        let buf = self
            .files
            .lock()
            .unwrap()
            .entry(path.to_path_buf())
            .or_default()
            .clone();
        Ok(Box::new(FaultSegment {
            buf,
            ctl: self.ctl.clone(),
        }))
    }
}

struct FaultSegment {
    buf: Arc<Mutex<Vec<u8>>>,
    ctl: Arc<FaultCtl>,
}

impl SegmentIo for FaultSegment {
    fn append(&mut self, data: &[u8]) -> io::Result<()> {
        let cap = self.ctl.write_cap.load(Ordering::SeqCst);
        if cap == u64::MAX {
            self.buf.lock().unwrap().extend_from_slice(data);
            return Ok(());
        }
        let allowed = cap.min(data.len() as u64) as usize;
        self.buf
            .lock()
            .unwrap()
            .extend_from_slice(&data[..allowed]);
        self.ctl
            .write_cap
            .store(cap - allowed as u64, Ordering::SeqCst);
        if allowed < data.len() {
            // the partial prefix stayed behind, exactly like a real short
            // write before ENOSPC
            return Err(io::Error::other("injected ENOSPC (short write)"));
        }
        Ok(())
    }

    fn read_at(&self, pos: u64, out: &mut [u8]) -> io::Result<()> {
        let buf = self.buf.lock().unwrap();
        let start = pos as usize;
        let end = start + out.len();
        if end > buf.len() {
            return Err(io::Error::new(
                io::ErrorKind::UnexpectedEof,
                "read past end of injected segment",
            ));
        }
        out.copy_from_slice(&buf[start..end]);
        Ok(())
    }

    fn truncate(&mut self, len: u64) -> io::Result<()> {
        if self.ctl.fail_truncate.load(Ordering::SeqCst) {
            return Err(io::Error::other("injected truncate failure"));
        }
        self.buf.lock().unwrap().truncate(len as usize);
        Ok(())
    }
}

#[cfg(test)]
mod tests {
    use super::super::QueueBroker;
    use super::*;
    use std::time::Duration;

    fn seg_path(p: usize) -> PathBuf {
        PathBuf::from(format!("/fault/t-{p}.log"))
    }

    /// Writes `n` records through a FaultFs broker and returns the final
    /// segment bytes.
    fn reference_log(fs: &Arc<FaultFs>, n: usize) -> Vec<u8> {
        let broker = QueueBroker::durable_with_fs("/fault", fs.clone(), None, None);
        let t = broker.topic("t", 1).unwrap();
        t.register_producer();
        for i in 0..n {
            t.append(0, format!("record-{i:04}").as_bytes()).unwrap();
        }
        fs.contents(seg_path(0)).unwrap()
    }

    #[test]
    fn crash_at_any_byte_leaves_a_replayable_log() {
        let fs = FaultFs::new();
        let full = reference_log(&fs, 10);
        // a crash can cut the segment at *any* byte; every prefix must
        // recover to a prefix of the appended records, never an error
        for cut in 0..=full.len() {
            let fs2 = FaultFs::new();
            fs2.set_contents(seg_path(0), full[..cut].to_vec());
            let broker = QueueBroker::durable_with_fs("/fault", fs2.clone(), None, None);
            let t = broker
                .topic("t", 1)
                .unwrap_or_else(|e| panic!("cut at byte {cut} must replay, got {e}"));
            let p = t.partition(0);
            let n = p.len();
            assert!(n <= 10, "cut at {cut} produced {n} records");
            if n > 0 {
                let (recs, _) = p.poll(0, 16, Duration::from_millis(5)).unwrap();
                for (i, r) in recs.iter().enumerate() {
                    assert_eq!(
                        r.as_ref(),
                        format!("record-{i:04}").as_bytes(),
                        "cut at {cut}: surviving records form an exact prefix"
                    );
                }
            }
            // the torn bytes are really gone: appending after recovery and
            // re-reading the file still parses end to end
            t.register_producer();
            t.append(0, b"post-crash").unwrap();
            let after = fs2.contents(seg_path(0)).unwrap();
            let fs3 = FaultFs::new();
            fs3.set_contents(seg_path(0), after);
            let b3 = QueueBroker::durable_with_fs("/fault", fs3, None, None);
            let t3 = b3.topic("t", 1).unwrap();
            assert_eq!(t3.partition(0).len(), n + 1);
        }
    }

    #[test]
    fn enospc_mid_append_fails_loud_but_log_stays_replayable() {
        let fs = FaultFs::new();
        let broker = QueueBroker::durable_with_fs("/fault", fs.clone(), None, None);
        let t = broker.topic("t", 1).unwrap();
        t.register_producer();
        t.append(0, b"first-record").unwrap();
        // allow 5 more bytes: the next frame tears mid-write
        fs.ctl().set_write_cap(5);
        let err = t.append(0, b"second-record").unwrap_err();
        assert!(format!("{err}").contains("ENOSPC"));
        // the torn frame is on "disk"; a restart replays only the full one
        let bytes = fs.contents(seg_path(0)).unwrap();
        let fs2 = FaultFs::new();
        fs2.set_contents(seg_path(0), bytes);
        let b2 = QueueBroker::durable_with_fs("/fault", fs2, None, None);
        let t2 = b2.topic("t", 1).unwrap();
        assert_eq!(t2.partition(0).len(), 1);
        // the original broker still serves the record from memory and
        // stops trusting the broken segment for later appends
        let (recs, _) = t
            .partition(0)
            .poll(0, 16, Duration::from_millis(5))
            .unwrap();
        assert_eq!(recs.len(), 2);
        assert_eq!(recs[1].as_ref(), b"second-record");
    }

    #[test]
    fn failing_truncate_surfaces_as_an_open_error() {
        let fs = FaultFs::new();
        let full = reference_log(&fs, 3);
        let fs2 = FaultFs::new();
        // torn tail that recovery must cut — but truncate is broken
        fs2.set_contents(seg_path(0), full[..full.len() - 4].to_vec());
        fs2.ctl().set_fail_truncate(true);
        let broker = QueueBroker::durable_with_fs("/fault", fs2, None, None);
        assert!(
            broker.topic("t", 1).is_err(),
            "an uncuttable torn tail must refuse to open, not limp on"
        );
    }

    #[test]
    fn bounded_faultfs_broker_serves_spilled_reads() {
        let fs = FaultFs::new();
        let broker = QueueBroker::durable_with_fs("/fault", fs, Some(256), None);
        broker.set_resident_tail(1);
        let t = broker.topic("t", 1).unwrap();
        t.register_producer();
        for i in 0..10u8 {
            t.append(0, &[i; 64]).unwrap();
        }
        assert!(broker.resident_bytes() <= 256);
        let (recs, next) = t
            .partition(0)
            .poll(0, 16, Duration::from_millis(5))
            .unwrap();
        assert_eq!(next, 10);
        for (i, r) in recs.iter().enumerate() {
            assert_eq!(r.as_ref(), &[i as u8; 64]);
        }
    }
}
