//! Small shared utilities: deterministic PRNG, unit parsing, formatting.

use std::time::Duration;

/// xorshift64* PRNG — deterministic, dependency-free. Used by synthetic
/// sources, the property-test harness, and shuffling decisions that must be
/// reproducible across runs.
#[derive(Debug, Clone)]
pub struct XorShift64 {
    state: u64,
}

impl XorShift64 {
    /// Creates a generator from a non-zero seed (zero is mapped away).
    pub fn new(seed: u64) -> Self {
        XorShift64 {
            state: seed.wrapping_mul(0x9e37_79b9_7f4a_7c15) | 1,
        }
    }

    /// Next raw 64-bit value.
    pub fn next_u64(&mut self) -> u64 {
        let mut x = self.state;
        x ^= x >> 12;
        x ^= x << 25;
        x ^= x >> 27;
        self.state = x;
        x.wrapping_mul(0x2545_f491_4f6c_dd1d)
    }

    /// Uniform integer in `[0, n)`. `n` must be > 0.
    pub fn gen_range(&mut self, n: u64) -> u64 {
        debug_assert!(n > 0);
        // Multiply-shift bound mapping; bias negligible for our n.
        ((self.next_u64() as u128 * n as u128) >> 64) as u64
    }

    /// Uniform f64 in `[0, 1)`.
    pub fn gen_f64(&mut self) -> f64 {
        (self.next_u64() >> 11) as f64 / (1u64 << 53) as f64
    }

    /// Uniform f32 in `[0, 1)`.
    pub fn gen_f32(&mut self) -> f32 {
        self.gen_f64() as f32
    }

    /// Bernoulli draw.
    pub fn gen_bool(&mut self, p: f64) -> bool {
        self.gen_f64() < p
    }

    /// Approximately normal draw (Irwin–Hall, 12 uniforms).
    pub fn gen_normal(&mut self, mean: f64, std: f64) -> f64 {
        let s: f64 = (0..12).map(|_| self.gen_f64()).sum();
        mean + (s - 6.0) * std
    }

    /// Picks a random element of a slice.
    pub fn pick<'a, T>(&mut self, xs: &'a [T]) -> &'a T {
        &xs[self.gen_range(xs.len() as u64) as usize]
    }
}

/// Parses a bandwidth string: `unlimited`, `10Mbit`, `1Gbit`, `100kbit`,
/// `1000bit`, case-insensitive, optional `/s` suffix. Returns bits/second,
/// `None` meaning unlimited.
pub fn parse_bandwidth(s: &str) -> Option<Option<u64>> {
    let s = s.trim().to_ascii_lowercase();
    let s = s.strip_suffix("/s").unwrap_or(&s);
    if s == "unlimited" || s == "inf" || s == "none" {
        return Some(None);
    }
    let (mult, rest) = if let Some(r) = s.strip_suffix("gbit") {
        (1_000_000_000u64, r)
    } else if let Some(r) = s.strip_suffix("mbit") {
        (1_000_000, r)
    } else if let Some(r) = s.strip_suffix("kbit") {
        (1_000, r)
    } else if let Some(r) = s.strip_suffix("bit") {
        (1, r)
    } else {
        return None;
    };
    let num: f64 = rest.trim().parse().ok()?;
    if num <= 0.0 {
        return None;
    }
    Some(Some((num * mult as f64) as u64))
}

/// Parses a duration string: `0ms`, `10ms`, `1s`, `100us`, `2m`.
pub fn parse_duration(s: &str) -> Option<Duration> {
    let s = s.trim().to_ascii_lowercase();
    let (mult_ns, rest) = if let Some(r) = s.strip_suffix("ms") {
        (1_000_000u64, r)
    } else if let Some(r) = s.strip_suffix("us") {
        (1_000, r)
    } else if let Some(r) = s.strip_suffix("ns") {
        (1, r)
    } else if let Some(r) = s.strip_suffix('m') {
        (60_000_000_000, r)
    } else if let Some(r) = s.strip_suffix('s') {
        (1_000_000_000, r)
    } else {
        return None;
    };
    let num: f64 = rest.trim().parse().ok()?;
    if num < 0.0 {
        return None;
    }
    Some(Duration::from_nanos((num * mult_ns as f64) as u64))
}

/// Formats a byte count with binary units.
pub fn fmt_bytes(b: u64) -> String {
    const UNITS: [&str; 5] = ["B", "KiB", "MiB", "GiB", "TiB"];
    let mut v = b as f64;
    let mut u = 0;
    while v >= 1024.0 && u < UNITS.len() - 1 {
        v /= 1024.0;
        u += 1;
    }
    if u == 0 {
        format!("{b} B")
    } else {
        format!("{v:.2} {}", UNITS[u])
    }
}

/// Formats events/second.
pub fn fmt_rate(events: u64, wall: Duration) -> String {
    let secs = wall.as_secs_f64();
    if secs <= 0.0 {
        return "inf ev/s".into();
    }
    let r = events as f64 / secs;
    if r >= 1e6 {
        format!("{:.2} Mev/s", r / 1e6)
    } else if r >= 1e3 {
        format!("{:.2} kev/s", r / 1e3)
    } else {
        format!("{r:.0} ev/s")
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn rng_is_deterministic() {
        let mut a = XorShift64::new(42);
        let mut b = XorShift64::new(42);
        for _ in 0..100 {
            assert_eq!(a.next_u64(), b.next_u64());
        }
    }

    #[test]
    fn rng_range_bounds() {
        let mut r = XorShift64::new(7);
        for _ in 0..10_000 {
            let v = r.gen_range(13);
            assert!(v < 13);
        }
    }

    #[test]
    fn rng_f64_bounds_and_spread() {
        let mut r = XorShift64::new(99);
        let mut sum = 0.0;
        for _ in 0..10_000 {
            let v = r.gen_f64();
            assert!((0.0..1.0).contains(&v));
            sum += v;
        }
        let mean = sum / 10_000.0;
        assert!((mean - 0.5).abs() < 0.02, "mean {mean}");
    }

    #[test]
    fn bandwidth_parsing() {
        assert_eq!(parse_bandwidth("unlimited"), Some(None));
        assert_eq!(parse_bandwidth("10Mbit"), Some(Some(10_000_000)));
        assert_eq!(parse_bandwidth("1Gbit"), Some(Some(1_000_000_000)));
        assert_eq!(parse_bandwidth("100Mbit/s"), Some(Some(100_000_000)));
        assert_eq!(parse_bandwidth("2.5gbit"), Some(Some(2_500_000_000)));
        assert_eq!(parse_bandwidth("100kbit"), Some(Some(100_000)));
        assert_eq!(parse_bandwidth("garbage"), None);
        assert_eq!(parse_bandwidth("-5Mbit"), None);
    }

    #[test]
    fn duration_parsing() {
        assert_eq!(parse_duration("0ms"), Some(Duration::ZERO));
        assert_eq!(parse_duration("10ms"), Some(Duration::from_millis(10)));
        assert_eq!(parse_duration("1s"), Some(Duration::from_secs(1)));
        assert_eq!(parse_duration("100us"), Some(Duration::from_micros(100)));
        assert_eq!(parse_duration("1.5s"), Some(Duration::from_millis(1500)));
        assert_eq!(parse_duration("oops"), None);
    }

    #[test]
    fn byte_formatting() {
        assert_eq!(fmt_bytes(512), "512 B");
        assert_eq!(fmt_bytes(2048), "2.00 KiB");
        assert_eq!(fmt_bytes(5 * 1024 * 1024), "5.00 MiB");
    }
}
