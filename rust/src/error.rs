//! Crate-wide error type.

use std::fmt;

/// Unified result type for the crate.
pub type Result<T> = std::result::Result<T, Error>;

/// Errors raised by the FlowUnits engine.
#[derive(Debug)]
pub enum Error {
    /// Cluster / job configuration file could not be parsed.
    Config { line: usize, msg: String },
    /// Constraint expression could not be parsed.
    Constraint(String),
    /// The logical graph is invalid (e.g. layer ordering violates the zone tree).
    Graph(String),
    /// The planner could not produce a feasible deployment.
    Placement(String),
    /// Topology is inconsistent (unknown zone/layer/location, cycles, ...).
    Topology(String),
    /// Queue substrate failure (I/O, corrupt segment, unknown topic).
    Queue(String),
    /// Value codec failure (truncated frame, bad tag, ...).
    Codec(String),
    /// A typed-layer decode failure: a `Value` did not match the native
    /// type a `StreamData` conversion expected (typed closures and
    /// `JobReport::take` surface this instead of panicking).
    Decode(String),
    /// Runtime execution failure.
    Runtime(String),
    /// Transport failure (closed lane, dead peer, handshake rejection,
    /// malformed frame off a socket). Delivery paths *count* these and
    /// keep running where possible — a dying peer must never take down
    /// the whole process — while control paths (register, deploy,
    /// report) surface them to the caller.
    Transport(String),
    /// XLA / PJRT failure (artifact missing, compile or execute error).
    Xla(String),
    /// Underlying I/O error.
    Io(std::io::Error),
}

impl fmt::Display for Error {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        match self {
            Error::Config { line, msg } => write!(f, "config error at line {line}: {msg}"),
            Error::Constraint(m) => write!(f, "constraint parse error: {m}"),
            Error::Graph(m) => write!(f, "logical graph error: {m}"),
            Error::Placement(m) => write!(f, "placement error: {m}"),
            Error::Topology(m) => write!(f, "topology error: {m}"),
            Error::Queue(m) => write!(f, "queue error: {m}"),
            Error::Codec(m) => write!(f, "codec error: {m}"),
            Error::Decode(m) => write!(f, "decode error: {m}"),
            Error::Runtime(m) => write!(f, "runtime error: {m}"),
            Error::Transport(m) => write!(f, "transport error: {m}"),
            Error::Xla(m) => write!(f, "xla error: {m}"),
            Error::Io(e) => write!(f, "io error: {e}"),
        }
    }
}

impl std::error::Error for Error {}

impl From<std::io::Error> for Error {
    fn from(e: std::io::Error) -> Self {
        Error::Io(e)
    }
}

#[cfg(feature = "xla")]
impl From<xla::Error> for Error {
    fn from(e: xla::Error) -> Self {
        Error::Xla(e.to_string())
    }
}
