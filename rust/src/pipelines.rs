//! Named pipelines shared by every entrypoint.
//!
//! In distributed mode the program is *one* logical graph materialised in
//! N processes: the coordinator daemon plans it, and every worker rebuilds
//! the identical graph (same pipeline name, same event count) and re-runs
//! the deterministic planner to learn which instances it owns. That only
//! works if graph construction lives in exactly one place — this module.
//! The CLI (`flowunits run`/`plan`/`fig3`), the coordinator daemon, and
//! workers all build pipelines through [`build`].

use crate::api::raw::{Source, StreamContext, WatermarkGen, WindowAgg, WindowAssigner};
use crate::error::{Error, Result};
use crate::value::Value;

/// Pipelines [`build`] knows how to construct.
pub const NAMES: &[&str] = &["eval", "wordcount", "wordcount_paced", "acme", "event_time"];

/// Words cycled by the wordcount sources.
const WORDS: [&str; 6] = ["stream", "edge", "cloud", "site", "data", "flow"];

/// Events/second *per source instance* for the paced wordcount variant —
/// slow enough that a test (or demo) can kill a worker mid-run.
const PACED_RATE: f64 = 20_000.0;

/// Builds the named pipeline into `ctx`. Construction is deterministic:
/// two processes calling this with the same `(pipeline, events)` get
/// identical logical graphs, and therefore identical placement plans.
pub fn build(ctx: &mut StreamContext, pipeline: &str, events: u64) -> Result<()> {
    match pipeline {
        "eval" => build_eval(ctx, events),
        "wordcount" => build_wordcount(ctx, Source::synthetic(events, wordcount_gen)),
        "wordcount_paced" => build_wordcount(
            ctx,
            Source::synthetic_rated(events, PACED_RATE, wordcount_gen),
        ),
        "acme" => build_acme(ctx, events),
        "event_time" => build_event_time(ctx, events),
        other => return Err(Error::Runtime(format!("unknown pipeline '{other}'"))),
    }
    Ok(())
}

fn wordcount_gen(_inst: u64, i: u64) -> Value {
    Value::Str(WORDS[(i % WORDS.len() as u64) as usize].to_string())
}

/// The paper's §V pipeline: O1 filters 67% at the edge, O2 windows+averages
/// at the site, O3 computes Collatz convergence steps in the cloud.
fn build_eval(ctx: &mut StreamContext, events: u64) {
    ctx.stream(Source::synthetic(events, |inst, i| {
        Value::I64((inst as i64) << 32 | (i as i64 & 0xffff_ffff))
    }))
    .to_layer("edge")
    .filter(|v| v.as_i64().unwrap() % 3 == 0) // O1: keep 33%
    .to_layer("site")
    .key_by(|v| Value::I64(v.as_i64().unwrap() % 16))
    .window(100, WindowAgg::Mean) // O2
    .to_layer("cloud")
    .map(|v| {
        // O3: Collatz convergence steps of the window average
        let (_k, mean) = v.as_pair().expect("keyed window output");
        let mut n = (mean.as_f64().unwrap().abs() as u64).max(1);
        let mut steps = 0i64;
        while n != 1 {
            n = if n % 2 == 0 { n / 2 } else { 3 * n + 1 };
            steps += 1;
        }
        Value::I64(steps)
    })
    .collect_count();
}

/// Keyed wordcount over a cycling word source; collects `(word, count)`.
fn build_wordcount(ctx: &mut StreamContext, source: Source) {
    ctx.stream(source)
        .to_layer("cloud")
        .group_by(|w| w.clone())
        .fold(Value::I64(0), |acc, _| {
            *acc = Value::I64(acc.as_i64().unwrap() + 1)
        })
        .collect_vec();
}

/// Fig. 1 pipeline with the XLA anomaly model at the cloud.
fn build_acme(ctx: &mut StreamContext, events: u64) {
    ctx.stream(Source::synthetic(events, |inst, i| {
        let t = i as f64 * 0.01;
        let v = (t.sin() * 10.0 + 50.0) + ((i % 97) as f64) * 0.1 + inst as f64;
        Value::F64(v)
    }))
    .to_layer("edge")
    .filter(|v| v.as_f64().unwrap().is_finite())
    .to_layer("site")
    .key_by(|v| Value::I64((v.as_f64().unwrap() * 10.0) as i64 % 4))
    .window(32, WindowAgg::FeatureStats)
    .to_layer("cloud")
    .xla_map("anomaly_v1", 64, 5)
    .add_constraint("xla = yes")
    .collect_count();
}

/// Event-time demo: sources emit deterministically disordered event
/// timestamps (blocks of 8 ticks delivered back-to-front, 5 ms apart —
/// at most 35 ms of disorder), the edge assigns timestamps under a 40 ms
/// bounded-out-of-orderness watermark, and the cloud counts per-key
/// tumbling event-time windows. Construction is deterministic, so the
/// distributed parity check covers watermark propagation too.
fn build_event_time(ctx: &mut StreamContext, events: u64) {
    ctx.stream(Source::synthetic(events, |_inst, i| {
        let tick = (i / 8) * 8 + (7 - i % 8);
        Value::I64(tick as i64 * 5)
    }))
    .to_layer("edge")
    .assign_timestamps(|v| v.as_i64().unwrap_or(0), WatermarkGen::bounded(40))
    .to_layer("cloud")
    .key_by(|v| Value::I64((v.as_i64().unwrap_or(0) / 5) % 4))
    .event_window(
        |v| v.as_i64().unwrap_or(0),
        WindowAssigner::tumbling(200),
        WindowAgg::Count,
        0,
    )
    .collect_vec();
}

/// Stable, human-diffable rendering of one collected value. Used for the
/// distributed-vs-in-process parity check: both sides render and sort, so
/// instance interleaving can't perturb the comparison.
pub fn render_value(v: &Value) -> String {
    if let Some((k, val)) = v.as_pair() {
        return format!("({}, {})", render_value(k), render_value(val));
    }
    if let Some(items) = v.as_list() {
        let inner: Vec<String> = items.iter().map(render_value).collect();
        return format!("[{}]", inner.join(", "));
    }
    if let Some(s) = v.as_str() {
        return s.to_string();
    }
    if let Some(i) = v.as_i64() {
        return i.to_string();
    }
    if let Some(f) = v.as_f64() {
        return format!("{f}");
    }
    format!("{v:?}")
}

/// Sorted `collected: <value>` lines for a set of collected values.
pub fn render_collected(values: &[Value]) -> Vec<String> {
    let mut lines: Vec<String> = values
        .iter()
        .map(|v| format!("collected: {}", render_value(v)))
        .collect();
    lines.sort();
    lines
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::api::raw::JobConfig;
    use crate::config::eval_cluster;
    use std::time::Duration;

    #[test]
    fn unknown_pipeline_is_an_error() {
        let mut ctx = StreamContext::new(eval_cluster(None, Duration::ZERO), JobConfig::default());
        assert!(build(&mut ctx, "nope", 10).is_err());
    }

    #[test]
    fn wordcount_builds_and_runs() {
        let mut ctx = StreamContext::new(eval_cluster(None, Duration::ZERO), JobConfig::default());
        build(&mut ctx, "wordcount", 600).unwrap();
        let report = ctx.execute().unwrap();
        let lines = render_collected(&report.collected);
        assert_eq!(lines.len(), 6, "one (word, count) pair per word");
        assert!(lines.iter().all(|l| l.contains("100")), "{lines:?}");
    }

    #[test]
    fn event_time_pipeline_counts_every_window_exactly() {
        let mut ctx = StreamContext::new(eval_cluster(None, Duration::ZERO), JobConfig::default());
        build(&mut ctx, "event_time", 1_600).unwrap();
        let report = ctx.execute().unwrap();
        // ticks form a permutation of 0..1600 → ts 0..8000ms, 40 tumbling
        // windows of 200ms × 4 keys, 10 records per (key, window)
        assert_eq!(report.collected.len(), 160, "40 windows × 4 keys");
        assert!(
            report
                .collected
                .iter()
                .all(|v| v.as_pair().and_then(|(_, c)| c.as_i64()) == Some(10)),
            "every pane counts its 10 records exactly"
        );
        assert_eq!(
            report
                .metrics
                .late_records
                .load(std::sync::atomic::Ordering::Relaxed),
            0,
            "disorder stays within the watermark bound"
        );
    }

    #[test]
    fn rendering_is_sorted_and_stable() {
        let vals = vec![
            Value::pair(Value::Str("b".into()), Value::I64(2)),
            Value::pair(Value::Str("a".into()), Value::I64(1)),
        ];
        assert_eq!(
            render_collected(&vals),
            vec!["collected: (a, 1)", "collected: (b, 2)"]
        );
    }
}
