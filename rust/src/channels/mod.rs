//! Channels between operator instances.
//!
//! Instances on the same host exchange [`Batch`] handles by refcount bump
//! through bounded in-memory channels (Renoir's in-memory path) — fan-out
//! duplication (`split` edges, `Broadcast` routing) shares one payload
//! allocation across every edge, never deep-cloning. Instances on
//! different hosts exchange *encoded frames*: the sender serialises the
//! batch **once** (the encoding is cached on the batch, so further
//! crossing edges re-use the same bytes) while still paying the real
//! encode cost and producing the real byte size; the frame traverses the
//! emulated inter-zone [`Link`](crate::netsim::Link) when the hosts are in
//! different zones, and the receiving worker decodes it (paying the real
//! decode cost) — mirroring Renoir's TCP path. Frame bytes themselves are
//! refcounted, so a broadcast over N crossing edges queues N references to
//! one buffer.
//!
//! Output ports route with one of three policies:
//! * `RoundRobin` — rebalance whole batches across allowed targets
//!   (forward edges);
//! * `Hash` — partition records by `stable_hash(key)` so every sender maps
//!   a key to the same target instance (keyed edges, paper's `group_by`).
//!   Batches arriving from a keying operator carry a per-record hash
//!   column ([`Batch::key_hashes`]) computed when the pair was built, so
//!   the shuffle is a one-sweep pre-partition over `u64`s — no `Value`
//!   tree is re-walked; column-less batches fall back to hashing on the
//!   fly;
//! * `Broadcast` — replicate to all targets (control/barrier use).

use crate::columnar::{ColumnBatch, ColumnBuffer, Layout};
use crate::metrics::{Metrics, MetricsRegistry};
use crate::netsim::Link;
use crate::transport::{InProcessLane, Lane, NetsimLane};
use crate::value::{Batch, BatchData, Value};
use std::sync::mpsc::{Receiver, Sender, SyncSender};
use std::sync::Arc;
use std::time::{Duration, Instant};

/// Per-frame overhead in accounted bytes (length prefix + CRC + TCP/IP
/// headers amortised per frame — matches a 1500-byte-MTU stream envelope).
pub const FRAME_OVERHEAD: usize = 48;

/// Default bound (in batches) of an instance inbox.
pub const DEFAULT_CHANNEL_CAPACITY: usize = 64;

/// A message travelling between operator instances.
#[derive(Debug)]
pub enum Msg {
    /// Same-host batch, shared by refcount.
    Batch(Batch),
    /// Same-host columnar batch, shared by refcount — the typed data
    /// plane's struct-of-arrays representation stays columnar across
    /// local stage edges. Framed lanes never see this variant: columns
    /// encode to the same [`Msg::Frame`] bytes as the equivalent row
    /// batch, so the wire format is unchanged.
    Columns(ColumnBatch),
    /// Cross-host batch, encoded; decoded by the receiving worker. The
    /// bytes are refcounted so broadcast frames share one buffer.
    Frame(Arc<[u8]>),
    /// One upstream producer finished.
    Eos,
    /// Drain-and-handoff epoch marker (dynamic updates): one upstream
    /// producer has quiesced for the given update epoch. Unlike [`Msg::Eos`]
    /// this does **not** end the stream — a consumer that has received the
    /// marker from every producer quiesces itself (snapshotting state and
    /// forwarding the marker) instead of flushing and cascading EOS.
    Epoch(u64),
    /// Event-time watermark: the sending producer promises it will emit no
    /// further record with an event timestamp below `ts`. Unlike epochs,
    /// watermarks carry the *sender's instance id* so a fan-in consumer can
    /// merge them min-of-inputs — the shared inbox channel is otherwise
    /// anonymous.
    Watermark(Watermark),
}

/// One watermark frame. `from` identifies the producing instance (inbox
/// messages carry no other sender identity); `origin_ms` is the wall-clock
/// time the watermark was *generated* at its source assigner, preserved
/// hop-to-hop so `watermark_lag_ms` measures end-to-end propagation.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub struct Watermark {
    /// Producing instance id (unique per job).
    pub from: u32,
    /// Event-time promise: no later record below this timestamp (ms).
    pub ts: i64,
    /// Wall-clock generation time at the originating assigner (ms since
    /// the Unix epoch).
    pub origin_ms: u64,
}

/// Epoch-kind tag bit: epochs with this bit set are *checkpoint* epochs
/// (periodic, coordinator-driven state capture) as opposed to planned
/// drain-and-handoff update epochs. The tag travels inside the existing
/// `u64` epoch payload, so the in-process channels, the socket EPOCH
/// frame, and every [`Inbox`] pass it through untouched — only the
/// endpoints (coordinator, quiesce path) interpret it.
pub const CHECKPOINT_BIT: u64 = 1 << 63;

/// Tags a sequence number as a checkpoint epoch.
pub fn checkpoint_epoch(seq: u64) -> u64 {
    seq | CHECKPOINT_BIT
}

/// True if `epoch` is a checkpoint epoch (vs. a planned-update epoch).
pub fn is_checkpoint(epoch: u64) -> bool {
    epoch & CHECKPOINT_BIT != 0
}

/// The sequence number of an epoch, with the kind tag stripped.
pub fn epoch_seq(epoch: u64) -> u64 {
    epoch & !CHECKPOINT_BIT
}

/// Hash used to route one record on a [`Routing::Hash`] edge: the pair
/// key for keyed records, the whole value otherwise. The coordinator's
/// restore re-partitioning (dynamic updates) must mirror live routing
/// exactly, so both sides share this helper.
pub fn route_hash(v: &Value) -> u64 {
    match v {
        Value::Pair(kv) => kv.0.stable_hash(),
        other => other.stable_hash(),
    }
}

/// Routing policy of an output port.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum Routing {
    /// Rebalance whole batches across targets.
    RoundRobin,
    /// Partition records by key hash (`Value::Pair` keys).
    Hash,
    /// Replicate every batch to every target.
    Broadcast,
}

/// One reachable downstream instance: a transport [`Lane`] plus the
/// edge's zone-crossing flag. The lane decides the payload shape — an
/// unframed lane moves [`Msg::Batch`] by refcount, a framed lane gets the
/// encode-once [`Msg::Frame`] bytes — and a failed delivery (closed or
/// poisoned endpoint, dead peer) is *counted* by the port, never a panic.
pub struct Target {
    lane: Box<dyn Lane>,
    /// Whether this edge crosses a zone boundary (metrics).
    pub crossing: bool,
}

impl Target {
    /// Same-host target over a bounded in-process channel.
    pub fn local(tx: SyncSender<Msg>) -> Target {
        Target::over(Box::new(InProcessLane::new(tx)), false)
    }

    /// Same-process target over an unbounded channel (worker inboxes fed
    /// by the socket demultiplexer, which must never block).
    pub fn loose(tx: Sender<Msg>) -> Target {
        Target::over(Box::new(InProcessLane::unbounded(tx)), false)
    }

    /// Cross-host target through an emulated [`Link`] with the route's
    /// latency stamped per frame.
    pub fn linked(
        tx: SyncSender<Msg>,
        link: Arc<Link<Msg>>,
        latency: Duration,
        crossing: bool,
    ) -> Target {
        Target::over(Box::new(NetsimLane::new(link, latency, tx)), crossing)
    }

    /// Target over any transport lane (sockets, custom transports).
    pub fn over(lane: Box<dyn Lane>, crossing: bool) -> Target {
        Target { lane, crossing }
    }

    /// True if batches cross this target as encoded frames.
    pub fn framed(&self) -> bool {
        self.lane.framed()
    }
}

/// Output port of an operator instance.
pub struct OutPort {
    targets: Vec<Target>,
    routing: Routing,
    rr_next: usize,
    /// Pending per-target buffers for `Hash` routing.
    pending: Vec<Vec<Value>>,
    /// Per-target key-hash columns aligned with `pending`, so delivered
    /// sub-batches carry their hashes forward (a re-shuffle downstream
    /// never recomputes them).
    pending_hashes: Vec<Vec<u64>>,
    /// Pending per-target *columnar* buffers for `Hash` routing: the
    /// shuffle stays struct-of-arrays end-to-end when the upstream chain
    /// ran columnar. Lazily allocated — row-only ports never touch these.
    col_pending: Vec<Option<ColumnBuffer>>,
    /// Flush threshold for hash-routed buffers.
    batch_capacity: usize,
    /// Producing instance id stamped onto outgoing watermarks so fan-in
    /// consumers can merge min-of-inputs (see [`Watermark::from`]).
    sender: u32,
    metrics: Option<Metrics>,
}

impl OutPort {
    /// Creates an output port over `targets`.
    pub fn new(
        targets: Vec<Target>,
        routing: Routing,
        batch_capacity: usize,
        metrics: Option<Metrics>,
    ) -> Self {
        let pending = targets.iter().map(|_| Vec::new()).collect();
        let pending_hashes = targets.iter().map(|_| Vec::new()).collect();
        let col_pending = targets.iter().map(|_| None).collect();
        OutPort {
            targets,
            routing,
            rr_next: 0,
            pending,
            pending_hashes,
            col_pending,
            // a zero capacity would make the hash carving loop spin on
            // empty chunks; one record per batch is the useful floor
            batch_capacity: batch_capacity.max(1),
            sender: 0,
            metrics,
        }
    }

    /// Stamps the producing instance id onto outgoing watermarks. Ports
    /// feeding a shared inbox must carry distinct ids or the min-of-inputs
    /// merge collapses the producers into one.
    pub fn with_sender(mut self, id: u32) -> Self {
        self.sender = id;
        self
    }

    /// Number of downstream targets.
    pub fn fan_out(&self) -> usize {
        self.targets.len()
    }

    /// Sends one batch according to the routing policy. Consumes the
    /// handle; `Broadcast` replication is a refcount bump per target, not
    /// a payload copy.
    pub fn send(&mut self, batch: Batch) {
        if batch.is_empty() || self.targets.is_empty() {
            return;
        }
        match self.routing {
            Routing::RoundRobin => {
                let t = self.rr_next % self.targets.len();
                self.rr_next = self.rr_next.wrapping_add(1);
                self.deliver(t, batch);
            }
            Routing::Broadcast => {
                let last = self.targets.len() - 1;
                for t in 0..last {
                    self.deliver(t, batch.clone());
                }
                self.deliver(last, batch);
            }
            Routing::Hash => {
                // Pre-partition the whole batch in one sweep: the key
                // hashes come from the batch's hash column when the
                // keying operator attached one (no `Value` tree-walks on
                // the shuffle), falling back to on-the-fly hashing for
                // un-keyed batches (e.g. frames decoded off the wire).
                // Copy-on-write takes the payload in place unless a
                // sibling edge shares the batch.
                // A representation switch (a columnar upstream falling
                // back to rows mid-stream) must not reorder records
                // already buffered for a target, so columnar pendings
                // drain first.
                self.flush_columns();
                let n = self.targets.len() as u64;
                let (values, hashes) = batch.into_parts();
                match hashes {
                    Some(hs) if hs.len() == values.len() => {
                        for (v, h) in values.into_iter().zip(hs) {
                            let t = (h % n) as usize;
                            self.pending_hashes[t].push(h);
                            self.pending[t].push(v);
                        }
                    }
                    _ => {
                        for v in values {
                            let h = route_hash(&v);
                            let t = (h % n) as usize;
                            self.pending_hashes[t].push(h);
                            self.pending[t].push(v);
                        }
                    }
                }
                // deliver every sub-batch that reached the flush
                // threshold (capacity check hoisted out of the sweep),
                // carving capacity-sized batches in one O(n) pass so a
                // huge inbound batch (e.g. a flat_map expansion) never
                // becomes one huge delivered frame
                for t in 0..self.targets.len() {
                    if self.pending[t].len() < self.batch_capacity {
                        continue;
                    }
                    let cap = self.batch_capacity;
                    let vals = std::mem::replace(&mut self.pending[t], Vec::with_capacity(cap));
                    let hs =
                        std::mem::replace(&mut self.pending_hashes[t], Vec::with_capacity(cap));
                    let mut vi = vals.into_iter();
                    let mut hi = hs.into_iter();
                    loop {
                        let chunk: Vec<Value> = vi.by_ref().take(cap).collect();
                        let chunk_h: Vec<u64> = hi.by_ref().take(chunk.len()).collect();
                        if chunk.len() < cap {
                            // tail below threshold stays pending (in the
                            // pre-sized buffers) for future sends
                            self.pending[t].extend(chunk);
                            self.pending_hashes[t].extend(chunk_h);
                            break;
                        }
                        self.deliver(t, Batch::with_hashes(chunk, chunk_h));
                    }
                }
            }
        }
    }

    /// Sends one batch in either representation: rows go through
    /// [`OutPort::send`], columns through [`OutPort::send_columns`].
    pub fn send_data(&mut self, data: BatchData) {
        match data {
            BatchData::Rows(b) => self.send(b),
            BatchData::Columns(c) => self.send_columns(c),
        }
    }

    /// Sends one columnar batch according to the routing policy. Local
    /// targets receive [`Msg::Columns`] by refcount; framed targets get
    /// the encode-once frame bytes (identical to the row encoding, so the
    /// receiver decodes without knowing the sender ran columnar). `Hash`
    /// routing pre-partitions rows into per-target [`ColumnBuffer`]s — the
    /// shuffle never materialises a `Value`.
    pub fn send_columns(&mut self, cb: ColumnBatch) {
        if cb.is_empty() || self.targets.is_empty() {
            return;
        }
        match self.routing {
            Routing::RoundRobin => {
                let t = self.rr_next % self.targets.len();
                self.rr_next = self.rr_next.wrapping_add(1);
                self.deliver_columns(t, cb);
            }
            Routing::Broadcast => {
                let last = self.targets.len() - 1;
                for t in 0..last {
                    self.deliver_columns(t, cb.clone());
                }
                self.deliver_columns(last, cb);
            }
            Routing::Hash => {
                // Mirror of the row Hash path at per-target FIFO fidelity:
                // row pendings (and columnar pendings of a different
                // layout) drain before this batch's rows are buffered.
                for t in 0..self.targets.len() {
                    if !self.pending[t].is_empty() {
                        self.deliver_pending(t);
                    }
                    let stale = self.col_pending[t]
                        .as_ref()
                        .map_or(false, |b| b.layout() != cb.layout());
                    if stale {
                        self.deliver_col_pending(t);
                        self.col_pending[t] = None;
                    }
                }
                let n = self.targets.len() as u64;
                let cols = cb.columns();
                // the route hash is the key for pair-shaped rows and the
                // whole row otherwise — same contract as `route_hash`
                let (key_layout, key_leaves) = match cb.layout() {
                    Layout::Pair(k, _) => (k.as_ref(), k.leaf_count()),
                    l => (l, l.leaf_count()),
                };
                for row in 0..cb.len() {
                    let h = match cb.key_hashes() {
                        Some(hs) => hs[row],
                        None => key_layout.hash_row(&cols[..key_leaves], row),
                    };
                    let t = (h % n) as usize;
                    let full = {
                        let buf = self.col_pending[t]
                            .get_or_insert_with(|| ColumnBuffer::new(cb.layout().clone()));
                        buf.push_row_from(cols, row, h);
                        if buf.len() >= self.batch_capacity {
                            Some(buf.take())
                        } else {
                            None
                        }
                    };
                    if let Some(full) = full {
                        self.deliver_columns(t, full);
                    }
                }
            }
        }
    }

    /// Delivers target `t`'s whole pending sub-batch (with its hash
    /// column), swapping in pre-sized buffers: re-growing from zero costs
    /// ~log2(batch) reallocs per delivered batch.
    fn deliver_pending(&mut self, t: usize) {
        let full = std::mem::replace(
            &mut self.pending[t],
            Vec::with_capacity(self.batch_capacity),
        );
        let hs = std::mem::replace(
            &mut self.pending_hashes[t],
            Vec::with_capacity(self.batch_capacity),
        );
        self.deliver(t, Batch::with_hashes(full, hs));
    }

    /// Flushes hash-routing buffers (call before EOS or on a timer).
    /// Idempotent: an empty buffer is skipped, so repeated flushes (or a
    /// flush racing a timer flush) never re-deliver records, and a drained
    /// buffer is replaced with a pre-sized one so `send` calls after a
    /// flush keep the no-realloc fast path.
    pub fn flush(&mut self) {
        for t in 0..self.targets.len() {
            if !self.pending[t].is_empty() {
                self.deliver_pending(t);
            }
            self.deliver_col_pending(t);
        }
    }

    /// Delivers target `t`'s pending columnar buffer, if any rows are
    /// buffered. The (empty) buffer stays allocated for future sends.
    fn deliver_col_pending(&mut self, t: usize) {
        let full = match self.col_pending[t].as_mut() {
            Some(buf) if !buf.is_empty() => buf.take(),
            _ => return,
        };
        self.deliver_columns(t, full);
    }

    /// Drains every pending columnar buffer (ordering barrier before row
    /// records are buffered for the same targets).
    fn flush_columns(&mut self) {
        for t in 0..self.targets.len() {
            self.deliver_col_pending(t);
        }
    }

    /// Flushes then signals EOS to every target.
    pub fn eos(&mut self) {
        self.flush();
        for t in 0..self.targets.len() {
            if self.targets[t].lane.deliver(Msg::Eos).is_err() {
                self.count_transport_error();
            }
        }
    }

    /// Flushes pending buffers, then forwards a drain-and-handoff epoch
    /// marker to every target. Direct inboxes count markers like EOS
    /// (quiescing once every producer delivered one); queue ingest
    /// swallows them, so downstream FlowUnits observe a pause, never a
    /// premature end-of-stream.
    pub fn epoch(&mut self, epoch: u64) {
        self.flush();
        for t in 0..self.targets.len() {
            if self.targets[t].lane.deliver(Msg::Epoch(epoch)).is_err() {
                self.count_transport_error();
            }
            if let Some(m) = &self.metrics {
                MetricsRegistry::add(&m.epochs_forwarded, 1);
            }
        }
    }

    /// Flushes pending buffers, then broadcasts an event-time watermark to
    /// every target (watermarks are control frames: they must reach every
    /// downstream partition regardless of the data routing policy). The
    /// flush keeps the ordering promise — no buffered record with a lower
    /// timestamp can arrive after the watermark on the same lane.
    pub fn watermark(&mut self, ts: i64, origin_ms: u64) {
        self.flush();
        let wm = Watermark {
            from: self.sender,
            ts,
            origin_ms,
        };
        for t in 0..self.targets.len() {
            if self.targets[t].lane.deliver(Msg::Watermark(wm)).is_err() {
                self.count_transport_error();
            }
            if let Some(m) = &self.metrics {
                MetricsRegistry::add(&m.watermarks_forwarded, 1);
            }
        }
    }

    fn deliver(&mut self, t: usize, batch: Batch) {
        if self.targets[t].crossing {
            if let Some(m) = &self.metrics {
                MetricsRegistry::add(&m.zone_crossings, batch.len() as u64);
            }
        }
        let msg = if self.targets[t].framed() {
            // Encode-once: the first framed edge pays the encode and
            // caches it on the batch; every further edge (this port or
            // a sibling) re-uses the bytes by refcount. The metrics
            // hook runs inside the one-time initialiser, so racing
            // senders on a shared batch still count a single encode.
            let bytes = batch.wire_with(|| {
                if let Some(m) = &self.metrics {
                    MetricsRegistry::add(&m.batch_encodes, 1);
                }
            });
            Msg::Frame(bytes)
        } else {
            // Unframed lane: refcount bump.
            Msg::Batch(batch)
        };
        if self.targets[t].lane.deliver(msg).is_err() {
            // Closed or poisoned endpoint, or a dead peer: the satellite
            // hardening counts the failure and keeps the instance alive
            // (a disconnected receiver during teardown lands here too).
            self.count_transport_error();
        }
    }

    fn deliver_columns(&mut self, t: usize, cb: ColumnBatch) {
        if cb.is_empty() {
            return;
        }
        if self.targets[t].crossing {
            if let Some(m) = &self.metrics {
                MetricsRegistry::add(&m.zone_crossings, cb.len() as u64);
            }
        }
        let msg = if self.targets[t].framed() {
            // Encode-once, straight from the columns: the frame bytes are
            // identical to the equivalent row batch's encoding, so the
            // receiver's decode path is unchanged.
            let bytes = cb.wire_with(|| {
                if let Some(m) = &self.metrics {
                    MetricsRegistry::add(&m.batch_encodes, 1);
                }
            });
            Msg::Frame(bytes)
        } else {
            Msg::Columns(cb)
        };
        if self.targets[t].lane.deliver(msg).is_err() {
            self.count_transport_error();
        }
    }

    fn count_transport_error(&self) {
        if let Some(m) = &self.metrics {
            MetricsRegistry::add(&m.transport_errors, 1);
        }
    }
}

/// Output side of an operator instance: one [`OutPort`] per outgoing
/// stage edge. A `split` stream has several edges, each of which receives
/// every batch *by shared reference* (a refcount bump per edge, zero
/// payload copies); linear stages have one port and terminal sinks none.
#[derive(Default)]
pub struct FanOut {
    ports: Vec<OutPort>,
}

impl FanOut {
    /// Wraps one port per outgoing edge.
    pub fn new(ports: Vec<OutPort>) -> Self {
        FanOut { ports }
    }

    /// No outgoing edges (terminal sink stages).
    pub fn none() -> Self {
        FanOut { ports: Vec::new() }
    }

    /// A single outgoing edge.
    pub fn single(port: OutPort) -> Self {
        FanOut { ports: vec![port] }
    }

    /// True if there is no outgoing edge.
    pub fn is_empty(&self) -> bool {
        self.ports.is_empty()
    }

    /// Sends `batch` down every outgoing edge (a refcount bump for all but
    /// the last), each edge applying its own routing policy.
    pub fn send(&mut self, batch: Batch) {
        if batch.is_empty() || self.ports.is_empty() {
            return;
        }
        let last = self.ports.len() - 1;
        for p in &mut self.ports[..last] {
            p.send(batch.clone());
        }
        self.ports[last].send(batch);
    }

    /// Sends a batch in either representation down every outgoing edge (a
    /// refcount bump for all but the last).
    pub fn send_data(&mut self, data: BatchData) {
        if data.is_empty() || self.ports.is_empty() {
            return;
        }
        match data {
            BatchData::Rows(b) => self.send(b),
            BatchData::Columns(c) => {
                let last = self.ports.len() - 1;
                for p in &mut self.ports[..last] {
                    p.send_columns(c.clone());
                }
                self.ports[last].send_columns(c);
            }
        }
    }

    /// Flushes pending hash-routing buffers on every edge.
    pub fn flush(&mut self) {
        for p in &mut self.ports {
            p.flush();
        }
    }

    /// Flushes then signals EOS down every edge.
    pub fn eos(&mut self) {
        for p in &mut self.ports {
            p.eos();
        }
    }

    /// Flushes then forwards an epoch marker down every edge.
    pub fn epoch(&mut self, epoch: u64) {
        for p in &mut self.ports {
            p.epoch(epoch);
        }
    }

    /// Flushes then broadcasts a watermark down every edge.
    pub fn watermark(&mut self, ts: i64, origin_ms: u64) {
        for p in &mut self.ports {
            p.watermark(ts, origin_ms);
        }
    }

    /// Stamps the producing instance id onto every port (watermark merge
    /// identity — see [`OutPort::with_sender`]).
    pub fn set_sender(&mut self, id: u32) {
        for p in &mut self.ports {
            p.sender = id;
        }
    }
}

/// What an [`Inbox`] yielded: a data batch, or one of the two terminal
/// conditions of the input stream.
#[derive(Debug)]
pub enum InboxEvent {
    /// A data batch (frames are decoded transparently).
    Batch(Batch),
    /// A columnar batch delivered over a local edge: the consuming chain
    /// keeps running struct-of-arrays without materialising rows.
    Columns(ColumnBatch),
    /// Every still-live producer has delivered the drain-and-handoff
    /// marker for this epoch (dynamic update): quiesce without EOS.
    Epoch(u64),
    /// The merged (min-of-inputs) event-time watermark advanced: every
    /// producer has promised no further record below `ts`. `origin_ms` is
    /// the generation wall-clock of the frame that unblocked the merge,
    /// preserved so downstream hops keep measuring end-to-end lag.
    Watermark {
        /// New merged watermark (event-time ms).
        ts: i64,
        /// Wall-clock generation time of the triggering frame.
        origin_ms: u64,
    },
    /// Every producer signalled EOS (or disconnected): end of stream.
    Eos,
}

/// Input side of an operator instance: one receiver fed by N producers.
pub struct Inbox {
    rx: Receiver<Msg>,
    producers: usize,
    eos_seen: usize,
    epoch_seen: usize,
    epoch: u64,
    /// Latest watermark (and when it was heard) per producer id (linear
    /// scan — fan-in degrees are small). The merged watermark is the min
    /// over these once every producer has reported at least once.
    wm_in: Vec<(u32, i64, Instant)>,
    /// Last merged watermark emitted downstream (monotonicity guard).
    wm_out: i64,
    /// Event-time idleness bound: a producer silent for this long is
    /// excluded from the min-of-inputs merge (and one that never reported
    /// stops gating it), so a stalled edge source cannot freeze event-time
    /// for the whole fan-in. `None` = strict semantics, wait forever.
    idle: Option<Duration>,
    /// When this inbox was built — silent-from-birth producers gate the
    /// merge until this is `idle` old.
    started: Instant,
    /// Set when every sender dropped *without* a terminal signal from some
    /// producer — an upstream crash, not a quiesce or a normal EOS. The
    /// recovery supervisor uses this to tell "stream genuinely ended" from
    /// "producer died mid-stream" (the latter must not cascade EOS).
    disconnected: bool,
    metrics: Option<Metrics>,
}

impl Inbox {
    /// Wraps a receiver expecting `producers` EOS signals.
    pub fn new(rx: Receiver<Msg>, producers: usize) -> Self {
        Inbox {
            rx,
            producers,
            eos_seen: 0,
            epoch_seen: 0,
            epoch: 0,
            wm_in: Vec::new(),
            wm_out: i64::MIN,
            idle: None,
            started: Instant::now(),
            disconnected: false,
            metrics: None,
        }
    }

    /// Sets the event-time idleness bound (see the `idle` field). With a
    /// bound set, [`Inbox::next`] wakes at least that often even on a
    /// silent channel, so an idle producer is noticed without new input.
    pub fn with_idle_timeout(mut self, idle: Option<Duration>) -> Self {
        self.idle = idle;
        self
    }

    /// The current merged event-time watermark, if every producer has
    /// reported one.
    pub fn watermark(&self) -> Option<i64> {
        (self.wm_out > i64::MIN).then_some(self.wm_out)
    }

    /// Folds one watermark frame into the per-producer merge state.
    /// Returns the advanced merged watermark when (a) every producer that
    /// has not already ended its stream reported at least once and (b) the
    /// min over the latest per-producer promises moved forward.
    fn merge_watermark(&mut self, wm: Watermark) -> Option<i64> {
        let now = Instant::now();
        match self.wm_in.iter_mut().find(|(f, ..)| *f == wm.from) {
            Some((_, t, heard)) => {
                *t = (*t).max(wm.ts);
                *heard = now;
            }
            None => self.wm_in.push((wm.from, wm.ts, now)),
        }
        let merged = self.remerge();
        if merged.is_some() {
            if let Some(m) = &self.metrics {
                let now = crate::time::now_ms();
                MetricsRegistry::fetch_max(
                    &m.watermark_lag_ms,
                    now.saturating_sub(wm.origin_ms),
                );
            }
        }
        merged
    }

    /// Re-evaluates the min-of-inputs merge against the current per-
    /// producer promises, returning the merged watermark if it advanced.
    fn remerge(&mut self) -> Option<i64> {
        let now = Instant::now();
        let mut min = i64::MAX;
        let mut live = 0usize;
        for &(_, ts, heard) in &self.wm_in {
            if self.idle.is_some_and(|d| now.duration_since(heard) > d) {
                // idle producer: its stale promise no longer holds the
                // merged clock down (it re-enters when it next reports)
                continue;
            }
            live += 1;
            min = min.min(ts);
        }
        if live == 0 {
            return None;
        }
        // A producer that already delivered EOS stopped advancing — treat
        // it as +inf so a finished source cannot stall the merge forever.
        // (EOS frames are anonymous, so this over-approximates when an
        // EOS'd producer also sits in `wm_in`; the min over live entries
        // is still a sound lower bound.) A producer that never reported
        // gates the merge until the idleness bound waives it.
        if self.wm_in.len() + self.eos_seen < self.producers {
            let waived = self
                .idle
                .is_some_and(|d| now.duration_since(self.started) > d);
            if !waived {
                return None;
            }
        }
        if min > self.wm_out {
            self.wm_out = min;
            Some(min)
        } else {
            None
        }
    }

    /// True if the stream terminated because every sender dropped without
    /// a terminal signal (producer crash) rather than via EOS/markers.
    pub fn disconnected(&self) -> bool {
        self.disconnected
    }

    /// Attaches a metrics registry so skipped corrupt frames are counted.
    pub fn with_metrics(mut self, metrics: Metrics) -> Self {
        self.metrics = Some(metrics);
        self
    }

    /// Counts one corrupt frame that was skipped instead of panicking the
    /// consuming instance (mirrors the queue substrate's poison handling).
    fn count_corrupt(&self) {
        if let Some(m) = &self.metrics {
            MetricsRegistry::add(&m.corrupt_records, 1);
            MetricsRegistry::add(&m.transport_errors, 1);
        }
    }

    /// True once every producer has delivered its terminal signal. An
    /// epoch completes when each producer has sent either the marker or
    /// EOS (a producer that genuinely finished before the update counts
    /// through its EOS) and at least one marker arrived.
    fn terminal(&self) -> Option<InboxEvent> {
        if self.epoch_seen > 0 && self.epoch_seen + self.eos_seen >= self.producers {
            return Some(InboxEvent::Epoch(self.epoch));
        }
        if self.eos_seen >= self.producers {
            return Some(InboxEvent::Eos);
        }
        None
    }

    /// Receives the next event, decoding frames (the decoded batch keeps
    /// the frame bytes as its cached encoding, so forwarding it across
    /// another boundary costs no re-encode). Terminal events are reported
    /// once all producers have delivered them — see [`InboxEvent`].
    pub fn next(&mut self) -> InboxEvent {
        loop {
            if let Some(ev) = self.terminal() {
                if matches!(ev, InboxEvent::Epoch(_)) {
                    // reset so a later epoch (after a respawn reusing this
                    // inbox, which does not happen today) starts clean
                    self.epoch_seen = 0;
                }
                return ev;
            }
            // With an idleness bound the wait is chopped so a producer
            // going silent is noticed (and the merge re-evaluated) even
            // when no further message ever arrives.
            let msg = match self.idle {
                Some(d) => match self.rx.recv_timeout(d) {
                    Ok(m) => Ok(m),
                    Err(std::sync::mpsc::RecvTimeoutError::Timeout) => {
                        if !self.wm_in.is_empty() {
                            if let Some(ts) = self.remerge() {
                                return InboxEvent::Watermark {
                                    ts,
                                    origin_ms: crate::time::now_ms(),
                                };
                            }
                        }
                        continue;
                    }
                    Err(std::sync::mpsc::RecvTimeoutError::Disconnected) => {
                        Err(std::sync::mpsc::RecvError)
                    }
                },
                None => self.rx.recv(),
            };
            match msg {
                Ok(Msg::Batch(b)) => return InboxEvent::Batch(b),
                Ok(Msg::Columns(c)) => return InboxEvent::Columns(c),
                Ok(Msg::Frame(bytes)) => match Batch::from_wire(bytes) {
                    Ok(b) => return InboxEvent::Batch(b),
                    Err(_) => {
                        // A frame that fails to decode is skipped and
                        // counted, not a panic: one corrupt producer (or a
                        // garbled socket) must not take the instance down.
                        self.count_corrupt();
                    }
                },
                Ok(Msg::Eos) => {
                    self.eos_seen += 1;
                }
                Ok(Msg::Epoch(e)) => {
                    self.epoch_seen += 1;
                    self.epoch = e;
                }
                Ok(Msg::Watermark(wm)) => {
                    let origin_ms = wm.origin_ms;
                    if let Some(ts) = self.merge_watermark(wm) {
                        return InboxEvent::Watermark { ts, origin_ms };
                    }
                }
                Err(_) => {
                    // All senders dropped with neither marker nor EOS from
                    // some producer — an abnormal teardown (producer
                    // crash), not a quiesce (a quiescing producer's marker
                    // is buffered before its sender drops, so it was
                    // already counted). Fall back to the EOS path so the
                    // stream terminates instead of quiescing half-drained,
                    // and remember the crash so a recovery-enabled consumer
                    // can exit without cascading a spurious EOS downstream.
                    self.disconnected = true;
                    self.eos_seen = self.producers;
                    self.epoch_seen = 0;
                }
            }
        }
    }

    /// Receives the next batch. Returns `None` once the stream terminated
    /// — either every producer signalled EOS / disconnected, or an epoch
    /// completed (callers that distinguish the two use [`Inbox::next`]).
    pub fn recv(&mut self) -> Option<Batch> {
        loop {
            match self.next() {
                InboxEvent::Batch(b) => return Some(b),
                InboxEvent::Columns(c) => return Some(c.to_batch()),
                // watermark-oblivious consumers skip the control event;
                // the merged value stays queryable via `watermark()`
                InboxEvent::Watermark { .. } => continue,
                InboxEvent::Epoch(_) | InboxEvent::Eos => return None,
            }
        }
    }

    /// Non-blocking variant used by instances that multiplex control
    /// messages; returns `Ok(None)` when no message is ready.
    pub fn try_recv(&mut self) -> Option<Option<Batch>> {
        if self.terminal().is_some() {
            return Some(None);
        }
        match self.rx.try_recv() {
            Ok(Msg::Batch(b)) => Some(Some(b)),
            Ok(Msg::Columns(c)) => Some(Some(c.to_batch())),
            Ok(Msg::Frame(bytes)) => match Batch::from_wire(bytes) {
                Ok(b) => Some(Some(b)),
                Err(_) => {
                    // skipped + counted; report "nothing ready" and let the
                    // caller poll again
                    self.count_corrupt();
                    None
                }
            },
            Ok(Msg::Eos) => {
                self.eos_seen += 1;
                if self.terminal().is_some() {
                    Some(None)
                } else {
                    None
                }
            }
            Ok(Msg::Epoch(e)) => {
                self.epoch_seen += 1;
                self.epoch = e;
                if self.terminal().is_some() {
                    Some(None)
                } else {
                    None
                }
            }
            Ok(Msg::Watermark(wm)) => {
                // control-multiplexing callers don't consume watermark
                // events; fold into the merge state and report "not ready"
                self.merge_watermark(wm);
                None
            }
            Err(std::sync::mpsc::TryRecvError::Empty) => None,
            Err(std::sync::mpsc::TryRecvError::Disconnected) => {
                if self.terminal().is_none() {
                    self.disconnected = true;
                }
                Some(None)
            }
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use std::sync::mpsc::sync_channel;

    fn local_target(cap: usize) -> (Target, Receiver<Msg>) {
        let (tx, rx) = sync_channel(cap);
        (Target::local(tx), rx)
    }

    #[test]
    fn round_robin_rotates_batches() {
        let (t1, r1) = local_target(8);
        let (t2, r2) = local_target(8);
        let mut port = OutPort::new(vec![t1, t2], Routing::RoundRobin, 16, None);
        port.send(vec![Value::I64(1)].into());
        port.send(vec![Value::I64(2)].into());
        port.send(vec![Value::I64(3)].into());
        let mut inbox1 = Inbox::new(r1, 1);
        let mut inbox2 = Inbox::new(r2, 1);
        assert_eq!(inbox1.recv().unwrap(), vec![Value::I64(1)]);
        assert_eq!(inbox2.recv().unwrap(), vec![Value::I64(2)]);
        assert_eq!(inbox1.recv().unwrap(), vec![Value::I64(3)]);
    }

    #[test]
    fn hash_routing_is_key_consistent() {
        let (t1, r1) = local_target(64);
        let (t2, r2) = local_target(64);
        let mut port = OutPort::new(vec![t1, t2], Routing::Hash, 4, None);
        for i in 0..64 {
            port.send(vec![Value::pair(Value::I64(i % 8), Value::I64(i))].into());
        }
        port.eos();
        let collect = |rx: Receiver<Msg>| {
            let mut inbox = Inbox::new(rx, 1);
            let mut keys = std::collections::BTreeSet::new();
            while let Some(b) = inbox.recv() {
                for v in b {
                    keys.insert(v.as_pair().unwrap().0.as_i64().unwrap());
                }
            }
            keys
        };
        let k1 = collect(r1);
        let k2 = collect(r2);
        assert!(k1.is_disjoint(&k2), "keys split across targets: {k1:?} / {k2:?}");
        assert_eq!(k1.len() + k2.len(), 8);
    }

    #[test]
    fn broadcast_replicates() {
        let (t1, r1) = local_target(8);
        let (t2, r2) = local_target(8);
        let mut port = OutPort::new(vec![t1, t2], Routing::Broadcast, 16, None);
        port.send(vec![Value::I64(9)].into());
        port.eos();
        for rx in [r1, r2] {
            let mut inbox = Inbox::new(rx, 1);
            assert_eq!(inbox.recv().unwrap(), vec![Value::I64(9)]);
            assert!(inbox.recv().is_none());
        }
    }

    #[test]
    fn inbox_waits_for_all_producers() {
        let (tx, rx) = sync_channel(8);
        let tx2 = tx.clone();
        let mut inbox = Inbox::new(rx, 2);
        tx.send(Msg::Eos).unwrap();
        tx2.send(Msg::Batch(vec![Value::I64(5)].into())).unwrap();
        tx2.send(Msg::Eos).unwrap();
        assert_eq!(inbox.recv().unwrap(), vec![Value::I64(5)]);
        assert!(inbox.recv().is_none());
    }

    #[test]
    fn remote_target_encodes_and_decodes() {
        let link = Link::new("test", None, false, None);
        let (tx, rx) = sync_channel(8);
        let target = Target::linked(tx, link.clone(), Duration::ZERO, true);
        let m = crate::metrics::MetricsRegistry::new();
        let mut port = OutPort::new(vec![target], Routing::RoundRobin, 16, Some(m.clone()));
        let batch = vec![
            Value::pair(Value::Str("k".into()), Value::F64(1.5)),
            Value::I64(-3),
        ];
        port.send(batch.clone().into());
        port.eos();
        let mut inbox = Inbox::new(rx, 1);
        assert_eq!(inbox.recv().unwrap(), batch);
        assert!(inbox.recv().is_none());
        assert!(link.bytes_sent() > FRAME_OVERHEAD as u64);
        assert_eq!(
            m.zone_crossings.load(std::sync::atomic::Ordering::Relaxed),
            2
        );
        link.shutdown();
    }

    #[test]
    fn fanout_duplicates_batches_across_edges() {
        let (t1, r1) = local_target(8);
        let (t2, r2) = local_target(8);
        let p1 = OutPort::new(vec![t1], Routing::RoundRobin, 16, None);
        let p2 = OutPort::new(vec![t2], Routing::RoundRobin, 16, None);
        let mut fan = FanOut::new(vec![p1, p2]);
        fan.send(vec![Value::I64(3), Value::I64(4)].into());
        fan.eos();
        for rx in [r1, r2] {
            let mut inbox = Inbox::new(rx, 1);
            assert_eq!(inbox.recv().unwrap(), vec![Value::I64(3), Value::I64(4)]);
            assert!(inbox.recv().is_none());
        }
    }

    #[test]
    fn hash_flush_on_eos_emits_partials() {
        let (t1, r1) = local_target(8);
        let mut port = OutPort::new(vec![t1], Routing::Hash, 1000, None);
        port.send(vec![Value::pair(Value::I64(1), Value::I64(10))].into());
        // below batch_capacity — nothing delivered yet
        let mut inbox = Inbox::new(r1, 1);
        port.eos();
        assert_eq!(
            inbox.recv().unwrap(),
            vec![Value::pair(Value::I64(1), Value::I64(10))]
        );
        assert!(inbox.recv().is_none());
    }

    #[test]
    fn broadcast_shares_one_payload_across_targets() {
        let (t1, r1) = local_target(8);
        let (t2, r2) = local_target(8);
        let (t3, r3) = local_target(8);
        let mut port = OutPort::new(vec![t1, t2, t3], Routing::Broadcast, 16, None);
        port.send(vec![Value::I64(1), Value::I64(2)].into());
        port.eos();
        let mut received = Vec::new();
        for rx in [r1, r2, r3] {
            let mut inbox = Inbox::new(rx, 1);
            received.push(inbox.recv().unwrap());
            assert!(inbox.recv().is_none());
        }
        assert!(Batch::ptr_eq(&received[0], &received[1]));
        assert!(Batch::ptr_eq(&received[1], &received[2]));
    }

    #[test]
    fn fanout_shares_one_payload_across_edges() {
        let (t1, r1) = local_target(8);
        let (t2, r2) = local_target(8);
        let p1 = OutPort::new(vec![t1], Routing::RoundRobin, 16, None);
        let p2 = OutPort::new(vec![t2], Routing::RoundRobin, 16, None);
        let mut fan = FanOut::new(vec![p1, p2]);
        fan.send(vec![Value::I64(3)].into());
        fan.eos();
        let a = Inbox::new(r1, 1).recv().unwrap();
        let b = Inbox::new(r2, 1).recv().unwrap();
        assert!(Batch::ptr_eq(&a, &b), "split edges share one allocation");
    }

    #[test]
    fn crossing_edges_encode_once_and_share_frame_bytes() {
        let link = Link::new("shared", None, false, None);
        let (tx1, rx1) = sync_channel(8);
        let (tx2, rx2) = sync_channel(8);
        let mk = |tx| Target::linked(tx, link.clone(), Duration::ZERO, true);
        let m = crate::metrics::MetricsRegistry::new();
        let mut port = OutPort::new(
            vec![mk(tx1), mk(tx2)],
            Routing::Broadcast,
            16,
            Some(m.clone()),
        );
        port.send(vec![Value::I64(1), Value::Str("payload".into())].into());
        // both targets must hold references to the SAME frame buffer
        let grab = |rx: &Receiver<Msg>| match rx.recv().unwrap() {
            Msg::Frame(bytes) => bytes,
            other => panic!("expected frame, got {other:?}"),
        };
        let f1 = grab(&rx1);
        let f2 = grab(&rx2);
        assert!(Arc::ptr_eq(&f1, &f2), "one encode serves both edges");
        assert_eq!(
            m.batch_encodes.load(std::sync::atomic::Ordering::Relaxed),
            1,
            "exactly one wire encode for the whole broadcast"
        );
        // both frames decode to the original batch
        let b = Batch::from_wire(f1).unwrap();
        assert_eq!(b, vec![Value::I64(1), Value::Str("payload".into())]);
        link.shutdown();
    }

    #[test]
    fn flush_is_idempotent_and_delivers_exactly_once() {
        let (t1, r1) = local_target(64);
        let mut port = OutPort::new(vec![t1], Routing::Hash, 1000, None);
        port.send(vec![Value::pair(Value::I64(1), Value::I64(10))].into());
        port.flush();
        port.flush(); // second flush must not re-deliver
        // buffers stay usable after a flush
        port.send(vec![Value::pair(Value::I64(1), Value::I64(11))].into());
        port.eos();
        let mut inbox = Inbox::new(r1, 1);
        let mut got = Vec::new();
        while let Some(b) = inbox.recv() {
            got.extend(b);
        }
        assert_eq!(
            got,
            vec![
                Value::pair(Value::I64(1), Value::I64(10)),
                Value::pair(Value::I64(1), Value::I64(11)),
            ],
            "each record delivered exactly once, in order"
        );
    }

    #[test]
    fn epoch_completes_only_after_all_producers_marked() {
        let (tx, rx) = sync_channel(8);
        let tx2 = tx.clone();
        let mut inbox = Inbox::new(rx, 2);
        tx.send(Msg::Epoch(3)).unwrap();
        // a laggard producer's data arriving after the first marker is
        // still delivered before the epoch completes
        tx2.send(Msg::Batch(vec![Value::I64(5)].into())).unwrap();
        tx2.send(Msg::Epoch(3)).unwrap();
        assert!(matches!(inbox.next(), InboxEvent::Batch(b) if b == vec![Value::I64(5)]));
        assert!(matches!(inbox.next(), InboxEvent::Epoch(3)));
    }

    #[test]
    fn checkpoint_epochs_round_trip_through_the_tag_bit() {
        let e = checkpoint_epoch(7);
        assert!(is_checkpoint(e));
        assert_eq!(epoch_seq(e), 7);
        assert!(!is_checkpoint(7));
        assert_eq!(epoch_seq(7), 7);
    }

    #[test]
    fn dropped_senders_mark_the_inbox_disconnected() {
        let (tx, rx) = sync_channel(8);
        let mut inbox = Inbox::new(rx, 1);
        tx.send(Msg::Batch(vec![Value::I64(1)].into())).unwrap();
        drop(tx); // crash: no EOS, no marker
        assert!(matches!(inbox.next(), InboxEvent::Batch(_)));
        assert!(matches!(inbox.next(), InboxEvent::Eos));
        assert!(inbox.disconnected(), "crash teardown is distinguishable");

        // a normal EOS does NOT set the flag
        let (tx, rx) = sync_channel(8);
        let mut inbox = Inbox::new(rx, 1);
        tx.send(Msg::Eos).unwrap();
        drop(tx);
        assert!(matches!(inbox.next(), InboxEvent::Eos));
        assert!(!inbox.disconnected());
    }

    #[test]
    fn epoch_counts_finished_producers_through_their_eos() {
        // one producer genuinely ended before the update; the other sends
        // the marker — the consumer must still quiesce, not hang
        let (tx, rx) = sync_channel(8);
        let tx2 = tx.clone();
        let mut inbox = Inbox::new(rx, 2);
        tx.send(Msg::Eos).unwrap();
        tx2.send(Msg::Epoch(7)).unwrap();
        assert!(matches!(inbox.next(), InboxEvent::Epoch(7)));
    }

    #[test]
    fn outport_epoch_flushes_pending_records_first() {
        let (t1, r1) = local_target(8);
        let mut port = OutPort::new(vec![t1], Routing::Hash, 1000, None);
        port.send(vec![Value::pair(Value::I64(1), Value::I64(10))].into());
        port.epoch(5);
        // buffered record arrives before the marker (channel FIFO)
        let mut inbox = Inbox::new(r1, 1);
        assert!(matches!(inbox.next(), InboxEvent::Batch(b)
            if b == vec![Value::pair(Value::I64(1), Value::I64(10))]));
        assert!(matches!(inbox.next(), InboxEvent::Epoch(5)));
    }

    #[test]
    fn hash_routing_bounds_delivered_batches_to_capacity() {
        // one giant inbound batch must be carved into capacity-sized
        // sub-batches, not delivered as one huge frame
        let (t1, r1) = local_target(1024);
        let mut port = OutPort::new(vec![t1], Routing::Hash, 32, None);
        let big: Vec<Value> = (0..1000)
            .map(|i| Value::pair(Value::I64(i % 8), Value::I64(i)))
            .collect();
        port.send(big.clone().into());
        port.eos();
        let mut inbox = Inbox::new(r1, 1);
        let mut got = Vec::new();
        while let Some(b) = inbox.recv() {
            assert!(b.len() <= 32, "sub-batch of {} exceeds capacity", b.len());
            assert_eq!(
                b.key_hashes().map(|h| h.len()),
                Some(b.len()),
                "carved sub-batches keep aligned hash columns"
            );
            got.extend(b.into_values());
        }
        assert_eq!(got, big, "single target receives every record in order");
    }

    #[test]
    fn closed_target_counts_error_instead_of_panicking() {
        let (tx, rx) = sync_channel(4);
        drop(rx); // receiver gone: every delivery now fails
        let m = crate::metrics::MetricsRegistry::new();
        let mut port = OutPort::new(
            vec![Target::local(tx)],
            Routing::RoundRobin,
            16,
            Some(m.clone()),
        );
        port.send(vec![Value::I64(1)].into());
        port.epoch(1);
        port.eos();
        assert_eq!(
            m.transport_errors.load(std::sync::atomic::Ordering::Relaxed),
            3,
            "batch + epoch + eos each counted, none panicked"
        );
    }

    #[test]
    fn corrupt_frame_is_skipped_and_counted() {
        let (tx, rx) = sync_channel(8);
        let m = crate::metrics::MetricsRegistry::new();
        let mut inbox = Inbox::new(rx, 1).with_metrics(m.clone());
        tx.send(Msg::Frame(vec![0xff, 0xff, 0xff].into())).unwrap();
        tx.send(Msg::Batch(vec![Value::I64(42)].into())).unwrap();
        tx.send(Msg::Eos).unwrap();
        // the corrupt frame is silently skipped; the good batch survives
        assert_eq!(inbox.recv().unwrap(), vec![Value::I64(42)]);
        assert!(inbox.recv().is_none());
        assert_eq!(
            m.corrupt_records.load(std::sync::atomic::Ordering::Relaxed),
            1
        );
        assert_eq!(
            m.transport_errors.load(std::sync::atomic::Ordering::Relaxed),
            1
        );
    }

    #[test]
    fn loose_targets_deliver_over_unbounded_channels() {
        let (tx, rx) = std::sync::mpsc::channel();
        let mut port = OutPort::new(vec![Target::loose(tx)], Routing::RoundRobin, 16, None);
        port.send(vec![Value::I64(7)].into());
        port.eos();
        let mut inbox = Inbox::new(rx, 1);
        assert_eq!(inbox.recv().unwrap(), vec![Value::I64(7)]);
        assert!(inbox.recv().is_none());
    }

    fn keyed_columns(n: i64) -> ColumnBatch {
        use crate::columnar::Column;
        let layout = Layout::pair(Layout::I64, Layout::I64);
        let mut cols = layout.new_columns(n as usize);
        for i in 0..n {
            match &mut cols[0] {
                Column::I64(v) => v.push(i % 8),
                _ => unreachable!(),
            }
            match &mut cols[1] {
                Column::I64(v) => v.push(i),
                _ => unreachable!(),
            }
        }
        ColumnBatch::new(layout, cols)
    }

    #[test]
    fn columnar_hash_routing_matches_row_routing() {
        // the same keyed records, sent as columns and as rows, must land
        // on the same targets in the same per-target order
        let route = |columnar: bool| {
            let (t1, r1) = local_target(1024);
            let (t2, r2) = local_target(1024);
            let mut port = OutPort::new(vec![t1, t2], Routing::Hash, 16, None);
            if columnar {
                port.send_columns(keyed_columns(200));
            } else {
                port.send(keyed_columns(200).to_batch());
            }
            port.eos();
            [r1, r2].map(|rx| {
                let mut inbox = Inbox::new(rx, 1);
                let mut got = Vec::new();
                while let Some(b) = inbox.recv() {
                    assert!(b.len() <= 16, "columnar shuffle respects capacity");
                    got.extend(b.into_values());
                }
                got
            })
        };
        assert_eq!(route(true), route(false));
    }

    #[test]
    fn columnar_batches_frame_identically_to_rows() {
        let link = Link::new("col", None, false, None);
        let (tx, rx) = sync_channel(8);
        let target = Target::linked(tx, link.clone(), Duration::ZERO, true);
        let mut port = OutPort::new(vec![target], Routing::RoundRobin, 16, None);
        let cb = keyed_columns(5);
        let expect = cb.to_batch();
        port.send_columns(cb);
        match rx.recv().unwrap() {
            Msg::Frame(bytes) => {
                assert_eq!(Batch::from_wire(bytes).unwrap(), expect.values());
            }
            other => panic!("expected frame, got {other:?}"),
        }
        link.shutdown();
    }

    #[test]
    fn columnar_local_edges_share_the_allocation() {
        let (t1, r1) = local_target(8);
        let (t2, r2) = local_target(8);
        let p1 = OutPort::new(vec![t1], Routing::RoundRobin, 16, None);
        let p2 = OutPort::new(vec![t2], Routing::RoundRobin, 16, None);
        let mut fan = FanOut::new(vec![p1, p2]);
        fan.send_data(keyed_columns(3).into());
        let grab = |rx: Receiver<Msg>| match rx.recv().unwrap() {
            Msg::Columns(c) => c,
            other => panic!("expected columns, got {other:?}"),
        };
        let a = grab(r1);
        let b = grab(r2);
        assert!(ColumnBatch::ptr_eq(&a, &b), "split edges share one allocation");
    }

    #[test]
    fn representation_switch_preserves_per_target_order() {
        // columns buffered below capacity, then rows for the same key:
        // the columnar pending must drain before the row is buffered
        let (t1, r1) = local_target(64);
        let mut port = OutPort::new(vec![t1], Routing::Hash, 1000, None);
        port.send_columns(keyed_columns(4));
        port.send(vec![Value::pair(Value::I64(0), Value::I64(99))].into());
        port.eos();
        let mut inbox = Inbox::new(r1, 1);
        let mut got = Vec::new();
        while let Some(b) = inbox.recv() {
            got.extend(b.into_values());
        }
        let mut expect: Vec<Value> = keyed_columns(4).to_batch().into_values();
        expect.push(Value::pair(Value::I64(0), Value::I64(99)));
        assert_eq!(got, expect);
    }

    #[test]
    fn watermarks_merge_min_of_inputs() {
        let (tx, rx) = sync_channel(16);
        let tx2 = tx.clone();
        let mut inbox = Inbox::new(rx, 2);
        let wm = |from, ts| {
            Msg::Watermark(Watermark {
                from,
                ts,
                origin_ms: 0,
            })
        };
        tx.send(wm(0, 100)).unwrap();
        tx2.send(Msg::Batch(vec![Value::I64(1)].into())).unwrap();
        // only one producer reported: no merged watermark yet, data flows
        assert!(matches!(inbox.next(), InboxEvent::Batch(_)));
        assert_eq!(inbox.watermark(), None);
        tx2.send(wm(1, 50)).unwrap();
        assert!(matches!(inbox.next(), InboxEvent::Watermark { ts: 50, .. }));
        // the slower producer advancing moves the min up to the other bound
        tx2.send(wm(1, 200)).unwrap();
        assert!(matches!(inbox.next(), InboxEvent::Watermark { ts: 100, .. }));
        assert_eq!(inbox.watermark(), Some(100));
        // a regressing producer never moves the merged watermark backwards
        tx.send(wm(0, 90)).unwrap();
        tx.send(Msg::Eos).unwrap();
        tx2.send(Msg::Eos).unwrap();
        assert!(matches!(inbox.next(), InboxEvent::Eos));
        assert_eq!(inbox.watermark(), Some(100));
    }

    #[test]
    fn finished_producer_does_not_stall_watermarks() {
        let (tx, rx) = sync_channel(8);
        let tx2 = tx.clone();
        let mut inbox = Inbox::new(rx, 2);
        tx.send(Msg::Eos).unwrap();
        tx2.send(Msg::Watermark(Watermark {
            from: 1,
            ts: 10,
            origin_ms: 0,
        }))
        .unwrap();
        // producer 0 ended its stream; producer 1's promise alone decides
        assert!(matches!(inbox.next(), InboxEvent::Watermark { ts: 10, .. }));
        tx2.send(Msg::Eos).unwrap();
        assert!(matches!(inbox.next(), InboxEvent::Eos));
    }

    /// Spawns a thread that keeps refreshing producer `from`'s watermark
    /// every 10 ms (starting at `ts0`, advancing by 10 per tick) until
    /// the stop flag flips.
    fn feed_watermarks(
        tx: SyncSender<Msg>,
        from: u32,
        ts0: i64,
        stop: std::sync::Arc<std::sync::atomic::AtomicBool>,
    ) -> std::thread::JoinHandle<()> {
        std::thread::spawn(move || {
            use std::sync::atomic::Ordering;
            let mut ts = ts0;
            while !stop.load(Ordering::SeqCst) {
                let wm = Msg::Watermark(Watermark {
                    from,
                    ts,
                    origin_ms: 0,
                });
                if tx.try_send(wm).is_err() {
                    break;
                }
                ts += 10;
                std::thread::sleep(Duration::from_millis(10));
            }
        })
    }

    #[test]
    fn idle_timeout_waives_a_never_reporting_producer() {
        use std::sync::atomic::{AtomicBool, Ordering};
        let (tx, rx) = sync_channel(256);
        let mut inbox =
            Inbox::new(rx, 2).with_idle_timeout(Some(Duration::from_millis(40)));
        // producer 0 keeps its promises fresh; producer 1 never reports —
        // under strict semantics the merge would be gated forever
        let stop = Arc::new(AtomicBool::new(false));
        let feeder = feed_watermarks(tx.clone(), 0, 10, stop.clone());
        drop(tx);
        let got = loop {
            match inbox.next() {
                InboxEvent::Watermark { ts, .. } => break ts,
                InboxEvent::Eos => panic!("eos before the idle waiver released a watermark"),
                _ => {}
            }
        };
        assert!(got > 0, "waived merge follows the live producer, got {got}");
        stop.store(true, Ordering::SeqCst);
        feeder.join().unwrap();
    }

    #[test]
    fn stale_producer_watermark_is_released_after_idle() {
        use std::sync::atomic::{AtomicBool, Ordering};
        let (tx, rx) = sync_channel(256);
        let mut inbox =
            Inbox::new(rx, 2).with_idle_timeout(Some(Duration::from_millis(40)));
        // producer 0 reports once and goes silent; producer 1 keeps
        // advancing from 200
        tx.send(Msg::Watermark(Watermark {
            from: 0,
            ts: 50,
            origin_ms: 0,
        }))
        .unwrap();
        let stop = Arc::new(AtomicBool::new(false));
        let feeder = feed_watermarks(tx.clone(), 1, 200, stop.clone());
        drop(tx);
        let mut first = None;
        let released = loop {
            match inbox.next() {
                InboxEvent::Watermark { ts, .. } => {
                    first.get_or_insert(ts);
                    if ts >= 200 {
                        break ts;
                    }
                }
                InboxEvent::Eos => panic!("eos before the stale promise was released"),
                _ => {}
            }
        };
        // while producer 0 counted as live its promise held the merge at
        // 50; once it idled out, the merge jumped to producer 1's clock
        assert_eq!(first, Some(50), "both promises merge min-first");
        assert!(released >= 200, "idle producer released the merge, got {released}");
        stop.store(true, Ordering::SeqCst);
        feeder.join().unwrap();
    }

    #[test]
    fn outport_watermark_flushes_pending_then_broadcasts() {
        let (t1, r1) = local_target(8);
        let (t2, r2) = local_target(8);
        let mut port =
            OutPort::new(vec![t1, t2], Routing::Hash, 1000, None).with_sender(7);
        port.send(vec![Value::pair(Value::I64(1), Value::I64(10))].into());
        port.watermark(42, 5);
        let mut batches = 0;
        let mut marks = 0;
        for rx in [r1, r2] {
            let mut saw_mark = false;
            while let Ok(msg) = rx.try_recv() {
                match msg {
                    Msg::Batch(_) => {
                        assert!(!saw_mark, "buffered records precede the watermark");
                        batches += 1;
                    }
                    Msg::Watermark(w) => {
                        assert_eq!((w.from, w.ts, w.origin_ms), (7, 42, 5));
                        saw_mark = true;
                        marks += 1;
                    }
                    other => panic!("unexpected {other:?}"),
                }
            }
            assert!(saw_mark);
        }
        assert_eq!(batches, 1, "hash routing delivers the record once");
        assert_eq!(marks, 2, "the watermark reaches every partition");
    }

    #[test]
    fn flush_restores_pending_capacity() {
        let (t1, _r1) = local_target(64);
        let mut port = OutPort::new(vec![t1], Routing::Hash, 32, None);
        port.send(vec![Value::pair(Value::I64(0), Value::I64(1))].into());
        port.flush();
        assert!(
            port.pending.iter().all(|p| p.capacity() >= 32),
            "flushed buffers are re-primed to batch capacity"
        );
    }
}
