//! # FlowUnits
//!
//! A from-scratch reproduction of *FlowUnits: Extending Dataflow for the
//! Edge-to-Cloud Computing Continuum* (Chini, De Martini, Margara, Cugola —
//! CS.DC 2025).
//!
//! FlowUnits extends the classic streaming-dataflow model (Renoir/Flink
//! style: all operator instances deployed before the computation starts,
//! message passing between instances) with three capabilities required by
//! edge-to-cloud computing-continuum deployments:
//!
//! 1. **Locality awareness** — hosts belong to geographical *zones*
//!    organised in a tree (edge → site → cloud). Operators are annotated
//!    with the *layer* they must run on; contiguous same-layer operators
//!    form a *FlowUnit*, replicated once per zone that covers an enabled
//!    *location*. Data may only flow along zone-tree edges.
//! 2. **Resource awareness** — hosts advertise *capabilities*
//!    (`n_cpu = 8`, `gpu = yes`, …); operators declare *requirements* as
//!    conjunctions of predicates (`n_cpu >= 4 && gpu = yes`) and are only
//!    instantiated on satisfying hosts.
//! 3. **Dynamic updates** — FlowUnit boundaries may be *decoupled* through a
//!    persistent queue substrate, so a single FlowUnit can be stopped,
//!    replaced, or new locations added, without disrupting the rest of the
//!    running deployment.
//!
//! The crate contains the full engine (the paper's Renoir substrate is
//! rebuilt here, not imported), the FlowUnits planner, a network emulation
//! layer standing in for the paper's Docker + `tc` testbed, the queue
//! substrate standing in for Kafka, and a PJRT runtime that executes
//! AOT-compiled JAX/Pallas analytics models (the paper's
//! hardware-constrained ML operators) on the streaming hot path.
//!
//! ## Quick start
//!
//! Jobs are DAGs of named FlowUnits: multiple sources, `union` merges,
//! `split` fan-outs, and multiple sinks are all first-class. The
//! front-end is **typed** — streams are `Stream<T>`/`KeyedStream<K, V>`,
//! closures take native Rust types, and keyed-only operators are
//! unreachable before `key_by` (illegal orderings are compile errors).
//! The untyped builder survives as `api::raw` for dynamic-update graph
//! construction and `Value`-level escape hatches.
//!
//! ```no_run
//! use flowunits::prelude::*;
//!
//! let cluster = ClusterSpec::parse(&std::fs::read_to_string("cluster.fu").unwrap()).unwrap();
//! let mut ctx = StreamContext::new(cluster, JobConfig::default());
//! let survivors = ctx
//!     .stream(Source::synthetic(1_000_000, |_, i| i as i64))
//!     .unit("ingest")
//!     .to_layer("edge")
//!     .filter(|v| v % 3 == 0)
//!     .unit("report")
//!     .to_layer("cloud")
//!     .map(|v| v * 2)
//!     .collect();
//! let mut report = ctx.execute().unwrap();
//! let values: Vec<i64> = report.take(survivors).unwrap();
//! println!("{} events, {:?}", values.len(), report.wall_time);
//! ```
//!
//! A deployed job exposes its units by name for zero-downtime updates:
//! `Deployment::update_unit("report", new_graph)` swaps one unit — even a
//! stateful, multi-stage one with direct internal channels — while the
//! rest keep running, using an epoch-based drain-and-handoff protocol
//! that hands operator state to the replacement instances and loses and
//! duplicates zero events (see `examples/dynamic_update.rs`).

pub mod api;
pub mod channels;
pub mod columnar;
pub mod config;
pub mod coordinator;
pub mod error;
pub mod graph;
pub mod metrics;
pub mod netsim;
pub mod placement;
pub mod pipelines;
pub mod proptest;
pub mod queue;
pub mod runtime;
pub mod time;
pub mod topology;
pub mod transport;
pub mod util;
pub mod value;

/// Convenience re-exports for typical users of the library. `Source`,
/// `Stream`, and `KeyedStream` are the **typed** front-end; the untyped
/// builder remains available under [`api::raw`].
pub mod prelude {
    pub use crate::api::{
        AutoscaleConfig, CollectHandle, Features, JobConfig, KeyedStream, PlannerKind,
        Replication, Source, Stream, StreamContext, StreamData, WatermarkGen, WindowAgg,
        WindowAssigner,
    };
    pub use crate::config::ClusterSpec;
    pub use crate::coordinator::{Coordinator, Deployment, JobReport};
    pub use crate::error::{Error, Result};
    pub use crate::graph::{LogicalGraph, UnitDef};
    pub use crate::netsim::LinkSpec;
    pub use crate::queue::{OverloadPolicy, ShedMode};
    pub use crate::topology::{Capabilities, ConstraintExpr, LayerId, LocationId, ZoneId};
    pub use crate::columnar::ColumnBatch;
    pub use crate::value::{Batch, BatchData, Value};
}
