//! Typed columnar batches: the struct-of-arrays fast path of the data
//! plane.
//!
//! The engine's dynamic representation boxes every event into a
//! [`Value`] tree — flexible, but on the hot path of a *typed* pipeline
//! it taxes each record with enum tags, a `Box` per keyed pair, and a
//! tree walk per hash or encode. [`ColumnBatch`] removes that tax for
//! the `StreamData` types with a static shape: a batch is stored as one
//! native column per leaf field (`Vec<i64>`/`Vec<f64>`/`Vec<bool>`/
//! `Vec<String>`, arrow-style struct-of-arrays), and the monomorphized
//! operators in `runtime::col_exec` iterate those slices directly.
//!
//! Columns are a **local** representation: at a process or queue
//! boundary a column batch encodes row-wise into exactly the frame
//! format of [`encode_batch`](crate::value::encode_batch), so the wire,
//! the queue substrate, and `SocketTransport` are untouched — a peer
//! cannot tell whether the sender ran columnar. Likewise
//! [`Layout::hash_row`] reproduces [`Value::stable_hash`] byte-for-byte,
//! so hash routing agrees across representations (the generalization of
//! the PR-5 key-hash column: [`ColumnBatch::key_hashes`] is a computed
//! column attached to the batch).
//!
//! Types without a static columnar shape (`Value`, `Vec<T>`, mixed
//! streams, `Features`) keep flowing as row [`Batch`]es; the two forms
//! meet in [`BatchData`](crate::value::BatchData).

use crate::value::{Batch, Fnv1a, Value, write_varint};
use std::sync::{Arc, OnceLock};

/// One native leaf column of a [`ColumnBatch`].
#[derive(Debug, Clone, PartialEq)]
pub enum Column {
    /// 64-bit signed integers.
    I64(Vec<i64>),
    /// 64-bit floats.
    F64(Vec<f64>),
    /// Booleans.
    Bool(Vec<bool>),
    /// UTF-8 strings.
    Str(Vec<String>),
}

impl Column {
    /// Number of rows in the column.
    pub fn len(&self) -> usize {
        match self {
            Column::I64(v) => v.len(),
            Column::F64(v) => v.len(),
            Column::Bool(v) => v.len(),
            Column::Str(v) => v.len(),
        }
    }

    /// True when the column holds no rows.
    pub fn is_empty(&self) -> bool {
        self.len() == 0
    }

    /// Appends row `row` of `src` (a column of the same leaf type).
    pub fn push_from(&mut self, src: &Column, row: usize) {
        match (self, src) {
            (Column::I64(d), Column::I64(s)) => d.push(s[row]),
            (Column::F64(d), Column::F64(s)) => d.push(s[row]),
            (Column::Bool(d), Column::Bool(s)) => d.push(s[row]),
            (Column::Str(d), Column::Str(s)) => d.push(s[row].clone()),
            _ => unreachable!("column leaf type mismatch"),
        }
    }
}

/// The static shape of a columnar `StreamData` type: which leaf columns
/// a [`ColumnBatch`] of that type carries, and how they nest back into
/// the dynamic [`Value`] representation.
///
/// `Pair` mirrors `(A, B)` / `Value::Pair` (the keyed-record shape);
/// `Triple` mirrors `(A, B, C)` / a three-element `Value::List`. Leaves
/// are stored flattened, in declaration order.
#[derive(Debug, Clone, PartialEq, Eq)]
pub enum Layout {
    /// `i64` leaf.
    I64,
    /// `f64` leaf.
    F64,
    /// `bool` leaf.
    Bool,
    /// `String` leaf.
    Str,
    /// `(A, B)` — the engine's `Pair(key, value)` shape.
    Pair(Box<Layout>, Box<Layout>),
    /// `(A, B, C)` — a three-element `Value::List`.
    Triple(Box<Layout>, Box<Layout>, Box<Layout>),
}

impl Layout {
    /// Convenience constructor for the keyed-record shape.
    pub fn pair(key: Layout, value: Layout) -> Layout {
        Layout::Pair(Box::new(key), Box::new(value))
    }

    /// Number of flattened leaf columns.
    pub fn leaf_count(&self) -> usize {
        match self {
            Layout::Pair(a, b) => a.leaf_count() + b.leaf_count(),
            Layout::Triple(a, b, c) => a.leaf_count() + b.leaf_count() + c.leaf_count(),
            _ => 1,
        }
    }

    /// Allocates one empty column per leaf, each with `capacity` rows
    /// reserved.
    pub fn new_columns(&self, capacity: usize) -> Vec<Column> {
        let mut out = Vec::with_capacity(self.leaf_count());
        self.push_new_columns(capacity, &mut out);
        out
    }

    fn push_new_columns(&self, capacity: usize, out: &mut Vec<Column>) {
        match self {
            Layout::I64 => out.push(Column::I64(Vec::with_capacity(capacity))),
            Layout::F64 => out.push(Column::F64(Vec::with_capacity(capacity))),
            Layout::Bool => out.push(Column::Bool(Vec::with_capacity(capacity))),
            Layout::Str => out.push(Column::Str(Vec::with_capacity(capacity))),
            Layout::Pair(a, b) => {
                a.push_new_columns(capacity, out);
                b.push_new_columns(capacity, out);
            }
            Layout::Triple(a, b, c) => {
                a.push_new_columns(capacity, out);
                b.push_new_columns(capacity, out);
                c.push_new_columns(capacity, out);
            }
        }
    }

    /// Materializes row `row` of `cols` (exactly this layout's leaves,
    /// flattened) as a dynamic [`Value`].
    pub fn read_value(&self, cols: &[Column], row: usize) -> Value {
        let mut idx = 0;
        self.read_value_inner(cols, &mut idx, row)
    }

    fn read_value_inner(&self, cols: &[Column], idx: &mut usize, row: usize) -> Value {
        match self {
            Layout::I64 | Layout::F64 | Layout::Bool | Layout::Str => {
                let v = match &cols[*idx] {
                    Column::I64(c) => Value::I64(c[row]),
                    Column::F64(c) => Value::F64(c[row]),
                    Column::Bool(c) => Value::Bool(c[row]),
                    Column::Str(c) => Value::Str(c[row].clone()),
                };
                *idx += 1;
                v
            }
            Layout::Pair(a, b) => {
                let k = a.read_value_inner(cols, idx, row);
                let v = b.read_value_inner(cols, idx, row);
                Value::pair(k, v)
            }
            Layout::Triple(a, b, c) => Value::List(vec![
                a.read_value_inner(cols, idx, row),
                b.read_value_inner(cols, idx, row),
                c.read_value_inner(cols, idx, row),
            ]),
        }
    }

    /// The routing hash of row `row` — byte-for-byte the
    /// [`Value::stable_hash`] of the materialized row, computed without
    /// materializing it.
    pub fn hash_row(&self, cols: &[Column], row: usize) -> u64 {
        let mut h = Fnv1a::new();
        let mut idx = 0;
        self.hash_row_inner(cols, &mut idx, row, &mut h);
        h.finish()
    }

    fn hash_row_inner(&self, cols: &[Column], idx: &mut usize, row: usize, h: &mut Fnv1a) {
        // tag bytes mirror Value::hash_into: Bool=1, I64=2, F64=3, Str=4
        // (raw bytes, no length), Pair=5, List=6 (elements, no count)
        match self {
            Layout::I64 | Layout::F64 | Layout::Bool | Layout::Str => {
                match &cols[*idx] {
                    Column::I64(c) => {
                        h.write_u8(2);
                        h.write(&c[row].to_le_bytes());
                    }
                    Column::F64(c) => {
                        h.write_u8(3);
                        h.write(&c[row].to_bits().to_le_bytes());
                    }
                    Column::Bool(c) => {
                        h.write_u8(1);
                        h.write_u8(c[row] as u8);
                    }
                    Column::Str(c) => {
                        h.write_u8(4);
                        h.write(c[row].as_bytes());
                    }
                }
                *idx += 1;
            }
            Layout::Pair(a, b) => {
                h.write_u8(5);
                a.hash_row_inner(cols, idx, row, h);
                b.hash_row_inner(cols, idx, row, h);
            }
            Layout::Triple(a, b, c) => {
                h.write_u8(6);
                a.hash_row_inner(cols, idx, row, h);
                b.hash_row_inner(cols, idx, row, h);
                c.hash_row_inner(cols, idx, row, h);
            }
        }
    }

    /// Appends the canonical wire encoding of row `row` to `out` —
    /// byte-for-byte what [`Value::encode_into`] would write for the
    /// materialized row.
    pub fn encode_row(&self, cols: &[Column], row: usize, out: &mut Vec<u8>) {
        let mut idx = 0;
        self.encode_row_inner(cols, &mut idx, row, out);
    }

    fn encode_row_inner(&self, cols: &[Column], idx: &mut usize, row: usize, out: &mut Vec<u8>) {
        // tags mirror Value::encode_into: Str carries a varint length,
        // a Triple is a List with a varint count of 3
        match self {
            Layout::I64 | Layout::F64 | Layout::Bool | Layout::Str => {
                match &cols[*idx] {
                    Column::I64(c) => {
                        out.push(2);
                        out.extend_from_slice(&c[row].to_le_bytes());
                    }
                    Column::F64(c) => {
                        out.push(3);
                        out.extend_from_slice(&c[row].to_bits().to_le_bytes());
                    }
                    Column::Bool(c) => {
                        out.push(1);
                        out.push(c[row] as u8);
                    }
                    Column::Str(c) => {
                        out.push(4);
                        write_varint(out, c[row].len() as u64);
                        out.extend_from_slice(c[row].as_bytes());
                    }
                }
                *idx += 1;
            }
            Layout::Pair(a, b) => {
                out.push(5);
                a.encode_row_inner(cols, idx, row, out);
                b.encode_row_inner(cols, idx, row, out);
            }
            Layout::Triple(a, b, c) => {
                out.push(6);
                write_varint(out, 3);
                a.encode_row_inner(cols, idx, row, out);
                b.encode_row_inner(cols, idx, row, out);
                c.encode_row_inner(cols, idx, row, out);
            }
        }
    }
}

#[derive(Debug)]
struct ColumnInner {
    layout: Layout,
    cols: Vec<Column>,
    len: usize,
    /// Optional per-row routing-hash column, aligned with the rows (the
    /// generalized computed column: the columnar `key_by` fills it with
    /// the key's [`Value::stable_hash`] so hash shuffles read one `u64`
    /// per row). Local-only, like [`Batch::key_hashes`].
    key_hashes: Option<Vec<u64>>,
    /// Lazily computed row-wise wire encoding
    /// ([`encode_batch`](crate::value::encode_batch) framing).
    wire: OnceLock<Arc<[u8]>>,
}

/// A reference-counted typed columnar batch — the struct-of-arrays twin
/// of the row [`Batch`].
///
/// Holds one native [`Column`] per leaf of its [`Layout`], all of equal
/// length, plus an optional computed routing-hash column
/// ([`ColumnBatch::key_hashes`]). Cloning bumps a refcount (broadcast
/// fan-out shares one allocation); the wire encoding is computed lazily,
/// once, in exactly the row [`encode_batch`](crate::value::encode_batch)
/// frame format — so at a process/queue boundary a columnar batch is
/// indistinguishable from a row batch, and the receiving side decodes
/// rows as usual.
///
/// Produced by typed columnar sources and the monomorphized operators in
/// `runtime::col_exec`; anything that needs the dynamic representation
/// materializes rows with [`ColumnBatch::to_batch`] (the `Value`
/// fallback path).
#[derive(Debug, Clone)]
pub struct ColumnBatch {
    inner: Arc<ColumnInner>,
}

impl ColumnBatch {
    /// Wraps `cols` (one per leaf of `layout`, all the same length) as a
    /// batch.
    pub fn new(layout: Layout, cols: Vec<Column>) -> ColumnBatch {
        Self::build(layout, cols, None)
    }

    /// [`ColumnBatch::new`] with a computed routing-hash column;
    /// `hashes[i]` must be the routing hash of row `i` (lengths must
    /// match or the column is discarded and counted, mirroring
    /// [`Batch::with_hashes`]).
    pub fn with_hashes(layout: Layout, cols: Vec<Column>, hashes: Vec<u64>) -> ColumnBatch {
        Self::build(layout, cols, Some(hashes))
    }

    fn build(layout: Layout, cols: Vec<Column>, hashes: Option<Vec<u64>>) -> ColumnBatch {
        debug_assert_eq!(cols.len(), layout.leaf_count(), "one column per leaf");
        let len = cols.first().map_or(0, Column::len);
        debug_assert!(
            cols.iter().all(|c| c.len() == len),
            "ragged columns in a batch"
        );
        let key_hashes = match hashes {
            Some(hs) if hs.len() == len => Some(hs),
            Some(hs) => {
                crate::value::note_hash_column_mismatch();
                debug_assert_eq!(hs.len(), len, "hash column misaligned with rows");
                None
            }
            None => None,
        };
        ColumnBatch {
            inner: Arc::new(ColumnInner {
                layout,
                cols,
                len,
                key_hashes,
                wire: OnceLock::new(),
            }),
        }
    }

    /// The batch's layout.
    pub fn layout(&self) -> &Layout {
        &self.inner.layout
    }

    /// The flattened leaf columns.
    pub fn columns(&self) -> &[Column] {
        &self.inner.cols
    }

    /// Number of rows.
    pub fn len(&self) -> usize {
        self.inner.len
    }

    /// True when the batch holds no rows.
    pub fn is_empty(&self) -> bool {
        self.inner.len == 0
    }

    /// The computed routing-hash column, if attached.
    pub fn key_hashes(&self) -> Option<&[u64]> {
        self.inner.key_hashes.as_deref()
    }

    /// True when `a` and `b` share one allocation (zero-copy fan-out
    /// instrumentation).
    pub fn ptr_eq(a: &ColumnBatch, b: &ColumnBatch) -> bool {
        Arc::ptr_eq(&a.inner, &b.inner)
    }

    /// Materializes row `i` as a dynamic [`Value`].
    pub fn row(&self, i: usize) -> Value {
        self.inner.layout.read_value(&self.inner.cols, i)
    }

    /// Materializes every row into a dynamic [`Batch`], carrying the
    /// routing-hash column over — the `Value` fallback path.
    pub fn to_batch(&self) -> Batch {
        let values: Vec<Value> = (0..self.inner.len).map(|i| self.row(i)).collect();
        match &self.inner.key_hashes {
            Some(hs) => Batch::with_hashes(values, hs.clone()),
            None => Batch::new(values),
        }
    }

    /// The wire encoding — the row-wise
    /// [`encode_batch`](crate::value::encode_batch) frame (varint row
    /// count, then each row's canonical encoding) — computed once and
    /// cached for every clone.
    pub fn wire(&self) -> Arc<[u8]> {
        self.wire_with(|| {})
    }

    /// [`ColumnBatch::wire`] with an `on_encode` hook running inside the
    /// one-time initializer (exact encode accounting, like
    /// [`Batch::wire_with`]).
    pub fn wire_with(&self, on_encode: impl FnOnce()) -> Arc<[u8]> {
        self.inner
            .wire
            .get_or_init(|| {
                on_encode();
                let mut out = Vec::with_capacity(8 + self.inner.len * 10);
                write_varint(&mut out, self.inner.len as u64);
                for row in 0..self.inner.len {
                    self.inner.layout.encode_row(&self.inner.cols, row, &mut out);
                }
                Arc::from(out)
            })
            .clone()
    }

    /// The cached wire encoding, if one has been computed.
    pub fn wire_cached(&self) -> Option<Arc<[u8]>> {
        self.inner.wire.get().cloned()
    }
}

/// A mutable columnar accumulation buffer: rows are appended from an
/// existing batch's columns (the hash shuffle partitioning a batch
/// across targets) and taken out as finished [`ColumnBatch`]es.
#[derive(Debug)]
pub struct ColumnBuffer {
    layout: Layout,
    cols: Vec<Column>,
    hashes: Vec<u64>,
    len: usize,
}

impl ColumnBuffer {
    /// Creates an empty buffer for `layout`.
    pub fn new(layout: Layout) -> ColumnBuffer {
        let cols = layout.new_columns(0);
        ColumnBuffer {
            layout,
            cols,
            hashes: Vec::new(),
            len: 0,
        }
    }

    /// The buffer's layout.
    pub fn layout(&self) -> &Layout {
        &self.layout
    }

    /// Number of buffered rows.
    pub fn len(&self) -> usize {
        self.len
    }

    /// True when nothing is buffered.
    pub fn is_empty(&self) -> bool {
        self.len == 0
    }

    /// Appends row `row` of `src` (columns of the same layout), with its
    /// routing hash.
    pub fn push_row_from(&mut self, src: &[Column], row: usize, hash: u64) {
        for (dst, s) in self.cols.iter_mut().zip(src) {
            dst.push_from(s, row);
        }
        self.hashes.push(hash);
        self.len += 1;
    }

    /// Takes the buffered rows as a [`ColumnBatch`], leaving the buffer
    /// empty (fresh columns of the same layout).
    pub fn take(&mut self) -> ColumnBatch {
        let cols = std::mem::replace(&mut self.cols, self.layout.new_columns(0));
        let hashes = std::mem::take(&mut self.hashes);
        self.len = 0;
        ColumnBatch::with_hashes(self.layout.clone(), cols, hashes)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::value::{encode_batch, StreamData};

    fn batch_of<T: StreamData>(items: Vec<T>) -> ColumnBatch {
        let layout = T::layout().expect("columnar type");
        let mut cols = layout.new_columns(items.len());
        for x in items {
            x.append_columns(&mut cols);
        }
        ColumnBatch::new(layout, cols)
    }

    #[test]
    fn scalar_roundtrip_through_columns() {
        let cb = batch_of(vec![1i64, -5, i64::MAX]);
        assert_eq!(cb.len(), 3);
        assert_eq!(cb.row(0), Value::I64(1));
        assert_eq!(cb.row(2), Value::I64(i64::MAX));
        assert_eq!(i64::read_columns(cb.columns(), 1), -5);
    }

    #[test]
    fn tuple_layouts_flatten_and_nest_back() {
        let cb = batch_of(vec![(7i64, ("k".to_string(), true))]);
        assert_eq!(cb.columns().len(), 3, "three flattened leaves");
        assert_eq!(
            cb.row(0),
            Value::pair(
                Value::I64(7),
                Value::pair(Value::Str("k".into()), Value::Bool(true))
            )
        );
        assert_eq!(
            <(i64, (String, bool))>::read_columns(cb.columns(), 0),
            (7, ("k".to_string(), true))
        );
    }

    #[test]
    fn triple_maps_to_three_element_list() {
        let cb = batch_of(vec![(1i64, 2.5f64, false)]);
        assert_eq!(
            cb.row(0),
            Value::List(vec![Value::I64(1), Value::F64(2.5), Value::Bool(false)])
        );
    }

    #[test]
    fn hash_row_matches_stable_hash_of_materialized_row() {
        let items = vec![
            (0i64, "alpha".to_string()),
            (-42, "".to_string()),
            (7, "βeta".to_string()),
        ];
        let cb = batch_of(items);
        for row in 0..cb.len() {
            assert_eq!(
                cb.layout().hash_row(cb.columns(), row),
                cb.row(row).stable_hash(),
                "row {row}"
            );
        }
    }

    #[test]
    fn encode_row_matches_value_encoding() {
        let cb = batch_of(vec![(1i64, 2.5f64, true), (-9, f64::NEG_INFINITY, false)]);
        for row in 0..cb.len() {
            let mut got = Vec::new();
            cb.layout().encode_row(cb.columns(), row, &mut got);
            assert_eq!(got, cb.row(row).encode(), "row {row}");
        }
    }

    #[test]
    fn wire_is_identical_to_row_batch_encoding() {
        let cb = batch_of(vec![("x".to_string(), 1i64), ("yz".to_string(), 2)]);
        let rows: Vec<Value> = (0..cb.len()).map(|i| cb.row(i)).collect();
        assert_eq!(cb.wire().as_ref(), encode_batch(&rows).as_slice());
        // encode-once: clones share the cache
        let twin = cb.clone();
        assert!(Arc::ptr_eq(&cb.wire(), &twin.wire()));
    }

    #[test]
    fn empty_batch_wire_and_materialization() {
        let cb = batch_of(Vec::<i64>::new());
        assert!(cb.is_empty());
        assert_eq!(cb.to_batch().len(), 0);
        assert_eq!(cb.wire().as_ref(), encode_batch(&[]).as_slice());
    }

    #[test]
    fn to_batch_carries_the_hash_column() {
        let layout = Layout::I64;
        let mut cols = layout.new_columns(2);
        3i64.append_columns(&mut cols);
        4i64.append_columns(&mut cols);
        let hashes = vec![Value::I64(3).stable_hash(), Value::I64(4).stable_hash()];
        let cb = ColumnBatch::with_hashes(layout, cols, hashes.clone());
        assert_eq!(cb.key_hashes(), Some(hashes.as_slice()));
        assert_eq!(cb.to_batch().key_hashes(), Some(hashes.as_slice()));
    }

    #[test]
    fn column_buffer_partitions_and_resets() {
        let src = batch_of(vec![(1i64, 10i64), (2, 20), (3, 30)]);
        let mut buf = ColumnBuffer::new(src.layout().clone());
        for row in [0usize, 2] {
            buf.push_row_from(src.columns(), row, src.layout().hash_row(src.columns(), row));
        }
        assert_eq!(buf.len(), 2);
        let taken = buf.take();
        assert!(buf.is_empty());
        assert_eq!(taken.len(), 2);
        assert_eq!(taken.row(1), src.row(2));
        assert_eq!(
            taken.key_hashes().unwrap()[1],
            src.layout().hash_row(src.columns(), 2)
        );
    }

    #[test]
    fn clone_shares_the_allocation() {
        let cb = batch_of(vec![1i64, 2]);
        let twin = cb.clone();
        assert!(ColumnBatch::ptr_eq(&cb, &twin));
    }
}
