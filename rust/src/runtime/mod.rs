//! Host runtime: stage-instance worker threads.
//!
//! Each planned stage instance runs [`run_instance`] on its own OS thread
//! (one per simulated core, mirroring Renoir's thread-per-instance
//! execution). An instance pulls from its input (a source generator, an
//! in-memory/remote channel inbox, or a queue partition), feeds batches
//! through the fused operator chain, and routes outputs through its
//! [`FanOut`] (one [`OutPort`](crate::channels::OutPort) per outgoing
//! stage edge). End-of-stream flushes stateful operators and cascades EOS
//! downstream.

pub mod exec;
pub mod xla_exec;

pub use exec::{flush_chain, run_chain, Collector, OpExec};

use crate::channels::{FanOut, Inbox};
use crate::graph::SourceKind;
use crate::metrics::{Metrics, MetricsRegistry};
use crate::queue::Topic;
use crate::value::{Batch, Value};
use std::sync::atomic::{AtomicBool, Ordering};
use std::sync::Arc;
use std::time::Duration;

/// Source generator state for a source-stage instance.
pub struct SourceRuntime {
    /// Source definition.
    pub kind: SourceKind,
    /// `(instance_index, instance_count)` split of the input.
    pub share: (u64, u64),
    /// Events per emitted batch.
    pub batch_size: usize,
    /// Cooperative stop flag (dynamic updates / unbounded sources).
    pub stop: Arc<AtomicBool>,
}

/// Where an instance's input comes from.
pub enum InputKind {
    /// This instance *is* a source.
    Source(SourceRuntime),
    /// Direct channel fed by upstream instances.
    Inbox(Inbox),
    /// One partition of a decoupling queue topic (consumer-group member).
    Queue {
        /// Topic shared by the FlowUnit boundary.
        topic: Arc<Topic>,
        /// Partition index owned by this instance.
        partition: usize,
        /// Consumer group (one per downstream FlowUnit instance set).
        group: String,
        /// Poll timeout per iteration.
        poll_timeout: Duration,
        /// Cooperative stop flag — set during a dynamic update to make the
        /// instance commit and exit *without* treating it as end-of-stream.
        stop: Arc<AtomicBool>,
    },
}

/// Everything a stage-instance thread needs.
pub struct InstanceRuntime {
    /// Instance id (diagnostics).
    pub id: usize,
    /// Fused operator chain.
    pub ops: Vec<Box<dyn OpExec>>,
    /// Input side.
    pub input: InputKind,
    /// Output side: one port per outgoing stage edge (empty for terminal
    /// sink stages; several for `split` fan-outs).
    pub outputs: FanOut,
    /// Job metrics.
    pub metrics: Metrics,
}

/// Runs one stage instance to completion. Returns the number of input
/// batches processed (diagnostics).
pub fn run_instance(mut rt: InstanceRuntime) -> u64 {
    let mut batches = 0u64;
    match rt.input {
        InputKind::Source(src) => {
            run_source(src, &mut rt.ops, &mut rt.outputs, &rt.metrics);
        }
        InputKind::Inbox(mut inbox) => {
            while let Some(batch) = inbox.recv() {
                batches += 1;
                let out = run_chain(&mut rt.ops, batch);
                route(&mut rt.outputs, out);
            }
        }
        InputKind::Queue {
            topic,
            partition,
            group,
            poll_timeout,
            stop,
        } => {
            let part = topic.partition(partition);
            let mut offset = part.committed(&group);
            loop {
                if stop.load(Ordering::Relaxed) {
                    // Dynamic update: leave without flushing state — the
                    // replacement instance resumes from the committed offset.
                    return batches;
                }
                match part.poll(offset, 64, poll_timeout) {
                    None => break, // closed + drained: end of stream
                    Some((recs, next)) => {
                        if recs.is_empty() {
                            continue; // poll timeout, still open
                        }
                        // each queue record *is* one encoded batch; decode
                        // it once, keeping the record bytes as the wire
                        // cache (re-appending downstream costs no encode)
                        for r in recs {
                            let b = Batch::from_wire(r).expect("corrupt queue record");
                            batches += 1;
                            let out = run_chain(&mut rt.ops, b);
                            route(&mut rt.outputs, out);
                        }
                        offset = next;
                        part.commit(&group, offset);
                    }
                }
            }
        }
    }
    // end of stream: flush stateful operators, cascade EOS
    let tail = flush_chain(&mut rt.ops);
    route(&mut rt.outputs, tail.into());
    rt.outputs.eos();
    batches
}

fn route(outputs: &mut FanOut, batch: Batch) {
    if batch.is_empty() {
        return;
    }
    outputs.send(batch);
}

fn run_source(
    src: SourceRuntime,
    ops: &mut [Box<dyn OpExec>],
    outputs: &mut FanOut,
    metrics: &Metrics,
) {
    let (idx, n) = src.share;
    match &src.kind {
        SourceKind::Synthetic { total, gen, rate } => {
            // split `total` across instances: instance idx gets the slice
            // [lo, hi) of the global event index space.
            let base = total / n;
            let rem = total % n;
            let count = base + if idx < rem { 1 } else { 0 };
            let lo = idx * base + idx.min(rem);
            let mut emitted = 0u64;
            let t0 = std::time::Instant::now();
            while emitted < count {
                if src.stop.load(Ordering::Relaxed) {
                    break;
                }
                let this_batch = (src.batch_size as u64).min(count - emitted);
                let mut batch = Vec::with_capacity(this_batch as usize);
                for i in 0..this_batch {
                    batch.push(gen(idx, lo + emitted + i));
                }
                emitted += this_batch;
                MetricsRegistry::add(&metrics.events_in, this_batch);
                let out = run_chain(ops, batch.into());
                route(outputs, out);
                if let Some(r) = rate {
                    // pace to `r` events/second for this instance
                    let target = Duration::from_secs_f64(emitted as f64 / r);
                    let elapsed = t0.elapsed();
                    if target > elapsed {
                        std::thread::sleep(target - elapsed);
                    }
                }
            }
        }
        SourceKind::Vector(values) => {
            let mut batch = Vec::with_capacity(src.batch_size);
            for (i, v) in values.iter().enumerate() {
                if (i as u64) % n != idx {
                    continue;
                }
                batch.push(v.clone());
                if batch.len() >= src.batch_size {
                    MetricsRegistry::add(&metrics.events_in, batch.len() as u64);
                    let out = run_chain(ops, std::mem::take(&mut batch).into());
                    route(outputs, out);
                }
            }
            if !batch.is_empty() {
                MetricsRegistry::add(&metrics.events_in, batch.len() as u64);
                let out = run_chain(ops, batch.into());
                route(outputs, out);
            }
        }
        SourceKind::FileLines(path) => {
            let text = std::fs::read_to_string(path)
                .unwrap_or_else(|e| panic!("source file {}: {e}", path.display()));
            let mut batch = Vec::with_capacity(src.batch_size);
            for (i, line) in text.lines().enumerate() {
                if (i as u64) % n != idx {
                    continue;
                }
                batch.push(Value::Str(line.to_string()));
                if batch.len() >= src.batch_size {
                    MetricsRegistry::add(&metrics.events_in, batch.len() as u64);
                    let out = run_chain(ops, std::mem::take(&mut batch).into());
                    route(outputs, out);
                }
            }
            if !batch.is_empty() {
                MetricsRegistry::add(&metrics.events_in, batch.len() as u64);
                let out = run_chain(ops, batch.into());
                route(outputs, out);
            }
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::channels::{Msg, OutPort, Routing, Target};
    use crate::graph::SinkKind;
    use std::sync::mpsc::sync_channel;

    fn collector_sink(
        metrics: &Metrics,
    ) -> (Arc<Collector>, Vec<Box<dyn OpExec>>) {
        let c = Arc::new(Collector::default());
        let sink: Vec<Box<dyn OpExec>> = vec![Box::new(exec::SinkExec::new(
            SinkKind::Collect,
            c.clone(),
            metrics.clone(),
        ))];
        (c, sink)
    }

    #[test]
    fn source_instance_generates_share_and_eos() {
        let metrics = MetricsRegistry::new();
        let (tx, rx) = sync_channel(64);
        let port = OutPort::new(
            vec![Target {
                tx,
                link: None,
                latency: Duration::ZERO,
                crossing: false,
            }],
            Routing::RoundRobin,
            16,
            None,
        );
        let rt = InstanceRuntime {
            id: 0,
            ops: vec![],
            input: InputKind::Source(SourceRuntime {
                kind: SourceKind::Synthetic {
                    total: 10,
                    gen: Arc::new(|_, i| Value::I64(i as i64)),
                    rate: None,
                },
                share: (1, 3), // instance 1 of 3: 10 = 4+3+3 → count 3, lo 4
                batch_size: 2,
                stop: Arc::new(AtomicBool::new(false)),
            }),
            outputs: FanOut::single(port),
            metrics: metrics.clone(),
        };
        run_instance(rt);
        let mut inbox = Inbox::new(rx, 1);
        let mut got = Vec::new();
        while let Some(b) = inbox.recv() {
            got.extend(b.into_iter().map(|v| v.as_i64().unwrap()));
        }
        assert_eq!(got, vec![4, 5, 6]);
        assert_eq!(metrics.events_in.load(Ordering::Relaxed), 3);
    }

    #[test]
    fn synthetic_shares_partition_index_space_exactly() {
        // all instances together must produce exactly [0, total)
        let total = 23u64;
        let n = 5u64;
        let metrics = MetricsRegistry::new();
        let mut all = Vec::new();
        for idx in 0..n {
            let (tx, rx) = sync_channel(1024);
            let port = OutPort::new(
                vec![Target {
                    tx,
                    link: None,
                    latency: Duration::ZERO,
                    crossing: false,
                }],
                Routing::RoundRobin,
                16,
                None,
            );
            run_instance(InstanceRuntime {
                id: idx as usize,
                ops: vec![],
                input: InputKind::Source(SourceRuntime {
                    kind: SourceKind::Synthetic {
                        total,
                        gen: Arc::new(|_, i| Value::I64(i as i64)),
                        rate: None,
                    },
                    share: (idx, n),
                    batch_size: 4,
                    stop: Arc::new(AtomicBool::new(false)),
                }),
                outputs: FanOut::single(port),
                metrics: metrics.clone(),
            });
            let mut inbox = Inbox::new(rx, 1);
            while let Some(b) = inbox.recv() {
                all.extend(b.into_iter().map(|v| v.as_i64().unwrap()));
            }
        }
        all.sort_unstable();
        assert_eq!(all, (0..total as i64).collect::<Vec<_>>());
    }

    #[test]
    fn inbox_instance_processes_and_sinks() {
        let metrics = MetricsRegistry::new();
        let (tx, rx) = sync_channel(8);
        let (collector, ops) = collector_sink(&metrics);
        tx.send(Msg::Batch(vec![Value::I64(1), Value::I64(2)].into()))
            .unwrap();
        tx.send(Msg::Eos).unwrap();
        run_instance(InstanceRuntime {
            id: 0,
            ops,
            input: InputKind::Inbox(Inbox::new(rx, 1)),
            outputs: FanOut::none(),
            metrics: metrics.clone(),
        });
        assert_eq!(collector.values.lock().unwrap().len(), 2);
        assert_eq!(metrics.events_out.load(Ordering::Relaxed), 2);
    }

    #[test]
    fn queue_instance_consumes_commits_and_ends() {
        let metrics = MetricsRegistry::new();
        let broker = crate::queue::QueueBroker::in_memory(None);
        let topic = broker.topic("t", 1).unwrap();
        topic.register_producer();
        topic
            .append(0, &crate::value::encode_batch(&[Value::I64(7)]))
            .unwrap();
        topic
            .append(0, &crate::value::encode_batch(&[Value::I64(8)]))
            .unwrap();
        topic.producer_done();
        let (collector, ops) = collector_sink(&metrics);
        run_instance(InstanceRuntime {
            id: 0,
            ops,
            input: InputKind::Queue {
                topic: topic.clone(),
                partition: 0,
                group: "g".into(),
                poll_timeout: Duration::from_millis(20),
                stop: Arc::new(AtomicBool::new(false)),
            },
            outputs: FanOut::none(),
            metrics,
        });
        assert_eq!(collector.values.lock().unwrap().len(), 2);
        assert_eq!(topic.partition(0).committed("g"), 2);
    }

    #[test]
    fn queue_instance_resumes_from_committed_offset() {
        let metrics = MetricsRegistry::new();
        let broker = crate::queue::QueueBroker::in_memory(None);
        let topic = broker.topic("t", 1).unwrap();
        topic.register_producer();
        for i in 0..4 {
            topic
                .append(0, &crate::value::encode_batch(&[Value::I64(i)]))
                .unwrap();
        }
        topic.producer_done();
        topic.partition(0).commit("g", 2); // pretend records 0,1 were handled
        let (collector, ops) = collector_sink(&metrics);
        run_instance(InstanceRuntime {
            id: 0,
            ops,
            input: InputKind::Queue {
                topic: topic.clone(),
                partition: 0,
                group: "g".into(),
                poll_timeout: Duration::from_millis(20),
                stop: Arc::new(AtomicBool::new(false)),
            },
            outputs: FanOut::none(),
            metrics,
        });
        let got: Vec<i64> = collector
            .values
            .lock()
            .unwrap()
            .iter()
            .map(|v| v.as_i64().unwrap())
            .collect();
        assert_eq!(got, vec![2, 3]);
    }

    #[test]
    fn stop_flag_halts_source_early() {
        let metrics = MetricsRegistry::new();
        let stop = Arc::new(AtomicBool::new(true)); // pre-stopped
        let (tx, rx) = sync_channel(8);
        let port = OutPort::new(
            vec![Target {
                tx,
                link: None,
                latency: Duration::ZERO,
                crossing: false,
            }],
            Routing::RoundRobin,
            16,
            None,
        );
        run_instance(InstanceRuntime {
            id: 0,
            ops: vec![],
            input: InputKind::Source(SourceRuntime {
                kind: SourceKind::Synthetic {
                    total: 1_000_000,
                    gen: Arc::new(|_, i| Value::I64(i as i64)),
                    rate: None,
                },
                share: (0, 1),
                batch_size: 64,
                stop,
            }),
            outputs: FanOut::single(port),
            metrics,
        });
        let mut inbox = Inbox::new(rx, 1);
        assert!(inbox.recv().is_none(), "no data, just EOS");
    }

    #[test]
    fn vector_source_round_robins_and_flushes_tail() {
        let metrics = MetricsRegistry::new();
        let vals: Vec<Value> = (0..7).map(Value::I64).collect();
        let (tx, rx) = sync_channel(64);
        let port = OutPort::new(
            vec![Target {
                tx,
                link: None,
                latency: Duration::ZERO,
                crossing: false,
            }],
            Routing::RoundRobin,
            16,
            None,
        );
        run_instance(InstanceRuntime {
            id: 0,
            ops: vec![],
            input: InputKind::Source(SourceRuntime {
                kind: SourceKind::Vector(Arc::new(vals)),
                share: (0, 2),
                batch_size: 2,
                stop: Arc::new(AtomicBool::new(false)),
            }),
            outputs: FanOut::single(port),
            metrics,
        });
        let mut inbox = Inbox::new(rx, 1);
        let mut got = Vec::new();
        while let Some(b) = inbox.recv() {
            got.extend(b.into_iter().map(|v| v.as_i64().unwrap()));
        }
        assert_eq!(got, vec![0, 2, 4, 6]);
    }

    #[test]
    fn rate_limited_source_paces_output() {
        let metrics = MetricsRegistry::new();
        let (tx, rx) = sync_channel(1024);
        let port = OutPort::new(
            vec![Target {
                tx,
                link: None,
                latency: Duration::ZERO,
                crossing: false,
            }],
            Routing::RoundRobin,
            16,
            None,
        );
        let t0 = std::time::Instant::now();
        run_instance(InstanceRuntime {
            id: 0,
            ops: vec![],
            input: InputKind::Source(SourceRuntime {
                kind: SourceKind::Synthetic {
                    total: 100,
                    gen: Arc::new(|_, i| Value::I64(i as i64)),
                    rate: Some(1000.0), // 100 events at 1000 ev/s ≈ 100 ms
                },
                share: (0, 1),
                batch_size: 10,
                stop: Arc::new(AtomicBool::new(false)),
            }),
            outputs: FanOut::single(port),
            metrics,
        });
        let dt = t0.elapsed();
        assert!(dt >= Duration::from_millis(80), "ran in {dt:?}");
        drop(rx);
    }
}
