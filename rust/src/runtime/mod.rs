//! Host runtime: stage-instance worker threads.
//!
//! Each planned stage instance runs [`run_instance`] on its own OS thread
//! (one per simulated core, mirroring Renoir's thread-per-instance
//! execution). An instance pulls from its input (a source generator, an
//! in-memory/remote channel inbox, or a queue partition), feeds batches
//! through the fused operator chain, and routes outputs through its
//! [`FanOut`] (one [`OutPort`](crate::channels::OutPort) per outgoing
//! stage edge). End-of-stream flushes stateful operators and cascades EOS
//! downstream.

pub mod col_exec;
pub mod exec;
pub mod xla_exec;

pub use exec::{
    advance_chain_watermark, drain_generated_watermarks, flush_chain, run_chain, run_chain_data,
    ChainBuffers, ChainInput, ColumnFlow, Collector, OpExec,
};

use crate::channels::{FanOut, Inbox, InboxEvent};
use crate::graph::SourceKind;
use crate::metrics::{Metrics, MetricsRegistry};
use crate::queue::Topic;
use crate::value::{Batch, BatchData, Value};
use std::sync::atomic::{AtomicBool, AtomicU64, AtomicUsize, Ordering};
use std::sync::Arc;
use std::time::{Duration, Instant};

/// Source generator state for a source-stage instance.
pub struct SourceRuntime {
    /// Source definition.
    pub kind: SourceKind,
    /// `(instance_index, instance_count)` split of the input.
    pub share: (u64, u64),
    /// Events per emitted batch.
    pub batch_size: usize,
    /// Cooperative stop flag (dynamic updates / unbounded sources).
    pub stop: Arc<AtomicBool>,
}

/// Where an instance's input comes from.
pub enum InputKind {
    /// This instance *is* a source.
    Source(SourceRuntime),
    /// Direct channel fed by upstream instances.
    Inbox(Inbox),
    /// A share of a decoupling queue topic's partitions (consumer-group
    /// member). Normally one partition per instance; after a
    /// placement-affecting dynamic update the instance count may differ
    /// from the partition count, so ownership is a round-robin assignment
    /// (an instance may own several partitions, or none). Consumption is
    /// event-driven: the instance parks once on the topic wait-set across
    /// all owned partitions ([`Topic::poll_many`]) and drains every ready
    /// partition per wakeup.
    Queue {
        /// Topic shared by the FlowUnit boundary.
        topic: Arc<Topic>,
        /// Partition indices owned by this instance.
        partitions: Vec<usize>,
        /// Consumer group (one per downstream FlowUnit instance set).
        group: String,
        /// Upper bound on one uninterrupted wait (stop flags are
        /// re-checked at least this often even without a kick).
        poll_timeout: Duration,
        /// Maximum records drained from one partition per poll
        /// ([`JobConfig::poll_max_records`](crate::coordinator::JobConfig::poll_max_records)).
        poll_max: usize,
        /// Cooperative stop flag — set during a dynamic update to make the
        /// instance commit, quiesce, and exit *without* treating it as
        /// end-of-stream.
        stop: Arc<AtomicBool>,
        /// Commit consumed offsets after every drain (legacy at-least-once
        /// mode). Checkpoint mode sets this `false`: offsets are recorded
        /// inside checkpoint records and committed *by the coordinator*
        /// only after a whole unit-zone checkpointed, so a crash replays
        /// from the last complete checkpoint instead of double-counting
        /// records an interior stage already folded into restored state.
        commit_each_drain: bool,
        /// Producers currently registered on the topic (shared with the
        /// ingest threads; `add_location` may grow it while the job runs).
        /// Watermark sentinel records carry per-producer identities, and
        /// the min-of-inputs merge refuses to advance until every expected
        /// producer has promised at least once — unless the idleness
        /// timeout below waives the silent ones.
        producers: Arc<AtomicUsize>,
        /// Event-time idleness bound: a producer that has not advanced its
        /// watermark for this long is excluded from the min-of-inputs
        /// merge (and a producer that never reported stops gating it), so
        /// one silent edge source cannot stall event-time for the whole
        /// zone. `None` = wait forever (strict semantics).
        idle_timeout: Option<Duration>,
    },
}

/// Per-producer watermark merge state for a queue consumer — the queue
/// equivalent of [`Inbox`]'s min-of-inputs merge, fed by watermark
/// sentinel records ([`crate::queue::decode_watermark`]) interleaved with
/// data in the partition logs. Producer promises are monotone per `from`,
/// so folding records from several owned partitions into one per-producer
/// max is sound.
struct QueueWmMerge {
    /// `(producer id, max promised ts, last heard)`.
    entries: Vec<(u32, i64, Instant)>,
    /// Last merged watermark emitted (monotonicity guard).
    out: i64,
    /// Idleness bound (see [`InputKind::Queue::idle_timeout`]).
    idle: Option<Duration>,
    /// When consumption started — silent-from-birth producers gate the
    /// merge until this is `idle` old.
    started: Instant,
}

impl QueueWmMerge {
    fn new(idle: Option<Duration>) -> Self {
        QueueWmMerge {
            entries: Vec::new(),
            out: i64::MIN,
            idle,
            started: Instant::now(),
        }
    }

    /// Folds one decoded sentinel into the merge; returns the advanced
    /// merged watermark, if the min over live producers moved forward.
    fn observe(&mut self, wm: crate::channels::Watermark, expected: usize) -> Option<i64> {
        let now = Instant::now();
        match self.entries.iter_mut().find(|(f, ..)| *f == wm.from) {
            Some((_, ts, heard)) => {
                *ts = (*ts).max(wm.ts);
                *heard = now;
            }
            None => self.entries.push((wm.from, wm.ts, now)),
        }
        self.merge(expected)
    }

    /// Re-evaluates the merge without new input (periodic idle check:
    /// producers crossing the idleness bound may unblock the min).
    fn idle_tick(&mut self, expected: usize) -> Option<i64> {
        self.idle?;
        if self.entries.is_empty() {
            return None;
        }
        self.merge(expected)
    }

    fn merge(&mut self, expected: usize) -> Option<i64> {
        let now = Instant::now();
        let mut min = i64::MAX;
        let mut live = 0usize;
        for &(_, ts, heard) in &self.entries {
            if self
                .idle
                .is_some_and(|d| now.duration_since(heard) > d)
            {
                // idle producer: its stale promise no longer holds the
                // merged clock down (it re-enters when it next reports)
                continue;
            }
            live += 1;
            min = min.min(ts);
        }
        if live == 0 {
            return None;
        }
        if self.entries.len() < expected {
            // some producer has *never* reported; strict semantics wait
            // forever, the idleness timeout waives them once consumption
            // has been running long enough to have heard from them
            let waived = self
                .idle
                .is_some_and(|d| now.duration_since(self.started) > d);
            if !waived {
                return None;
            }
        }
        if min > self.out {
            self.out = min;
            Some(min)
        } else {
            None
        }
    }
}

/// Drain-and-handoff context of one instance: where to snapshot held state
/// when quiescing for a dynamic update or checkpoint, and which epoch is
/// in progress.
pub struct Handoff {
    /// Per-unit state topic (snapshots are appended as records keyed by
    /// stage + zone + epoch; the coordinator reads them back to seed the
    /// replacement instances).
    pub state_topic: Arc<Topic>,
    /// Stage this instance executes (snapshot record key).
    pub stage: usize,
    /// Zone this instance runs in (snapshot record key).
    pub zone: String,
    /// Deployment-wide epoch stamp, written by the coordinator *before*
    /// stop flags are set / markers begin to flow. Checkpoint epochs carry
    /// [`crate::channels::CHECKPOINT_BIT`].
    pub epoch: Arc<AtomicU64>,
    /// Checkpoint mode: quiescing records input offsets even for stateless
    /// chains (a replayed entry stage with no record would restart from
    /// offset 0 and double-feed restored interior state), and a producer
    /// crash makes the instance exit *without* EOS so the recovery
    /// supervisor can respawn the whole unit-zone.
    pub checkpoint: bool,
    /// Set once this instance flushed and cascaded EOS normally. Later
    /// rolls (checkpoint, recovery, rescale) must not respawn it — a fresh
    /// incarnation would emit a second EOS into downstream accounting.
    pub eos_done: Arc<AtomicBool>,
}

impl Handoff {
    /// Appends one state record to the state topic. Record layout (flat
    /// list): `[I64 stage, Str zone, I64 epoch, List snaps, List offsets]`
    /// — one snapshot entry (or `Null` for stateless operators) per
    /// executor in the fused chain, and one `Pair(partition, next_offset)`
    /// per owned input partition (empty for inbox-fed stages). A failed
    /// append is surfaced in `state_append_failures` — the record was
    /// dropped, never silently discarded.
    pub fn save(
        &self,
        epoch: u64,
        snaps: Vec<Value>,
        offsets: &[(usize, usize)],
        metrics: &Metrics,
    ) {
        let rec = state_record(self.stage as i64, &self.zone, epoch, snaps, offsets);
        if self.state_topic.partition(0).append(&rec.encode()).is_err() {
            MetricsRegistry::add(&metrics.state_append_failures, 1);
        }
    }
}

/// Builds one state-topic record in the shared flat layout (see
/// [`Handoff::save`]; the coordinator uses the same shape for its epoch
/// commit markers, with stage `-1`).
pub fn state_record(
    stage: i64,
    zone: &str,
    epoch: u64,
    snaps: Vec<Value>,
    offsets: &[(usize, usize)],
) -> Value {
    Value::List(vec![
        Value::I64(stage),
        Value::Str(zone.to_string()),
        Value::I64(epoch as i64),
        Value::List(snaps),
        Value::List(
            offsets
                .iter()
                .map(|&(p, o)| Value::pair(Value::I64(p as i64), Value::I64(o as i64)))
                .collect(),
        ),
    ])
}

/// Everything a stage-instance thread needs.
pub struct InstanceRuntime {
    /// Instance id (diagnostics).
    pub id: usize,
    /// Fused operator chain.
    pub ops: Vec<Box<dyn OpExec>>,
    /// Input side.
    pub input: InputKind,
    /// Output side: one port per outgoing stage edge (empty for terminal
    /// sink stages; several for `split` fan-outs).
    pub outputs: FanOut,
    /// Job metrics.
    pub metrics: Metrics,
    /// Drain-and-handoff context (`None` when the deployment has no queue
    /// substrate, or for source instances — source units are not
    /// hot-swappable).
    pub handoff: Option<Handoff>,
    /// Per-operator state to restore before the first batch (one entry per
    /// executor, `Value::Null` = nothing; empty = fresh start).
    pub restore: Vec<Value>,
}

/// Runs one stage instance to completion. Returns the number of input
/// batches processed (also published as the labelled counter
/// `inst.{id}.batches`, the autoscaler's per-instance throughput input).
pub fn run_instance(rt: InstanceRuntime) -> u64 {
    let id = rt.id;
    let metrics = rt.metrics.clone();
    let batches = run_instance_inner(rt);
    if batches > 0 {
        MetricsRegistry::add(&metrics.counter(&format!("inst.{id}.batches")), batches);
    }
    batches
}

fn run_instance_inner(mut rt: InstanceRuntime) -> u64 {
    // restore handed-off state before the first batch
    if !rt.restore.is_empty() {
        let restore = std::mem::take(&mut rt.restore);
        for (op, state) in rt.ops.iter_mut().zip(restore) {
            if !matches!(state, Value::Null) {
                op.restore(state);
            }
        }
    }
    let mut batches = 0u64;
    // per-instance chain scratch: recycled across every batch this
    // instance processes (see ChainBuffers)
    let mut bufs = ChainBuffers::new(Some(rt.metrics.clone()));
    match rt.input {
        InputKind::Source(src) => {
            run_source(src, &mut rt.ops, &mut rt.outputs, &rt.metrics, &mut bufs);
        }
        InputKind::Inbox(mut inbox) => loop {
            match inbox.next() {
                InboxEvent::Batch(batch) => {
                    batches += 1;
                    let out = run_chain(&mut rt.ops, batch, &mut bufs);
                    route(&mut rt.outputs, out);
                    drain_watermarks(&mut rt.ops, &mut rt.outputs);
                }
                InboxEvent::Columns(cb) => {
                    batches += 1;
                    let out = run_chain_data(&mut rt.ops, cb.into(), &mut bufs);
                    route_data(&mut rt.outputs, out);
                    drain_watermarks(&mut rt.ops, &mut rt.outputs);
                }
                InboxEvent::Watermark { ts, origin_ms } => {
                    // the merged (min-of-inputs) upstream clock advanced:
                    // cascade it through the chain — firing any due panes
                    // as ordinary output — and forward it with its origin
                    // stamp intact so the lag metric measures true
                    // end-to-end propagation
                    let mut fired = Vec::new();
                    let fwd =
                        exec::advance_chain_watermark(&mut rt.ops, 0, ts, &mut fired);
                    route(&mut rt.outputs, fired.into());
                    if let Some(w) = fwd {
                        rt.outputs.watermark(w, origin_ms);
                    }
                }
                InboxEvent::Eos => {
                    if inbox.disconnected() && rt.handoff.as_ref().is_some_and(|h| h.checkpoint) {
                        // A producer crashed (senders dropped without EOS
                        // or marker). Under checkpointing the supervisor
                        // respawns the whole unit-zone from the last
                        // committed checkpoint; exiting *without* EOS here
                        // keeps downstream EOS accounting intact — the
                        // respawned incarnation will terminate the stream.
                        return batches;
                    }
                    break;
                }
                InboxEvent::Epoch(epoch) => {
                    // Dynamic update / checkpoint: every producer quiesced
                    // — snapshot held state, forward the marker, exit
                    // without EOS.
                    quiesce(&mut rt.ops, &mut rt.outputs, &rt.handoff, epoch, &[], &rt.metrics);
                    return batches;
                }
            }
        },
        InputKind::Queue {
            topic,
            partitions,
            group,
            poll_timeout,
            poll_max,
            stop,
            commit_each_drain,
            producers,
            idle_timeout,
        } => {
            let mut offsets: Vec<usize> = partitions
                .iter()
                .map(|&p| topic.partition(p).committed(&group))
                .collect();
            let mut wmerge = QueueWmMerge::new(idle_timeout);
            loop {
                // Acquire pairs with the coordinator's store: the update
                // epoch is bumped before the stop flag is raised, and the
                // acquire edge makes that bump visible to the epoch load
                // below (a relaxed load could legally stamp the snapshot
                // with the previous epoch on weak-memory hardware).
                if stop.load(Ordering::Acquire) {
                    // Dynamic update / checkpoint: snapshot state together
                    // with the offsets it covers and quiesce. In legacy
                    // mode everything processed so far is already
                    // committed; in checkpoint mode the coordinator
                    // commits these recorded offsets once the whole
                    // unit-zone quiesced.
                    let epoch = rt
                        .handoff
                        .as_ref()
                        .map(|h| h.epoch.load(Ordering::SeqCst))
                        .unwrap_or(0);
                    let covered: Vec<(usize, usize)> = partitions
                        .iter()
                        .zip(&offsets)
                        .map(|(&p, &o)| (p, o))
                        .collect();
                    quiesce(
                        &mut rt.ops,
                        &mut rt.outputs,
                        &rt.handoff,
                        epoch,
                        &covered,
                        &rt.metrics,
                    );
                    return batches;
                }
                // One park across every owned partition; any append/close
                // (or a coordinator kick) wakes it and the drain covers
                // every ready partition. `None` = all closed + consumed.
                let Some(drained) =
                    topic.poll_many(&partitions, &mut offsets, poll_max, poll_timeout)
                else {
                    // End of stream. In checkpoint mode nothing was
                    // committed per drain — commit the final offsets now
                    // so the job-level lag accounting drains to zero.
                    if !commit_each_drain {
                        for (slot, &p) in partitions.iter().enumerate() {
                            topic.partition(p).commit(&group, offsets[slot]);
                        }
                    }
                    break;
                };
                for (slot, recs) in drained {
                    // each queue record *is* one encoded batch; decode it
                    // once, keeping the record bytes as the wire cache
                    // (re-appending downstream costs no encode). A corrupt
                    // record is skipped and reported, never fatal.
                    for r in recs {
                        if r.is_empty() {
                            // shed/compaction tombstone: the offset is
                            // burned, the payload is gone by policy
                            continue;
                        }
                        if let Some(wm) = crate::queue::decode_watermark(&r) {
                            // event-time sentinel written by queue ingest:
                            // fold into the per-producer merge, cascading
                            // through the chain only when the min over
                            // live producers advances
                            let origin_ms = wm.origin_ms;
                            if let Some(ts) =
                                wmerge.observe(wm, producers.load(Ordering::SeqCst))
                            {
                                cascade_watermark(
                                    &mut rt.ops,
                                    &mut rt.outputs,
                                    ts,
                                    origin_ms,
                                );
                            }
                            continue;
                        }
                        match Batch::from_wire(r) {
                            Ok(b) => {
                                batches += 1;
                                let out = run_chain(&mut rt.ops, b, &mut bufs);
                                route(&mut rt.outputs, out);
                                drain_watermarks(&mut rt.ops, &mut rt.outputs);
                            }
                            Err(_) => {
                                MetricsRegistry::add(&rt.metrics.corrupt_records, 1);
                            }
                        }
                    }
                    // one commit per drained partition per wakeup (the
                    // poll advanced `offsets[slot]` past these records);
                    // checkpoint mode defers the commit to the coordinator
                    if commit_each_drain {
                        topic.partition(partitions[slot]).commit(&group, offsets[slot]);
                    }
                }
                // idleness re-check once per wakeup: a producer crossing
                // the idle bound can unblock the merged clock even with no
                // new sentinel in the drain
                if let Some(ts) = wmerge.idle_tick(producers.load(Ordering::SeqCst)) {
                    cascade_watermark(
                        &mut rt.ops,
                        &mut rt.outputs,
                        ts,
                        crate::time::now_ms(),
                    );
                }
            }
        }
    }
    // end of stream: flush stateful operators, cascade EOS
    let tail = flush_chain(&mut rt.ops);
    route(&mut rt.outputs, tail.into());
    rt.outputs.eos();
    if let Some(h) = &rt.handoff {
        // a normally-completed instance must never be respawned by a
        // later checkpoint/recovery roll (it would EOS a second time)
        h.eos_done.store(true, Ordering::SeqCst);
    }
    batches
}

/// Drain-and-handoff quiesce: snapshot each operator's held state into the
/// unit's state topic, then forward the epoch marker downstream (after
/// flushing any pending routed records). No EOS is emitted — downstream
/// consumers observe a pause, never an end-of-stream.
///
/// `offsets` are the `(partition, next_offset)` pairs the held state
/// covers (empty for inbox-fed stages). In checkpoint mode a record is
/// written even for a stateless chain when offsets are present: the
/// replacement must resume from here, not replay the topic from zero into
/// already-restored interior state.
fn quiesce(
    ops: &mut [Box<dyn OpExec>],
    outputs: &mut FanOut,
    handoff: &Option<Handoff>,
    epoch: u64,
    offsets: &[(usize, usize)],
    metrics: &Metrics,
) {
    if let Some(h) = handoff {
        let snaps: Vec<Value> = ops
            .iter_mut()
            .map(|op| op.snapshot().unwrap_or(Value::Null))
            .collect();
        let stateful = snaps.iter().any(|s| !matches!(s, Value::Null));
        if stateful || (h.checkpoint && !offsets.is_empty()) {
            h.save(epoch, snaps, offsets, metrics);
        }
    }
    outputs.epoch(epoch);
}

fn route(outputs: &mut FanOut, batch: Batch) {
    if batch.is_empty() {
        return;
    }
    outputs.send(batch);
}

/// Post-batch event-time bookkeeping: cascades any watermarks the chain's
/// timestamp assigners minted while processing the last batch, routes the
/// panes those watermarks fired, and forwards the surviving watermark
/// downstream stamped with the current wall clock (the origin of the
/// `watermark_lag_ms` metric). A chain without assigners returns
/// immediately — the poll is a per-operator `None`.
/// Cascades an externally-merged watermark (queue sentinel merge) through
/// the chain: fires due panes as ordinary output and forwards the
/// surviving watermark with its origin stamp intact.
fn cascade_watermark(ops: &mut [Box<dyn OpExec>], outputs: &mut FanOut, ts: i64, origin_ms: u64) {
    let mut fired = Vec::new();
    let fwd = exec::advance_chain_watermark(ops, 0, ts, &mut fired);
    route(outputs, fired.into());
    if let Some(w) = fwd {
        outputs.watermark(w, origin_ms);
    }
}

fn drain_watermarks(ops: &mut [Box<dyn OpExec>], outputs: &mut FanOut) {
    let mut fired = Vec::new();
    let fwd = exec::drain_generated_watermarks(ops, &mut fired);
    route(outputs, fired.into());
    if let Some(w) = fwd {
        outputs.watermark(w, crate::time::now_ms());
    }
}

fn route_data(outputs: &mut FanOut, data: BatchData) {
    if data.is_empty() {
        return;
    }
    outputs.send_data(data);
}

fn run_source(
    src: SourceRuntime,
    ops: &mut [Box<dyn OpExec>],
    outputs: &mut FanOut,
    metrics: &Metrics,
    bufs: &mut ChainBuffers,
) {
    let (idx, n) = src.share;
    match &src.kind {
        SourceKind::Synthetic { total, gen, rate } => {
            // split `total` across instances: instance idx gets the slice
            // [lo, hi) of the global event index space.
            let base = total / n;
            let rem = total % n;
            let count = base + if idx < rem { 1 } else { 0 };
            let lo = idx * base + idx.min(rem);
            let mut emitted = 0u64;
            let t0 = std::time::Instant::now();
            while emitted < count {
                if src.stop.load(Ordering::Relaxed) {
                    break;
                }
                let this_batch = (src.batch_size as u64).min(count - emitted);
                let mut batch = Vec::with_capacity(this_batch as usize);
                for i in 0..this_batch {
                    batch.push(gen(idx, lo + emitted + i));
                }
                emitted += this_batch;
                MetricsRegistry::add(&metrics.events_in, this_batch);
                let out = run_chain(ops, batch.into(), bufs);
                route(outputs, out);
                drain_watermarks(ops, outputs);
                if let Some(r) = rate {
                    // pace to `r` events/second for this instance
                    let target = Duration::from_secs_f64(emitted as f64 / r);
                    let elapsed = t0.elapsed();
                    if target > elapsed {
                        std::thread::sleep(target - elapsed);
                    }
                }
            }
        }
        SourceKind::SyntheticColumns { total, gen, rate } => {
            // identical share split to `Synthetic`, but each emitted batch
            // is born columnar: the generator fills native columns for a
            // whole index range, so no `Value` is ever allocated upstream
            // of a fallback point.
            let base = total / n;
            let rem = total % n;
            let count = base + if idx < rem { 1 } else { 0 };
            let lo = idx * base + idx.min(rem);
            let mut emitted = 0u64;
            let t0 = std::time::Instant::now();
            while emitted < count {
                if src.stop.load(Ordering::Relaxed) {
                    break;
                }
                let this_batch = (src.batch_size as u64).min(count - emitted);
                let start = lo + emitted;
                let cb = gen(idx, start..start + this_batch);
                emitted += this_batch;
                MetricsRegistry::add(&metrics.events_in, this_batch);
                let out = run_chain_data(ops, cb.into(), bufs);
                route_data(outputs, out);
                drain_watermarks(ops, outputs);
                if let Some(r) = rate {
                    let target = Duration::from_secs_f64(emitted as f64 / r);
                    let elapsed = t0.elapsed();
                    if target > elapsed {
                        std::thread::sleep(target - elapsed);
                    }
                }
            }
        }
        SourceKind::Vector(values) => {
            let mut batch = Vec::with_capacity(src.batch_size);
            for (i, v) in values.iter().enumerate() {
                if (i as u64) % n != idx {
                    continue;
                }
                batch.push(v.clone());
                if batch.len() >= src.batch_size {
                    MetricsRegistry::add(&metrics.events_in, batch.len() as u64);
                    let out = run_chain(ops, std::mem::take(&mut batch).into(), bufs);
                    route(outputs, out);
                    drain_watermarks(ops, outputs);
                }
            }
            if !batch.is_empty() {
                MetricsRegistry::add(&metrics.events_in, batch.len() as u64);
                let out = run_chain(ops, batch.into(), bufs);
                route(outputs, out);
                drain_watermarks(ops, outputs);
            }
        }
        SourceKind::FileLines(path) => {
            // Unreadable files are rejected by `Coordinator::deploy` before
            // any thread spawns; this guards the race where the file
            // disappears between validation and the read — the instance
            // produces nothing (and counts the failure) instead of
            // panicking the whole job.
            let text = match std::fs::read_to_string(path) {
                Ok(t) => t,
                Err(_) => {
                    MetricsRegistry::add(&metrics.source_errors, 1);
                    String::new()
                }
            };
            let mut batch = Vec::with_capacity(src.batch_size);
            for (i, line) in text.lines().enumerate() {
                if (i as u64) % n != idx {
                    continue;
                }
                batch.push(Value::Str(line.to_string()));
                if batch.len() >= src.batch_size {
                    MetricsRegistry::add(&metrics.events_in, batch.len() as u64);
                    let out = run_chain(ops, std::mem::take(&mut batch).into(), bufs);
                    route(outputs, out);
                    drain_watermarks(ops, outputs);
                }
            }
            if !batch.is_empty() {
                MetricsRegistry::add(&metrics.events_in, batch.len() as u64);
                let out = run_chain(ops, batch.into(), bufs);
                route(outputs, out);
                drain_watermarks(ops, outputs);
            }
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::channels::{Msg, OutPort, Routing, Target};
    use crate::graph::SinkKind;
    use std::sync::mpsc::sync_channel;

    fn collector_sink(
        metrics: &Metrics,
    ) -> (Arc<Collector>, Vec<Box<dyn OpExec>>) {
        let c = Arc::new(Collector::default());
        let sink: Vec<Box<dyn OpExec>> = vec![Box::new(exec::SinkExec::new(
            SinkKind::Collect,
            0,
            c.clone(),
            metrics.clone(),
        ))];
        (c, sink)
    }

    #[test]
    fn source_instance_generates_share_and_eos() {
        let metrics = MetricsRegistry::new();
        let (tx, rx) = sync_channel(64);
        let port = OutPort::new(
            vec![Target::local(tx)],
            Routing::RoundRobin,
            16,
            None,
        );
        let rt = InstanceRuntime {
            id: 0,
            ops: vec![],
            input: InputKind::Source(SourceRuntime {
                kind: SourceKind::Synthetic {
                    total: 10,
                    gen: Arc::new(|_, i| Value::I64(i as i64)),
                    rate: None,
                },
                share: (1, 3), // instance 1 of 3: 10 = 4+3+3 → count 3, lo 4
                batch_size: 2,
                stop: Arc::new(AtomicBool::new(false)),
            }),
            outputs: FanOut::single(port),
            metrics: metrics.clone(),
            handoff: None,
            restore: Vec::new(),
        };
        run_instance(rt);
        let mut inbox = Inbox::new(rx, 1);
        let mut got = Vec::new();
        while let Some(b) = inbox.recv() {
            got.extend(b.into_iter().map(|v| v.as_i64().unwrap()));
        }
        assert_eq!(got, vec![4, 5, 6]);
        assert_eq!(metrics.events_in.load(Ordering::Relaxed), 3);
    }

    #[test]
    fn synthetic_shares_partition_index_space_exactly() {
        // all instances together must produce exactly [0, total)
        let total = 23u64;
        let n = 5u64;
        let metrics = MetricsRegistry::new();
        let mut all = Vec::new();
        for idx in 0..n {
            let (tx, rx) = sync_channel(1024);
            let port = OutPort::new(
                vec![Target::local(tx)],
                Routing::RoundRobin,
                16,
                None,
            );
            run_instance(InstanceRuntime {
                id: idx as usize,
                ops: vec![],
                input: InputKind::Source(SourceRuntime {
                    kind: SourceKind::Synthetic {
                        total,
                        gen: Arc::new(|_, i| Value::I64(i as i64)),
                        rate: None,
                    },
                    share: (idx, n),
                    batch_size: 4,
                    stop: Arc::new(AtomicBool::new(false)),
                }),
                outputs: FanOut::single(port),
                metrics: metrics.clone(),
                handoff: None,
                restore: Vec::new(),
            });
            let mut inbox = Inbox::new(rx, 1);
            while let Some(b) = inbox.recv() {
                all.extend(b.into_iter().map(|v| v.as_i64().unwrap()));
            }
        }
        all.sort_unstable();
        assert_eq!(all, (0..total as i64).collect::<Vec<_>>());
    }

    #[test]
    fn inbox_instance_processes_and_sinks() {
        let metrics = MetricsRegistry::new();
        let (tx, rx) = sync_channel(8);
        let (collector, ops) = collector_sink(&metrics);
        tx.send(Msg::Batch(vec![Value::I64(1), Value::I64(2)].into()))
            .unwrap();
        tx.send(Msg::Eos).unwrap();
        run_instance(InstanceRuntime {
            id: 0,
            ops,
            input: InputKind::Inbox(Inbox::new(rx, 1)),
            outputs: FanOut::none(),
            metrics: metrics.clone(),
            handoff: None,
            restore: Vec::new(),
        });
        assert_eq!(collector.values.lock().unwrap().len(), 2);
        assert_eq!(metrics.events_out.load(Ordering::Relaxed), 2);
    }

    #[test]
    fn queue_instance_consumes_commits_and_ends() {
        let metrics = MetricsRegistry::new();
        let broker = crate::queue::QueueBroker::in_memory(None);
        let topic = broker.topic("t", 1).unwrap();
        topic.register_producer();
        topic
            .append(0, &crate::value::encode_batch(&[Value::I64(7)]))
            .unwrap();
        topic
            .append(0, &crate::value::encode_batch(&[Value::I64(8)]))
            .unwrap();
        topic.producer_done();
        let (collector, ops) = collector_sink(&metrics);
        run_instance(InstanceRuntime {
            id: 0,
            ops,
            input: InputKind::Queue {
                topic: topic.clone(),
                partitions: vec![0],
                group: "g".into(),
                poll_timeout: Duration::from_millis(20),
                poll_max: 64,
                stop: Arc::new(AtomicBool::new(false)),
                commit_each_drain: true,
                producers: Arc::new(AtomicUsize::new(1)),
                idle_timeout: None,
            },
            outputs: FanOut::none(),
            metrics,
            handoff: None,
            restore: Vec::new(),
        });
        assert_eq!(collector.values.lock().unwrap().len(), 2);
        assert_eq!(topic.partition(0).committed("g"), 2);
    }

    #[test]
    fn queue_instance_resumes_from_committed_offset() {
        let metrics = MetricsRegistry::new();
        let broker = crate::queue::QueueBroker::in_memory(None);
        let topic = broker.topic("t", 1).unwrap();
        topic.register_producer();
        for i in 0..4 {
            topic
                .append(0, &crate::value::encode_batch(&[Value::I64(i)]))
                .unwrap();
        }
        topic.producer_done();
        topic.partition(0).commit("g", 2); // pretend records 0,1 were handled
        let (collector, ops) = collector_sink(&metrics);
        run_instance(InstanceRuntime {
            id: 0,
            ops,
            input: InputKind::Queue {
                topic: topic.clone(),
                partitions: vec![0],
                group: "g".into(),
                poll_timeout: Duration::from_millis(20),
                poll_max: 64,
                stop: Arc::new(AtomicBool::new(false)),
                commit_each_drain: true,
                producers: Arc::new(AtomicUsize::new(1)),
                idle_timeout: None,
            },
            outputs: FanOut::none(),
            metrics,
            handoff: None,
            restore: Vec::new(),
        });
        let got: Vec<i64> = collector
            .values
            .lock()
            .unwrap()
            .iter()
            .map(|v| v.as_i64().unwrap())
            .collect();
        assert_eq!(got, vec![2, 3]);
    }

    #[test]
    fn corrupt_queue_record_is_skipped_and_reported() {
        let metrics = MetricsRegistry::new();
        let broker = crate::queue::QueueBroker::in_memory(None);
        let topic = broker.topic("t", 1).unwrap();
        topic.register_producer();
        topic
            .append(0, &crate::value::encode_batch(&[Value::I64(1)]))
            .unwrap();
        topic.append(0, b"\xC8garbage-not-a-batch").unwrap();
        topic
            .append(0, &crate::value::encode_batch(&[Value::I64(2)]))
            .unwrap();
        topic.producer_done();
        let (collector, ops) = collector_sink(&metrics);
        run_instance(InstanceRuntime {
            id: 0,
            ops,
            input: InputKind::Queue {
                topic: topic.clone(),
                partitions: vec![0],
                group: "g".into(),
                poll_timeout: Duration::from_millis(20),
                poll_max: 64,
                stop: Arc::new(AtomicBool::new(false)),
                commit_each_drain: true,
                producers: Arc::new(AtomicUsize::new(1)),
                idle_timeout: None,
            },
            outputs: FanOut::none(),
            metrics: metrics.clone(),
            handoff: None,
            restore: Vec::new(),
        });
        // both good records survive; the corrupt one is skipped, counted,
        // and the offset still advances past it
        let got: Vec<i64> = collector
            .values
            .lock()
            .unwrap()
            .iter()
            .map(|v| v.as_i64().unwrap())
            .collect();
        assert_eq!(got, vec![1, 2]);
        assert_eq!(metrics.corrupt_records.load(Ordering::Relaxed), 1);
        assert_eq!(topic.partition(0).committed("g"), 3);
    }

    #[test]
    fn queue_instance_quiesces_with_snapshot_on_stop() {
        let metrics = MetricsRegistry::new();
        let broker = crate::queue::QueueBroker::in_memory(None);
        let topic = broker.topic("t", 1).unwrap();
        let state = broker.topic("state", 1).unwrap();
        topic.register_producer();
        topic
            .append(
                0,
                &crate::value::encode_batch(&[Value::pair(Value::I64(1), Value::I64(5))]),
            )
            .unwrap();
        let sum: crate::graph::ReduceFn =
            Arc::new(|a, b| Value::I64(a.as_i64().unwrap() + b.as_i64().unwrap()));
        let ops: Vec<Box<dyn OpExec>> = vec![Box::new(exec::ReduceExec::new(sum))];
        let stop = Arc::new(AtomicBool::new(false));
        let epoch = Arc::new(AtomicU64::new(9));
        let (tx, rx) = sync_channel(8);
        let port = OutPort::new(
            vec![Target::local(tx)],
            Routing::RoundRobin,
            16,
            None,
        );
        let stop2 = stop.clone();
        let h = std::thread::spawn({
            let topic = topic.clone();
            let state = state.clone();
            let epoch = epoch.clone();
            move || {
                run_instance(InstanceRuntime {
                    id: 3,
                    ops,
                    input: InputKind::Queue {
                        topic,
                        partitions: vec![0],
                        group: "g".into(),
                        poll_timeout: Duration::from_millis(5),
                        poll_max: 64,
                        stop: stop2,
                        commit_each_drain: true,
                        producers: Arc::new(AtomicUsize::new(1)),
                        idle_timeout: None,
                    },
                    outputs: FanOut::single(port),
                    metrics: MetricsRegistry::new(),
                    handoff: Some(Handoff {
                        state_topic: state,
                        stage: 2,
                        zone: "C0".into(),
                        epoch,
                        checkpoint: false,
                        eos_done: Arc::new(AtomicBool::new(false)),
                    }),
                    restore: Vec::new(),
                })
            }
        });
        // give it time to consume the record, then signal the update
        std::thread::sleep(Duration::from_millis(100));
        stop.store(true, Ordering::SeqCst);
        h.join().unwrap();
        // downstream saw the epoch marker, not EOS, and no flushed state
        let mut inbox = Inbox::new(rx, 1);
        assert!(matches!(inbox.next(), InboxEvent::Epoch(9)));
        // the reduce state landed in the state topic
        assert_eq!(state.partition(0).len(), 1);
        let (recs, _) = state
            .partition(0)
            .poll(0, 10, Duration::from_millis(10))
            .unwrap();
        let rec = Value::decode_exact(&recs[0]).unwrap();
        let fields = rec.as_list().unwrap();
        assert_eq!(fields[0].as_i64(), Some(2), "stage");
        assert_eq!(fields[1], Value::Str("C0".into()), "zone");
        assert_eq!(fields[2].as_i64(), Some(9), "epoch");
        assert_eq!(
            fields[3].as_list().unwrap()[0],
            Value::List(vec![Value::pair(Value::I64(1), Value::I64(5))]),
            "reduce snapshot"
        );
        assert_eq!(
            fields[4].as_list().unwrap(),
            &[Value::pair(Value::I64(0), Value::I64(1))],
            "offsets covered by the snapshot"
        );
    }

    #[test]
    fn checkpoint_mode_records_offsets_for_stateless_chains() {
        // a stateless queue-fed entry stage must still record the offsets
        // its processing covered: the replacement replays from there, not
        // from zero (which would double-feed restored interior state)
        let broker = crate::queue::QueueBroker::in_memory(None);
        let topic = broker.topic("t", 1).unwrap();
        let state = broker.topic("state", 1).unwrap();
        topic.register_producer();
        topic
            .append(0, &crate::value::encode_batch(&[Value::I64(7)]))
            .unwrap();
        let stop = Arc::new(AtomicBool::new(false));
        let stop2 = stop.clone();
        let state2 = state.clone();
        let topic2 = topic.clone();
        let h = std::thread::spawn(move || {
            run_instance(InstanceRuntime {
                id: 0,
                ops: vec![], // stateless
                input: InputKind::Queue {
                    topic: topic2,
                    partitions: vec![0],
                    group: "g".into(),
                    poll_timeout: Duration::from_millis(5),
                    poll_max: 64,
                    stop: stop2,
                    commit_each_drain: false,
                    producers: Arc::new(AtomicUsize::new(1)),
                    idle_timeout: None,
                },
                outputs: FanOut::none(),
                metrics: MetricsRegistry::new(),
                handoff: Some(Handoff {
                    state_topic: state2,
                    stage: 1,
                    zone: "C0".into(),
                    epoch: Arc::new(AtomicU64::new(3)),
                    checkpoint: true,
                    eos_done: Arc::new(AtomicBool::new(false)),
                }),
                restore: Vec::new(),
            })
        });
        std::thread::sleep(Duration::from_millis(100));
        stop.store(true, Ordering::SeqCst);
        topic.kick();
        h.join().unwrap();
        // checkpoint mode also defers the commit to the coordinator
        assert_eq!(topic.partition(0).committed("g"), 0, "no self-commit");
        assert_eq!(state.partition(0).len(), 1, "stateless chain still saved");
        let (recs, _) = state
            .partition(0)
            .poll(0, 10, Duration::from_millis(10))
            .unwrap();
        let rec = Value::decode_exact(&recs[0]).unwrap();
        let fields = rec.as_list().unwrap();
        assert!(fields[3].as_list().unwrap().is_empty(), "no state held");
        assert_eq!(
            fields[4].as_list().unwrap(),
            &[Value::pair(Value::I64(0), Value::I64(1))],
            "covered offsets recorded"
        );
    }

    #[test]
    fn inbox_instance_quiesces_on_epoch_and_forwards_marker() {
        let metrics = MetricsRegistry::new();
        let (up_tx, up_rx) = sync_channel(8);
        let (down_tx, down_rx) = sync_channel(8);
        let port = OutPort::new(
            vec![Target::local(down_tx)],
            Routing::RoundRobin,
            16,
            None,
        );
        up_tx
            .send(Msg::Batch(vec![Value::I64(1)].into()))
            .unwrap();
        up_tx.send(Msg::Epoch(4)).unwrap();
        run_instance(InstanceRuntime {
            id: 0,
            ops: vec![],
            input: InputKind::Inbox(Inbox::new(up_rx, 1)),
            outputs: FanOut::single(port),
            metrics,
            handoff: None,
            restore: Vec::new(),
        });
        let mut inbox = Inbox::new(down_rx, 1);
        assert!(matches!(inbox.next(), InboxEvent::Batch(b) if b == vec![Value::I64(1)]));
        assert!(
            matches!(inbox.next(), InboxEvent::Epoch(4)),
            "marker forwarded, no EOS emitted"
        );
    }

    #[test]
    fn queue_instance_with_no_partitions_ends_immediately() {
        // placement updates can leave an instance with zero partitions —
        // it must EOS cleanly, not hang
        let metrics = MetricsRegistry::new();
        let broker = crate::queue::QueueBroker::in_memory(None);
        let topic = broker.topic("t", 1).unwrap();
        let (collector, ops) = collector_sink(&metrics);
        run_instance(InstanceRuntime {
            id: 0,
            ops,
            input: InputKind::Queue {
                topic,
                partitions: Vec::new(),
                group: "g".into(),
                poll_timeout: Duration::from_millis(5),
                poll_max: 64,
                stop: Arc::new(AtomicBool::new(false)),
                commit_each_drain: true,
                producers: Arc::new(AtomicUsize::new(1)),
                idle_timeout: None,
            },
            outputs: FanOut::none(),
            metrics,
            handoff: None,
            restore: Vec::new(),
        });
        assert!(collector.values.lock().unwrap().is_empty());
    }

    #[test]
    fn queue_instance_consumes_multiple_owned_partitions() {
        let metrics = MetricsRegistry::new();
        let broker = crate::queue::QueueBroker::in_memory(None);
        let topic = broker.topic("t", 3).unwrap();
        topic.register_producer();
        for p in 0..3u64 {
            topic
                .append(p, &crate::value::encode_batch(&[Value::I64(p as i64)]))
                .unwrap();
        }
        topic.producer_done();
        let (collector, ops) = collector_sink(&metrics);
        run_instance(InstanceRuntime {
            id: 0,
            ops,
            input: InputKind::Queue {
                topic: topic.clone(),
                partitions: vec![0, 1, 2],
                group: "g".into(),
                poll_timeout: Duration::from_millis(20),
                poll_max: 64,
                stop: Arc::new(AtomicBool::new(false)),
                commit_each_drain: true,
                producers: Arc::new(AtomicUsize::new(1)),
                idle_timeout: None,
            },
            outputs: FanOut::none(),
            metrics,
            handoff: None,
            restore: Vec::new(),
        });
        let mut got: Vec<i64> = collector
            .values
            .lock()
            .unwrap()
            .iter()
            .map(|v| v.as_i64().unwrap())
            .collect();
        got.sort_unstable();
        assert_eq!(got, vec![0, 1, 2]);
        for p in 0..3 {
            assert_eq!(topic.partition(p).committed("g"), 1, "partition {p}");
        }
    }

    #[test]
    fn restored_state_feeds_the_next_incarnation() {
        let metrics = MetricsRegistry::new();
        let (collector, mut ops) = collector_sink(&metrics);
        let sum: crate::graph::ReduceFn =
            Arc::new(|a, b| Value::I64(a.as_i64().unwrap() + b.as_i64().unwrap()));
        let mut chain: Vec<Box<dyn OpExec>> = vec![Box::new(exec::ReduceExec::new(sum))];
        chain.append(&mut ops);
        let (tx, rx) = sync_channel(8);
        tx.send(Msg::Batch(
            vec![Value::pair(Value::I64(0), Value::I64(2))].into(),
        ))
        .unwrap();
        tx.send(Msg::Eos).unwrap();
        run_instance(InstanceRuntime {
            id: 0,
            ops: chain,
            input: InputKind::Inbox(Inbox::new(rx, 1)),
            outputs: FanOut::none(),
            metrics,
            handoff: None,
            restore: vec![
                Value::List(vec![Value::pair(Value::I64(0), Value::I64(40))]),
                Value::Null,
            ],
        });
        let got = collector.values.lock().unwrap();
        assert_eq!(
            got.as_slice(),
            &[Value::pair(Value::I64(0), Value::I64(42))],
            "pre-handoff accumulator merged with post-handoff input"
        );
    }

    #[test]
    fn watermarks_flow_through_an_instance_and_fire_windows() {
        // chain: assigner (bound 0) -> event-time tumbling window. The
        // instance must route fired panes as data, forward its minted
        // watermark as a control frame, and fire the rest at EOS.
        let metrics = MetricsRegistry::new();
        let (up_tx, up_rx) = sync_channel(8);
        let (down_tx, down_rx) = sync_channel(64);
        let port = OutPort::new(
            vec![Target::local(down_tx)],
            Routing::RoundRobin,
            16,
            None,
        )
        .with_sender(5);
        up_tx
            .send(Msg::Batch(vec![Value::I64(5), Value::I64(12)].into()))
            .unwrap();
        up_tx.send(Msg::Eos).unwrap();
        let ts: crate::time::TsFn = Arc::new(|v: &Value| v.as_i64().unwrap_or(0));
        let ops: Vec<Box<dyn OpExec>> = vec![
            Box::new(exec::AssignTsExec::new(
                ts.clone(),
                crate::time::WatermarkGen::BoundedOutOfOrderness { bound_ms: 0 },
            )),
            Box::new(exec::EventWindowExec::new(
                ts,
                crate::time::WindowAssigner::Tumbling { size_ms: 10 },
                crate::graph::WindowAgg::Count,
                0,
            )),
        ];
        run_instance(InstanceRuntime {
            id: 0,
            ops,
            input: InputKind::Inbox(Inbox::new(up_rx, 1)),
            outputs: FanOut::single(port),
            metrics,
            handoff: None,
            restore: Vec::new(),
        });
        let mut inbox = Inbox::new(down_rx, 1);
        // watermark 12 closes [0,10): its pane (record 5) fires as data
        assert!(matches!(
            inbox.next(),
            InboxEvent::Batch(b) if b == vec![Value::pair(Value::Null, Value::I64(1))]
        ));
        assert!(
            matches!(inbox.next(), InboxEvent::Watermark { ts: 12, .. }),
            "the minted watermark travels as a control frame"
        );
        // EOS flushes the still-open [10,20) pane (record 12)
        assert!(matches!(
            inbox.next(),
            InboxEvent::Batch(b) if b == vec![Value::pair(Value::Null, Value::I64(1))]
        ));
        assert!(matches!(inbox.next(), InboxEvent::Eos));
    }

    #[test]
    fn stop_flag_halts_source_early() {
        let metrics = MetricsRegistry::new();
        let stop = Arc::new(AtomicBool::new(true)); // pre-stopped
        let (tx, rx) = sync_channel(8);
        let port = OutPort::new(
            vec![Target::local(tx)],
            Routing::RoundRobin,
            16,
            None,
        );
        run_instance(InstanceRuntime {
            id: 0,
            ops: vec![],
            input: InputKind::Source(SourceRuntime {
                kind: SourceKind::Synthetic {
                    total: 1_000_000,
                    gen: Arc::new(|_, i| Value::I64(i as i64)),
                    rate: None,
                },
                share: (0, 1),
                batch_size: 64,
                stop,
            }),
            outputs: FanOut::single(port),
            metrics,
            handoff: None,
            restore: Vec::new(),
        });
        let mut inbox = Inbox::new(rx, 1);
        assert!(inbox.recv().is_none(), "no data, just EOS");
    }

    #[test]
    fn vector_source_round_robins_and_flushes_tail() {
        let metrics = MetricsRegistry::new();
        let vals: Vec<Value> = (0..7).map(Value::I64).collect();
        let (tx, rx) = sync_channel(64);
        let port = OutPort::new(
            vec![Target::local(tx)],
            Routing::RoundRobin,
            16,
            None,
        );
        run_instance(InstanceRuntime {
            id: 0,
            ops: vec![],
            input: InputKind::Source(SourceRuntime {
                kind: SourceKind::Vector(Arc::new(vals)),
                share: (0, 2),
                batch_size: 2,
                stop: Arc::new(AtomicBool::new(false)),
            }),
            outputs: FanOut::single(port),
            metrics,
            handoff: None,
            restore: Vec::new(),
        });
        let mut inbox = Inbox::new(rx, 1);
        let mut got = Vec::new();
        while let Some(b) = inbox.recv() {
            got.extend(b.into_iter().map(|v| v.as_i64().unwrap()));
        }
        assert_eq!(got, vec![0, 2, 4, 6]);
    }

    #[test]
    fn rate_limited_source_paces_output() {
        let metrics = MetricsRegistry::new();
        let (tx, rx) = sync_channel(1024);
        let port = OutPort::new(
            vec![Target::local(tx)],
            Routing::RoundRobin,
            16,
            None,
        );
        let t0 = std::time::Instant::now();
        run_instance(InstanceRuntime {
            id: 0,
            ops: vec![],
            input: InputKind::Source(SourceRuntime {
                kind: SourceKind::Synthetic {
                    total: 100,
                    gen: Arc::new(|_, i| Value::I64(i as i64)),
                    rate: Some(1000.0), // 100 events at 1000 ev/s ≈ 100 ms
                },
                share: (0, 1),
                batch_size: 10,
                stop: Arc::new(AtomicBool::new(false)),
            }),
            outputs: FanOut::single(port),
            metrics,
            handoff: None,
            restore: Vec::new(),
        });
        let dt = t0.elapsed();
        assert!(dt >= Duration::from_millis(80), "ran in {dt:?}");
        drop(rx);
    }
}
